//! Benchmark harness (criterion is unavailable offline; this is a
//! custom `harness = false` bench binary driven by `util::bench`).
//!
//! Two layers of output:
//!   1. Experiment tables E1..E10 — the "tables & figures" of the paper
//!      reproduction (quick mode by default; `-- --full` for the sizes
//!      recorded in EXPERIMENTS.md).
//!   2. Micro/throughput benchmarks of the hot paths: CoverWithBalls,
//!      bulk assignment (scalar vs XLA engine), local search, and the
//!      end-to-end 3-round solve.
//!
//! Usage:
//!   cargo bench                    # everything, quick experiments
//!   cargo bench -- e4              # one experiment
//!   cargo bench -- micro           # only the micro benches
//!   cargo bench -- --full          # full-size experiment tables

use std::sync::Arc;

use mrcoreset::algorithms::local_search::{local_search, LocalSearchCfg};
use mrcoreset::algorithms::Instance;
use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::coreset::cover_with_balls;
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::eval::{run_experiment, ALL_IDS};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::runtime::XlaEngine;
use mrcoreset::util::bench::bench;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let filters: Vec<&String> =
        args.iter().filter(|a| !a.starts_with("--") && !a.contains("bench")).collect();
    let want = |name: &str| {
        filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
    };

    // ---- experiment tables -------------------------------------------
    for id in ALL_IDS {
        if want(id) && (filters.iter().any(|f| f.as_str() == *id) || filters.is_empty()) {
            let res = run_experiment(id, !full).expect("known id");
            println!("{}", res.render());
        }
    }

    // ---- micro benches ------------------------------------------------
    if !want("micro") && !filters.is_empty() {
        return;
    }
    println!("## micro benchmarks\n");
    let n = 20_000usize;
    let k = 8usize;
    let (data, _) = GaussianMixtureSpec { n, d: 4, k, seed: 1, ..Default::default() }.generate();
    let shared = Arc::new(data);
    let plain = EuclideanSpace::new(shared.clone());
    let pts: Vec<u32> = (0..n as u32).collect();
    let centers: Vec<u32> = (0..256u32).collect();

    // bulk assignment: scalar vs engine
    let r = bench("assign 20k x 256 (scalar)", 1, 5, || {
        std::hint::black_box(plain.assign(&pts, &centers));
    });
    println!("{r}   [{:.1} Mpairs/s]", r.throughput_per_sec(n * 256) / 1e6);
    if let Some(engine) = XlaEngine::load_default() {
        let mut engine = engine;
        engine.set_dispatch_threshold(1);
        let fast = EuclideanSpace::with_engine(shared.clone(), Arc::new(engine));
        let r = bench("assign 20k x 256 (xla engine)", 1, 5, || {
            std::hint::black_box(fast.assign(&pts, &centers));
        });
        println!("{r}   [{:.1} Mpairs/s]", r.throughput_per_sec(n * 256) / 1e6);
    }

    // CoverWithBalls throughput
    let t: Vec<u32> = (0..16u32).map(|i| i * 1000).collect();
    let a = plain.assign(&pts, &t);
    let radius = a.dist.iter().sum::<f64>() / n as f64;
    let r = bench("cover_with_balls 20k (eps=.5 b=2)", 1, 5, || {
        std::hint::black_box(cover_with_balls(&plain, &pts, &t, radius, 0.5, 2.0));
    });
    println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(n) / 1e3);

    // weighted local search on a coreset-sized instance
    let sub: Vec<u32> = (0..2000u32).map(|i| i * 10).collect();
    let w = vec![10u64; sub.len()];
    let r = bench("local_search 2k weighted k=8", 1, 3, || {
        let cfg = LocalSearchCfg::default();
        std::hint::black_box(local_search(
            &plain,
            Objective::Median,
            Instance::new(&sub, &w),
            k,
            None,
            &cfg,
        ));
    });
    println!("{r}");

    // end-to-end 3-round solve
    for obj in [Objective::Median, Objective::Means] {
        let r = bench(&format!("solve 3-round {obj} 20k eps=.5"), 1, 3, || {
            let cfg = ClusterConfig::new(obj, k, 0.5);
            std::hint::black_box(solve(&plain, &pts, &cfg));
        });
        println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(n) / 1e3);
    }
}
