//! Benchmark harness (criterion is unavailable offline; this is a
//! custom `harness = false` bench binary driven by `util::bench`).
//!
//! Two layers of output:
//!   1. Experiment tables E1..E12 — the "tables & figures" of the paper
//!      reproduction (quick mode by default; `-- --full` for the sizes
//!      recorded in EXPERIMENTS.md).
//!   2. Micro/throughput benchmarks of the hot paths: CoverWithBalls,
//!      bulk assignment (per distance-kernel backend: scalar loop,
//!      blocked, simd, XLA engine — the `euclidean.assign.*` series in
//!      BENCH_micro.json), local search, the
//!      end-to-end 3-round solve, the outlier-robust pipeline, and the
//!      geometry-pruning comparison (pruned vs unpruned cover,
//!      incremental vs rebuild swap scan) — persisted as
//!      BENCH_micro.json / BENCH_outliers.json / BENCH_pruning.json for
//!      cross-PR perf tracking (CI runs the smoke configuration and
//!      uploads the JSON artifacts per PR).
//!
//! Usage (lib/bins/tests set `bench = false`, so trailing args reach
//! only this harness):
//!   cargo bench                    # everything, quick experiments
//!   cargo bench -- e4              # one experiment
//!   cargo bench -- micro           # only the micro benches
//!   cargo bench -- pruning         # only the pruning comparison
//!   cargo bench -- micro --smoke   # CI smoke sizes
//!   cargo bench -- --full          # full-size experiment tables

use std::sync::Arc;

use mrcoreset::algorithms::lloyd::{lloyd, lloyd_reference, LloydCfg};
use mrcoreset::algorithms::local_search::{local_search, local_search_reference, LocalSearchCfg};
use mrcoreset::algorithms::Instance;
use mrcoreset::baselines::ene_im_moseley::{self, EimCfg};
use mrcoreset::baselines::kmeans_parallel::{self, KmeansParCfg};
use mrcoreset::baselines::pamae_lite::{self, PamaeCfg};
use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::coreset::{
    cover_with_balls, cover_with_balls_weighted, cover_with_balls_weighted_unpruned,
};
use mrcoreset::data::synth::{GaussianMixtureSpec, NoiseSpec};
use mrcoreset::eval::{run_experiment, ALL_IDS};
use mrcoreset::mapreduce::Simulator;
use mrcoreset::metric::counter;
use mrcoreset::metric::dense::{sq_euclidean, EuclideanSpace};
use mrcoreset::metric::kernel::KernelKind;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::outliers::{local_search_outliers, robust_cost};
use mrcoreset::runtime::XlaEngine;
use mrcoreset::util::bench::{
    bench, to_json, to_json_with_metrics, with_meta, BenchMeta, BenchResult,
};

/// Persist results as machine-readable JSON next to the bench output so
/// the perf trajectory is tracked across PRs, not just printed. Every
/// document carries a `"meta"` stamp (schema version, smoke flag,
/// thread count, git sha) so artifacts in the cross-PR series are
/// self-describing.
fn write_bench_json(path: &str, results: &[BenchResult], smoke: bool) {
    write_json_doc(path, with_meta(to_json(results), &BenchMeta::collect(smoke)));
}

fn write_json_doc(path: &str, doc: String) {
    match std::fs::write(path, doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

/// Bench names are the keys of the cross-PR JSON series: full-size runs
/// must keep their historical "20k"-style labels, and smoke sizes print
/// the same way.
fn fmt_k(n: usize) -> String {
    if n % 1000 == 0 {
        format!("{}k", n / 1000)
    } else {
        n.to_string()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let smoke = args.iter().any(|a| a == "--smoke");
    let filters: Vec<&String> =
        args.iter().filter(|a| !a.starts_with("--") && !a.contains("bench")).collect();
    let want = |name: &str| {
        filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
    };

    // ---- experiment tables -------------------------------------------
    for id in ALL_IDS {
        if want(id) && (filters.iter().any(|f| f.as_str() == *id) || filters.is_empty()) {
            let res = run_experiment(id, !full).expect("known id");
            println!("{}", res.render());
        }
    }

    // `micro` implies the pruning comparison; `pruning` runs it alone.
    let run_micro = filters.is_empty() || want("micro");
    let run_pruning = run_micro || want("pruning");
    if run_micro {
        micro_benches(smoke);
        outlier_benches(smoke);
    }
    if run_pruning {
        pruning_benches(smoke);
    }
}

fn micro_benches(smoke: bool) {
    println!("## micro benchmarks\n");
    let n = if smoke { 4_000usize } else { 20_000 };
    let samples = if smoke { 2 } else { 5 };
    let k = 8usize;
    let (data, _) = GaussianMixtureSpec { n, d: 4, k, seed: 1, ..Default::default() }.generate();
    let shared = Arc::new(data);
    let plain = EuclideanSpace::new(shared.clone());
    let pts: Vec<u32> = (0..n as u32).collect();
    let centers: Vec<u32> = (0..256u32).collect();
    let nk = fmt_k(n);

    // bulk assignment: per-point scalar loop (what every hot path
    // issued before the batched engine) vs the tiled nearest_batch.
    // The baseline computes through sq_euclidean directly — not
    // MetricSpace::dist — so the per-call work-counter charge doesn't
    // pad the scalar side of the comparison.
    let data = shared.clone();
    let scalar_assign = move |pts: &[u32], centers: &[u32]| {
        let mut dist = vec![f64::INFINITY; pts.len()];
        let mut idx = vec![0u32; pts.len()];
        for (i, &p) in pts.iter().enumerate() {
            for (j, &c) in centers.iter().enumerate() {
                let d = sq_euclidean(data.row(p), data.row(c)).sqrt();
                if d < dist[i] {
                    dist[i] = d;
                    idx[i] = j as u32;
                }
            }
        }
        (dist, idx)
    };
    let mut micro_results: Vec<BenchResult> = Vec::new();
    let rs = bench(&format!("assign {nk} x 256 (scalar dist loop)"), 1, samples, || {
        std::hint::black_box(scalar_assign(&pts, &centers));
    });
    println!("{rs}   [{:.1} Mpairs/s]", rs.throughput_per_sec(n * 256) / 1e6);
    micro_results.push(rs.clone());
    let rb = bench(&format!("assign {nk} x 256 (nearest_batch)"), 1, samples, || {
        std::hint::black_box(plain.nearest_batch(&pts, &centers));
    });
    println!("{rb}   [{:.1} Mpairs/s]", rb.throughput_per_sec(n * 256) / 1e6);
    micro_results.push(rb.clone());
    println!(
        "batched/scalar speedup: {:.2}x",
        rs.median.as_secs_f64() / rb.median.as_secs_f64().max(1e-12)
    );
    let (_, evals) = counter::counted(|| plain.nearest_batch(&pts, &centers));
    println!("distance evals per assignment pass: {evals}\n");

    // Per-kernel assignment series — the cross-PR perf trajectory of
    // the pluggable backends. Key shape `euclidean.assign.<kernel>` is
    // load-bearing: BENCH_baseline/BENCH_micro.json and the CI kernel
    // matrix gate on these names.
    let mut kernel_medians: Vec<(&'static str, f64)> = Vec::new();
    for kind in [KernelKind::Scalar, KernelKind::Blocked, KernelKind::Simd] {
        let kspace = EuclideanSpace::with_kernel(shared.clone(), kind);
        let r = bench(&format!("euclidean.assign.{} {nk} x 256", kind.name()), 1, samples, || {
            std::hint::black_box(kspace.nearest_batch(&pts, &centers));
        });
        println!("{r}   [{:.1} Mpairs/s]", r.throughput_per_sec(n * 256) / 1e6);
        kernel_medians.push((kind.name(), r.median.as_secs_f64()));
        micro_results.push(r);
    }
    let median_of = |name: &str| -> f64 {
        kernel_medians.iter().find(|(k, _)| *k == name).map(|(_, t)| *t).unwrap_or(f64::NAN)
    };
    // speedups vs the seed per-point scalar loop (the pre-kernel
    // baseline every hot path used to issue)
    let loop_t = rs.median.as_secs_f64();
    let blocked_speedup = loop_t / median_of("blocked").max(1e-12);
    let simd_speedup = loop_t / median_of("simd").max(1e-12);
    println!(
        "assignment speedup vs scalar loop: blocked {blocked_speedup:.2}x  \
         simd {simd_speedup:.2}x\n"
    );
    if !smoke && blocked_speedup < 5.0 {
        eprintln!(
            "warning: blocked assignment speedup {blocked_speedup:.2}x below the 5x \
             acceptance bar"
        );
    }

    if let Some(engine) = XlaEngine::load_default() {
        let mut engine = engine;
        engine.set_dispatch_threshold(1);
        let fast = EuclideanSpace::with_engine(shared.clone(), Arc::new(engine));
        let r = bench(&format!("assign {nk} x 256 (xla engine)"), 1, samples, || {
            std::hint::black_box(fast.assign(&pts, &centers));
        });
        println!("{r}   [{:.1} Mpairs/s]", r.throughput_per_sec(n * 256) / 1e6);
        micro_results.push(r);
    }

    // CoverWithBalls throughput (production pruned path). The full-size
    // center grid keeps its historical i*1000 placement so the
    // BENCH_micro.json series stays comparable across PRs; smoke scales.
    let t_step = if smoke { n as u32 / 16 } else { 1_000 };
    let t: Vec<u32> = (0..16u32).map(|i| i * t_step).collect();
    let a = plain.assign(&pts, &t);
    let radius = a.dist.iter().sum::<f64>() / n as f64;
    let r = bench(&format!("cover_with_balls {nk} (eps=.5 b=2)"), 1, samples, || {
        std::hint::black_box(cover_with_balls(&plain, &pts, &t, radius, 0.5, 2.0));
    });
    println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(n) / 1e3);
    micro_results.push(r);

    // weighted local search on a coreset-sized instance
    let sub: Vec<u32> = (0..(n as u32 / 10)).map(|i| i * 10).collect();
    let w = vec![10u64; sub.len()];
    let ls_name = format!("local_search {} weighted k=8", fmt_k(sub.len()));
    let r = bench(&ls_name, 1, samples.min(3), || {
        let cfg = LocalSearchCfg::default();
        std::hint::black_box(local_search(
            &plain,
            Objective::Median,
            Instance::new(&sub, &w),
            k,
            None,
            &cfg,
        ));
    });
    println!("{r}");
    micro_results.push(r);

    // end-to-end 3-round solve
    for obj in [Objective::Median, Objective::Means] {
        let r = bench(&format!("solve 3-round {obj} {nk} eps=.5"), 1, samples.min(3), || {
            let cfg = ClusterConfig::new(obj, k, 0.5);
            std::hint::black_box(solve(&plain, &pts, &cfg));
        });
        println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(n) / 1e3);
        micro_results.push(r);
    }
    // Deterministic work counts gate cross-PR regressions (bench-diff
    // reads "metrics" only); `*_ratio` keys are timing-derived context
    // and are skipped by the gate.
    let metrics: Vec<(&str, f64)> = vec![
        ("assign_dist_evals", evals as f64),
        ("assign_blocked_speedup_ratio", blocked_speedup),
        ("assign_simd_speedup_ratio", simd_speedup),
    ];
    write_json_doc(
        "BENCH_micro.json",
        with_meta(to_json_with_metrics(&micro_results, &metrics), &BenchMeta::collect(smoke)),
    );
}

fn outlier_benches(smoke: bool) {
    println!("\n## outliers benchmarks\n");
    let n = if smoke { 2_500usize } else { 10_000 };
    let samples = if smoke { 2 } else { 5 };
    let k = 8usize;
    let noise = if smoke { 50usize } else { 200 };
    let nspec =
        GaussianMixtureSpec { n, d: 2, k, spread: 30.0, seed: 2, ..Default::default() };
    let (ndata, _) = nspec.generate_with_noise(&NoiseSpec {
        count: noise,
        expanse: 10.0,
        offset: 40.0,
        seed: 3,
    });
    let ntotal = ndata.n();
    let nspace = EuclideanSpace::new(Arc::new(ndata));
    let npts: Vec<u32> = (0..ntotal as u32).collect();
    let nk = fmt_k(n);
    let mut outlier_results: Vec<BenchResult> = Vec::new();

    let unit = vec![1u64; npts.len()];
    let inst = Instance::new(&npts, &unit);
    let cs_step = if smoke { n as u32 / 8 } else { 1_000 }; // historical grid at full size
    let cs: Vec<u32> = (0..8u32).map(|i| i * cs_step).collect();
    let r = bench(&format!("robust_cost {nk} z={noise}"), 1, samples, || {
        std::hint::black_box(robust_cost(&nspace, Objective::Median, inst, &cs, noise as u64));
    });
    println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(ntotal) / 1e3);
    outlier_results.push(r);

    let sub: Vec<u32> = (0..(n as u32 / 5)).map(|i| i * 5).collect();
    let w = vec![5u64; sub.len()];
    let r = bench(
        &format!("local_search_outliers {} weighted k=8 z={}", fmt_k(sub.len()), noise / 2),
        1,
        samples.min(3),
        || {
            let cfg = LocalSearchCfg::default();
            std::hint::black_box(local_search_outliers(
                &nspace,
                Objective::Median,
                Instance::new(&sub, &w),
                k,
                (noise / 2) as u64,
                None,
                &cfg,
            ));
        },
    );
    println!("{r}");
    outlier_results.push(r);

    for obj in [Objective::Median, Objective::Means] {
        let r = bench(
            &format!("solve 3-round robust {obj} {nk} z={noise}"),
            1,
            samples.min(3),
            || {
                let mut cfg = ClusterConfig::new(obj, k, 0.5);
                cfg.outliers = noise;
                std::hint::black_box(solve(&nspace, &npts, &cfg));
            },
        );
        println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(ntotal) / 1e3);
        outlier_results.push(r);
    }
    write_bench_json("BENCH_outliers.json", &outlier_results, smoke);
}

/// Geometry-pruning comparison: the quantities that matter here are
/// distance evaluations (the paper-model work metric), measured via
/// `metric::counter` and emitted alongside the timings into
/// BENCH_pruning.json — the acceptance bar is a ≥3x dist_evals
/// reduction for CoverWithBalls on the e2-style mixture workload.
fn pruning_benches(smoke: bool) {
    println!("\n## pruning benchmarks\n");
    let n = if smoke { 4_000usize } else { 20_000 };
    let samples = if smoke { 2 } else { 5 };
    let (data, _) =
        GaussianMixtureSpec { n, d: 4, k: 8, seed: 11, ..Default::default() }.generate();
    let shared = Arc::new(data);
    // pinned to an exact kernel: bounds pruning is only active under
    // uniform precision, and the pruned-vs-unpruned dist_evals metrics
    // must stay meaningful (and gate-stable) under any MRCORESET_KERNEL
    let space = EuclideanSpace::with_kernel(shared.clone(), KernelKind::Blocked);
    let pts: Vec<u32> = (0..n as u32).collect();
    let nk = fmt_k(n);
    let t: Vec<u32> = (0..16u32).map(|i| i * (n as u32 / 16)).collect();
    let a = space.assign(&pts, &t);
    let radius = a.dist.iter().sum::<f64>() / n as f64;
    let mut results: Vec<BenchResult> = Vec::new();

    // --- CoverWithBalls: pruned vs unpruned ---------------------------
    let (cover_u, evals_unpruned) = counter::counted(|| {
        cover_with_balls_weighted_unpruned(&space, &pts, None, &t, radius, 0.5, 2.0)
    });
    let (cover_p, evals_pruned) = counter::counted(|| {
        cover_with_balls_weighted(&space, &pts, None, &t, radius, 0.5, 2.0)
    });
    assert_eq!(cover_u.set.indices, cover_p.set.indices, "pruned cover drifted");
    assert_eq!(cover_u.set.weights, cover_p.set.weights, "pruned cover weights drifted");
    let cover_ratio = evals_unpruned as f64 / evals_pruned.max(1) as f64;

    let ru = bench(&format!("cover {nk} unpruned (eps=.5 b=2)"), 1, samples, || {
        std::hint::black_box(cover_with_balls_weighted_unpruned(
            &space, &pts, None, &t, radius, 0.5, 2.0,
        ));
    });
    println!("{ru}   [{:.1} Mpairs/s]", evals_unpruned as f64 / ru.median.as_secs_f64() / 1e6);
    results.push(ru.clone());
    let rp = bench(&format!("cover {nk} pruned (eps=.5 b=2)"), 1, samples, || {
        std::hint::black_box(cover_with_balls_weighted(&space, &pts, None, &t, radius, 0.5, 2.0));
    });
    println!("{rp}   [{:.1} Mpairs/s]", evals_pruned as f64 / rp.median.as_secs_f64() / 1e6);
    results.push(rp.clone());
    println!(
        "cover dist_evals: unpruned={evals_unpruned} pruned={evals_pruned} \
         saved={:.2}x   wall speedup {:.2}x",
        cover_ratio,
        ru.median.as_secs_f64() / rp.median.as_secs_f64().max(1e-12)
    );

    // --- local-search swap scan: incremental vs rebuild book ----------
    let sub: Vec<u32> = (0..(n as u32 / 10)).map(|i| i * 10).collect();
    let w = vec![10u64; sub.len()];
    let inst = Instance::new(&sub, &w);
    let cfg = LocalSearchCfg::default();
    let (sol_r, evals_rebuild) = counter::counted(|| {
        local_search_reference(&space, Objective::Median, inst, 8, None, &cfg)
    });
    let (sol_i, evals_incremental) =
        counter::counted(|| local_search(&space, Objective::Median, inst, 8, None, &cfg));
    assert_eq!(sol_r.centers, sol_i.centers, "incremental local search drifted");
    assert_eq!(sol_r.cost.to_bits(), sol_i.cost.to_bits(), "incremental cost drifted");
    let ls_ratio = evals_rebuild as f64 / evals_incremental.max(1) as f64;

    let rr_name = format!("local_search {} rebuild-book", fmt_k(sub.len()));
    let rr = bench(&rr_name, 1, samples.min(3), || {
        std::hint::black_box(local_search_reference(
            &space,
            Objective::Median,
            inst,
            8,
            None,
            &cfg,
        ));
    });
    println!("{rr}   [{:.1} Mpairs/s]", evals_rebuild as f64 / rr.median.as_secs_f64() / 1e6);
    results.push(rr.clone());
    let ri_name = format!("local_search {} incremental-book", fmt_k(sub.len()));
    let ri = bench(&ri_name, 1, samples.min(3), || {
        std::hint::black_box(local_search(&space, Objective::Median, inst, 8, None, &cfg));
    });
    println!("{ri}   [{:.1} Mpairs/s]", evals_incremental as f64 / ri.median.as_secs_f64() / 1e6);
    results.push(ri.clone());
    println!(
        "swap-scan dist_evals: rebuild={evals_rebuild} incremental={evals_incremental} \
         saved={:.2}x   wall speedup {:.2}x",
        ls_ratio,
        rr.median.as_secs_f64() / ri.median.as_secs_f64().max(1e-12)
    );
    if cover_ratio < 3.0 {
        eprintln!(
            "warning: cover pruning ratio {cover_ratio:.2}x below the 3x acceptance bar"
        );
    }

    // --- baselines: pruned vs unpruned assignment paths ---------------
    // Each twin runs under a 1-thread simulator inside `counter::counted`
    // (so leader-side folds are captured too); the solver rounds shared
    // byte-for-byte by both twins ("kmeans||-reduce", "pamae-pam",
    // "eim-solve") are subtracted via the simulator's per-round
    // attribution, isolating the assignment paths the pruning touches.
    // Lloyd has no simulator rounds; its twins are counted whole.
    let k = 8usize;

    let kp_cfg = KmeansParCfg::new(k);
    let count_kp = |pruned: bool| {
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            if pruned {
                kmeans_parallel::run(&space, Objective::Means, &pts, k, &kp_cfg, &sim)
            } else {
                kmeans_parallel::run_unpruned(&space, Objective::Means, &pts, k, &kp_cfg, &sim)
            }
        });
        total - sim.take_stats().dist_evals_for("kmeans||-reduce")
    };
    let kp_unpruned = count_kp(false);
    let kp_pruned = count_kp(true);
    let kp_ratio = kp_unpruned as f64 / kp_pruned.max(1) as f64;
    let r = bench(&format!("kmeans|| {nk} unpruned assign"), 1, samples.min(3), || {
        let sim = Simulator::new();
        std::hint::black_box(kmeans_parallel::run_unpruned(
            &space, Objective::Means, &pts, k, &kp_cfg, &sim,
        ));
    });
    println!("{r}");
    results.push(r);
    let r = bench(&format!("kmeans|| {nk} pruned assign"), 1, samples.min(3), || {
        let sim = Simulator::new();
        std::hint::black_box(kmeans_parallel::run(
            &space, Objective::Means, &pts, k, &kp_cfg, &sim,
        ));
    });
    println!("{r}");
    results.push(r);
    println!(
        "kmeans|| assign dist_evals: unpruned={kp_unpruned} pruned={kp_pruned} \
         saved={kp_ratio:.2}x"
    );

    // PAMAE-lite: reduced sampling config so the unpruned twin's PAM
    // share stays a small fraction of the bench runtime.
    let pm_cfg = PamaeCfg { num_samples: 3, sample_size: 160, refine_size: 200, seed: 0x9A3 };
    let count_pm = |pruned: bool| {
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            if pruned {
                pamae_lite::run(&space, Objective::Median, &pts, k, &pm_cfg, &sim)
            } else {
                pamae_lite::run_unpruned(&space, Objective::Median, &pts, k, &pm_cfg, &sim)
            }
        });
        total - sim.take_stats().dist_evals_for("pamae-pam")
    };
    let pm_unpruned = count_pm(false);
    let pm_pruned = count_pm(true);
    let pm_ratio = pm_unpruned as f64 / pm_pruned.max(1) as f64;
    let r = bench(&format!("pamae-lite {nk} unpruned assign"), 1, samples.min(3), || {
        let sim = Simulator::new();
        std::hint::black_box(pamae_lite::run_unpruned(
            &space, Objective::Median, &pts, k, &pm_cfg, &sim,
        ));
    });
    println!("{r}");
    results.push(r);
    let r = bench(&format!("pamae-lite {nk} pruned assign"), 1, samples.min(3), || {
        let sim = Simulator::new();
        std::hint::black_box(pamae_lite::run(&space, Objective::Median, &pts, k, &pm_cfg, &sim));
    });
    println!("{r}");
    results.push(r);
    println!(
        "pamae-lite assign dist_evals: unpruned={pm_unpruned} pruned={pm_pruned} \
         saved={pm_ratio:.2}x"
    );

    let eim_cfg = EimCfg {
        sample_per_iter: (n / 60).max(k),
        stop_below: (n / 20).max(2 * k),
        seed: 6,
    };
    let count_eim = |pruned: bool| {
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            if pruned {
                ene_im_moseley::run(&space, Objective::Median, &pts, k, &eim_cfg, &sim)
            } else {
                ene_im_moseley::run_unpruned(&space, Objective::Median, &pts, k, &eim_cfg, &sim)
            }
        });
        total - sim.take_stats().dist_evals_for("eim-solve")
    };
    let eim_unpruned = count_eim(false);
    let eim_pruned = count_eim(true);
    let eim_ratio = eim_unpruned as f64 / eim_pruned.max(1) as f64;
    let r = bench(&format!("ene-im-moseley {nk} unpruned filter"), 1, samples.min(3), || {
        let sim = Simulator::new();
        std::hint::black_box(ene_im_moseley::run_unpruned(
            &space, Objective::Median, &pts, k, &eim_cfg, &sim,
        ));
    });
    println!("{r}");
    results.push(r);
    let r = bench(&format!("ene-im-moseley {nk} pruned filter"), 1, samples.min(3), || {
        let sim = Simulator::new();
        std::hint::black_box(ene_im_moseley::run(
            &space, Objective::Median, &pts, k, &eim_cfg, &sim,
        ));
    });
    println!("{r}");
    results.push(r);
    println!(
        "ene-im-moseley filter dist_evals: unpruned={eim_unpruned} pruned={eim_pruned} \
         saved={eim_ratio:.2}x"
    );

    let ll_cfg = LloydCfg::default();
    let unit = vec![1u64; pts.len()];
    let (sol_ref, ll_ref) = counter::counted(|| lloyd_reference(&shared, &pts, &unit, k, &ll_cfg));
    let (sol_bnd, ll_bounded) = counter::counted(|| lloyd(&shared, &pts, &unit, k, &ll_cfg));
    assert_eq!(
        sol_ref.cost.to_bits(),
        sol_bnd.cost.to_bits(),
        "bounded lloyd drifted from the reference"
    );
    let ll_ratio = ll_ref as f64 / ll_bounded.max(1) as f64;
    let r = bench(&format!("lloyd {nk} full-rescan"), 1, samples.min(3), || {
        std::hint::black_box(lloyd_reference(&shared, &pts, &unit, k, &ll_cfg));
    });
    println!("{r}");
    results.push(r);
    let r = bench(&format!("lloyd {nk} bounded"), 1, samples.min(3), || {
        std::hint::black_box(lloyd(&shared, &pts, &unit, k, &ll_cfg));
    });
    println!("{r}");
    results.push(r);
    println!(
        "lloyd dist_evals: full-rescan={ll_ref} bounded={ll_bounded} saved={ll_ratio:.2}x"
    );

    for (name, ratio, bar) in [
        ("kmeans|| assign", kp_ratio, 3.0),
        ("pamae-lite assign", pm_ratio, 3.0),
        ("ene-im-moseley filter", eim_ratio, 3.0),
        ("lloyd", ll_ratio, 2.0),
    ] {
        if ratio < bar {
            eprintln!(
                "warning: {name} pruning ratio {ratio:.2}x below the {bar}x acceptance bar"
            );
        }
    }

    let metrics: Vec<(&str, f64)> = vec![
        ("cover_dist_evals_unpruned", evals_unpruned as f64),
        ("cover_dist_evals_pruned", evals_pruned as f64),
        ("cover_evals_saved_ratio", cover_ratio),
        ("ls_dist_evals_rebuild", evals_rebuild as f64),
        ("ls_dist_evals_incremental", evals_incremental as f64),
        ("ls_evals_saved_ratio", ls_ratio),
        ("kmeanspar_assign_evals_unpruned", kp_unpruned as f64),
        ("kmeanspar_assign_evals_pruned", kp_pruned as f64),
        ("kmeanspar_assign_evals_saved_ratio", kp_ratio),
        ("pamae_assign_evals_unpruned", pm_unpruned as f64),
        ("pamae_assign_evals_pruned", pm_pruned as f64),
        ("pamae_assign_evals_saved_ratio", pm_ratio),
        ("eim_filter_evals_unpruned", eim_unpruned as f64),
        ("eim_filter_evals_pruned", eim_pruned as f64),
        ("eim_filter_evals_saved_ratio", eim_ratio),
        ("lloyd_evals_full_rescan", ll_ref as f64),
        ("lloyd_evals_bounded", ll_bounded as f64),
        ("lloyd_evals_saved_ratio", ll_ratio),
    ];
    write_json_doc(
        "BENCH_pruning.json",
        with_meta(to_json_with_metrics(&results, &metrics), &BenchMeta::collect(smoke)),
    );
}
