//! Benchmark harness (criterion is unavailable offline; this is a
//! custom `harness = false` bench binary driven by `util::bench`).
//!
//! Two layers of output:
//!   1. Experiment tables E1..E12 — the "tables & figures" of the paper
//!      reproduction (quick mode by default; `-- --full` for the sizes
//!      recorded in EXPERIMENTS.md).
//!   2. Micro/throughput benchmarks of the hot paths: CoverWithBalls,
//!      bulk assignment (scalar vs XLA engine), local search, the
//!      end-to-end 3-round solve, and the outlier-robust pipeline —
//!      persisted as BENCH_micro.json / BENCH_outliers.json for
//!      cross-PR perf tracking.
//!
//! Usage:
//!   cargo bench                    # everything, quick experiments
//!   cargo bench -- e4              # one experiment
//!   cargo bench -- micro           # only the micro benches
//!   cargo bench -- --full          # full-size experiment tables

use std::sync::Arc;

use mrcoreset::algorithms::local_search::{local_search, LocalSearchCfg};
use mrcoreset::algorithms::Instance;
use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::coreset::cover_with_balls;
use mrcoreset::data::synth::{GaussianMixtureSpec, NoiseSpec};
use mrcoreset::eval::{run_experiment, ALL_IDS};
use mrcoreset::metric::dense::{sq_euclidean, EuclideanSpace};
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::outliers::{local_search_outliers, robust_cost};
use mrcoreset::runtime::XlaEngine;
use mrcoreset::util::bench::{bench, to_json, BenchResult};

/// Persist results as machine-readable JSON next to the bench output so
/// the perf trajectory is tracked across PRs, not just printed.
fn write_bench_json(path: &str, results: &[BenchResult]) {
    match std::fs::write(path, to_json(results)) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let filters: Vec<&String> =
        args.iter().filter(|a| !a.starts_with("--") && !a.contains("bench")).collect();
    let want = |name: &str| {
        filters.is_empty() || filters.iter().any(|f| name.contains(f.as_str()))
    };

    // ---- experiment tables -------------------------------------------
    for id in ALL_IDS {
        if want(id) && (filters.iter().any(|f| f.as_str() == *id) || filters.is_empty()) {
            let res = run_experiment(id, !full).expect("known id");
            println!("{}", res.render());
        }
    }

    // ---- micro benches ------------------------------------------------
    if !want("micro") && !filters.is_empty() {
        return;
    }
    println!("## micro benchmarks\n");
    let n = 20_000usize;
    let k = 8usize;
    let (data, _) = GaussianMixtureSpec { n, d: 4, k, seed: 1, ..Default::default() }.generate();
    let shared = Arc::new(data);
    let plain = EuclideanSpace::new(shared.clone());
    let pts: Vec<u32> = (0..n as u32).collect();
    let centers: Vec<u32> = (0..256u32).collect();

    // bulk assignment: per-point scalar loop (what every hot path
    // issued before the batched engine) vs the tiled nearest_batch.
    // The baseline computes through sq_euclidean directly — not
    // MetricSpace::dist — so the per-call work-counter charge doesn't
    // pad the scalar side of the comparison.
    let data = shared.clone();
    let scalar_assign = move |pts: &[u32], centers: &[u32]| {
        let mut dist = vec![f64::INFINITY; pts.len()];
        let mut idx = vec![0u32; pts.len()];
        for (i, &p) in pts.iter().enumerate() {
            for (j, &c) in centers.iter().enumerate() {
                let d = sq_euclidean(data.row(p), data.row(c)).sqrt();
                if d < dist[i] {
                    dist[i] = d;
                    idx[i] = j as u32;
                }
            }
        }
        (dist, idx)
    };
    let mut micro_results: Vec<BenchResult> = Vec::new();
    let rs = bench("assign 20k x 256 (scalar dist loop)", 1, 5, || {
        std::hint::black_box(scalar_assign(&pts, &centers));
    });
    println!("{rs}   [{:.1} Mpairs/s]", rs.throughput_per_sec(n * 256) / 1e6);
    micro_results.push(rs.clone());
    let rb = bench("assign 20k x 256 (nearest_batch)", 1, 5, || {
        std::hint::black_box(plain.nearest_batch(&pts, &centers));
    });
    println!("{rb}   [{:.1} Mpairs/s]", rb.throughput_per_sec(n * 256) / 1e6);
    micro_results.push(rb.clone());
    println!(
        "batched/scalar speedup: {:.2}x",
        rs.median.as_secs_f64() / rb.median.as_secs_f64().max(1e-12)
    );
    let (_, evals) = mrcoreset::metric::counter::counted(|| plain.nearest_batch(&pts, &centers));
    println!("distance evals per assignment pass: {evals}\n");
    if let Some(engine) = XlaEngine::load_default() {
        let mut engine = engine;
        engine.set_dispatch_threshold(1);
        let fast = EuclideanSpace::with_engine(shared.clone(), Arc::new(engine));
        let r = bench("assign 20k x 256 (xla engine)", 1, 5, || {
            std::hint::black_box(fast.assign(&pts, &centers));
        });
        println!("{r}   [{:.1} Mpairs/s]", r.throughput_per_sec(n * 256) / 1e6);
        micro_results.push(r);
    }

    // CoverWithBalls throughput
    let t: Vec<u32> = (0..16u32).map(|i| i * 1000).collect();
    let a = plain.assign(&pts, &t);
    let radius = a.dist.iter().sum::<f64>() / n as f64;
    let r = bench("cover_with_balls 20k (eps=.5 b=2)", 1, 5, || {
        std::hint::black_box(cover_with_balls(&plain, &pts, &t, radius, 0.5, 2.0));
    });
    println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(n) / 1e3);
    micro_results.push(r);

    // weighted local search on a coreset-sized instance
    let sub: Vec<u32> = (0..2000u32).map(|i| i * 10).collect();
    let w = vec![10u64; sub.len()];
    let r = bench("local_search 2k weighted k=8", 1, 3, || {
        let cfg = LocalSearchCfg::default();
        std::hint::black_box(local_search(
            &plain,
            Objective::Median,
            Instance::new(&sub, &w),
            k,
            None,
            &cfg,
        ));
    });
    println!("{r}");
    micro_results.push(r);

    // end-to-end 3-round solve
    for obj in [Objective::Median, Objective::Means] {
        let r = bench(&format!("solve 3-round {obj} 20k eps=.5"), 1, 3, || {
            let cfg = ClusterConfig::new(obj, k, 0.5);
            std::hint::black_box(solve(&plain, &pts, &cfg));
        });
        println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(n) / 1e3);
        micro_results.push(r);
    }
    write_bench_json("BENCH_micro.json", &micro_results);

    // ---- outliers micro benches ---------------------------------------
    println!("\n## outliers benchmarks\n");
    let noise = 200usize;
    let nspec =
        GaussianMixtureSpec { n: 10_000, d: 2, k, spread: 30.0, seed: 2, ..Default::default() };
    let (ndata, _) = nspec.generate_with_noise(&NoiseSpec {
        count: noise,
        expanse: 10.0,
        offset: 40.0,
        seed: 3,
    });
    let ntotal = ndata.n();
    let nspace = EuclideanSpace::new(Arc::new(ndata));
    let npts: Vec<u32> = (0..ntotal as u32).collect();
    let mut outlier_results: Vec<BenchResult> = Vec::new();

    let unit = vec![1u64; npts.len()];
    let inst = Instance::new(&npts, &unit);
    let cs: Vec<u32> = (0..8u32).map(|i| i * 1000).collect();
    let r = bench("robust_cost 10k z=200", 1, 5, || {
        std::hint::black_box(robust_cost(&nspace, Objective::Median, inst, &cs, noise as u64));
    });
    println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(ntotal) / 1e3);
    outlier_results.push(r);

    let sub: Vec<u32> = (0..2000u32).map(|i| i * 5).collect();
    let w = vec![5u64; sub.len()];
    let r = bench("local_search_outliers 2k weighted k=8 z=100", 1, 3, || {
        let cfg = LocalSearchCfg::default();
        std::hint::black_box(local_search_outliers(
            &nspace,
            Objective::Median,
            Instance::new(&sub, &w),
            k,
            100,
            None,
            &cfg,
        ));
    });
    println!("{r}");
    outlier_results.push(r);

    for obj in [Objective::Median, Objective::Means] {
        let r = bench(&format!("solve 3-round robust {obj} 10k z=200"), 1, 3, || {
            let mut cfg = ClusterConfig::new(obj, k, 0.5);
            cfg.outliers = noise;
            std::hint::black_box(solve(&nspace, &npts, &cfg));
        });
        println!("{r}   [{:.0} kpts/s]", r.throughput_per_sec(ntotal) / 1e3);
        outlier_results.push(r);
    }
    write_bench_json("BENCH_outliers.json", &outlier_results);
}
