//! Point storage substrates.
//!
//! Algorithms in this crate address points by `u32` index into one
//! immutable store; subsets (partitions, coresets, solutions) are index
//! vectors. This makes MapReduce partitioning, weighting, and shuffles
//! cheap and keeps the storage layout friendly to the XLA fast path
//! (dense row-major f32 blocks gathered by index).

use std::sync::Arc;

/// Dense row-major f32 matrix: `n` points with `d` features each.
#[derive(Clone, Debug, PartialEq)]
pub struct VectorData {
    data: Vec<f32>,
    n: usize,
    d: usize,
}

impl VectorData {
    pub fn new(data: Vec<f32>, d: usize) -> VectorData {
        assert!(d > 0, "VectorData: d must be positive");
        assert!(data.len() % d == 0, "data len {} not divisible by d {}", data.len(), d);
        let n = data.len() / d;
        VectorData { data, n, d }
    }

    pub fn zeros(n: usize, d: usize) -> VectorData {
        VectorData { data: vec![0.0; n * d], n, d }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> VectorData {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged rows");
            data.extend_from_slice(r);
        }
        VectorData::new(data, d)
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn row(&self, i: u32) -> &[f32] {
        let i = i as usize;
        debug_assert!(i < self.n);
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: u32) -> &mut [f32] {
        let i = i as usize;
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Gather rows by index into a new dense block (XLA input staging).
    pub fn gather(&self, idx: &[u32]) -> VectorData {
        let mut data = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        VectorData { data, n: idx.len(), d: self.d }
    }

    /// Gather rows into `out`, padding remaining rows with `pad_value`.
    /// `out` must hold `rows_out * d` f32s with `rows_out >= idx.len()`.
    pub fn gather_padded(&self, idx: &[u32], out: &mut [f32], pad_value: f32) {
        assert!(out.len() % self.d == 0);
        let rows_out = out.len() / self.d;
        assert!(rows_out >= idx.len(), "pad target smaller than gather set");
        for (r, &i) in idx.iter().enumerate() {
            out[r * self.d..(r + 1) * self.d].copy_from_slice(self.row(i));
        }
        out[idx.len() * self.d..].fill(pad_value);
    }

    /// Gather rows by index into a caller-owned buffer, reusing its
    /// allocation (tile staging for the kernel backends — a hot call
    /// that would otherwise allocate per block).
    pub fn gather_into(&self, idx: &[u32], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(idx.len() * self.d);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
    }
}

/// Exact-products squared L2 norm of one row, accumulated in f64
/// (each `x_i * x_i` is an exact product of f32s widened to f64, so the
/// only rounding is the f64 summation — negligible against f32 inputs).
#[inline]
pub fn sq_norm_f64(row: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &x in row {
        let x = x as f64;
        acc += x * x;
    }
    acc
}

/// Per-row squared L2 norms of a dense `(rows, d)` block (precomputed
/// `||c||²` column for the norm-decomposition assignment kernels).
pub fn sq_norms_f64(block: &[f32], d: usize) -> Vec<f64> {
    assert!(d > 0 && block.len() % d == 0);
    block.chunks_exact(d).map(sq_norm_f64).collect()
}

/// A weighted subset of a point store (the coreset representation).
/// Weights are positive integers per Definition 2.3 of the paper
/// (`w(x) = |{y : tau(y) = x}|`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WeightedSet {
    pub indices: Vec<u32>,
    pub weights: Vec<u64>,
}

impl WeightedSet {
    pub fn new(indices: Vec<u32>, weights: Vec<u64>) -> WeightedSet {
        assert_eq!(indices.len(), weights.len());
        debug_assert!(weights.iter().all(|&w| w > 0), "weights must be positive");
        WeightedSet { indices, weights }
    }

    /// Unit-weight view of a plain index set.
    pub fn unit(indices: Vec<u32>) -> WeightedSet {
        let weights = vec![1u64; indices.len()];
        WeightedSet { indices, weights }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Total represented weight (= |P| when built per Definition 2.3).
    pub fn total_weight(&self) -> u64 {
        self.weights.iter().sum()
    }

    /// Concatenate coresets from partitions (composability, Lemma 2.7).
    pub fn union(parts: &[WeightedSet]) -> WeightedSet {
        let mut out = WeightedSet::default();
        for p in parts {
            out.merge(p);
        }
        out
    }

    /// Append one partition's coreset — the streaming form of [`union`]
    /// (same concatenation order when called in slot order), so an
    /// out-of-core fold never holds more than one part resident.
    ///
    /// [`union`]: WeightedSet::union
    pub fn merge(&mut self, other: &WeightedSet) {
        self.indices.extend_from_slice(&other.indices);
        self.weights.extend_from_slice(&other.weights);
    }
}

/// Shared handle to vector data (spaces and the XLA engine hold clones).
pub type SharedVectors = Arc<VectorData>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip() {
        let v = VectorData::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(v.n(), 3);
        assert_eq!(v.d(), 2);
        assert_eq!(v.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rejected() {
        VectorData::from_rows(&[vec![1.0], vec![2.0, 3.0]]);
    }

    #[test]
    fn gather_selects_rows() {
        let v = VectorData::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let g = v.gather(&[3, 1]);
        assert_eq!(g.raw(), &[3.0, 1.0]);
    }

    #[test]
    fn gather_padded_fills() {
        let v = VectorData::from_rows(&[vec![1.0, 1.0], vec![2.0, 2.0]]);
        let mut out = vec![0.0f32; 4 * 2];
        v.gather_padded(&[1], &mut out, 9.0);
        assert_eq!(out, vec![2.0, 2.0, 9.0, 9.0, 9.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    fn gather_into_reuses_buffer() {
        let v = VectorData::from_rows(&[vec![0.0, 1.0], vec![2.0, 3.0], vec![4.0, 5.0]]);
        let mut buf = vec![9.0f32; 100];
        v.gather_into(&[2, 0], &mut buf);
        assert_eq!(buf, vec![4.0, 5.0, 0.0, 1.0]);
        v.gather_into(&[1], &mut buf);
        assert_eq!(buf, vec![2.0, 3.0]);
    }

    #[test]
    fn sq_norms_match_rows() {
        let v = VectorData::from_rows(&[vec![3.0, 4.0], vec![0.0, 0.0], vec![-1.0, 2.0]]);
        assert_eq!(sq_norms_f64(v.raw(), 2), vec![25.0, 0.0, 5.0]);
        assert_eq!(sq_norm_f64(v.row(2)), 5.0);
    }

    #[test]
    fn weighted_set_union_and_totals() {
        let a = WeightedSet::new(vec![0, 1], vec![2, 3]);
        let b = WeightedSet::unit(vec![5]);
        let u = WeightedSet::union(&[a, b]);
        assert_eq!(u.indices, vec![0, 1, 5]);
        assert_eq!(u.total_weight(), 6);
    }
}
