//! Data substrate: synthetic workload generators with controlled
//! intrinsic dimension, a deterministic workload-trace generator, string
//! cluster generators, and CSV I/O.
//!
//! The paper names no datasets (it is a theory paper); experiments use
//! these generators, whose parameters map 1:1 onto the quantities the
//! theory bounds: n, k, the intrinsic/doubling dimension D, and cluster
//! separation (how easy the instance is). See DESIGN.md §5.

pub mod csv;
pub mod strings;
pub mod synth;
pub mod trace;

pub use synth::{GaussianMixtureSpec, ManifoldSpec};
pub use trace::TraceSpec;
