//! Clustered string dataset generator (for the Levenshtein space).
//!
//! Each cluster is a random seed string; members are derived by a few
//! random edits (substitution/insertion/deletion), giving ground-truth
//! cluster structure under edit distance — the "general metric space"
//! workload for the k-median experiments and `examples/general_metric.rs`.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct StringClusterSpec {
    pub n: usize,
    pub clusters: usize,
    pub base_len: usize,
    /// Max edits applied to derive a member from its seed string.
    pub max_edits: usize,
    pub seed: u64,
}

impl Default for StringClusterSpec {
    fn default() -> Self {
        StringClusterSpec { n: 2000, clusters: 10, base_len: 24, max_edits: 4, seed: 1 }
    }
}

const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz";

impl StringClusterSpec {
    pub fn generate(&self) -> (Vec<Vec<u8>>, Vec<u32>) {
        assert!(self.clusters >= 1 && self.base_len >= self.max_edits + 1);
        let mut rng = Rng::new(self.seed);
        let seeds: Vec<Vec<u8>> = (0..self.clusters)
            .map(|_| (0..self.base_len).map(|_| ALPHABET[rng.below(ALPHABET.len())]).collect())
            .collect();
        let mut out = Vec::with_capacity(self.n);
        let mut labels = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let c = i % self.clusters;
            let mut s = seeds[c].clone();
            let edits = rng.below(self.max_edits + 1);
            for _ in 0..edits {
                match rng.below(3) {
                    0 if !s.is_empty() => {
                        // substitute
                        let p = rng.below(s.len());
                        s[p] = ALPHABET[rng.below(ALPHABET.len())];
                    }
                    1 => {
                        // insert
                        let p = rng.below(s.len() + 1);
                        s.insert(p, ALPHABET[rng.below(ALPHABET.len())]);
                    }
                    _ if !s.is_empty() => {
                        // delete
                        let p = rng.below(s.len());
                        s.remove(p);
                    }
                    _ => {}
                }
            }
            out.push(s);
            labels.push(c as u32);
        }
        (out, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::levenshtein::levenshtein;

    #[test]
    fn deterministic() {
        let spec = StringClusterSpec { n: 100, ..Default::default() };
        assert_eq!(spec.generate(), spec.generate());
    }

    #[test]
    fn members_close_to_cluster_seed() {
        let spec =
            StringClusterSpec { n: 200, clusters: 4, max_edits: 3, seed: 5, ..Default::default() };
        let (strs, labels) = spec.generate();
        // same-cluster pairs within 2*max_edits; the random 24-char seeds
        // themselves are pairwise far apart with overwhelming probability
        for i in 0..50 {
            for j in 0..50 {
                if labels[i] == labels[j] {
                    assert!(levenshtein(&strs[i], &strs[j]) <= 6);
                }
            }
        }
    }
}
