//! Synthetic vector workloads.
//!
//! `GaussianMixtureSpec`: classic well/ill-separated Gaussian mixtures in
//! R^d with an outlier fraction — the workhorse for accuracy experiments.
//!
//! `ManifoldSpec`: points drawn on a random `intrinsic_dim`-dimensional
//! affine subspace (plus small normal noise), embedded in
//! `ambient_dim`-dimensional space via a random rotation. The *doubling*
//! dimension of such data is ~intrinsic_dim regardless of ambient_dim —
//! exactly the regime where the paper's bounds are interesting (E2, E10).

use crate::points::VectorData;
use crate::util::rng::Rng;

/// Gaussian mixture in R^d.
#[derive(Clone, Debug)]
pub struct GaussianMixtureSpec {
    pub n: usize,
    pub d: usize,
    pub k: usize,
    /// Center box half-width; cluster stddev is 1.0, so larger = better
    /// separated.
    pub spread: f64,
    /// Fraction of points replaced by uniform outliers over 2x the box.
    pub outlier_frac: f64,
    pub seed: u64,
}

impl Default for GaussianMixtureSpec {
    fn default() -> Self {
        GaussianMixtureSpec { n: 10_000, d: 8, k: 10, spread: 20.0, outlier_frac: 0.0, seed: 1 }
    }
}

impl GaussianMixtureSpec {
    /// Generate points; returns (data, ground-truth component of each point).
    pub fn generate(&self) -> (VectorData, Vec<u32>) {
        assert!(self.k >= 1 && self.n >= self.k);
        let mut rng = Rng::new(self.seed);
        // component centers
        let centers: Vec<Vec<f64>> = (0..self.k)
            .map(|_| (0..self.d).map(|_| rng.range_f64(-self.spread, self.spread)).collect())
            .collect();
        let mut data = Vec::with_capacity(self.n * self.d);
        let mut labels = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let comp = i % self.k; // balanced components, deterministic
            if rng.f64() < self.outlier_frac {
                for _ in 0..self.d {
                    data.push(rng.range_f64(-2.0 * self.spread, 2.0 * self.spread) as f32);
                }
                labels.push(u32::MAX); // outlier marker
            } else {
                for j in 0..self.d {
                    data.push((centers[comp][j] + rng.gaussian()) as f32);
                }
                labels.push(comp as u32);
            }
        }
        (VectorData::new(data, self.d), labels)
    }
}

/// Uniform background noise injected into a mixture workload (the
/// outliers subsystem's E12 workload). Unlike `outlier_frac` — which
/// *replaces* a random fraction of mixture points — a `NoiseSpec`
/// appends an exact, deterministic number of noise points after the
/// mixture, so experiments know both the true outlier count (the z to
/// solve with) and their indices (`n..n+count`, labelled `u32::MAX`).
#[derive(Clone, Debug)]
pub struct NoiseSpec {
    /// Number of uniform noise points appended after the mixture.
    pub count: usize,
    /// Noise box half-width, as a multiple of the mixture's `spread`.
    pub expanse: f64,
    /// Noise box center along every axis, as a multiple of `spread`
    /// (0 centers the noise on the data; large values give a far-flung
    /// blob — the adversarial regime for non-robust solvers).
    pub offset: f64,
    pub seed: u64,
}

impl Default for NoiseSpec {
    fn default() -> Self {
        NoiseSpec { count: 0, expanse: 10.0, offset: 0.0, seed: 0xBAD }
    }
}

impl GaussianMixtureSpec {
    /// Generate the mixture, then append `noise.count` uniform points
    /// drawn from the box `offset·spread ± expanse·spread` per axis.
    /// Noise points get label `u32::MAX` and occupy indices
    /// `self.n..self.n + noise.count`.
    pub fn generate_with_noise(&self, noise: &NoiseSpec) -> (VectorData, Vec<u32>) {
        let (base, mut labels) = self.generate();
        let mut rng = Rng::new(noise.seed);
        let center = self.spread * noise.offset;
        let half = self.spread * noise.expanse;
        let mut data = base.raw().to_vec();
        for _ in 0..noise.count {
            for _ in 0..self.d {
                data.push(rng.range_f64(center - half, center + half) as f32);
            }
            labels.push(u32::MAX);
        }
        (VectorData::new(data, self.d), labels)
    }
}

/// Low-intrinsic-dimension manifold embedded in a higher ambient space.
#[derive(Clone, Debug)]
pub struct ManifoldSpec {
    pub n: usize,
    pub intrinsic_dim: usize,
    pub ambient_dim: usize,
    pub k: usize,
    /// Cluster center spread within the intrinsic subspace.
    pub spread: f64,
    /// Isotropic ambient noise added after embedding (0 keeps the data
    /// exactly on the subspace).
    pub ambient_noise: f64,
    pub seed: u64,
}

impl Default for ManifoldSpec {
    fn default() -> Self {
        ManifoldSpec {
            n: 10_000,
            intrinsic_dim: 2,
            ambient_dim: 16,
            k: 8,
            spread: 20.0,
            ambient_noise: 0.0,
            seed: 1,
        }
    }
}

impl ManifoldSpec {
    pub fn generate(&self) -> (VectorData, Vec<u32>) {
        assert!(self.intrinsic_dim <= self.ambient_dim);
        let mut rng = Rng::new(self.seed);
        // random (ambient x intrinsic) orthonormal embedding via Gram-Schmidt
        let basis = random_orthonormal(self.ambient_dim, self.intrinsic_dim, &mut rng);
        let spec = GaussianMixtureSpec {
            n: self.n,
            d: self.intrinsic_dim,
            k: self.k,
            spread: self.spread,
            outlier_frac: 0.0,
            seed: rng.next_u64(),
        };
        let (low, labels) = spec.generate();
        let mut data = vec![0f32; self.n * self.ambient_dim];
        for i in 0..self.n {
            let lrow = low.row(i as u32);
            let orow = &mut data[i * self.ambient_dim..(i + 1) * self.ambient_dim];
            for (a, brow) in orow.iter_mut().zip(&basis) {
                let mut acc = 0.0f64;
                for (x, b) in lrow.iter().zip(brow) {
                    acc += *x as f64 * b;
                }
                *a = acc as f32;
            }
            if self.ambient_noise > 0.0 {
                for a in orow.iter_mut() {
                    *a += (rng.gaussian() * self.ambient_noise) as f32;
                }
            }
        }
        (VectorData::new(data, self.ambient_dim), labels)
    }
}

/// `rows` x `cols` matrix whose ROWS are the ambient coordinates of `cols`
/// orthonormal basis vectors... returned as `rows` rows each of length
/// `cols`: basis[a][i] = component a of basis vector i.
fn random_orthonormal(rows: usize, cols: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    // Build `cols` orthonormal vectors of length `rows` (Gram-Schmidt),
    // then transpose into row-major [rows][cols].
    let mut vecs: Vec<Vec<f64>> = Vec::with_capacity(cols);
    while vecs.len() < cols {
        let mut v: Vec<f64> = (0..rows).map(|_| rng.gaussian()).collect();
        for u in &vecs {
            let dot: f64 = v.iter().zip(u).map(|(a, b)| a * b).sum();
            for (x, y) in v.iter_mut().zip(u) {
                *x -= dot * y;
            }
        }
        let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 1e-9 {
            for x in &mut v {
                *x /= norm;
            }
            vecs.push(v);
        }
    }
    (0..rows).map(|a| (0..cols).map(|i| vecs[i][a]).collect()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::dense::EuclideanSpace;
    use crate::metric::doubling::correlation_dimension;
    use crate::metric::MetricSpace;
    use std::sync::Arc;

    #[test]
    fn mixture_shapes_and_determinism() {
        let spec = GaussianMixtureSpec { n: 1000, d: 4, k: 5, ..Default::default() };
        let (a, la) = spec.generate();
        let (b, lb) = spec.generate();
        assert_eq!(a, b);
        assert_eq!(la, lb);
        assert_eq!(a.n(), 1000);
        assert_eq!(a.d(), 4);
        assert!(la.iter().all(|&l| l < 5));
    }

    #[test]
    fn outliers_marked() {
        let spec = GaussianMixtureSpec {
            n: 2000,
            outlier_frac: 0.1,
            seed: 3,
            ..Default::default()
        };
        let (_, labels) = spec.generate();
        let outliers = labels.iter().filter(|&&l| l == u32::MAX).count();
        assert!((100..400).contains(&outliers), "outliers {outliers}");
    }

    #[test]
    fn noise_spec_appends_exact_count_with_markers() {
        let spec =
            GaussianMixtureSpec { n: 500, d: 3, k: 4, spread: 20.0, seed: 7, ..Default::default() };
        let noise = NoiseSpec { count: 25, expanse: 10.0, offset: 0.0, seed: 9 };
        let (data, labels) = spec.generate_with_noise(&noise);
        assert_eq!(data.n(), 525);
        assert_eq!(labels.len(), 525);
        assert!(labels[..500].iter().all(|&l| l < 4));
        assert!(labels[500..].iter().all(|&l| l == u32::MAX));
        // noise coordinates live in the declared box
        for i in 500..525u32 {
            for &x in data.row(i) {
                assert!(x.abs() <= 200.0 + 1e-3, "noise coord {x} outside box");
            }
        }
        // base mixture is bit-identical to generating without noise
        let (plain, _) = spec.generate();
        assert_eq!(&data.raw()[..500 * 3], plain.raw());
    }

    #[test]
    fn noise_spec_offset_shifts_the_box() {
        let spec =
            GaussianMixtureSpec { n: 100, d: 2, k: 2, spread: 10.0, seed: 8, ..Default::default() };
        let noise = NoiseSpec { count: 40, expanse: 2.0, offset: 50.0, seed: 10 };
        let (data, _) = spec.generate_with_noise(&noise);
        // box: 500 ± 20 per axis
        for i in 100..140u32 {
            for &x in data.row(i) {
                assert!((480.0..=520.0).contains(&(x as f64)), "noise coord {x}");
            }
        }
    }

    #[test]
    fn clusters_are_separated_when_spread_large() {
        let spec = GaussianMixtureSpec {
            n: 500,
            d: 4,
            k: 3,
            spread: 100.0,
            seed: 5,
            ..Default::default()
        };
        let (data, labels) = spec.generate();
        let s = EuclideanSpace::new(Arc::new(data));
        // same-cluster distances are far below cross-cluster ones
        let mut same_max = 0.0f64;
        let mut cross_min = f64::INFINITY;
        for i in 0..200u32 {
            for j in (i + 1)..200u32 {
                let d = s.dist(i, j);
                if labels[i as usize] == labels[j as usize] {
                    same_max = same_max.max(d);
                } else {
                    cross_min = cross_min.min(d);
                }
            }
        }
        assert!(same_max < cross_min, "same_max={same_max} cross_min={cross_min}");
    }

    #[test]
    fn manifold_intrinsic_dimension_visible() {
        let spec = ManifoldSpec {
            n: 2000,
            intrinsic_dim: 2,
            ambient_dim: 12,
            k: 1,
            spread: 0.0, // single broad cluster: pure manifold sampling
            ..Default::default()
        };
        let (data, _) = spec.generate();
        assert_eq!(data.d(), 12);
        let s = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..2000).collect();
        let dim = correlation_dimension(&s, &pts, 20_000, 7);
        assert!((1.4..2.6).contains(&dim), "estimated intrinsic dim {dim}");
    }

    #[test]
    fn orthonormal_basis_is_orthonormal() {
        let mut rng = Rng::new(11);
        let basis = random_orthonormal(8, 3, &mut rng); // [8][3]
        for i in 0..3 {
            for j in 0..3 {
                let dot: f64 = (0..8).map(|a| basis[a][i] * basis[a][j]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-9, "gram[{i}][{j}]={dot}");
            }
        }
    }
}
