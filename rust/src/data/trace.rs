//! Deterministic "workload trace" generator — the stand-in for a real
//! production trace (none is available offline; see DESIGN.md §5).
//!
//! Models a stream of feature vectors arriving from a set of drifting
//! sources with occasional bursts and background noise, the shape of data
//! MapReduce clustering jobs actually ingest (e.g. user/session feature
//! logs). The generator is seeded and fully reproducible, and its
//! non-stationarity makes partitions heterogeneous — stressing exactly
//! the composability property (Lemma 2.7) that makes the paper's coreset
//! construction work on *arbitrary* partitions.

use crate::points::VectorData;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub n: usize,
    pub d: usize,
    /// Number of drifting sources (true clusters).
    pub sources: usize,
    /// Per-step drift magnitude of each source center.
    pub drift: f64,
    /// Probability a source bursts (emits a dense run of points).
    pub burst_prob: f64,
    /// Background-noise fraction (points from no source).
    pub noise_frac: f64,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            n: 20_000,
            d: 8,
            sources: 12,
            drift: 0.05,
            burst_prob: 0.002,
            noise_frac: 0.02,
            seed: 1,
        }
    }
}

impl TraceSpec {
    /// Generate the trace in arrival order; labels give the source id
    /// (u32::MAX for background noise).
    pub fn generate(&self) -> (VectorData, Vec<u32>) {
        assert!(self.sources >= 1);
        let mut rng = Rng::new(self.seed);
        let box_half = 25.0;
        let mut centers: Vec<Vec<f64>> = (0..self.sources)
            .map(|_| (0..self.d).map(|_| rng.range_f64(-box_half, box_half)).collect())
            .collect();
        let mut data = Vec::with_capacity(self.n * self.d);
        let mut labels = Vec::with_capacity(self.n);
        let mut burst_left = 0usize;
        let mut burst_src = 0usize;
        let mut i = 0usize;
        while i < self.n {
            // all sources drift each arrival
            for c in &mut centers {
                for x in c.iter_mut() {
                    *x = (*x + rng.gaussian() * self.drift).clamp(-2.0 * box_half, 2.0 * box_half);
                }
            }
            let src = if burst_left > 0 {
                burst_left -= 1;
                burst_src
            } else if rng.f64() < self.burst_prob {
                burst_src = rng.below(self.sources);
                burst_left = 20 + rng.below(80);
                burst_src
            } else {
                rng.below(self.sources)
            };
            if rng.f64() < self.noise_frac {
                for _ in 0..self.d {
                    data.push(rng.range_f64(-2.0 * box_half, 2.0 * box_half) as f32);
                }
                labels.push(u32::MAX);
            } else {
                for j in 0..self.d {
                    data.push((centers[src][j] + rng.gaussian()) as f32);
                }
                labels.push(src as u32);
            }
            i += 1;
        }
        (VectorData::new(data, self.d), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let spec = TraceSpec { n: 3000, d: 4, ..Default::default() };
        let (a, la) = spec.generate();
        let (b, _) = spec.generate();
        assert_eq!(a, b);
        assert_eq!(a.n(), 3000);
        assert_eq!(la.len(), 3000);
    }

    #[test]
    fn has_noise_and_all_sources() {
        let spec =
            TraceSpec { n: 20_000, sources: 6, noise_frac: 0.05, seed: 2, ..Default::default() };
        let (_, labels) = spec.generate();
        let noise = labels.iter().filter(|&&l| l == u32::MAX).count();
        assert!(noise > 500, "noise count {noise}");
        for s in 0..6u32 {
            assert!(labels.contains(&s), "source {s} never emitted");
        }
    }

    #[test]
    fn drift_moves_sources() {
        // first and last thousand points of one source should have
        // different means when drift is large
        let spec = TraceSpec {
            n: 30_000,
            d: 2,
            sources: 1,
            drift: 0.2,
            noise_frac: 0.0,
            seed: 3,
            ..Default::default()
        };
        let (data, _) = spec.generate();
        let mean = |lo: usize, hi: usize| -> Vec<f64> {
            let mut m = vec![0.0; 2];
            for i in lo..hi {
                for j in 0..2 {
                    m[j] += data.row(i as u32)[j] as f64;
                }
            }
            m.iter().map(|v| v / (hi - lo) as f64).collect()
        };
        let early = mean(0, 1000);
        let late = mean(29_000, 30_000);
        let shift: f64 = early.iter().zip(&late).map(|(a, b)| (a - b).abs()).sum();
        assert!(shift > 1.0, "drift produced shift {shift}");
    }
}
