//! Minimal CSV I/O for dense f32 point sets (no header by default).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::points::VectorData;

/// Load a CSV of floats. Lines starting with `#` and a first non-numeric
/// header row are skipped. All rows must have the same arity.
pub fn load_csv(path: &Path) -> Result<VectorData> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut data: Vec<f32> = Vec::new();
    let mut d: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        let parsed: Result<Vec<f32>, _> = cells.iter().map(|c| c.parse::<f32>()).collect();
        match parsed {
            Err(_) if data.is_empty() && d.is_none() => continue, // header row
            Err(e) => bail!("{}:{}: {}", path.display(), lineno + 1, e),
            Ok(row) => {
                match d {
                    None => d = Some(row.len()),
                    Some(d0) if d0 != row.len() => {
                        bail!("{}:{}: arity {} != {}", path.display(), lineno + 1, row.len(), d0)
                    }
                    _ => {}
                }
                data.extend(row);
            }
        }
    }
    let d = d.context("empty csv")?;
    Ok(VectorData::new(data, d))
}

pub fn save_csv(path: &Path, data: &VectorData) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..data.n() {
        let row = data.row(i as u32);
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("mrcoreset_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("pts.csv");
        let v = VectorData::from_rows(&[vec![1.5, -2.0], vec![0.0, 3.25]]);
        save_csv(&p, &v).unwrap();
        let back = load_csv(&p).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn skips_header_and_comments() {
        let dir = std::env::temp_dir().join("mrcoreset_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("hdr.csv");
        std::fs::write(&p, "# comment\nx,y\n1,2\n3,4\n").unwrap();
        let v = load_csv(&p).unwrap();
        assert_eq!(v.n(), 2);
        assert_eq!(v.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn ragged_rejected() {
        let dir = std::env::temp_dir().join("mrcoreset_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(load_csv(&p).is_err());
    }
}
