//! Intrinsic-dimension estimation (correlation dimension).
//!
//! The paper's bounds scale with the doubling dimension D of the metric
//! space; a very desirable property it proves is that the algorithms
//! *adapt* to the dataset's intrinsic dimension without knowing it
//! (§1.2). Experiment E10 uses this estimator to show the measured
//! coreset size tracks the intrinsic (not ambient) dimension.
//!
//! Estimator: the Grassberger–Procaccia correlation dimension — the
//! slope of log C(r) vs log r, where C(r) is the fraction of sampled
//! point pairs within distance r. For doubling spaces the correlation
//! dimension lower-bounds the doubling dimension and tracks it on the
//! manifold-like workloads we generate.

use crate::util::rng::Rng;
use crate::util::stats::linear_fit;

use super::MetricSpace;

/// Estimate intrinsic dimension from `pairs` sampled distances, fitting
/// between the q_lo and q_hi distance quantiles (avoids the noise floor
/// and the saturated tail).
pub fn correlation_dimension(
    space: &dyn MetricSpace,
    pts: &[u32],
    pairs: usize,
    seed: u64,
) -> f64 {
    assert!(pts.len() >= 2, "need at least 2 points");
    let mut rng = Rng::new(seed);
    let mut dists: Vec<f64> = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let a = pts[rng.below(pts.len())];
        let mut b = pts[rng.below(pts.len())];
        let mut tries = 0;
        while b == a && tries < 16 {
            b = pts[rng.below(pts.len())];
            tries += 1;
        }
        if b == a {
            continue; // index list is (nearly) all the same point
        }
        let d = space.dist(a, b);
        if d > 0.0 {
            dists.push(d);
        }
    }
    if dists.len() < 16 {
        return 0.0; // degenerate (all duplicates)
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // C(r) at small-distance percentiles: the short-range regime where
    // ball growth reflects intrinsic dimension (long-range pairs are
    // dominated by cluster placement, not the manifold).
    let n = dists.len();
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for pct in [1, 2, 3, 5, 8, 12, 16, 20, 25, 30] {
        let i = (pct * n / 100).min(n - 1);
        let r = dists[i];
        if r <= 0.0 {
            continue;
        }
        let c = (i + 1) as f64 / n as f64;
        xs.push(r.ln());
        ys.push(c.ln());
    }
    if xs.len() < 3 {
        return 0.0;
    }
    // Collinear duplicates (discrete metrics) are fine for OLS.
    let (_, slope, _) = linear_fit(&xs, &ys);
    slope.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::dense::EuclideanSpace;
    use crate::points::VectorData;
    use std::sync::Arc;

    fn uniform_cube(n: usize, d: usize, seed: u64) -> EuclideanSpace {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.f64() as f32).collect();
        EuclideanSpace::new(Arc::new(VectorData::new(data, d)))
    }

    #[test]
    fn line_has_dimension_about_one() {
        let s = uniform_cube(2000, 1, 1);
        let pts: Vec<u32> = (0..2000).collect();
        let d = correlation_dimension(&s, &pts, 20_000, 7);
        assert!((0.7..1.3).contains(&d), "estimated {d}");
    }

    #[test]
    fn plane_has_dimension_about_two() {
        let s = uniform_cube(2000, 2, 2);
        let pts: Vec<u32> = (0..2000).collect();
        let d = correlation_dimension(&s, &pts, 20_000, 7);
        assert!((1.6..2.5).contains(&d), "estimated {d}");
    }

    #[test]
    fn higher_dim_estimates_order_correctly() {
        let pts: Vec<u32> = (0..1500).collect();
        let d2 = correlation_dimension(&uniform_cube(1500, 2, 3), &pts, 15_000, 7);
        let d4 = correlation_dimension(&uniform_cube(1500, 4, 4), &pts, 15_000, 7);
        assert!(d2 < d4, "d2={d2} d4={d4}");
    }

    #[test]
    fn degenerate_all_same_point() {
        let v = VectorData::from_rows(&vec![vec![1.0, 1.0]; 50]);
        let s = EuclideanSpace::new(Arc::new(v));
        let pts: Vec<u32> = (0..50).collect();
        assert_eq!(correlation_dimension(&s, &pts, 1000, 7), 0.0);
    }
}
