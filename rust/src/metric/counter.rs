//! Distance-evaluation accounting for the batched distance engine.
//!
//! Distance evaluations are the work measure the MapReduce model counts
//! alongside memory (cf. Ene–Im–Moseley and the k-center coreset line of
//! work — every algorithm in this family is dominated by its pairwise
//! distance passes). Every `MetricSpace` implementation charges this
//! counter: scalar `dist` charges 1, bulk queries charge
//! `|pts| · |centers|` up front — one unit per (point, center) pair the
//! query covers, *independent of early-exit optimizations*, so the
//! metric is comparable across scalar, tiled, and engine-dispatched
//! paths.
//!
//! The counter is a monotone per-thread tally (thread-safe by
//! construction: no cross-thread sharing). `Simulator::round` reads it
//! around each reducer invocation to attribute work per reducer — every
//! reducer closure runs entirely on one thread — and aggregates the
//! deltas into `RoundStats`. Use [`counted`] to measure a block of work
//! on the current thread directly.

use std::cell::Cell;

thread_local! {
    static TALLY: Cell<u64> = const { Cell::new(0) };
}

/// Charge `n` distance evaluations to the current thread's tally.
#[inline]
pub fn charge(n: usize) {
    TALLY.with(|c| c.set(c.get().wrapping_add(n as u64)));
}

/// Monotone count of distance evaluations charged on this thread since
/// it started. Take differences to measure a span of work.
#[inline]
pub fn thread_count() -> u64 {
    TALLY.with(|c| c.get())
}

/// Run `f`, returning its result and the number of distance evaluations
/// charged on this thread while it ran. Work `f` spawns onto other
/// threads is not captured — measure those on their own threads (the
/// simulator does exactly that per reducer).
pub fn counted<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let start = thread_count();
    let out = f();
    (out, thread_count() - start)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate_monotonically() {
        let before = thread_count();
        charge(3);
        charge(0);
        charge(7);
        assert_eq!(thread_count() - before, 10);
    }

    #[test]
    fn counted_measures_only_the_block() {
        charge(5); // outside noise
        let ((), evals) = counted(|| charge(42));
        assert_eq!(evals, 42);
    }

    #[test]
    fn threads_have_independent_tallies() {
        charge(100);
        let inner = std::thread::spawn(|| {
            let ((), e) = counted(|| charge(9));
            (e, thread_count())
        })
        .join()
        .unwrap();
        assert_eq!(inner.0, 9);
        assert_eq!(inner.1, 9, "fresh thread starts at zero");
    }
}
