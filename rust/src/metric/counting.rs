//! Instrumented wrapper counting distance evaluations — the abstract
//! work measure used by the experiment harness and the perf pass (it is
//! the paper's only "computation" besides bookkeeping).

use std::sync::atomic::{AtomicU64, Ordering};

use super::{Assignment, MetricSpace};

/// Wraps a space and counts `dist` evaluations (including those inside the
/// default bulk ops; engine-dispatched bulk ops count as pts*centers).
/// The bulk queries delegate to the inner space, so wrapping does not
/// lose its batched fast paths. This per-instance counter predates (and
/// complements) the crate-wide `metric::counter` tally: use this to
/// meter one space in isolation, the tally for per-reducer accounting.
pub struct CountingSpace<'a> {
    inner: &'a dyn MetricSpace,
    count: AtomicU64,
}

impl<'a> CountingSpace<'a> {
    pub fn new(inner: &'a dyn MetricSpace) -> CountingSpace<'a> {
        CountingSpace { inner, count: AtomicU64::new(0) }
    }

    pub fn evals(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }
}

impl MetricSpace for CountingSpace<'_> {
    fn n_points(&self) -> usize {
        self.inner.n_points()
    }

    fn dist(&self, i: u32, j: u32) -> f64 {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.dist(i, j)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn kernel_name(&self) -> &'static str {
        self.inner.kernel_name()
    }

    fn uniform_precision(&self) -> bool {
        self.inner.uniform_precision()
    }

    fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
        self.count.fetch_add(pts.len() as u64, Ordering::Relaxed);
        self.inner.dist_batch(pts, c, out)
    }

    /// Forwarded so wrapping keeps the inner space's pruning override;
    /// mirrors the counter contract by counting only computed distances.
    fn dist_batch_pruned(
        &self,
        pts: &[u32],
        c: u32,
        lower: &[f64],
        cutoff: &[f64],
        out: &mut [f64],
    ) -> usize {
        let computed = self.inner.dist_batch_pruned(pts, c, lower, cutoff, out);
        self.count.fetch_add(computed as u64, Ordering::Relaxed);
        computed
    }

    fn nearest_batch(&self, pts: &[u32], centers: &[u32]) -> Assignment {
        self.count.fetch_add((pts.len() * centers.len()) as u64, Ordering::Relaxed);
        self.inner.nearest_batch(pts, centers)
    }

    fn min_update(&self, pts: &[u32], c: u32, cur: &mut [f64]) {
        self.count.fetch_add(pts.len() as u64, Ordering::Relaxed);
        self.inner.min_update(pts, c, cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::dense::EuclideanSpace;
    use crate::points::VectorData;
    use std::sync::Arc;

    #[test]
    fn counts_dist_and_bulk() {
        let v = Arc::new(VectorData::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]));
        let e = EuclideanSpace::new(v);
        let c = CountingSpace::new(&e);
        assert_eq!(c.evals(), 0);
        c.dist(0, 1);
        assert_eq!(c.evals(), 1);
        c.assign(&[0, 1, 2], &[0, 2]);
        assert_eq!(c.evals(), 1 + 6);
        let mut cur = vec![f64::INFINITY; 3];
        c.min_update(&[0, 1, 2], 1, &mut cur);
        assert_eq!(c.evals(), 1 + 6 + 3);
        c.reset();
        assert_eq!(c.evals(), 0);
    }

    #[test]
    fn counts_only_computed_pruned_distances() {
        use crate::metric::kernel::KernelKind;
        let v = Arc::new(VectorData::from_rows(&[vec![0.0], vec![1.0], vec![10.0]]));
        // pinned to an exact kernel: the skip accounting asserted below
        // requires pruning to be active (inexact kernels bypass it)
        let e = EuclideanSpace::with_kernel(v, KernelKind::Blocked);
        let c = CountingSpace::new(&e);
        // distances to 0 are 0,1,10; lower bounds are exact, cutoff 2.0:
        // the 10.0 entry is prunable by the inner Euclidean override
        let mut out = vec![0.0f64; 3];
        let computed =
            c.dist_batch_pruned(&[0, 1, 2], 0, &[0.0, 1.0, 10.0], &[2.0; 3], &mut out);
        assert_eq!(computed, 2);
        assert_eq!(c.evals(), 2, "pruned pairs must not be counted");
    }
}
