//! Additional general-metric substrates: angular (spherical) distance on
//! dense vectors and Hamming distance over fixed-length codes — further
//! witnesses that the constructions only ever use the `MetricSpace`
//! contract.

use crate::points::SharedVectors;

use super::{counter, MetricSpace};

/// Angular distance: the angle between vectors (arc length on the unit
/// sphere). A proper metric on normalized directions; zero vectors are
/// rejected at construction.
pub struct AngularSpace {
    /// unit-normalized rows
    unit: Vec<Vec<f64>>,
}

impl AngularSpace {
    pub fn new(data: SharedVectors) -> AngularSpace {
        let mut unit = Vec::with_capacity(data.n());
        for i in 0..data.n() {
            let row = data.row(i as u32);
            let norm: f64 = row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            assert!(norm > 1e-12, "AngularSpace: zero vector at row {i}");
            unit.push(row.iter().map(|&x| x as f64 / norm).collect());
        }
        AngularSpace { unit }
    }
}

impl MetricSpace for AngularSpace {
    fn n_points(&self) -> usize {
        self.unit.len()
    }

    fn dist(&self, i: u32, j: u32) -> f64 {
        counter::charge(1);
        if i == j {
            return 0.0;
        }
        let a = &self.unit[i as usize];
        let b = &self.unit[j as usize];
        let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        dot.clamp(-1.0, 1.0).acos()
    }

    fn name(&self) -> &'static str {
        "angular"
    }

    /// `acos` is ill-conditioned near dot ≈ ±1: for nearly-parallel
    /// vectors the absolute error can reach ~1e-16/θ, orders beyond the
    /// relative margin pruned callers budget for. Triangle-inequality
    /// bounds assembled from these distances are therefore not reliable
    /// — report so, and pruned callers compute every comparison.
    fn uniform_precision(&self) -> bool {
        false
    }
}

/// Hamming distance over fixed-length byte codes (e.g. binary hashes,
/// categorical feature tuples).
pub struct HammingSpace {
    codes: Vec<Vec<u8>>,
}

impl HammingSpace {
    pub fn new(codes: Vec<Vec<u8>>) -> HammingSpace {
        assert!(!codes.is_empty());
        let len = codes[0].len();
        assert!(codes.iter().all(|c| c.len() == len), "Hamming codes must share a length");
        HammingSpace { codes }
    }

    pub fn code(&self, i: u32) -> &[u8] {
        &self.codes[i as usize]
    }
}

impl MetricSpace for HammingSpace {
    fn n_points(&self) -> usize {
        self.codes.len()
    }

    fn dist(&self, i: u32, j: u32) -> f64 {
        counter::charge(1);
        let a = &self.codes[i as usize];
        let b = &self.codes[j as usize];
        a.iter().zip(b).filter(|(x, y)| x != y).count() as f64
    }

    fn name(&self) -> &'static str {
        "hamming"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::VectorData;
    use std::sync::Arc;

    #[test]
    fn angular_known_values() {
        let data = Arc::new(VectorData::from_rows(&[
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![-1.0, 0.0],
            vec![5.0, 0.0], // same direction as row 0
        ]));
        let s = AngularSpace::new(data);
        assert!((s.dist(0, 1) - std::f64::consts::FRAC_PI_2).abs() < 1e-9);
        assert!((s.dist(0, 2) - std::f64::consts::PI).abs() < 1e-9);
        assert!(s.dist(0, 3) < 1e-6, "scale-invariant");
        assert_eq!(s.dist(1, 1), 0.0);
    }

    #[test]
    fn angular_triangle_inequality() {
        let data = Arc::new(VectorData::from_rows(&[
            vec![1.0, 0.2, -0.3],
            vec![0.4, 1.0, 0.0],
            vec![-0.2, 0.5, 0.9],
            vec![0.7, -0.7, 0.1],
        ]));
        let s = AngularSpace::new(data);
        for i in 0..4u32 {
            for j in 0..4u32 {
                for k in 0..4u32 {
                    assert!(s.dist(i, j) <= s.dist(i, k) + s.dist(k, j) + 1e-9);
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "zero vector")]
    fn angular_rejects_zero() {
        let data = Arc::new(VectorData::from_rows(&[vec![0.0, 0.0]]));
        let _ = AngularSpace::new(data);
    }

    #[test]
    fn hamming_values_and_axioms() {
        let s = HammingSpace::new(vec![b"abcd".to_vec(), b"abcf".to_vec(), b"xbcf".to_vec()]);
        assert_eq!(s.dist(0, 1), 1.0);
        assert_eq!(s.dist(0, 2), 2.0);
        assert_eq!(s.dist(1, 2), 1.0);
        assert!(s.dist(0, 2) <= s.dist(0, 1) + s.dist(1, 2));
    }

    #[test]
    #[should_panic(expected = "share a length")]
    fn hamming_rejects_ragged() {
        let _ = HammingSpace::new(vec![b"ab".to_vec(), b"abc".to_vec()]);
    }

    #[test]
    fn clustering_works_on_angular_space() {
        // two direction bundles -> k-median k=2 recovers them
        use crate::algorithms::local_search::{local_search, LocalSearchCfg};
        use crate::algorithms::Instance;
        use crate::metric::Objective;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4);
        let mut rows = Vec::new();
        for base in [[1.0f64, 0.0], [0.0, 1.0]] {
            for _ in 0..30 {
                rows.push(vec![
                    (base[0] + rng.gaussian() * 0.05) as f32,
                    (base[1] + rng.gaussian() * 0.05) as f32,
                ]);
            }
        }
        let s = AngularSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let pts: Vec<u32> = (0..60).collect();
        let w = vec![1u64; 60];
        let sol = local_search(
            &s,
            Objective::Median,
            Instance::new(&pts, &w),
            2,
            None,
            &LocalSearchCfg::default(),
        );
        // one center per bundle
        let buckets: Vec<usize> = sol.centers.iter().map(|&c| (c / 30) as usize).collect();
        assert_ne!(buckets[0], buckets[1], "centers {:?}", sol.centers);
    }
}
