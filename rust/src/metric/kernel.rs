//! Pluggable vectorized kernel backends for the dense vector metrics.
//!
//! A [`DistKernel`] owns the bulk-query arithmetic of a dense space —
//! `dist_batch` rows, `nearest_batch` scans, `min_update` folds — while
//! the space keeps the [`MetricSpace`](super::MetricSpace) contract:
//! counter charging (bulk ops charge `|pts| · |centers|` *before*
//! dispatching, so `dist_evals` is kernel-invariant), pruning-gate
//! decisions, and the pruned code paths themselves. Kernels never touch
//! [`super::counter`].
//!
//! # Backends
//!
//! | kernel    | L2 assignment                   | L1/L∞ rows | exact | prunable |
//! |-----------|---------------------------------|------------|-------|----------|
//! | `scalar`  | f64 per-pair reference fold     | f64 scalar | yes   | yes      |
//! | `blocked` | cache-blocked `‖x‖²+‖c‖²−2x·c` f32 scan + exact f64 verify | f64 scalar | yes | yes |
//! | `simd`    | 4-lane f32 SIMD accumulation    | 4-lane f32 SIMD | no | no  |
//! | engine    | `BulkEngine` dispatch (PJRT), blocked CPU fallback | blocked | no | no |
//!
//! `auto` resolves to `blocked` (or the engine kernel when a
//! [`BulkEngine`] is attached). Selection mirrors the executor override
//! pattern: `MRCORESET_KERNEL` overrides the built-in default, an
//! explicit `--kernel`/constructor choice overrides the environment.
//!
//! # Exactness contract
//!
//! Kernels reporting `uniform_precision() == true` must be *decision
//! bit-identical* to [`ScalarKernel`]: same `Assignment` bits, same
//! argmin ties, same `min_update` results. The blocked kernel achieves
//! this without paying f64 GEMM cost: the norm-decomposition scan is
//! only a *bounding* pass. With per-pair margin `M = (d+8)·ε₃₂·(‖x‖²+‖c‖²)`
//! (the 4-lane f32 dot's forward error is below `(d/4+2)·ε₃₂·(‖x‖²+‖c‖²)`,
//! so `M` carries ≥4x analytic headroom; randomized cross-validation
//! measured ≥11x), every center whose approximate squared distance could
//! reach the minimum lands in a candidate set that is then verified with
//! the exact f64 `sq_euclidean` in center order — in the common case one
//! exact evaluation per point, the winner, whose exact distance the
//! output needs anyway. Inexact kernels (`simd`, engine) report
//! `uniform_precision() == false`; the owning spaces then route
//! `dist_batch_pruned` through the plain batch path and bounds-pruned
//! callers fall back to their exact reference folds.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::points::{sq_norm_f64, sq_norms_f64, VectorData};

use super::dense::{chebyshev, manhattan, sq_euclidean, BulkEngine};
use super::Assignment;

/// f32 machine epsilon as f64 (2⁻²³) — the unit of the blocked margin.
const EPS32: f64 = f32::EPSILON as f64;

/// Requested kernel backend. `Auto` lets construction pick: the blocked
/// exact kernel, or the engine kernel when a `BulkEngine` is attached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    Auto,
    Scalar,
    Blocked,
    Simd,
}

impl KernelKind {
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "auto" => Some(KernelKind::Auto),
            "scalar" => Some(KernelKind::Scalar),
            "blocked" => Some(KernelKind::Blocked),
            "simd" => Some(KernelKind::Simd),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Scalar => "scalar",
            KernelKind::Blocked => "blocked",
            KernelKind::Simd => "simd",
        }
    }

    /// `MRCORESET_KERNEL` override, mirroring `MRCORESET_EXECUTOR`:
    /// unrecognized values fall through to the built-in default.
    pub fn from_env() -> Option<KernelKind> {
        std::env::var("MRCORESET_KERNEL").ok().and_then(|v| KernelKind::parse(&v))
    }

    /// Selection order: explicit choice (CLI/constructor) beats the
    /// environment override beats `Auto`.
    pub fn resolve(explicit: Option<KernelKind>) -> KernelKind {
        explicit.or_else(KernelKind::from_env).unwrap_or(KernelKind::Auto)
    }
}

/// Bulk-query backend for dense row-major f32 data. See the module docs
/// for the exactness contract; implementations never charge the
/// distance counter (the owning space charges before dispatch).
pub trait DistKernel: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether bulk results are bit-identical to the scalar f64
    /// reference (and therefore safe to build pruning bounds from).
    fn uniform_precision(&self) -> bool;

    /// `out[i] = d(pts[i], c)` under L2.
    fn l2_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]);

    /// Nearest-center assignment under L2; ties break toward the
    /// earlier center position (strict `<` fold semantics).
    fn l2_nearest(&self, data: &VectorData, pts: &[u32], centers: &[u32]) -> Assignment;

    /// `cur[i] = min(cur[i], d(pts[i], c))` under L2.
    fn l2_min_update(&self, data: &VectorData, pts: &[u32], c: u32, cur: &mut [f64]);

    /// `out[i] = d(pts[i], c)` under L1 (Manhattan).
    fn l1_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]);

    /// `out[i] = d(pts[i], c)` under L∞ (Chebyshev).
    fn linf_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]);
}

/// Build the kernel for a resolved kind. Returns the kernel plus
/// whether the engine is actually in the dispatch path (an explicit
/// non-auto kind pins the CPU kernel and sidelines the engine).
pub fn build(kind: KernelKind, engine: Option<Arc<dyn BulkEngine>>) -> (Arc<dyn DistKernel>, bool) {
    match kind {
        KernelKind::Scalar => (Arc::new(ScalarKernel), false),
        KernelKind::Blocked => (Arc::new(BlockedKernel), false),
        KernelKind::Simd => (Arc::new(SimdKernel), false),
        KernelKind::Auto => match engine {
            Some(e) => (Arc::new(EngineKernel::new(e)), true),
            None => (Arc::new(BlockedKernel), false),
        },
    }
}

/// Shared fold shape: visit centers in ascending position per point with
/// a strict `<` update — the reference semantics every kernel's
/// `nearest` must reproduce (it is exactly the trait-default fold over
/// `dist_batch` rows, reordered point-major).
fn fold_nearest<R>(data: &VectorData, pts: &[u32], centers: &[u32], row_dist: R) -> Assignment
where
    R: Fn(&[f32], &[f32]) -> f64,
{
    let d = data.d();
    let cblock = data.gather(centers);
    let craw = cblock.raw();
    let n = pts.len();
    let mut dist = vec![0.0f64; n];
    let mut idx = vec![0u32; n];
    for (i, &p) in pts.iter().enumerate() {
        let prow = data.row(p);
        let (mut bd, mut bj) = (f64::INFINITY, 0u32);
        for j in 0..centers.len() {
            let e = row_dist(prow, &craw[j * d..(j + 1) * d]);
            if e < bd {
                bd = e;
                bj = j as u32;
            }
        }
        dist[i] = bd;
        idx[i] = bj;
    }
    Assignment { dist, idx }
}

/// Exact f64 per-pair reference: the semantics every exact backend is
/// pinned against (and the `scalar` series in `BENCH_micro.json`).
pub struct ScalarKernel;

impl DistKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn uniform_precision(&self) -> bool {
        true
    }

    fn l2_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = sq_euclidean(data.row(p), crow).sqrt();
        }
    }

    fn l2_nearest(&self, data: &VectorData, pts: &[u32], centers: &[u32]) -> Assignment {
        fold_nearest(data, pts, centers, |a, b| sq_euclidean(a, b).sqrt())
    }

    fn l2_min_update(&self, data: &VectorData, pts: &[u32], c: u32, cur: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in cur.iter_mut().zip(pts) {
            let e = sq_euclidean(data.row(p), crow).sqrt();
            if e < *o {
                *o = e;
            }
        }
    }

    fn l1_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = manhattan(data.row(p), crow);
        }
    }

    fn linf_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = chebyshev(data.row(p), crow);
        }
    }
}

/// Cache-blocked GEMM-style L2 assignment: a 4-lane f32
/// norm-decomposition scan over L1-resident center tiles bounds the
/// candidate set, exact f64 verification picks the winner — decision
/// bit-identical to [`ScalarKernel`] (module docs prove the margin).
pub struct BlockedKernel;

/// Point tile: bounds the approx-row scratch and keeps the staged point
/// rows hot while a center tile is resident.
const TILE_P: usize = 64;

impl BlockedKernel {
    /// Center tile sized for L1d residency: ~24 KiB of f32 rows leaves
    /// room for the point tile and the approx scratch lines.
    fn tile_c(d: usize) -> usize {
        (24 * 1024 / (4 * d)).clamp(8, 1024)
    }
}

impl DistKernel for BlockedKernel {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn uniform_precision(&self) -> bool {
        true
    }

    fn l2_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        // single-center rows are the value-producing primitive every
        // caller folds over: keep them on the exact f64 reference path
        let crow = data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = sq_euclidean(data.row(p), crow).sqrt();
        }
    }

    fn l2_nearest(&self, data: &VectorData, pts: &[u32], centers: &[u32]) -> Assignment {
        let d = data.d();
        let n = pts.len();
        let k = centers.len();
        let cblock = data.gather(centers);
        let craw = cblock.raw();
        let cnorms = sq_norms_f64(craw, d);
        let kappa = (d as f64 + 8.0) * EPS32;
        let tile_c = Self::tile_c(d);
        let mut dist = vec![0.0f64; n];
        let mut idx = vec![0u32; n];
        let rows = TILE_P.min(n.max(1));
        let mut approx = vec![0.0f64; rows * k];
        let mut pnorms = [0.0f64; TILE_P];
        for p0 in (0..n).step_by(TILE_P) {
            let pl = TILE_P.min(n - p0);
            for pi in 0..pl {
                pnorms[pi] = sq_norm_f64(data.row(pts[p0 + pi]));
            }
            // GEMM-shaped scan: each center tile stays L1-resident while
            // being re-streamed across the whole point tile
            for c0 in (0..k).step_by(tile_c) {
                let c1 = (c0 + tile_c).min(k);
                for pi in 0..pl {
                    let prow = data.row(pts[p0 + pi]);
                    let pn = pnorms[pi];
                    let row = &mut approx[pi * k..(pi + 1) * k];
                    for j in c0..c1 {
                        let dot = dot_f32(prow, &craw[j * d..(j + 1) * d]) as f64;
                        row[j] = pn + cnorms[j] - 2.0 * dot;
                    }
                }
            }
            // candidate envelope + exact verification, in center order,
            // with the same linear-domain strict-< comparisons as the
            // reference fold (sqrt rounding can tie squared-distinct
            // values, so the squared domain must not decide the argmin)
            for pi in 0..pl {
                let prow = data.row(pts[p0 + pi]);
                let pn = pnorms[pi];
                let row = &approx[pi * k..(pi + 1) * k];
                let mut best_ub = f64::INFINITY;
                for j in 0..k {
                    let ub = row[j] + kappa * (pn + cnorms[j]);
                    if ub < best_ub {
                        best_ub = ub;
                    }
                }
                let (mut bd, mut bj) = (f64::INFINITY, 0u32);
                for j in 0..k {
                    if row[j] - kappa * (pn + cnorms[j]) <= best_ub {
                        let e = sq_euclidean(prow, &craw[j * d..(j + 1) * d]).sqrt();
                        if e < bd {
                            bd = e;
                            bj = j as u32;
                        }
                    }
                }
                dist[p0 + pi] = bd;
                idx[p0 + pi] = bj;
            }
        }
        Assignment { dist, idx }
    }

    fn l2_min_update(&self, data: &VectorData, pts: &[u32], c: u32, cur: &mut [f64]) {
        let d = data.d();
        let crow = data.row(c);
        let cn = sq_norm_f64(crow);
        let kappa = (d as f64 + 8.0) * EPS32;
        for (i, &p) in pts.iter().enumerate() {
            let prow = data.row(p);
            let pn = sq_norm_f64(prow);
            let scale = pn + cn;
            let approx = scale - 2.0 * dot_f32(prow, crow) as f64;
            // sound skip: beyond the f32-scale margin, 1e-12 relative
            // slack absorbs the squared-vs-linear domain rounding of
            // `cur²`, so a skipped pair provably satisfies e >= cur.
            // cur = INFINITY (or any non-improving bound) always computes.
            if approx - kappa * scale > cur[i] * cur[i] * (1.0 + 1e-12) {
                continue;
            }
            let e = sq_euclidean(prow, crow).sqrt();
            if e < cur[i] {
                cur[i] = e;
            }
        }
    }

    fn l1_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        ScalarKernel.l1_dist_batch(data, pts, c, out)
    }

    fn linf_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        ScalarKernel.linf_dist_batch(data, pts, c, out)
    }
}

/// Explicit-SIMD f32 kernel: 4-lane accumulation for all three dense
/// metrics (SSE2 on x86_64, a lane-for-lane portable mirror elsewhere —
/// identical results either way). Fast but inexact relative to the f64
/// reference, so it reports `uniform_precision() == false` and never
/// feeds the bounds-pruned paths.
pub struct SimdKernel;

impl DistKernel for SimdKernel {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn uniform_precision(&self) -> bool {
        false
    }

    fn l2_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            // widen before sqrt: integer-exact inputs still round-trip
            *o = (simd_rows::l2_row(data.row(p), crow) as f64).sqrt();
        }
    }

    fn l2_nearest(&self, data: &VectorData, pts: &[u32], centers: &[u32]) -> Assignment {
        fold_nearest(data, pts, centers, |a, b| (simd_rows::l2_row(a, b) as f64).sqrt())
    }

    fn l2_min_update(&self, data: &VectorData, pts: &[u32], c: u32, cur: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in cur.iter_mut().zip(pts) {
            let e = (simd_rows::l2_row(data.row(p), crow) as f64).sqrt();
            if e < *o {
                *o = e;
            }
        }
    }

    fn l1_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = simd_rows::l1_row(data.row(p), crow) as f64;
        }
    }

    fn linf_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        let crow = data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = simd_rows::linf_row(data.row(p), crow) as f64;
        }
    }
}

/// `BulkEngine` (PJRT) dispatch folded in as a kernel backend. Large
/// blocks go to the engine (f32 engine numerics); small blocks and every
/// call after the first dispatch failure take the blocked CPU kernel —
/// the failure latch replaces the old per-call gather-then-fallback
/// double work with exactly one wasted gather per process.
pub struct EngineKernel {
    engine: Arc<dyn BulkEngine>,
    fallback: BlockedKernel,
    threshold: usize,
    failed: AtomicBool,
}

impl EngineKernel {
    pub fn new(engine: Arc<dyn BulkEngine>) -> EngineKernel {
        let threshold = engine.dispatch_threshold();
        EngineKernel { engine, fallback: BlockedKernel, threshold, failed: AtomicBool::new(false) }
    }

    fn engine_ready(&self, pairs: usize) -> bool {
        pairs >= self.threshold && !self.failed.load(Ordering::Relaxed)
    }

    fn disable(&self, err: &anyhow::Error) {
        if !self.failed.swap(true, Ordering::Relaxed) {
            crate::obs::log::warn(&format!(
                "engine dispatch failed ({err}); all further bulk queries use the blocked CPU \
                 kernel"
            ));
        }
    }
}

impl DistKernel for EngineKernel {
    fn name(&self) -> &'static str {
        "engine"
    }

    /// Engine blocks are f32 while small blocks are f64 — mixed output
    /// is unsound to build pruning bounds from.
    fn uniform_precision(&self) -> bool {
        false
    }

    fn l2_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        if self.engine_ready(pts.len()) {
            let x = data.gather(pts);
            let cb = data.gather(&[c]);
            let mut cur = vec![f32::INFINITY; pts.len()];
            match self.engine.min_update_block(&x, &cb, &mut cur) {
                Ok(()) => {
                    for (o, s) in out.iter_mut().zip(&cur) {
                        *o = (*s as f64).max(0.0).sqrt();
                    }
                    return;
                }
                Err(e) => self.disable(&e),
            }
        }
        self.fallback.l2_dist_batch(data, pts, c, out)
    }

    fn l2_nearest(&self, data: &VectorData, pts: &[u32], centers: &[u32]) -> Assignment {
        if self.engine_ready(pts.len() * centers.len()) {
            let x = data.gather(pts);
            let c = data.gather(centers);
            match self.engine.assign_block(&x, &c) {
                Ok((d2, idx)) => {
                    return Assignment {
                        dist: d2.iter().map(|&v| (v as f64).max(0.0).sqrt()).collect(),
                        idx: idx.iter().map(|&v| v as u32).collect(),
                    };
                }
                Err(e) => self.disable(&e),
            }
        }
        self.fallback.l2_nearest(data, pts, centers)
    }

    fn l2_min_update(&self, data: &VectorData, pts: &[u32], c: u32, cur: &mut [f64]) {
        if self.engine_ready(pts.len()) {
            let x = data.gather(pts);
            let cb = data.gather(&[c]);
            // engine works on squared distances
            let mut cur_sq: Vec<f32> = cur.iter().map(|&v| (v * v) as f32).collect();
            match self.engine.min_update_block(&x, &cb, &mut cur_sq) {
                Ok(()) => {
                    for (o, s) in cur.iter_mut().zip(&cur_sq) {
                        *o = (*s as f64).max(0.0).sqrt();
                    }
                    return;
                }
                Err(e) => self.disable(&e),
            }
        }
        self.fallback.l2_min_update(data, pts, c, cur)
    }

    fn l1_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        self.fallback.l1_dist_batch(data, pts, c, out)
    }

    fn linf_dist_batch(&self, data: &VectorData, pts: &[u32], c: u32, out: &mut [f64]) {
        self.fallback.linf_dist_batch(data, pts, c, out)
    }
}

/// 4-lane f32 dot product (the blocked kernel's bounding scan). Lane
/// shape and `(l0+l1)+(l2+l3)` combine order are fixed: the margin in
/// the module docs is proved against exactly this summation.
#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() / 4 * 4;
    let mut l = [0.0f32; 4];
    let mut i = 0;
    while i < n4 {
        l[0] += a[i] * b[i];
        l[1] += a[i + 1] * b[i + 1];
        l[2] += a[i + 2] * b[i + 2];
        l[3] += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut acc = (l[0] + l[1]) + (l[2] + l[3]);
    for j in n4..a.len() {
        acc += a[j] * b[j];
    }
    acc
}

#[cfg(all(target_arch = "x86_64", target_feature = "sse2"))]
mod simd_rows {
    //! SSE2 row primitives. `sse2` is part of the x86_64 baseline
    //! feature set, so the cfg gate is static — no runtime detection.
    //! The portable mirror below uses the same lane shapes and combine
    //! order, so both paths produce bit-identical f32 results.
    use std::arch::x86_64::*;

    #[inline]
    pub fn l1_row(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        // SAFETY: sse2 is statically enabled (module cfg); unaligned
        // loads via _mm_loadu_ps; i + 4 <= n4 <= len keeps loads in
        // bounds.
        unsafe {
            let sign = _mm_set1_ps(-0.0);
            let mut acc = _mm_setzero_ps();
            let mut i = 0;
            while i < n4 {
                let va = _mm_loadu_ps(a.as_ptr().add(i));
                let vb = _mm_loadu_ps(b.as_ptr().add(i));
                acc = _mm_add_ps(acc, _mm_andnot_ps(sign, _mm_sub_ps(va, vb)));
                i += 4;
            }
            let mut l = [0.0f32; 4];
            _mm_storeu_ps(l.as_mut_ptr(), acc);
            let mut s = (l[0] + l[1]) + (l[2] + l[3]);
            for j in n4..a.len() {
                s += (a[j] - b[j]).abs();
            }
            s
        }
    }

    #[inline]
    pub fn l2_row(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        // SAFETY: as in l1_row.
        unsafe {
            let mut acc = _mm_setzero_ps();
            let mut i = 0;
            while i < n4 {
                let va = _mm_loadu_ps(a.as_ptr().add(i));
                let vb = _mm_loadu_ps(b.as_ptr().add(i));
                let dv = _mm_sub_ps(va, vb);
                acc = _mm_add_ps(acc, _mm_mul_ps(dv, dv));
                i += 4;
            }
            let mut l = [0.0f32; 4];
            _mm_storeu_ps(l.as_mut_ptr(), acc);
            let mut s = (l[0] + l[1]) + (l[2] + l[3]);
            for j in n4..a.len() {
                let dj = a[j] - b[j];
                s += dj * dj;
            }
            s
        }
    }

    #[inline]
    pub fn linf_row(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        // SAFETY: as in l1_row. _mm_max_ps NaN semantics are irrelevant:
        // |x−y| of finite inputs is never NaN.
        unsafe {
            let sign = _mm_set1_ps(-0.0);
            let mut acc = _mm_setzero_ps();
            let mut i = 0;
            while i < n4 {
                let va = _mm_loadu_ps(a.as_ptr().add(i));
                let vb = _mm_loadu_ps(b.as_ptr().add(i));
                acc = _mm_max_ps(acc, _mm_andnot_ps(sign, _mm_sub_ps(va, vb)));
                i += 4;
            }
            let mut l = [0.0f32; 4];
            _mm_storeu_ps(l.as_mut_ptr(), acc);
            let mut s = (l[0].max(l[1])).max(l[2].max(l[3]));
            for j in n4..a.len() {
                s = s.max((a[j] - b[j]).abs());
            }
            s
        }
    }
}

#[cfg(not(all(target_arch = "x86_64", target_feature = "sse2")))]
mod simd_rows {
    //! Portable lane-for-lane mirror of the SSE2 path: same 4-lane
    //! shapes and combine order, so results are bit-identical across
    //! architectures (IEEE ops applied in the same sequence).

    #[inline]
    pub fn l1_row(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        let mut l = [0.0f32; 4];
        let mut i = 0;
        while i < n4 {
            l[0] += (a[i] - b[i]).abs();
            l[1] += (a[i + 1] - b[i + 1]).abs();
            l[2] += (a[i + 2] - b[i + 2]).abs();
            l[3] += (a[i + 3] - b[i + 3]).abs();
            i += 4;
        }
        let mut s = (l[0] + l[1]) + (l[2] + l[3]);
        for j in n4..a.len() {
            s += (a[j] - b[j]).abs();
        }
        s
    }

    #[inline]
    pub fn l2_row(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        let mut l = [0.0f32; 4];
        let mut i = 0;
        while i < n4 {
            let d0 = a[i] - b[i];
            let d1 = a[i + 1] - b[i + 1];
            let d2 = a[i + 2] - b[i + 2];
            let d3 = a[i + 3] - b[i + 3];
            l[0] += d0 * d0;
            l[1] += d1 * d1;
            l[2] += d2 * d2;
            l[3] += d3 * d3;
            i += 4;
        }
        let mut s = (l[0] + l[1]) + (l[2] + l[3]);
        for j in n4..a.len() {
            let dj = a[j] - b[j];
            s += dj * dj;
        }
        s
    }

    #[inline]
    pub fn linf_row(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n4 = a.len() / 4 * 4;
        let mut l = [0.0f32; 4];
        let mut i = 0;
        while i < n4 {
            l[0] = l[0].max((a[i] - b[i]).abs());
            l[1] = l[1].max((a[i + 1] - b[i + 1]).abs());
            l[2] = l[2].max((a[i + 2] - b[i + 2]).abs());
            l[3] = l[3].max((a[i + 3] - b[i + 3]).abs());
            i += 4;
        }
        let mut s = (l[0].max(l[1])).max(l[2].max(l[3]));
        for j in n4..a.len() {
            s = s.max((a[j] - b[j]).abs());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use std::sync::atomic::AtomicUsize;

    fn mixture(n: usize, d: usize, seed: u64) -> VectorData {
        GaussianMixtureSpec { n, d, k: 4, seed, ..Default::default() }.generate().0
    }

    /// Tie-heavy adversarial grid: duplicated rows and exactly
    /// equidistant centers exercise the argmin tie-break.
    fn tie_grid() -> VectorData {
        let mut rows = Vec::new();
        for x in 0..6 {
            for y in 0..6 {
                rows.push(vec![x as f32, y as f32, 0.0]);
                rows.push(vec![x as f32, y as f32, 0.0]);
            }
        }
        VectorData::from_rows(&rows)
    }

    fn assert_assignment_bits(a: &Assignment, b: &Assignment, ctx: &str) {
        assert_eq!(a.idx, b.idx, "{ctx}: idx");
        for (i, (x, y)) in a.dist.iter().zip(&b.dist).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: dist[{i}]");
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in
            [KernelKind::Auto, KernelKind::Scalar, KernelKind::Blocked, KernelKind::Simd]
        {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::parse("xla"), None);
        assert_eq!(KernelKind::resolve(Some(KernelKind::Simd)), KernelKind::Simd);
    }

    #[test]
    fn blocked_nearest_bitwise_matches_scalar() {
        for (data, tag) in [(mixture(300, 7, 3), "mixture"), (tie_grid(), "tie_grid")] {
            let pts: Vec<u32> = (0..data.n() as u32).collect();
            let centers: Vec<u32> = (0..data.n() as u32).step_by(5).collect();
            let a = ScalarKernel.l2_nearest(&data, &pts, &centers);
            let b = BlockedKernel.l2_nearest(&data, &pts, &centers);
            assert_assignment_bits(&a, &b, tag);
        }
    }

    #[test]
    fn blocked_min_update_bitwise_matches_scalar() {
        let data = mixture(200, 5, 9);
        let pts: Vec<u32> = (0..200).collect();
        let mut a = vec![f64::INFINITY; 200];
        let mut b = vec![f64::INFINITY; 200];
        for c in [0u32, 7, 100, 100, 199] {
            ScalarKernel.l2_min_update(&data, &pts, c, &mut a);
            BlockedKernel.l2_min_update(&data, &pts, c, &mut b);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "c={c} i={i}");
            }
        }
    }

    #[test]
    fn simd_rows_bounded_relative_error() {
        let data = mixture(150, 13, 5);
        let pts: Vec<u32> = (0..150).collect();
        let mut exact = vec![0.0f64; 150];
        let mut fast = vec![0.0f64; 150];
        assert!(!SimdKernel.uniform_precision());
        type Batch = fn(&SimdKernel, &VectorData, &[u32], u32, &mut [f64]);
        type RefBatch = fn(&ScalarKernel, &VectorData, &[u32], u32, &mut [f64]);
        let ops: [(Batch, RefBatch); 3] = [
            (SimdKernel::l2_dist_batch, ScalarKernel::l2_dist_batch),
            (SimdKernel::l1_dist_batch, ScalarKernel::l1_dist_batch),
            (SimdKernel::linf_dist_batch, ScalarKernel::linf_dist_batch),
        ];
        for (fast_op, exact_op) in ops {
            for c in [0u32, 42, 149] {
                fast_op(&SimdKernel, &data, &pts, c, &mut fast);
                exact_op(&ScalarKernel, &data, &pts, c, &mut exact);
                for i in 0..150 {
                    let tol = 1e-4 * (1.0 + exact[i]);
                    assert!(
                        (fast[i] - exact[i]).abs() <= tol,
                        "c={c} i={i}: {} vs {}",
                        fast[i],
                        exact[i]
                    );
                }
            }
        }
    }

    struct FailingEngine {
        calls: AtomicUsize,
    }

    impl BulkEngine for FailingEngine {
        fn assign_block(
            &self,
            _x: &VectorData,
            _c: &VectorData,
        ) -> anyhow::Result<(Vec<f32>, Vec<i32>)> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("injected failure")
        }

        fn min_update_block(
            &self,
            _x: &VectorData,
            _c: &VectorData,
            _cur: &mut [f32],
        ) -> anyhow::Result<()> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("injected failure")
        }

        fn dispatch_threshold(&self) -> usize {
            1
        }
    }

    #[test]
    fn engine_kernel_latches_off_after_first_failure() {
        let engine = Arc::new(FailingEngine { calls: AtomicUsize::new(0) });
        let kernel = EngineKernel::new(engine.clone());
        assert!(!kernel.uniform_precision());
        let data = mixture(60, 4, 1);
        let pts: Vec<u32> = (0..60).collect();
        let centers = [0u32, 20, 40];
        let a = kernel.l2_nearest(&data, &pts, &centers);
        assert_eq!(engine.calls.load(Ordering::Relaxed), 1, "first call dispatches");
        let b = kernel.l2_nearest(&data, &pts, &centers);
        assert_eq!(engine.calls.load(Ordering::Relaxed), 1, "latch skips the engine");
        let reference = BlockedKernel.l2_nearest(&data, &pts, &centers);
        assert_assignment_bits(&a, &reference, "first (fallback)");
        assert_assignment_bits(&b, &reference, "second (latched)");
    }

    #[test]
    fn build_resolves_auto_by_engine_presence() {
        let (k, active) = build(KernelKind::Auto, None);
        assert_eq!(k.name(), "blocked");
        assert!(!active);
        let engine: Arc<dyn BulkEngine> = Arc::new(FailingEngine { calls: AtomicUsize::new(0) });
        let (k, active) = build(KernelKind::Auto, Some(engine.clone()));
        assert_eq!(k.name(), "engine");
        assert!(active);
        // an explicit kind pins the CPU kernel and sidelines the engine
        let (k, active) = build(KernelKind::Scalar, Some(engine));
        assert_eq!(k.name(), "scalar");
        assert!(!active);
    }
}
