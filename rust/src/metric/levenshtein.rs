//! Levenshtein (edit-distance) metric over byte strings — the
//! genuinely-non-Euclidean space exercising the paper's "general metric
//! spaces" claim end to end (no XLA fast path exists or is needed here).

use super::{counter, MetricSpace};

/// A set of byte strings with edit distance.
pub struct StringSpace {
    strings: Vec<Vec<u8>>,
}

impl StringSpace {
    pub fn new(strings: Vec<Vec<u8>>) -> StringSpace {
        StringSpace { strings }
    }

    pub fn from_strs<S: AsRef<str>>(strs: &[S]) -> StringSpace {
        StringSpace { strings: strs.iter().map(|s| s.as_ref().as_bytes().to_vec()).collect() }
    }

    pub fn string(&self, i: u32) -> &[u8] {
        &self.strings[i as usize]
    }
}

/// Classic two-row DP Levenshtein; O(|a|*|b|) time, O(min) space.
pub fn levenshtein(a: &[u8], b: &[u8]) -> usize {
    let (a, b) = if a.len() < b.len() { (a, b) } else { (b, a) };
    if a.is_empty() {
        return b.len();
    }
    let mut prev: Vec<usize> = (0..=a.len()).collect();
    let mut cur = vec![0usize; a.len() + 1];
    for (j, &bc) in b.iter().enumerate() {
        cur[0] = j + 1;
        for (i, &ac) in a.iter().enumerate() {
            let sub = prev[i] + usize::from(ac != bc);
            cur[i + 1] = sub.min(prev[i + 1] + 1).min(cur[i] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[a.len()]
}

impl MetricSpace for StringSpace {
    fn n_points(&self) -> usize {
        self.strings.len()
    }

    fn dist(&self, i: u32, j: u32) -> f64 {
        counter::charge(1);
        if i == j {
            return 0.0;
        }
        levenshtein(&self.strings[i as usize], &self.strings[j as usize]) as f64
    }

    /// Batched edit distances against one string: the DP rows are
    /// allocated once per batch (not once per pair), and the virtual
    /// dispatch happens per center instead of per pair.
    fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
        assert_eq!(pts.len(), out.len());
        counter::charge(pts.len());
        let cs = &self.strings[c as usize];
        let mut prev: Vec<usize> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        for (o, &p) in out.iter_mut().zip(pts) {
            if p == c {
                *o = 0.0;
                continue;
            }
            *o = levenshtein_with(&self.strings[p as usize], cs, &mut prev, &mut cur) as f64;
        }
    }

    /// Geometry-pruned batch: each skipped pair saves an entire
    /// O(|a|·|b|) DP table — the most expensive distance in the tree —
    /// and only computed pairs charge the counter. Computed entries go
    /// through the same DP (and the same `p == c` shortcut) as
    /// `dist_batch`, so they are bit-identical to it.
    fn dist_batch_pruned(
        &self,
        pts: &[u32],
        c: u32,
        lower: &[f64],
        cutoff: &[f64],
        out: &mut [f64],
    ) -> usize {
        assert_eq!(pts.len(), lower.len());
        assert_eq!(pts.len(), cutoff.len());
        assert_eq!(pts.len(), out.len());
        let cs = &self.strings[c as usize];
        let mut prev: Vec<usize> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut computed = 0usize;
        for i in 0..pts.len() {
            if lower[i] > cutoff[i] {
                out[i] = f64::INFINITY;
            } else if pts[i] == c {
                out[i] = 0.0;
                computed += 1;
            } else {
                let s = &self.strings[pts[i] as usize];
                out[i] = levenshtein_with(s, cs, &mut prev, &mut cur) as f64;
                computed += 1;
            }
        }
        counter::charge(computed);
        computed
    }

    fn name(&self) -> &'static str {
        "levenshtein"
    }
}

/// Levenshtein DP reusing caller-provided row buffers (the batched inner
/// loop). Same recurrence as [`levenshtein`], which remains the scalar
/// reference.
fn levenshtein_with(a: &[u8], b: &[u8], prev: &mut Vec<usize>, cur: &mut Vec<usize>) -> usize {
    let (a, b) = if a.len() < b.len() { (a, b) } else { (b, a) };
    if a.is_empty() {
        return b.len();
    }
    prev.clear();
    prev.extend(0..=a.len());
    cur.clear();
    cur.resize(a.len() + 1, 0);
    for (j, &bc) in b.iter().enumerate() {
        cur[0] = j + 1;
        for (i, &ac) in a.iter().enumerate() {
            let sub = prev[i] + usize::from(ac != bc);
            cur[i + 1] = sub.min(prev[i + 1] + 1).min(cur[i] + 1);
        }
        std::mem::swap(prev, cur);
    }
    prev[a.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(levenshtein(b"kitten", b"sitting"), 3);
        assert_eq!(levenshtein(b"", b"abc"), 3);
        assert_eq!(levenshtein(b"abc", b""), 3);
        assert_eq!(levenshtein(b"abc", b"abc"), 0);
        assert_eq!(levenshtein(b"flaw", b"lawn"), 2);
    }

    #[test]
    fn symmetric() {
        assert_eq!(levenshtein(b"abcdef", b"azced"), levenshtein(b"azced", b"abcdef"));
    }

    #[test]
    fn triangle_inequality_sample() {
        let words: Vec<&[u8]> = vec![b"cluster", b"clusters", b"custard", b"mustard", b"cloister"];
        let s = StringSpace::new(words.iter().map(|w| w.to_vec()).collect());
        let n = s.n_points() as u32;
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    assert!(s.dist(i, k) <= s.dist(i, j) + s.dist(j, k));
                }
            }
        }
    }

    #[test]
    fn space_wiring() {
        let s = StringSpace::from_strs(&["abc", "abd"]);
        assert_eq!(s.n_points(), 2);
        assert_eq!(s.dist(0, 1), 1.0);
        assert_eq!(s.name(), "levenshtein");
    }

    #[test]
    fn dist_batch_matches_scalar_dp() {
        let s = StringSpace::from_strs(&["cluster", "clusters", "custard", "", "cloister"]);
        let pts: Vec<u32> = (0..5).collect();
        let mut out = vec![0.0f64; 5];
        for c in 0..5u32 {
            s.dist_batch(&pts, c, &mut out);
            for (i, &p) in pts.iter().enumerate() {
                assert_eq!(out[i], s.dist(p, c), "p={p} c={c}");
            }
        }
    }
}
