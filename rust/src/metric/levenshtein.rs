//! Levenshtein (edit-distance) metric over byte strings — the
//! genuinely-non-Euclidean space exercising the paper's "general metric
//! spaces" claim end to end (no XLA fast path exists or is needed here).

use super::MetricSpace;

/// A set of byte strings with edit distance.
pub struct StringSpace {
    strings: Vec<Vec<u8>>,
}

impl StringSpace {
    pub fn new(strings: Vec<Vec<u8>>) -> StringSpace {
        StringSpace { strings }
    }

    pub fn from_strs<S: AsRef<str>>(strs: &[S]) -> StringSpace {
        StringSpace { strings: strs.iter().map(|s| s.as_ref().as_bytes().to_vec()).collect() }
    }

    pub fn string(&self, i: u32) -> &[u8] {
        &self.strings[i as usize]
    }
}

/// Classic two-row DP Levenshtein; O(|a|*|b|) time, O(min) space.
pub fn levenshtein(a: &[u8], b: &[u8]) -> usize {
    let (a, b) = if a.len() < b.len() { (a, b) } else { (b, a) };
    if a.is_empty() {
        return b.len();
    }
    let mut prev: Vec<usize> = (0..=a.len()).collect();
    let mut cur = vec![0usize; a.len() + 1];
    for (j, &bc) in b.iter().enumerate() {
        cur[0] = j + 1;
        for (i, &ac) in a.iter().enumerate() {
            let sub = prev[i] + usize::from(ac != bc);
            cur[i + 1] = sub.min(prev[i + 1] + 1).min(cur[i] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[a.len()]
}

impl MetricSpace for StringSpace {
    fn n_points(&self) -> usize {
        self.strings.len()
    }

    fn dist(&self, i: u32, j: u32) -> f64 {
        if i == j {
            return 0.0;
        }
        levenshtein(&self.strings[i as usize], &self.strings[j as usize]) as f64
    }

    fn name(&self) -> &'static str {
        "levenshtein"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(levenshtein(b"kitten", b"sitting"), 3);
        assert_eq!(levenshtein(b"", b"abc"), 3);
        assert_eq!(levenshtein(b"abc", b""), 3);
        assert_eq!(levenshtein(b"abc", b"abc"), 0);
        assert_eq!(levenshtein(b"flaw", b"lawn"), 2);
    }

    #[test]
    fn symmetric() {
        assert_eq!(levenshtein(b"abcdef", b"azced"), levenshtein(b"azced", b"abcdef"));
    }

    #[test]
    fn triangle_inequality_sample() {
        let words: Vec<&[u8]> = vec![b"cluster", b"clusters", b"custard", b"mustard", b"cloister"];
        let s = StringSpace::new(words.iter().map(|w| w.to_vec()).collect());
        let n = s.n_points() as u32;
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    assert!(s.dist(i, k) <= s.dist(i, j) + s.dist(j, k));
                }
            }
        }
    }

    #[test]
    fn space_wiring() {
        let s = StringSpace::from_strs(&["abc", "abd"]);
        assert_eq!(s.n_points(), 2);
        assert_eq!(s.dist(0, 1), 1.0);
        assert_eq!(s.name(), "levenshtein");
    }
}
