//! Levenshtein (edit-distance) metric over byte strings — the
//! genuinely-non-Euclidean space exercising the paper's "general metric
//! spaces" claim end to end.
//!
//! Two backends, selected like the dense kernels at construction:
//!
//! - **scalar** — the classic two-row DP ([`levenshtein`]), kept as the
//!   correctness reference;
//! - **bitparallel** (default for every non-`scalar`
//!   [`KernelKind`]) — Myers' bit-parallel algorithm
//!   ([`myers`], Hyyrö's formulation) when the shorter string fits a
//!   64-bit word (one `u64` of bit ops per text character instead of a
//!   DP row), plus a **banded** DP ([`levenshtein_banded`]) on the
//!   pruned path that uses the caller's cutoff to bound the band to
//!   `2k+1` diagonals and abandons a pair as soon as a whole row
//!   exceeds `k`.
//!
//! Both backends produce exact integer distances, so
//! `uniform_precision()` stays `true` either way and every value the
//! space returns is bit-identical across backends — except that the
//! banded pruned path may report a pair whose exact distance provably
//! exceeds the cutoff as `f64::INFINITY` (band overflow), the sentinel
//! the [`MetricSpace::dist_batch_pruned`] contract reserves for decided
//! comparisons. Charging is backend-invariant: every non-caller-skipped
//! pair charges 1 whether it ran the full DP, the bit-parallel scan, or
//! an abandoned band, so `dist_evals` never depends on the kernel.

use super::kernel::KernelKind;
use super::{counter, MetricSpace};

/// A set of byte strings with edit distance.
pub struct StringSpace {
    strings: Vec<Vec<u8>>,
    /// Use Myers bit-parallel + banded pruning (any non-`scalar` kind).
    bitparallel: bool,
}

impl StringSpace {
    pub fn new(strings: Vec<Vec<u8>>) -> StringSpace {
        StringSpace::with_kernel(strings, KernelKind::resolve(None))
    }

    /// Construct with an explicit kernel backend (bypasses the
    /// `MRCORESET_KERNEL` environment resolution). `scalar` pins the
    /// two-row DP everywhere; every other kind enables the
    /// bit-parallel/banded fast paths.
    pub fn with_kernel(strings: Vec<Vec<u8>>, kind: KernelKind) -> StringSpace {
        StringSpace { strings, bitparallel: kind != KernelKind::Scalar }
    }

    pub fn from_strs<S: AsRef<str>>(strs: &[S]) -> StringSpace {
        StringSpace::new(strs.iter().map(|s| s.as_ref().as_bytes().to_vec()).collect())
    }

    pub fn string(&self, i: u32) -> &[u8] {
        &self.strings[i as usize]
    }

    /// One pair on the configured backend: Myers when the shorter side
    /// fits a word, DP otherwise (and always on the scalar backend).
    fn edit_dist(&self, a: &[u8], b: &[u8], prev: &mut Vec<usize>, cur: &mut Vec<usize>) -> usize {
        if self.bitparallel {
            let (p, t) = if a.len() <= b.len() { (a, b) } else { (b, a) };
            if !p.is_empty() && p.len() <= 64 {
                return myers(p, t);
            }
        }
        levenshtein_with(a, b, prev, cur)
    }
}

/// Classic two-row DP Levenshtein; O(|a|*|b|) time, O(min) space.
/// The scalar correctness reference for both fast paths.
pub fn levenshtein(a: &[u8], b: &[u8]) -> usize {
    let (a, b) = if a.len() < b.len() { (a, b) } else { (b, a) };
    if a.is_empty() {
        return b.len();
    }
    let mut prev: Vec<usize> = (0..=a.len()).collect();
    let mut cur = vec![0usize; a.len() + 1];
    for (j, &bc) in b.iter().enumerate() {
        cur[0] = j + 1;
        for (i, &ac) in a.iter().enumerate() {
            let sub = prev[i] + usize::from(ac != bc);
            cur[i + 1] = sub.min(prev[i + 1] + 1).min(cur[i] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[a.len()]
}

/// Myers' bit-parallel Levenshtein (Hyyrö's formulation): the whole DP
/// column lives in two `u64` delta vectors, one word of bit ops per
/// text character. Requires `1 <= pattern.len() <= 64`.
pub fn myers(pattern: &[u8], text: &[u8]) -> usize {
    debug_assert!((1..=64).contains(&pattern.len()));
    let mut peq = [0u64; 256];
    for (i, &pc) in pattern.iter().enumerate() {
        peq[pc as usize] |= 1u64 << i;
    }
    myers_with(&peq, pattern.len(), text)
}

/// Myers inner loop over a prebuilt match-vector table (`peq[ch]` has
/// bit `i` set iff `pattern[i] == ch`) — shared so a batch against one
/// center builds the table once.
fn myers_with(peq: &[u64; 256], m: usize, text: &[u8]) -> usize {
    debug_assert!((1..=64).contains(&m));
    let mut pv = !0u64;
    let mut mv = 0u64;
    let mut score = m;
    let hibit = 1u64 << (m - 1);
    for &tc in text {
        let eq = peq[tc as usize];
        let xv = eq | mv;
        let xh = (((eq & pv).wrapping_add(pv)) ^ pv) | eq;
        let mut ph = mv | !(xh | pv);
        let mut mh = pv & xh;
        if ph & hibit != 0 {
            score += 1;
        }
        if mh & hibit != 0 {
            score -= 1;
        }
        ph = (ph << 1) | 1;
        mh <<= 1;
        pv = mh | !(xv | ph);
        mv = ph & xv;
    }
    score
}

/// Banded Levenshtein with cutoff `k`: only the `2k+1` diagonals that
/// can hold a value `<= k` are evaluated, and the pair is abandoned as
/// soon as a whole row exceeds `k`. Returns `Some(d)` with the exact
/// distance iff `d <= k`, `None` iff the exact distance exceeds `k`.
/// O(k·min(|a|,|b|)) time. Callers must ensure `k < max(|a|,|b|)`
/// (a wider band is the full table — use the plain DP).
pub fn levenshtein_banded(a: &[u8], b: &[u8], k: usize) -> Option<usize> {
    levenshtein_banded_with(a, b, k, &mut Vec::new(), &mut Vec::new())
}

/// [`levenshtein_banded`] reusing caller-provided row buffers (the
/// batched pruned inner loop).
fn levenshtein_banded_with(
    a: &[u8],
    b: &[u8],
    k: usize,
    prev: &mut Vec<usize>,
    cur: &mut Vec<usize>,
) -> Option<usize> {
    let (a, b) = if a.len() < b.len() { (a, b) } else { (b, a) };
    let (m, n) = (a.len(), b.len());
    if n - m > k {
        // the length difference alone exceeds the cutoff
        return None;
    }
    // `big` caps every out-of-band cell; values are clamped to it so the
    // early-abandon test (`best > k`) is a plain compare. Callers keep
    // k < n, so this cannot overflow.
    let big = k + 1;
    prev.clear();
    prev.extend((0..=m).map(|i| if i <= k { i } else { big }));
    cur.clear();
    cur.resize(m + 1, big);
    for (jm1, &bc) in b.iter().enumerate() {
        let j = jm1 + 1;
        let lo = j.saturating_sub(k).max(1);
        let hi = (j + k).min(m);
        let mut best = big;
        if lo == 1 {
            // boundary column: in-band iff j <= k
            cur[0] = if j <= k { j } else { big };
            best = best.min(cur[0]);
        } else {
            // left edge of the band: neutralize the stale cell the
            // i == lo recurrence reads as `cur[lo-1]`
            cur[lo - 1] = big;
        }
        for i in lo..=hi {
            let cost = usize::from(a[i - 1] != bc);
            let v = (prev[i] + 1).min(cur[i - 1] + 1).min(prev[i - 1] + cost).min(big);
            cur[i] = v;
            if v < best {
                best = v;
            }
        }
        // right edge: the next row's i == hi+1 recurrence reads
        // `prev[hi+1]`, which would otherwise be a stale cell from two
        // rows back once the band has slid past it
        if hi + 1 <= m {
            cur[hi + 1] = big;
        }
        if best > k {
            // every extension of this row only grows: abandon
            return None;
        }
        std::mem::swap(prev, cur);
    }
    if prev[m] <= k {
        Some(prev[m])
    } else {
        None
    }
}

impl MetricSpace for StringSpace {
    fn n_points(&self) -> usize {
        self.strings.len()
    }

    fn dist(&self, i: u32, j: u32) -> f64 {
        counter::charge(1);
        if i == j {
            return 0.0;
        }
        let (mut prev, mut cur) = (Vec::new(), Vec::new());
        self.edit_dist(&self.strings[i as usize], &self.strings[j as usize], &mut prev, &mut cur)
            as f64
    }

    fn name(&self) -> &'static str {
        "levenshtein"
    }

    fn kernel_name(&self) -> &'static str {
        if self.bitparallel {
            "bitparallel"
        } else {
            "scalar"
        }
    }

    /// Batched edit distances against one string: the Myers match-vector
    /// table (or the DP rows on the scalar backend) is built once per
    /// batch, and the virtual dispatch happens per center instead of
    /// per pair.
    fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
        assert_eq!(pts.len(), out.len());
        counter::charge(pts.len());
        let cs = &self.strings[c as usize];
        let mut prev: Vec<usize> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let cpeq = if self.bitparallel && !cs.is_empty() && cs.len() <= 64 {
            let mut peq = [0u64; 256];
            for (i, &pc) in cs.iter().enumerate() {
                peq[pc as usize] |= 1u64 << i;
            }
            Some(peq)
        } else {
            None
        };
        for (o, &p) in out.iter_mut().zip(pts) {
            if p == c {
                *o = 0.0;
                continue;
            }
            let s = &self.strings[p as usize];
            *o = match &cpeq {
                Some(peq) => myers_with(peq, cs.len(), s) as f64,
                None => self.edit_dist(s, cs, &mut prev, &mut cur) as f64,
            };
        }
    }

    /// Geometry-pruned batch. A caller-skipped pair (lower bound beyond
    /// the cutoff) costs nothing and charges nothing, as everywhere.
    /// On the bit-parallel backend every *computed* pair additionally
    /// runs banded with `k = floor(cutoff)`: `O(k·min)` instead of the
    /// full table, with band overflow reported as the `INFINITY`
    /// sentinel (exact distance provably `> cutoff` — integer distances
    /// make `> floor(cutoff)` and `> cutoff` the same decision). Every
    /// non-caller-skipped pair still charges 1, so `dist_evals` is
    /// identical across backends; the time saved per eval is what the
    /// band buys.
    fn dist_batch_pruned(
        &self,
        pts: &[u32],
        c: u32,
        lower: &[f64],
        cutoff: &[f64],
        out: &mut [f64],
    ) -> usize {
        assert_eq!(pts.len(), lower.len());
        assert_eq!(pts.len(), cutoff.len());
        assert_eq!(pts.len(), out.len());
        let cs = &self.strings[c as usize];
        let mut prev: Vec<usize> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut computed = 0usize;
        for i in 0..pts.len() {
            if lower[i] > cutoff[i] {
                out[i] = f64::INFINITY;
                continue;
            }
            computed += 1;
            if pts[i] == c {
                out[i] = 0.0;
                continue;
            }
            let s = &self.strings[pts[i] as usize];
            if self.bitparallel {
                let maxlen = s.len().max(cs.len());
                let cut = cutoff[i];
                let band = if cut.is_finite() { cut.max(0.0).floor() as usize } else { usize::MAX };
                if band < maxlen {
                    out[i] = match levenshtein_banded_with(s, cs, band, &mut prev, &mut cur) {
                        Some(v) => v as f64,
                        None => f64::INFINITY,
                    };
                    continue;
                }
                // band covers the whole table: the plain fast path wins
            }
            out[i] = self.edit_dist(s, cs, &mut prev, &mut cur) as f64;
        }
        counter::charge(computed);
        computed
    }
}

/// Levenshtein DP reusing caller-provided row buffers (the batched inner
/// loop). Same recurrence as [`levenshtein`], which remains the scalar
/// reference.
fn levenshtein_with(a: &[u8], b: &[u8], prev: &mut Vec<usize>, cur: &mut Vec<usize>) -> usize {
    let (a, b) = if a.len() < b.len() { (a, b) } else { (b, a) };
    if a.is_empty() {
        return b.len();
    }
    prev.clear();
    prev.extend(0..=a.len());
    cur.clear();
    cur.resize(a.len() + 1, 0);
    for (j, &bc) in b.iter().enumerate() {
        cur[0] = j + 1;
        for (i, &ac) in a.iter().enumerate() {
            let sub = prev[i] + usize::from(ac != bc);
            cur[i + 1] = sub.min(prev[i + 1] + 1).min(cur[i] + 1);
        }
        std::mem::swap(prev, cur);
    }
    prev[a.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic string generator (LCG over a 4-letter alphabet —
    /// small alphabets maximize match-vector collisions).
    fn gen_string(state: &mut u64, max_len: usize) -> Vec<u8> {
        let mut next = || {
            *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (*state >> 33) as usize
        };
        let len = next() % (max_len + 1);
        (0..len).map(|_| b"abcd"[next() % 4]).collect()
    }

    #[test]
    fn known_values() {
        assert_eq!(levenshtein(b"kitten", b"sitting"), 3);
        assert_eq!(levenshtein(b"", b"abc"), 3);
        assert_eq!(levenshtein(b"abc", b""), 3);
        assert_eq!(levenshtein(b"abc", b"abc"), 0);
        assert_eq!(levenshtein(b"flaw", b"lawn"), 2);
    }

    #[test]
    fn symmetric() {
        assert_eq!(levenshtein(b"abcdef", b"azced"), levenshtein(b"azced", b"abcdef"));
    }

    #[test]
    fn myers_matches_dp() {
        assert_eq!(myers(b"kitten", b"sitting"), 3);
        let mut state = 0x9e3779b97f4a7c15u64;
        for trial in 0..300 {
            let a = gen_string(&mut state, 64);
            let b = gen_string(&mut state, 90);
            if a.is_empty() {
                continue;
            }
            assert_eq!(myers(&a, &b), levenshtein(&a, &b), "trial={trial}");
        }
        // full-word pattern (m == 64): the high-bit masks are exercised
        let a = vec![b'a'; 64];
        let b: Vec<u8> = (0..100).map(|i| if i % 3 == 0 { b'a' } else { b'b' }).collect();
        assert_eq!(myers(&a, &b), levenshtein(&a, &b));
    }

    #[test]
    fn banded_matches_dp_including_sentinel() {
        let words: &[&[u8]] =
            &[b"cluster", b"clusters", b"custard", b"mustard", b"cloister", b"", b"x"];
        for &a in words {
            for &b in words {
                let exact = levenshtein(a, b);
                let maxlen = a.len().max(b.len());
                for k in 0..maxlen {
                    let got = levenshtein_banded(a, b, k);
                    let want = if exact <= k { Some(exact) } else { None };
                    assert_eq!(got, want, "a={a:?} b={b:?} k={k}");
                }
            }
        }
        let mut state = 0x243f6a8885a308d3u64;
        for trial in 0..300 {
            let a = gen_string(&mut state, 40);
            let b = gen_string(&mut state, 40);
            let exact = levenshtein(&a, &b);
            let maxlen = a.len().max(b.len());
            for k in [0, 1, 2, exact.saturating_sub(1), exact, exact + 1] {
                if k >= maxlen {
                    continue;
                }
                let got = levenshtein_banded(&a, &b, k);
                let want = if exact <= k { Some(exact) } else { None };
                assert_eq!(got, want, "trial={trial} k={k}");
            }
        }
    }

    #[test]
    fn backends_agree_on_every_pair() {
        let mut state = 0x452821e638d01377u64;
        let strings: Vec<Vec<u8>> = (0..20).map(|_| gen_string(&mut state, 80)).collect();
        let scalar = StringSpace::with_kernel(strings.clone(), KernelKind::Scalar);
        let fast = StringSpace::with_kernel(strings, KernelKind::Auto);
        assert_eq!(scalar.kernel_name(), "scalar");
        assert_eq!(fast.kernel_name(), "bitparallel");
        let pts: Vec<u32> = (0..20).collect();
        let mut a = vec![0.0f64; 20];
        let mut b = vec![0.0f64; 20];
        for c in 0..20u32 {
            scalar.dist_batch(&pts, c, &mut a);
            fast.dist_batch(&pts, c, &mut b);
            for i in 0..20 {
                assert_eq!(a[i].to_bits(), b[i].to_bits(), "c={c} i={i}");
                assert_eq!(fast.dist(pts[i], c), a[i], "c={c} i={i}");
            }
        }
    }

    #[test]
    fn triangle_inequality_sample() {
        let words: Vec<&[u8]> = vec![b"cluster", b"clusters", b"custard", b"mustard", b"cloister"];
        let s = StringSpace::new(words.iter().map(|w| w.to_vec()).collect());
        let n = s.n_points() as u32;
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    assert!(s.dist(i, k) <= s.dist(i, j) + s.dist(j, k));
                }
            }
        }
    }

    #[test]
    fn space_wiring() {
        let s = StringSpace::from_strs(&["abc", "abd"]);
        assert_eq!(s.n_points(), 2);
        assert_eq!(s.dist(0, 1), 1.0);
        assert_eq!(s.name(), "levenshtein");
    }

    #[test]
    fn dist_batch_matches_scalar_dp() {
        let s = StringSpace::from_strs(&["cluster", "clusters", "custard", "", "cloister"]);
        let pts: Vec<u32> = (0..5).collect();
        let mut out = vec![0.0f64; 5];
        for c in 0..5u32 {
            s.dist_batch(&pts, c, &mut out);
            for (i, &p) in pts.iter().enumerate() {
                assert_eq!(out[i], s.dist(p, c), "p={p} c={c}");
            }
        }
    }

    #[test]
    fn pruned_batch_banded_decides_like_reference() {
        use super::super::counter;
        let strs = ["cluster", "clusters", "custard", "mustard", "cloister", ""];
        for kind in [KernelKind::Scalar, KernelKind::Auto] {
            let s = StringSpace::with_kernel(
                strs.iter().map(|w| w.as_bytes().to_vec()).collect(),
                kind,
            );
            let pts: Vec<u32> = (0..strs.len() as u32).collect();
            for c in pts.clone() {
                let lower: Vec<f64> =
                    pts.iter().map(|&p| (s.dist(p, 0) - s.dist(c, 0)).abs()).collect();
                let mut reference = vec![0.0f64; pts.len()];
                s.dist_batch(&pts, c, &mut reference);
                for cut in [0.0f64, 1.5, 3.0, 100.0, f64::INFINITY] {
                    let cutoff = vec![cut; pts.len()];
                    let mut out = vec![0.0f64; pts.len()];
                    let (computed, evals) = counter::counted(|| {
                        s.dist_batch_pruned(&pts, c, &lower, &cutoff, &mut out)
                    });
                    assert_eq!(computed as u64, evals);
                    // charging is backend-invariant: every pair the
                    // caller's bound did not skip charges, banded or not
                    let expect = lower.iter().filter(|&&l| l <= cut).count();
                    assert_eq!(computed, expect, "kind={kind:?} c={c} cut={cut}");
                    for i in 0..pts.len() {
                        // sentinel or value, the cutoff decision matches
                        assert_eq!(
                            out[i] <= cut,
                            reference[i] <= cut,
                            "kind={kind:?} c={c} i={i} cut={cut}"
                        );
                        if out[i].is_finite() {
                            assert_eq!(out[i].to_bits(), reference[i].to_bits());
                        }
                    }
                }
            }
        }
    }
}
