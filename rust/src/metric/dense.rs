//! Dense vector metrics over row-major f32 storage: Euclidean (with the
//! optional XLA/Pallas fast path), Manhattan (L1), and Chebyshev (L∞).

use std::sync::Arc;

use crate::points::{SharedVectors, VectorData};

use super::{counter, Assignment, MetricSpace};

/// Batched distance backend contract, implemented by `runtime::XlaEngine`
/// over the AOT HLO artifacts. Distances here are SQUARED Euclidean (that
/// is what the kernels emit); callers take sqrt.
pub trait BulkEngine: Send + Sync {
    /// x: (n, d) row-major points block; c: (k, d) centers block.
    /// Returns per-row (min squared distance, argmin position).
    fn assign_block(&self, x: &VectorData, c: &VectorData) -> anyhow::Result<(Vec<f32>, Vec<i32>)>;

    /// Fold a single center (1, d) into `cur` (squared distances).
    fn min_update_block(&self, x: &VectorData, c: &VectorData, cur: &mut [f32])
        -> anyhow::Result<()>;

    /// Smallest problem (pts.len() * centers.len()) worth dispatching.
    /// Perf pass measurement (EXPERIMENTS.md §Perf): on this CPU testbed
    /// the tiled scalar scan (431 Mpairs/s) beats both the
    /// interpret-mode Pallas HLO (36 Mpairs/s) and a pure-jnp XLA
    /// lowering (~100 Mpairs/s) at clustering dimensionalities, so the
    /// default never auto-dispatches; the engine path remains for real
    /// accelerator backends and is exercised by tests via
    /// `set_dispatch_threshold`.
    fn dispatch_threshold(&self) -> usize {
        usize::MAX
    }
}

/// Euclidean (L2) metric. `engine` optionally routes the bulk queries
/// (`nearest_batch`/`dist_batch`/`min_update`) through the PJRT-compiled
/// kernels for large blocks; the scalar path is always available and is
/// the correctness reference (tests compare them).
pub struct EuclideanSpace {
    data: SharedVectors,
    engine: Option<Arc<dyn BulkEngine>>,
}

impl EuclideanSpace {
    pub fn new(data: SharedVectors) -> EuclideanSpace {
        EuclideanSpace { data, engine: None }
    }

    pub fn with_engine(data: SharedVectors, engine: Arc<dyn BulkEngine>) -> EuclideanSpace {
        EuclideanSpace { data, engine: Some(engine) }
    }

    pub fn set_engine(&mut self, engine: Option<Arc<dyn BulkEngine>>) {
        self.engine = engine;
    }

    pub fn data(&self) -> &SharedVectors {
        &self.data
    }

    pub fn has_engine(&self) -> bool {
        self.engine.is_some()
    }

    #[inline]
    fn sq_dist(&self, i: u32, j: u32) -> f64 {
        sq_euclidean(self.data.row(i), self.data.row(j))
    }
}

#[inline]
pub fn sq_euclidean(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let diff = (*x - *y) as f64;
        acc += diff * diff;
    }
    acc
}

impl MetricSpace for EuclideanSpace {
    fn n_points(&self) -> usize {
        self.data.n()
    }

    #[inline]
    fn dist(&self, i: u32, j: u32) -> f64 {
        counter::charge(1);
        self.sq_dist(i, j).sqrt()
    }

    fn name(&self) -> &'static str {
        "euclidean"
    }

    /// Bulk distances to one stored point. The CPU path is f64 all the
    /// way and is the correctness reference the tiled scan is checked
    /// against (the batch-equivalence property tests pin it to scalar
    /// `dist` at 1e-12). Engine-dispatched blocks route through the
    /// min_update kernel with an infinite running minimum and, like the
    /// engine branch of `nearest_batch`, return f32-precision distances
    /// — the documented engine numerics (see runtime tests' tolerances).
    fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
        assert_eq!(pts.len(), out.len());
        counter::charge(pts.len());
        if let Some(engine) = &self.engine {
            if pts.len() >= engine.dispatch_threshold() {
                let x = self.data.gather(pts);
                let cb = self.data.gather(&[c]);
                let mut cur = vec![f32::INFINITY; pts.len()];
                if engine.min_update_block(&x, &cb, &mut cur).is_ok() {
                    for (o, s) in out.iter_mut().zip(&cur) {
                        *o = (*s as f64).max(0.0).sqrt();
                    }
                    return;
                }
            }
        }
        let crow = self.data.row(c);
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = sq_euclidean(self.data.row(p), crow).sqrt();
        }
    }

    /// With an engine attached, bulk queries may return f32-precision
    /// distances for large blocks while small blocks stay f64 — bounds
    /// built from such mixed output are unsound, so pruned callers must
    /// not trust them (they fall back to computing every comparison).
    fn uniform_precision(&self) -> bool {
        self.engine.is_none()
    }

    /// Geometry-pruned bulk distances: pairs whose caller-supplied lower
    /// bound exceeds the cutoff are skipped entirely (no coordinates
    /// touched, no counter charge); computed entries go through the same
    /// f64 `sq_euclidean(..).sqrt()` expression as the scalar `dist_batch`
    /// path, so they are bit-identical to it. This path never dispatches
    /// to the engine: the pruned survivor set is sparse and irregular,
    /// which is exactly where kernel dispatch overhead loses.
    fn dist_batch_pruned(
        &self,
        pts: &[u32],
        c: u32,
        lower: &[f64],
        cutoff: &[f64],
        out: &mut [f64],
    ) -> usize {
        assert_eq!(pts.len(), lower.len());
        assert_eq!(pts.len(), cutoff.len());
        assert_eq!(pts.len(), out.len());
        let crow = self.data.row(c);
        let mut computed = 0usize;
        for i in 0..pts.len() {
            if lower[i] > cutoff[i] {
                out[i] = f64::INFINITY;
            } else {
                out[i] = sq_euclidean(self.data.row(pts[i]), crow).sqrt();
                computed += 1;
            }
        }
        counter::charge(computed);
        computed
    }

    fn nearest_batch(&self, pts: &[u32], centers: &[u32]) -> Assignment {
        assert!(!centers.is_empty(), "nearest_batch: empty center set");
        counter::charge(pts.len() * centers.len());
        if let Some(engine) = &self.engine {
            if pts.len() * centers.len() >= engine.dispatch_threshold() {
                let x = self.data.gather(pts);
                let c = self.data.gather(centers);
                match engine.assign_block(&x, &c) {
                    Ok((d2, idx)) => {
                        return Assignment {
                            dist: d2.iter().map(|&v| (v as f64).max(0.0).sqrt()).collect(),
                            idx: idx.iter().map(|&v| v as u32).collect(),
                        };
                    }
                    Err(e) => {
                        // Fall back to the scalar path; the engine logs once.
                        crate::obs::log::warn(&format!(
                            "engine assign failed ({e}); using scalar path"
                        ));
                    }
                }
            }
        }
        scalar_assign(&self.data, pts, centers)
    }

    fn min_update(&self, pts: &[u32], c: u32, cur: &mut [f64]) {
        assert_eq!(pts.len(), cur.len());
        counter::charge(pts.len());
        if let Some(engine) = &self.engine {
            // a single-center pass does pts.len() distance evals; the PJRT
            // dispatch overhead only amortizes on large blocks
            if pts.len() >= engine.dispatch_threshold() {
                let x = self.data.gather(pts);
                let cb = self.data.gather(&[c]);
                // engine works on squared distances
                let mut cur_sq: Vec<f32> = cur.iter().map(|&d| (d * d) as f32).collect();
                if engine.min_update_block(&x, &cb, &mut cur_sq).is_ok() {
                    for (o, s) in cur.iter_mut().zip(&cur_sq) {
                        *o = (*s as f64).max(0.0).sqrt();
                    }
                    return;
                }
            }
        }
        let crow = self.data.row(c);
        for (i, &p) in pts.iter().enumerate() {
            let cut = (cur[i] * cur[i]) as f32;
            let dd = sq_dist_f32(self.data.row(p), crow, cut);
            if dd < cut {
                // recompute the accepted winner in f64 (same contract as
                // scalar_assign)
                cur[i] = sq_euclidean(self.data.row(p), crow).sqrt();
            }
        }
    }
}

/// Cache-tiled nearest-center scan. Centers are staged once into a
/// contiguous block and processed in L1-sized tiles against point tiles,
/// with a d-specialized squared-distance kernel (f32 accumulation inside
/// a tile is safe: distances are compared, not summed). ~2-3x over the
/// naive per-point scan at clustering-typical d (see EXPERIMENTS.md §Perf).
fn scalar_assign(data: &VectorData, pts: &[u32], centers: &[u32]) -> Assignment {
    let d = data.d();
    let n = pts.len();
    // stage centers contiguously (they are re-streamed n/TILE_P times)
    let cblock = data.gather(centers);
    let craw = cblock.raw();
    let mut dist = vec![f32::INFINITY; n];
    let mut idx = vec![0u32; n];
    const TILE_P: usize = 64;
    const TILE_C: usize = 512;
    let mut prow_cache: Vec<&[f32]> = Vec::with_capacity(TILE_P);
    for p0 in (0..n).step_by(TILE_P) {
        let p1 = (p0 + TILE_P).min(n);
        prow_cache.clear();
        prow_cache.extend(pts[p0..p1].iter().map(|&p| data.row(p)));
        for c0 in (0..centers.len()).step_by(TILE_C) {
            let c1 = (c0 + TILE_C).min(centers.len());
            for (pi, prow) in prow_cache.iter().enumerate() {
                let (mut best, mut best_j) = (dist[p0 + pi], idx[p0 + pi]);
                for j in c0..c1 {
                    let crow = &craw[j * d..(j + 1) * d];
                    let dd = sq_dist_f32(prow, crow, best);
                    if dd < best {
                        best = dd;
                        best_j = j as u32;
                    }
                }
                dist[p0 + pi] = best;
                idx[p0 + pi] = best_j;
            }
        }
    }
    // recompute winners in f64: the scan used f32 for speed, the output
    // contract stays at f64 accuracy (argmin ties within f32 noise are
    // documented and harmless to every caller)
    let dist64: Vec<f64> = pts
        .iter()
        .zip(&idx)
        .map(|(&p, &j)| {
            sq_euclidean(data.row(p), &craw[j as usize * d..(j as usize + 1) * d]).sqrt()
        })
        .collect();
    Assignment { dist: dist64, idx }
}

/// f32 squared distance with small-d specialization and early exit
/// against the running best (`cut`).
#[inline(always)]
fn sq_dist_f32(a: &[f32], b: &[f32], cut: f32) -> f32 {
    match a.len() {
        1 => {
            let d0 = a[0] - b[0];
            d0 * d0
        }
        2 => {
            let d0 = a[0] - b[0];
            let d1 = a[1] - b[1];
            d0 * d0 + d1 * d1
        }
        3 => {
            let d0 = a[0] - b[0];
            let d1 = a[1] - b[1];
            let d2 = a[2] - b[2];
            d0 * d0 + d1 * d1 + d2 * d2
        }
        4 => {
            let d0 = a[0] - b[0];
            let d1 = a[1] - b[1];
            let d2 = a[2] - b[2];
            let d3 = a[3] - b[3];
            (d0 * d0 + d1 * d1) + (d2 * d2 + d3 * d3)
        }
        _ => {
            // chunks of 4 keep the compiler vectorizing; early exit every
            // 16 dims bounds wasted work on far centers in high d
            let mut acc = 0.0f32;
            let mut chunks = a.chunks_exact(4).zip(b.chunks_exact(4));
            let mut i = 0;
            for (ca, cb) in &mut chunks {
                let d0 = ca[0] - cb[0];
                let d1 = ca[1] - cb[1];
                let d2 = ca[2] - cb[2];
                let d3 = ca[3] - cb[3];
                acc += (d0 * d0 + d1 * d1) + (d2 * d2 + d3 * d3);
                i += 4;
                if i % 16 == 0 && acc >= cut {
                    return acc;
                }
            }
            for k in (a.len() - a.len() % 4)..a.len() {
                let dk = a[k] - b[k];
                acc += dk * dk;
            }
            acc
        }
    }
}

macro_rules! vector_space {
    ($name:ident, $metric_name:literal, $dist_fn:expr) => {
        pub struct $name {
            data: SharedVectors,
        }

        impl $name {
            pub fn new(data: SharedVectors) -> $name {
                $name { data }
            }

            pub fn data(&self) -> &SharedVectors {
                &self.data
            }
        }

        impl MetricSpace for $name {
            fn n_points(&self) -> usize {
                self.data.n()
            }

            #[inline]
            fn dist(&self, i: u32, j: u32) -> f64 {
                counter::charge(1);
                let f: fn(&[f32], &[f32]) -> f64 = $dist_fn;
                f(self.data.row(i), self.data.row(j))
            }

            /// Batched: stage the center row once, stream the points.
            fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
                assert_eq!(pts.len(), out.len());
                counter::charge(pts.len());
                let f: fn(&[f32], &[f32]) -> f64 = $dist_fn;
                let crow = self.data.row(c);
                for (o, &p) in out.iter_mut().zip(pts) {
                    *o = f(self.data.row(p), crow);
                }
            }

            /// Geometry-pruned batch: skip (and do not charge) pairs the
            /// caller's lower bound already decides; computed entries use
            /// the same distance expression as `dist_batch`.
            fn dist_batch_pruned(
                &self,
                pts: &[u32],
                c: u32,
                lower: &[f64],
                cutoff: &[f64],
                out: &mut [f64],
            ) -> usize {
                assert_eq!(pts.len(), lower.len());
                assert_eq!(pts.len(), cutoff.len());
                assert_eq!(pts.len(), out.len());
                let f: fn(&[f32], &[f32]) -> f64 = $dist_fn;
                let crow = self.data.row(c);
                let mut computed = 0usize;
                for i in 0..pts.len() {
                    if lower[i] > cutoff[i] {
                        out[i] = f64::INFINITY;
                    } else {
                        out[i] = f(self.data.row(pts[i]), crow);
                        computed += 1;
                    }
                }
                counter::charge(computed);
                computed
            }

            fn name(&self) -> &'static str {
                $metric_name
            }
        }
    };
}

#[inline]
pub fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((*x - *y) as f64).abs()).sum()
}

#[inline]
pub fn chebyshev(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((*x - *y) as f64).abs()).fold(0.0, f64::max)
}

vector_space!(ManhattanSpace, "manhattan", manhattan);
vector_space!(ChebyshevSpace, "chebyshev", chebyshev);

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> SharedVectors {
        Arc::new(VectorData::from_rows(&[
            vec![0.0, 0.0],
            vec![3.0, 4.0],
            vec![1.0, 1.0],
            vec![-2.0, 0.5],
        ]))
    }

    #[test]
    fn euclidean_known_distances() {
        let s = EuclideanSpace::new(data());
        assert!((s.dist(0, 1) - 5.0).abs() < 1e-9);
        assert_eq!(s.dist(2, 2), 0.0);
        assert!((s.dist(0, 2) - 2f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn metric_axioms_on_sample() {
        for s in [
            &EuclideanSpace::new(data()) as &dyn MetricSpace,
            &ManhattanSpace::new(data()),
            &ChebyshevSpace::new(data()),
        ] {
            let n = s.n_points() as u32;
            for i in 0..n {
                assert_eq!(s.dist(i, i), 0.0);
                for j in 0..n {
                    assert!((s.dist(i, j) - s.dist(j, i)).abs() < 1e-12);
                    for k in 0..n {
                        assert!(s.dist(i, k) <= s.dist(i, j) + s.dist(j, k) + 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn manhattan_chebyshev_values() {
        let m = ManhattanSpace::new(data());
        let c = ChebyshevSpace::new(data());
        assert!((m.dist(0, 1) - 7.0).abs() < 1e-9);
        assert!((c.dist(0, 1) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn pruned_batch_exact_and_honestly_charged_all_spaces() {
        use super::super::counter;
        let d = data();
        let pts: Vec<u32> = (0..4).collect();
        for s in [
            &EuclideanSpace::new(d.clone()) as &dyn MetricSpace,
            &ManhattanSpace::new(d.clone()),
            &ChebyshevSpace::new(d.clone()),
        ] {
            for c in 0..4u32 {
                // triangle-inequality lower bounds via reference point 0:
                // d(p, c) >= |d(p, 0) - d(c, 0)|
                let lower: Vec<f64> =
                    pts.iter().map(|&p| (s.dist(p, 0) - s.dist(c, 0)).abs()).collect();
                let mut reference = vec![0.0f64; 4];
                s.dist_batch(&pts, c, &mut reference);
                for cut in [0.0f64, 1.0, 2.5, 100.0] {
                    let cutoff = vec![cut; 4];
                    let mut out = vec![0.0f64; 4];
                    let (computed, evals) = counter::counted(|| {
                        s.dist_batch_pruned(&pts, c, &lower, &cutoff, &mut out)
                    });
                    assert_eq!(computed as u64, evals, "{} c={c}", s.name());
                    for i in 0..4 {
                        if lower[i] > cut {
                            // pruned: must decide `<= cut` the same way
                            assert!(out[i] > cut && reference[i] > cut);
                        } else {
                            assert_eq!(
                                out[i].to_bits(),
                                reference[i].to_bits(),
                                "{} c={c} i={i}",
                                s.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn l1_ge_l2_ge_linf() {
        let d = data();
        let e = EuclideanSpace::new(d.clone());
        let m = ManhattanSpace::new(d.clone());
        let c = ChebyshevSpace::new(d);
        for i in 0..4u32 {
            for j in 0..4u32 {
                assert!(m.dist(i, j) >= e.dist(i, j) - 1e-12);
                assert!(e.dist(i, j) >= c.dist(i, j) - 1e-12);
            }
        }
    }
}
