//! Dense vector metrics over row-major f32 storage: Euclidean (L2),
//! Manhattan (L1), and Chebyshev (L∞).
//!
//! Bulk queries route through a pluggable [`DistKernel`]
//! backend selected at construction (see [`super::kernel`] for the
//! backend table and the exactness contract). The scalar pairwise
//! `dist` stays on the exact f64 reference path on every backend, so
//! metric axioms and known-distance expectations hold regardless of the
//! configured kernel.

use std::sync::Arc;

use crate::points::{SharedVectors, VectorData};

use super::kernel::{self, DistKernel, KernelKind};
use super::{counter, Assignment, MetricSpace};

/// Default smallest problem size (`pts.len() * centers.len()` pairs)
/// worth dispatching to an attached accelerator engine. Below this the
/// blocked CPU kernel wins on dispatch overhead alone; the measured
/// crossover for real backends lands well under the per-dispatch cost
/// of gather + transfer (see the `euclidean.assign.*` series in
/// `BENCH_micro.json` for the CPU side of the comparison). Overridable
/// per engine via `set_dispatch_threshold`.
pub const DEFAULT_DISPATCH_THRESHOLD: usize = 1 << 15;

/// Batched distance backend contract, implemented by `runtime::XlaEngine`
/// over the AOT HLO artifacts. Distances here are SQUARED Euclidean (that
/// is what the kernels emit); callers take sqrt.
///
/// An attached engine is consumed through
/// [`kernel::EngineKernel`](super::kernel::EngineKernel): blocks of at
/// least [`dispatch_threshold`](BulkEngine::dispatch_threshold) pairs
/// dispatch here, smaller blocks and everything after a dispatch
/// failure take the blocked CPU kernel.
pub trait BulkEngine: Send + Sync {
    /// x: (n, d) row-major points block; c: (k, d) centers block.
    /// Returns per-row (min squared distance, argmin position).
    fn assign_block(&self, x: &VectorData, c: &VectorData) -> anyhow::Result<(Vec<f32>, Vec<i32>)>;

    /// Fold a single center (1, d) into `cur` (squared distances).
    fn min_update_block(&self, x: &VectorData, c: &VectorData, cur: &mut [f32])
        -> anyhow::Result<()>;

    /// Smallest problem (pts.len() * centers.len()) worth dispatching.
    fn dispatch_threshold(&self) -> usize {
        DEFAULT_DISPATCH_THRESHOLD
    }
}

/// Euclidean (L2) metric. Bulk queries go through the configured
/// [`DistKernel`]; an attached [`BulkEngine`] is folded in as the
/// engine kernel when the resolved kind is `auto`. The scalar f64 path
/// is always the correctness reference (tests compare against it).
pub struct EuclideanSpace {
    data: SharedVectors,
    kernel: Arc<dyn DistKernel>,
    /// Requested kind (after env resolution) — kept so `set_engine`
    /// rebuilds the kernel under the same policy.
    kind: KernelKind,
    engine: Option<Arc<dyn BulkEngine>>,
    engine_active: bool,
}

impl EuclideanSpace {
    pub fn new(data: SharedVectors) -> EuclideanSpace {
        EuclideanSpace::with_kernel(data, KernelKind::resolve(None))
    }

    /// Construct with an explicit kernel backend (bypasses the
    /// `MRCORESET_KERNEL` environment resolution).
    pub fn with_kernel(data: SharedVectors, kind: KernelKind) -> EuclideanSpace {
        let (kernel, engine_active) = kernel::build(kind, None);
        EuclideanSpace { data, kernel, kind, engine: None, engine_active }
    }

    pub fn with_engine(data: SharedVectors, engine: Arc<dyn BulkEngine>) -> EuclideanSpace {
        let mut s = EuclideanSpace::new(data);
        s.set_engine(Some(engine));
        s
    }

    pub fn set_engine(&mut self, engine: Option<Arc<dyn BulkEngine>>) {
        let (kernel, engine_active) = kernel::build(self.kind, engine.clone());
        self.kernel = kernel;
        self.engine = engine;
        self.engine_active = engine_active;
    }

    pub fn data(&self) -> &SharedVectors {
        &self.data
    }

    /// Whether an engine is actually in the dispatch path (an explicit
    /// non-auto `--kernel` pins the CPU backend and sidelines any
    /// attached engine).
    pub fn has_engine(&self) -> bool {
        self.engine_active
    }

    #[inline]
    fn sq_dist(&self, i: u32, j: u32) -> f64 {
        sq_euclidean(self.data.row(i), self.data.row(j))
    }
}

#[inline]
pub fn sq_euclidean(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let diff = (*x - *y) as f64;
        acc += diff * diff;
    }
    acc
}

impl MetricSpace for EuclideanSpace {
    fn n_points(&self) -> usize {
        self.data.n()
    }

    #[inline]
    fn dist(&self, i: u32, j: u32) -> f64 {
        counter::charge(1);
        self.sq_dist(i, j).sqrt()
    }

    fn name(&self) -> &'static str {
        "euclidean"
    }

    fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// Bulk distances to one stored point, via the configured kernel.
    /// Exact backends are bit-identical to the scalar `dist` expression;
    /// inexact backends (simd, engine-dispatched blocks) return
    /// f32-precision distances — the documented fast-path numerics.
    fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
        assert_eq!(pts.len(), out.len());
        counter::charge(pts.len());
        self.kernel.l2_dist_batch(&self.data, pts, c, out);
    }

    /// Bounds built from a kernel that mixes precisions across block
    /// sizes (engine) or runs f32 throughout (simd) are unsound, so
    /// pruned callers must not trust them.
    fn uniform_precision(&self) -> bool {
        self.kernel.uniform_precision()
    }

    /// Geometry-pruned bulk distances: pairs whose caller-supplied lower
    /// bound exceeds the cutoff are skipped entirely (no coordinates
    /// touched, no counter charge); computed entries go through the same
    /// f64 `sq_euclidean(..).sqrt()` expression as the exact `dist_batch`
    /// path, so they are bit-identical to it. Under an inexact kernel
    /// the skip test would compare exact-domain bounds against
    /// fast-path values, so this falls back to the plain batch (keeping
    /// pruned and unpruned twins bit-identical per kernel). The skip
    /// loop never dispatches to an engine either way: the pruned
    /// survivor set is sparse and irregular, which is exactly where
    /// kernel dispatch overhead loses.
    fn dist_batch_pruned(
        &self,
        pts: &[u32],
        c: u32,
        lower: &[f64],
        cutoff: &[f64],
        out: &mut [f64],
    ) -> usize {
        assert_eq!(pts.len(), lower.len());
        assert_eq!(pts.len(), cutoff.len());
        assert_eq!(pts.len(), out.len());
        if !self.kernel.uniform_precision() {
            self.dist_batch(pts, c, out);
            return pts.len();
        }
        let crow = self.data.row(c);
        let mut computed = 0usize;
        for i in 0..pts.len() {
            if lower[i] > cutoff[i] {
                out[i] = f64::INFINITY;
            } else {
                out[i] = sq_euclidean(self.data.row(pts[i]), crow).sqrt();
                computed += 1;
            }
        }
        counter::charge(computed);
        computed
    }

    fn nearest_batch(&self, pts: &[u32], centers: &[u32]) -> Assignment {
        assert!(!centers.is_empty(), "nearest_batch: empty center set");
        counter::charge(pts.len() * centers.len());
        self.kernel.l2_nearest(&self.data, pts, centers)
    }

    fn min_update(&self, pts: &[u32], c: u32, cur: &mut [f64]) {
        assert_eq!(pts.len(), cur.len());
        counter::charge(pts.len());
        self.kernel.l2_min_update(&self.data, pts, c, cur)
    }
}

macro_rules! vector_space {
    ($name:ident, $metric_name:literal, $dist_fn:expr, $row_batch:ident) => {
        pub struct $name {
            data: SharedVectors,
            kernel: Arc<dyn DistKernel>,
        }

        impl $name {
            pub fn new(data: SharedVectors) -> $name {
                $name::with_kernel(data, KernelKind::resolve(None))
            }

            /// Construct with an explicit kernel backend (bypasses the
            /// `MRCORESET_KERNEL` environment resolution).
            pub fn with_kernel(data: SharedVectors, kind: KernelKind) -> $name {
                let (kernel, _) = kernel::build(kind, None);
                $name { data, kernel }
            }

            pub fn data(&self) -> &SharedVectors {
                &self.data
            }
        }

        impl MetricSpace for $name {
            fn n_points(&self) -> usize {
                self.data.n()
            }

            #[inline]
            fn dist(&self, i: u32, j: u32) -> f64 {
                counter::charge(1);
                let f: fn(&[f32], &[f32]) -> f64 = $dist_fn;
                f(self.data.row(i), self.data.row(j))
            }

            /// Batched rows via the configured kernel (exact backends
            /// reproduce the scalar `dist` expression bit-for-bit).
            fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
                assert_eq!(pts.len(), out.len());
                counter::charge(pts.len());
                self.kernel.$row_batch(&self.data, pts, c, out);
            }

            fn uniform_precision(&self) -> bool {
                self.kernel.uniform_precision()
            }

            fn kernel_name(&self) -> &'static str {
                self.kernel.name()
            }

            /// Geometry-pruned batch: skip (and do not charge) pairs the
            /// caller's lower bound already decides; computed entries use
            /// the same distance expression as the exact `dist_batch`.
            /// Inexact kernels fall back to the plain batch (exact-domain
            /// bounds cannot prune fast-path values soundly).
            fn dist_batch_pruned(
                &self,
                pts: &[u32],
                c: u32,
                lower: &[f64],
                cutoff: &[f64],
                out: &mut [f64],
            ) -> usize {
                assert_eq!(pts.len(), lower.len());
                assert_eq!(pts.len(), cutoff.len());
                assert_eq!(pts.len(), out.len());
                if !self.kernel.uniform_precision() {
                    self.dist_batch(pts, c, out);
                    return pts.len();
                }
                let f: fn(&[f32], &[f32]) -> f64 = $dist_fn;
                let crow = self.data.row(c);
                let mut computed = 0usize;
                for i in 0..pts.len() {
                    if lower[i] > cutoff[i] {
                        out[i] = f64::INFINITY;
                    } else {
                        out[i] = f(self.data.row(pts[i]), crow);
                        computed += 1;
                    }
                }
                counter::charge(computed);
                computed
            }

            fn name(&self) -> &'static str {
                $metric_name
            }
        }
    };
}

#[inline]
pub fn manhattan(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((*x - *y) as f64).abs()).sum()
}

#[inline]
pub fn chebyshev(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((*x - *y) as f64).abs()).fold(0.0, f64::max)
}

vector_space!(ManhattanSpace, "manhattan", manhattan, l1_dist_batch);
vector_space!(ChebyshevSpace, "chebyshev", chebyshev, linf_dist_batch);

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> SharedVectors {
        Arc::new(VectorData::from_rows(&[
            vec![0.0, 0.0],
            vec![3.0, 4.0],
            vec![1.0, 1.0],
            vec![-2.0, 0.5],
        ]))
    }

    #[test]
    fn euclidean_known_distances() {
        let s = EuclideanSpace::new(data());
        assert!((s.dist(0, 1) - 5.0).abs() < 1e-9);
        assert_eq!(s.dist(2, 2), 0.0);
        assert!((s.dist(0, 2) - 2f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn metric_axioms_on_sample() {
        for s in [
            &EuclideanSpace::new(data()) as &dyn MetricSpace,
            &ManhattanSpace::new(data()),
            &ChebyshevSpace::new(data()),
        ] {
            let n = s.n_points() as u32;
            for i in 0..n {
                assert_eq!(s.dist(i, i), 0.0);
                for j in 0..n {
                    assert!((s.dist(i, j) - s.dist(j, i)).abs() < 1e-12);
                    for k in 0..n {
                        assert!(s.dist(i, k) <= s.dist(i, j) + s.dist(j, k) + 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn manhattan_chebyshev_values() {
        let m = ManhattanSpace::new(data());
        let c = ChebyshevSpace::new(data());
        assert!((m.dist(0, 1) - 7.0).abs() < 1e-9);
        assert!((c.dist(0, 1) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn pruned_batch_exact_and_honestly_charged_all_spaces() {
        use super::super::counter;
        let d = data();
        let pts: Vec<u32> = (0..4).collect();
        // pinned to an exact kernel: this test asserts pruning-active
        // behavior (skip accounting), which inexact kernels bypass
        for s in [
            &EuclideanSpace::with_kernel(d.clone(), KernelKind::Blocked) as &dyn MetricSpace,
            &ManhattanSpace::with_kernel(d.clone(), KernelKind::Blocked),
            &ChebyshevSpace::with_kernel(d.clone(), KernelKind::Blocked),
        ] {
            for c in 0..4u32 {
                // triangle-inequality lower bounds via reference point 0:
                // d(p, c) >= |d(p, 0) - d(c, 0)|
                let lower: Vec<f64> =
                    pts.iter().map(|&p| (s.dist(p, 0) - s.dist(c, 0)).abs()).collect();
                let mut reference = vec![0.0f64; 4];
                s.dist_batch(&pts, c, &mut reference);
                for cut in [0.0f64, 1.0, 2.5, 100.0] {
                    let cutoff = vec![cut; 4];
                    let mut out = vec![0.0f64; 4];
                    let (computed, evals) = counter::counted(|| {
                        s.dist_batch_pruned(&pts, c, &lower, &cutoff, &mut out)
                    });
                    assert_eq!(computed as u64, evals, "{} c={c}", s.name());
                    for i in 0..4 {
                        if lower[i] > cut {
                            // pruned: must decide `<= cut` the same way
                            assert!(out[i] > cut && reference[i] > cut);
                        } else {
                            assert_eq!(
                                out[i].to_bits(),
                                reference[i].to_bits(),
                                "{} c={c} i={i}",
                                s.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn inexact_kernel_pruned_batch_falls_back_to_plain_batch() {
        let d = data();
        let pts: Vec<u32> = (0..4).collect();
        for s in [
            &EuclideanSpace::with_kernel(d.clone(), KernelKind::Simd) as &dyn MetricSpace,
            &ManhattanSpace::with_kernel(d.clone(), KernelKind::Simd),
            &ChebyshevSpace::with_kernel(d.clone(), KernelKind::Simd),
        ] {
            assert!(!s.uniform_precision(), "{}", s.name());
            assert_eq!(s.kernel_name(), "simd");
            let mut plain = vec![0.0f64; 4];
            s.dist_batch(&pts, 1, &mut plain);
            let lower = vec![1e9; 4]; // would skip everything if trusted
            let cutoff = vec![0.0; 4];
            let mut out = vec![0.0f64; 4];
            let computed = s.dist_batch_pruned(&pts, 1, &lower, &cutoff, &mut out);
            assert_eq!(computed, 4, "{}", s.name());
            for i in 0..4 {
                assert_eq!(out[i].to_bits(), plain[i].to_bits(), "{} i={i}", s.name());
            }
        }
    }

    #[test]
    fn kernel_selection_is_visible() {
        // with_kernel bypasses the environment, so these hold under any
        // MRCORESET_KERNEL (the CI matrix leg sets it)
        let d = data();
        assert_eq!(
            EuclideanSpace::with_kernel(d.clone(), KernelKind::Auto).kernel_name(),
            "blocked"
        );
        assert_eq!(
            EuclideanSpace::with_kernel(d.clone(), KernelKind::Scalar).kernel_name(),
            "scalar"
        );
        let e = EuclideanSpace::with_kernel(d, KernelKind::Scalar);
        assert!(!e.has_engine());
    }

    #[test]
    fn l1_ge_l2_ge_linf() {
        let d = data();
        let e = EuclideanSpace::new(d.clone());
        let m = ManhattanSpace::new(d.clone());
        let c = ChebyshevSpace::new(d);
        for i in 0..4u32 {
            for j in 0..4u32 {
                assert!(m.dist(i, j) >= e.dist(i, j) - 1e-12);
                assert!(e.dist(i, j) >= c.dist(i, j) - 1e-12);
            }
        }
    }
}
