//! Incremental nearest-center tracking with triangle-inequality pruning.
//!
//! Every sampling baseline (kmeans‖, PAMAE-lite, Ene–Im–Moseley) folds a
//! growing center set over a fixed point set: "for each point, keep the
//! distance and index of the nearest center seen so far". The reference
//! fold pays `|pts|` distance evaluations per new center. With the current
//! nearest distance `a = d(x, C)` in hand and one cached center-to-center
//! row, the triangle inequality gives `d(x, c_new) >= |d(c_new, c_x) - a|`
//! where `c_x` is x's current nearest center — so any point with
//! `|d(c_new, c_x) - a| > a` cannot switch to `c_new` and its evaluation
//! is skipped outright via [`MetricSpace::dist_batch_pruned`].
//!
//! [`NearestTracker`] maintains exactly that state, bucketing points by
//! their current nearest center as `coreset/cover.rs` does so whole
//! buckets are eliminated with a single comparison against the bucket's
//! distance ceiling. Guarantee: **bit-identical** results to the
//! reference fold ([`assign_reference`]) — skipped pairs are only those
//! whose strict `d < current` comparison a deflated lower bound already
//! decided negatively, so the surviving updates (and ties, which always
//! keep the earliest center) are untouched.
//!
//! Bounds are only trusted when [`MetricSpace::uniform_precision`] holds;
//! otherwise the tracker silently degrades to the reference fold (every
//! pair computed, identical charges), so callers need no second code
//! path for engine-attached spaces.

use super::{Assignment, MetricSpace};
use crate::obs::counters as obs;

/// Snapshot of a tracker's adaptive give-up ledger. The same numbers are
/// charged incrementally to `obs::counters` under `pruned.*`, so traced
/// runs see them per reducer without holding the tracker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PruneLedger {
    /// Evaluations the pruned path actually computed (rows + survivors).
    pub evals_charged: u64,
    /// Evaluations the reference fold would have computed.
    pub evals_baseline: u64,
    /// False once the give-up latch has fired (bounds cost more than
    /// they saved; later pushes fold everything).
    pub bounds_paying: bool,
}

/// Relative slack applied to every lower bound before it may veto a
/// distance evaluation (same contract as `coreset/cover.rs`): distances
/// are f64 results of a metric's own arithmetic, so bounds derived from
/// them are deflated by ~1e-12 relative before use. Pruning then only
/// skips comparisons decided by a margin far above accumulated f64
/// round-off; everything inside the margin is computed exactly.
const LB_MARGIN: f64 = 1e-12;

/// Incremental nearest-center state over a fixed `pts` slice.
pub struct NearestTracker<'a> {
    space: &'a dyn MetricSpace,
    pts: &'a [u32],
    centers: Vec<u32>,
    /// Exact distance to the current nearest center (never a bound).
    dist: Vec<f64>,
    /// Index into `centers` of the current nearest center.
    idx: Vec<u32>,
    /// Bounds usable at all (requested && uniform precision)?
    use_bounds: bool,
    /// Bounds currently paying for themselves? (give-up latch)
    bounds_paying: bool,
    /// Per-center buckets of positions into `pts`, plus each bucket's
    /// distance ceiling (max `dist` over members; stale-high is safe).
    buckets: Vec<Vec<u32>>,
    bucket_hi: Vec<f64>,
    /// Give-up ledger: evaluations spent by the pruned path vs what the
    /// reference fold would have spent.
    pruned_evals: u64,
    baseline_evals: u64,
    // scratch buffers reused across pushes
    sel: Vec<u32>,
    lower: Vec<f64>,
    cutoff: Vec<f64>,
    out: Vec<f64>,
}

impl<'a> NearestTracker<'a> {
    /// Empty tracker (no centers yet). `bounds` requests pruning; it is
    /// honoured only when the space reports uniform precision.
    pub fn new(space: &'a dyn MetricSpace, pts: &'a [u32], bounds: bool) -> Self {
        let n = pts.len();
        NearestTracker {
            space,
            pts,
            centers: Vec::new(),
            dist: vec![f64::INFINITY; n],
            idx: vec![u32::MAX; n],
            use_bounds: bounds && space.uniform_precision(),
            bounds_paying: true,
            buckets: Vec::new(),
            bucket_hi: Vec::new(),
            pruned_evals: 0,
            baseline_evals: 0,
            sel: Vec::new(),
            lower: Vec::new(),
            cutoff: Vec::new(),
            out: Vec::new(),
        }
    }

    /// Resume from previously-tracked state: `dist[i]`/`idx[i]` must be
    /// the exact nearest distance/index of `pts[i]` over `centers`.
    pub fn with_state(
        space: &'a dyn MetricSpace,
        pts: &'a [u32],
        centers: Vec<u32>,
        dist: Vec<f64>,
        idx: Vec<u32>,
        bounds: bool,
    ) -> Self {
        assert_eq!(pts.len(), dist.len());
        assert_eq!(pts.len(), idx.len());
        let mut t = NearestTracker {
            space,
            pts,
            centers,
            dist,
            idx,
            use_bounds: bounds && space.uniform_precision(),
            bounds_paying: true,
            buckets: Vec::new(),
            bucket_hi: Vec::new(),
            pruned_evals: 0,
            baseline_evals: 0,
            sel: Vec::new(),
            lower: Vec::new(),
            cutoff: Vec::new(),
            out: Vec::new(),
        };
        if t.use_bounds && !t.centers.is_empty() {
            t.buckets = vec![Vec::new(); t.centers.len()];
            t.bucket_hi = vec![0.0; t.centers.len()];
            for (pos, &j) in t.idx.iter().enumerate() {
                let j = j as usize;
                assert!(j < t.centers.len(), "with_state: idx out of range");
                t.buckets[j].push(pos as u32);
                if t.dist[pos] > t.bucket_hi[j] {
                    t.bucket_hi[j] = t.dist[pos];
                }
            }
        }
        t
    }

    pub fn centers(&self) -> &[u32] {
        &self.centers
    }

    pub fn dist(&self) -> &[f64] {
        &self.dist
    }

    pub fn idx(&self) -> &[u32] {
        &self.idx
    }

    /// Consume the tracker, returning the `(dist, idx)` assignment state.
    pub fn into_state(self) -> (Vec<f64>, Vec<u32>) {
        (self.dist, self.idx)
    }

    pub fn assignment(&self) -> Assignment {
        Assignment { dist: self.dist.clone(), idx: self.idx.clone() }
    }

    /// Current give-up ledger (see [`PruneLedger`]).
    pub fn ledger(&self) -> PruneLedger {
        PruneLedger {
            evals_charged: self.pruned_evals,
            evals_baseline: self.baseline_evals,
            bounds_paying: self.bounds_paying,
        }
    }

    /// Fold one new center into the tracked state. Computes the cached
    /// center-to-center row itself when bounds are active.
    pub fn push(&mut self, c: u32) {
        if self.bounds_active() {
            let mut row = vec![0.0; self.centers.len()];
            self.space.dist_batch(&self.centers, c, &mut row);
            self.pruned_evals += row.len() as u64;
            obs::add("pruned.evals_charged", row.len() as u64);
            self.push_bounded(c, &row);
        } else {
            self.push_full(c);
        }
    }

    /// Fold one new center using a caller-supplied center-to-center row
    /// (`row[j] = d(centers[j], c)`, already computed and charged — e.g.
    /// broadcast once by a coordinator and shared across reducers). The
    /// row is ignored when bounds are inactive.
    pub fn push_with_row(&mut self, c: u32, row: &[f64]) {
        if self.bounds_active() {
            assert_eq!(row.len(), self.centers.len(), "push_with_row: row length");
            self.push_bounded(c, row);
        } else {
            self.push_full(c);
        }
    }

    fn bounds_active(&self) -> bool {
        // a center row costs |C| evals; once |C| catches up with |pts|
        // the row alone outweighs the reference fold
        self.use_bounds
            && self.bounds_paying
            && !self.centers.is_empty()
            && self.centers.len() < self.pts.len()
    }

    /// Reference fold: every pair computed (identical to the historical
    /// per-center `dist_batch` loop, strict `<` keeps the earliest
    /// center on ties).
    fn push_full(&mut self, c: u32) {
        let j = self.centers.len() as u32;
        self.out.resize(self.pts.len(), 0.0);
        self.space.dist_batch(self.pts, c, &mut self.out);
        for (i, &d) in self.out.iter().enumerate() {
            if d < self.dist[i] {
                self.dist[i] = d;
                self.idx[i] = j;
            }
        }
        self.centers.push(c);
        self.pruned_evals += self.pts.len() as u64;
        self.baseline_evals += self.pts.len() as u64;
        obs::add("pruned.evals_charged", self.pts.len() as u64);
        obs::add("pruned.evals_baseline", self.pts.len() as u64);
        if self.use_bounds && self.bounds_paying {
            // seed / refresh buckets so a later push can prune
            self.rebuild_buckets();
        }
    }

    fn rebuild_buckets(&mut self) {
        self.buckets = vec![Vec::new(); self.centers.len()];
        self.bucket_hi = vec![0.0; self.centers.len()];
        for (pos, &j) in self.idx.iter().enumerate() {
            let j = j as usize;
            self.buckets[j].push(pos as u32);
            if self.dist[pos] > self.bucket_hi[j] {
                self.bucket_hi[j] = self.dist[pos];
            }
        }
    }

    /// Bounds-pruned fold of one new center, given the row of distances
    /// from `c` to every existing center.
    fn push_bounded(&mut self, c: u32, row: &[f64]) {
        let jn = self.centers.len() as u32;
        let n = self.pts.len();
        self.baseline_evals += n as u64;
        obs::add("pruned.evals_baseline", n as u64);
        let mut moved: Vec<u32> = Vec::new();
        let mut moved_hi = 0.0f64;
        let mut computed_total = 0usize;
        for b in 0..self.buckets.len() {
            if self.buckets[b].is_empty() {
                continue;
            }
            let dcb = row[b];
            let hi = self.bucket_hi[b];
            // bucket-level veto: for every member `a <= hi`, the member
            // bound `dcb - a - LB_MARGIN*(dcb + a)` already exceeds its
            // cutoff `a` whenever `dcb - LB_MARGIN*(dcb + hi) > 2*hi`
            if dcb - LB_MARGIN * (dcb + hi) > 2.0 * hi {
                obs::incr("pruned.veto_bucket");
                continue;
            }
            // assemble the bucket's survivors for the pruned batch
            self.sel.clear();
            self.lower.clear();
            self.cutoff.clear();
            for &pos in &self.buckets[b] {
                let a = self.dist[pos as usize];
                let lb = ((dcb - a).abs() - LB_MARGIN * (dcb + a)).max(0.0);
                self.sel.push(self.pts[pos as usize]);
                self.lower.push(lb);
                self.cutoff.push(a);
            }
            self.out.resize(self.sel.len(), 0.0);
            let computed = self.space.dist_batch_pruned(
                &self.sel,
                c,
                &self.lower,
                &self.cutoff,
                &mut self.out,
            );
            computed_total += computed;
            // apply updates and compact the bucket in place, moving
            // switchers to the new center's bucket
            let mut write = 0usize;
            let mut hi_new = 0.0f64;
            for s in 0..self.buckets[b].len() {
                let pos = self.buckets[b][s];
                let d = self.out[s];
                if d < self.dist[pos as usize] {
                    self.dist[pos as usize] = d;
                    self.idx[pos as usize] = jn;
                    moved.push(pos);
                    if d > moved_hi {
                        moved_hi = d;
                    }
                } else {
                    self.buckets[b][write] = pos;
                    write += 1;
                    if self.dist[pos as usize] > hi_new {
                        hi_new = self.dist[pos as usize];
                    }
                }
            }
            self.buckets[b].truncate(write);
            self.bucket_hi[b] = hi_new;
        }
        self.buckets.push(moved);
        self.bucket_hi.push(moved_hi);
        self.centers.push(c);
        // give-up ledger: if pruning persistently spends more than the
        // reference fold would (rows + surviving evals), latch it off —
        // the state stays exact, later pushes just fold everything.
        self.pruned_evals += computed_total as u64;
        obs::add("pruned.evals_charged", computed_total as u64);
        let slack = self.pts.len() as u64 + 64;
        if self.pruned_evals > self.baseline_evals + slack {
            self.bounds_paying = false;
            self.buckets.clear();
            self.bucket_hi.clear();
            obs::incr("pruned.give_up");
        }
    }
}

/// One-shot pruned assignment: fold `centers` in order through a
/// [`NearestTracker`]. Bit-identical to [`assign_reference`].
pub fn assign_pruned(space: &dyn MetricSpace, pts: &[u32], centers: &[u32]) -> Assignment {
    assert!(!centers.is_empty(), "assign_pruned: empty center set");
    let mut t = NearestTracker::new(space, pts, true);
    for &c in centers {
        t.push(c);
    }
    let (dist, idx) = t.into_state();
    Assignment { dist, idx }
}

/// Reference assignment: the plain per-center `dist_batch` fold with
/// strict `<` updates (the `MetricSpace::nearest_batch` trait default),
/// spelled out so spaces that override `nearest_batch` with approximate
/// kernels (engine-attached Euclidean) still produce the exact fold the
/// pruned twin is pinned against.
pub fn assign_reference(space: &dyn MetricSpace, pts: &[u32], centers: &[u32]) -> Assignment {
    assert!(!centers.is_empty(), "assign_reference: empty center set");
    let n = pts.len();
    let mut dist = vec![f64::INFINITY; n];
    let mut idx = vec![u32::MAX; n];
    let mut buf = vec![0.0f64; n];
    for (j, &c) in centers.iter().enumerate() {
        space.dist_batch(pts, c, &mut buf);
        for (i, &d) in buf.iter().enumerate() {
            if d < dist[i] {
                dist[i] = d;
                idx[i] = j as u32;
            }
        }
    }
    Assignment { dist, idx }
}

/// Incremental center-to-center rows for a center list: `rows[j]` holds
/// `d(centers[j], centers[..j])` — the broadcast a coordinator computes
/// once so every reducer's tracker can prune against the same cached
/// geometry. Total cost m(m-1)/2 evaluations.
pub fn center_rows(space: &dyn MetricSpace, centers: &[u32]) -> Vec<Vec<f64>> {
    let mut rows = Vec::with_capacity(centers.len());
    for j in 0..centers.len() {
        let mut row = vec![0.0; j];
        if j > 0 {
            space.dist_batch(&centers[..j], centers[j], &mut row);
        }
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::counter;
    use crate::metric::dense::{EuclideanSpace, ManhattanSpace};

    fn mixture(n: usize, seed: u64) -> Arc<crate::points::VectorData> {
        let (data, _) = GaussianMixtureSpec {
            n,
            d: 3,
            k: 4,
            spread: 20.0,
            outlier_frac: 0.02,
            seed,
            ..Default::default()
        }
        .generate();
        Arc::new(data)
    }

    #[test]
    fn pruned_assignment_bit_identical_and_cheaper() {
        let data = mixture(600, 7);
        let spaces: Vec<Box<dyn MetricSpace>> = vec![
            Box::new(EuclideanSpace::new(data.clone())),
            Box::new(ManhattanSpace::new(data)),
        ];
        let pts: Vec<u32> = (0..600).collect();
        let centers: Vec<u32> = vec![3, 77, 150, 301, 420, 599];
        for space in &spaces {
            let (reference, eref) =
                counter::counted(|| assign_reference(space.as_ref(), &pts, &centers));
            let (pruned, epr) = counter::counted(|| assign_pruned(space.as_ref(), &pts, &centers));
            assert_eq!(pruned.idx, reference.idx, "{}", space.name());
            for (a, b) in pruned.dist.iter().zip(&reference.dist) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", space.name());
            }
            assert!(epr <= eref, "{}: pruned {epr} > reference {eref}", space.name());
        }
    }

    #[test]
    fn incremental_push_matches_fresh_fold() {
        let data = mixture(400, 13);
        let space = EuclideanSpace::new(data);
        let pts: Vec<u32> = (0..400).collect();
        let centers: Vec<u32> = vec![10, 42, 200, 333];
        let mut t = NearestTracker::new(&space, &pts, true);
        for (m, &c) in centers.iter().enumerate() {
            t.push(c);
            let reference = assign_reference(&space, &pts, &centers[..=m]);
            assert_eq!(t.idx(), &reference.idx[..], "prefix {m}");
            for (a, b) in t.dist().iter().zip(&reference.dist) {
                assert_eq!(a.to_bits(), b.to_bits(), "prefix {m}");
            }
        }
    }

    #[test]
    fn with_state_resumes_exactly() {
        let data = mixture(300, 29);
        let space = EuclideanSpace::new(data);
        let pts: Vec<u32> = (0..300).collect();
        let head: Vec<u32> = vec![5, 100];
        let tail: Vec<u32> = vec![222, 17, 290];
        let a0 = assign_reference(&space, &pts, &head);
        let mut t = NearestTracker::with_state(&space, &pts, head.clone(), a0.dist, a0.idx, true);
        let rows = center_rows(&space, &[head.clone(), tail.clone()].concat());
        for (i, &c) in tail.iter().enumerate() {
            t.push_with_row(c, &rows[head.len() + i]);
        }
        let all: Vec<u32> = head.iter().chain(&tail).copied().collect();
        let reference = assign_reference(&space, &pts, &all);
        assert_eq!(t.idx(), &reference.idx[..]);
        for (a, b) in t.dist().iter().zip(&reference.dist) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Adversarial (bounds-hostile) input: all points duplicated at one
    /// location. Every lower bound is 0 and never strictly exceeds its
    /// 0 cutoff, so nothing is ever vetoed — the center rows are pure
    /// overhead. Once that overhead exceeds the slack, the give-up latch
    /// must fire (once), the `pruned.give_up` counter must record it,
    /// and the state must remain bit-identical to the reference fold.
    #[test]
    fn give_up_latch_fires_on_duplicate_points() {
        use crate::points::VectorData;

        let rows: Vec<Vec<f32>> = vec![vec![0.0, 0.0]; 64];
        // pinned to an exact kernel: the latch semantics asserted below
        // require bounds to be active (inexact kernels disable them)
        let space = EuclideanSpace::with_kernel(
            Arc::new(VectorData::from_rows(&rows)),
            crate::metric::kernel::KernelKind::Blocked,
        );
        let pts: Vec<u32> = (0..64).collect();
        let centers: Vec<u32> = (0..40).collect();
        let before = obs::snapshot();
        let mut t = NearestTracker::new(&space, &pts, true);
        for &c in &centers {
            t.push(c);
        }
        let led = t.ledger();
        assert!(!led.bounds_paying, "latch must have fired: {led:?}");
        assert!(
            led.evals_charged > led.evals_baseline,
            "rows cost extra on duplicates: {led:?}"
        );
        let delta = obs::delta_since(&before);
        let give_ups = delta.iter().find(|(k, _)| k == "pruned.give_up");
        assert_eq!(give_ups, Some(&("pruned.give_up".to_string(), 1)), "delta: {delta:?}");
        let reference = assign_reference(&space, &pts, &centers);
        assert_eq!(t.idx(), &reference.idx[..]);
        for (a, b) in t.dist().iter().zip(&reference.dist) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// On a well-spread input the ledger shows bounds paying for
    /// themselves and no give-up is recorded.
    #[test]
    fn ledger_reports_savings_on_spread_input() {
        let data = mixture(600, 21);
        // pinned to an exact kernel: bounds must be active for the
        // ledger to have savings to report
        let space =
            EuclideanSpace::with_kernel(data, crate::metric::kernel::KernelKind::Blocked);
        let pts: Vec<u32> = (0..600).collect();
        let before = obs::snapshot();
        let mut t = NearestTracker::new(&space, &pts, true);
        for &c in &[3u32, 77, 150, 301, 420, 599] {
            t.push(c);
        }
        let led = t.ledger();
        assert!(led.bounds_paying);
        assert!(led.evals_charged <= led.evals_baseline, "{led:?}");
        let delta = obs::delta_since(&before);
        assert!(delta.iter().all(|(k, _)| k != "pruned.give_up"), "delta: {delta:?}");
        assert!(
            delta.iter().any(|(k, _)| k == "pruned.evals_charged"),
            "charges must be mirrored to obs counters: {delta:?}"
        );
    }

    #[test]
    fn bounds_disabled_without_uniform_precision() {
        // a space that disavows uniform precision must get the full fold
        // (equal charges to the reference) while staying bit-identical
        struct NonUniform(EuclideanSpace);
        impl MetricSpace for NonUniform {
            fn n_points(&self) -> usize {
                self.0.n_points()
            }
            fn dist(&self, i: u32, j: u32) -> f64 {
                self.0.dist(i, j)
            }
            fn name(&self) -> &'static str {
                "non-uniform"
            }
            fn uniform_precision(&self) -> bool {
                false
            }
        }
        let data = mixture(200, 3);
        let space = NonUniform(EuclideanSpace::new(data));
        let pts: Vec<u32> = (0..200).collect();
        let centers: Vec<u32> = vec![1, 50, 120];
        let (reference, eref) = counter::counted(|| assign_reference(&space, &pts, &centers));
        let (pruned, epr) = counter::counted(|| assign_pruned(&space, &pts, &centers));
        assert_eq!(pruned.idx, reference.idx);
        assert_eq!(epr, eref, "no pruning allowed: charges must match");
    }
}
