//! Metric-space substrate (paper §2).
//!
//! The paper works in *general metric spaces*: solutions must be subsets
//! of the input (`S ⊆ P`). Accordingly, `MetricSpace` exposes distances
//! between stored points by index; every algorithm, coreset construction,
//! and baseline in this crate is generic over this trait. The dense
//! Euclidean implementation optionally routes the bulk operations through
//! the AOT-compiled XLA/Pallas kernels (see `runtime::XlaEngine`), while
//! e.g. the Levenshtein space exercises the genuinely-general-metric path.

pub mod counting;
pub mod dense;
pub mod extra;
pub mod doubling;
pub mod levenshtein;

/// Clustering objective: k-median sums distances, k-means sums squares.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    Median,
    Means,
}

impl Objective {
    /// Per-point cost contribution of a distance.
    #[inline]
    pub fn cost_of(self, d: f64) -> f64 {
        match self {
            Objective::Median => d,
            Objective::Means => d * d,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Objective::Median => "k-median",
            Objective::Means => "k-means",
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Result of a bulk nearest-center pass: for each queried point, the
/// distance (plain, not squared) to — and position (within the queried
/// center list) of — its closest center.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    pub dist: Vec<f64>,
    pub idx: Vec<u32>,
}

impl Assignment {
    /// Weighted cost under an objective; `weights[i]` pairs with point i.
    pub fn cost(&self, obj: Objective, weights: &[u64]) -> f64 {
        assert_eq!(self.dist.len(), weights.len());
        self.dist
            .iter()
            .zip(weights)
            .map(|(&d, &w)| w as f64 * obj.cost_of(d))
            .sum()
    }

    pub fn cost_unit(&self, obj: Objective) -> f64 {
        self.dist.iter().map(|&d| obj.cost_of(d)).sum()
    }
}

/// A metric over a fixed set of stored points, addressed by index.
pub trait MetricSpace: Send + Sync {
    /// Number of stored points (valid indices are `0..n_points()`).
    fn n_points(&self) -> usize;

    /// Distance between stored points `i` and `j`. Must satisfy the
    /// metric axioms (identity, symmetry, triangle inequality).
    fn dist(&self, i: u32, j: u32) -> f64;

    fn name(&self) -> &'static str;

    /// Nearest-center assignment of `pts` against `centers`.
    /// Implementations may override with batched fast paths; the default
    /// is the straightforward double loop.
    fn assign(&self, pts: &[u32], centers: &[u32]) -> Assignment {
        assert!(!centers.is_empty(), "assign: empty center set");
        let mut dist = Vec::with_capacity(pts.len());
        let mut idx = Vec::with_capacity(pts.len());
        for &p in pts {
            let mut best = f64::INFINITY;
            let mut best_j = 0u32;
            for (j, &c) in centers.iter().enumerate() {
                let d = self.dist(p, c);
                if d < best {
                    best = d;
                    best_j = j as u32;
                }
            }
            dist.push(best);
            idx.push(best_j);
        }
        Assignment { dist, idx }
    }

    /// Fold one new center into a running per-point min-distance vector:
    /// `cur[i] = min(cur[i], d(pts[i], c))`. The greedy inner step of
    /// CoverWithBalls, k-means++ and Gonzalez.
    fn min_update(&self, pts: &[u32], c: u32, cur: &mut [f64]) {
        assert_eq!(pts.len(), cur.len());
        for (i, &p) in pts.iter().enumerate() {
            let d = self.dist(p, c);
            if d < cur[i] {
                cur[i] = d;
            }
        }
    }

    /// Weighted clustering cost of `centers` over (`pts`, `weights`).
    fn weighted_cost(&self, obj: Objective, pts: &[u32], weights: &[u64], centers: &[u32]) -> f64 {
        self.assign(pts, centers).cost(obj, weights)
    }
}

/// Convenience: unit-weight cost.
pub fn cost_unit(space: &dyn MetricSpace, obj: Objective, pts: &[u32], centers: &[u32]) -> f64 {
    space.assign(pts, centers).cost_unit(obj)
}

#[cfg(test)]
mod tests {
    use super::dense::EuclideanSpace;
    use super::*;
    use crate::points::VectorData;
    use std::sync::Arc;

    fn line_space() -> EuclideanSpace {
        // points 0,1,2,3,4 at x = 0,1,2,3,10
        let v = VectorData::from_rows(&[
            vec![0.0],
            vec![1.0],
            vec![2.0],
            vec![3.0],
            vec![10.0],
        ]);
        EuclideanSpace::new(Arc::new(v))
    }

    #[test]
    fn default_assign_picks_nearest() {
        let s = line_space();
        let a = s.assign(&[0, 1, 2, 3, 4], &[0, 3]);
        assert_eq!(a.idx, vec![0, 0, 1, 1, 1]);
        assert_eq!(a.dist, vec![0.0, 1.0, 1.0, 0.0, 7.0]);
    }

    #[test]
    fn objective_costs() {
        let s = line_space();
        let a = s.assign(&[0, 1, 4], &[0]);
        assert_eq!(a.cost_unit(Objective::Median), 0.0 + 1.0 + 10.0);
        assert_eq!(a.cost_unit(Objective::Means), 0.0 + 1.0 + 100.0);
        assert_eq!(a.cost(Objective::Median, &[1, 2, 1]), 0.0 + 2.0 + 10.0);
    }

    #[test]
    fn min_update_monotone() {
        let s = line_space();
        let pts = [0, 1, 2, 3, 4];
        let mut cur = vec![f64::INFINITY; 5];
        s.min_update(&pts, 4, &mut cur);
        assert_eq!(cur, vec![10.0, 9.0, 8.0, 7.0, 0.0]);
        s.min_update(&pts, 0, &mut cur);
        assert_eq!(cur, vec![0.0, 1.0, 2.0, 3.0, 0.0]);
    }
}
