//! Metric-space substrate (paper §2) and the batched distance engine.
//!
//! The paper works in *general metric spaces*: solutions must be subsets
//! of the input (`S ⊆ P`). Accordingly, `MetricSpace` exposes distances
//! between stored points by index; every algorithm, coreset construction,
//! and baseline in this crate is generic over this trait.
//!
//! # Batched distance engine
//!
//! All hot paths issue **bulk queries** instead of per-pair scalar calls:
//!
//! - [`MetricSpace::dist_batch`] — distances of a point block to one
//!   stored point (the greedy inner step of CoverWithBalls, k-means++,
//!   local-search candidate evaluation, PAM BUILD, ...);
//! - [`MetricSpace::nearest_batch`] — nearest-center assignment of a
//!   point block against a center block (the Voronoi pass every
//!   construction and baseline performs);
//! - [`MetricSpace::min_update`] — fold one new center into a running
//!   min-distance vector.
//!
//! Default implementations reduce everything to `dist_batch` (one
//! virtual call per center instead of per pair), and `dist_batch` itself
//! defaults to a scalar loop, so a new metric only has to implement
//! `dist` to work and can override the bulk ops to go fast.
//!
//! # Kernel backends ([`kernel`])
//!
//! The dense vector spaces route their bulk overrides through a
//! pluggable [`kernel::DistKernel`] selected at construction
//! (`--kernel auto|scalar|blocked|simd` on the CLI, `MRCORESET_KERNEL`
//! in the environment, [`kernel::KernelKind`] on the constructors):
//!
//! | kind      | resolves to | exact | notes |
//! |-----------|-------------|-------|-------|
//! | `auto`    | `blocked`, or the engine kernel when a `BulkEngine` is attached | per backend | the default |
//! | `scalar`  | f64 per-pair reference | yes | the semantics everything is pinned against |
//! | `blocked` | cache-blocked `‖x‖²+‖c‖²−2x·c` f32 scan + exact f64 verify | yes | decision bit-identical to `scalar` |
//! | `simd`    | 4-lane f32 SIMD rows (L1/L2/L∞) | no | fastest; opts out of pruning |
//!
//! The `DistKernel` contract in one paragraph: kernels own arithmetic
//! only — the space still charges [`counter`] (bulk ops charge
//! `|pts| · |centers|` *before* dispatching, so `dist_evals` is
//! kernel-invariant), still owns the pruned skip loops, and still
//! answers `dist` on the exact f64 path on every backend. A kernel
//! declares [`kernel::DistKernel::uniform_precision`]: exact backends
//! must be decision bit-identical to `scalar` and may feed
//! bounds-grade pruning; inexact backends report `false`, which makes
//! the owning space report `false` too — pruned callers then take
//! their historical exact code paths and `dist_batch_pruned` falls
//! back to the plain batch. The string/Levenshtein space keeps its own
//! fast path (bit-parallel and banded DP, see [`levenshtein`]) —
//! exercising the genuinely-general-metric route.
//!
//! # Geometry-pruned queries
//!
//! [`MetricSpace::dist_batch_pruned`] is the bounds-aware variant of
//! `dist_batch`: the caller supplies a per-point *lower bound* on the
//! distance (derived from the triangle inequality over distances it
//! already holds) plus a per-point cutoff, and the implementation may
//! skip any pair whose bound already exceeds the cutoff. Skipping is
//! exact, not approximate — a skipped pair is one whose comparison
//! against the cutoff was already decided — so pruned callers
//! (CoverWithBalls, the incremental local-search book) stay bit-identical
//! to their unpruned references.
//!
//! # Distance-evaluation accounting
//!
//! Every implementation charges [`counter`] — 1 unit per (point, center)
//! pair covered by a query, regardless of early-exit tricks — giving the
//! simulator a per-reducer work metric (`RoundStats::dist_evals`) next
//! to its memory meter. See `counter` for the threading contract.
//!
//! The one deliberate exception is `dist_batch_pruned`: a pruned pair is
//! work that genuinely never happened (no coordinates are touched), so
//! the primitive charges only the distances it actually computes. That
//! keeps the work metric honest — `RoundStats::dist_evals` reports real
//! evaluations, and pruning PRs show up as measurable reductions.

pub mod counter;
pub mod counting;
pub mod dense;
pub mod doubling;
pub mod extra;
pub mod kernel;
pub mod levenshtein;
pub mod pruned;

/// Clustering objective: k-median sums distances, k-means sums squares.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Objective {
    Median,
    Means,
}

impl Objective {
    /// Per-point cost contribution of a distance.
    #[inline]
    pub fn cost_of(self, d: f64) -> f64 {
        match self {
            Objective::Median => d,
            Objective::Means => d * d,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Objective::Median => "k-median",
            Objective::Means => "k-means",
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Result of a bulk nearest-center pass: for each queried point, the
/// distance (plain, not squared) to — and position (within the queried
/// center list) of — its closest center.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    pub dist: Vec<f64>,
    pub idx: Vec<u32>,
}

impl Assignment {
    /// Weighted cost under an objective; `weights[i]` pairs with point i.
    pub fn cost(&self, obj: Objective, weights: &[u64]) -> f64 {
        assert_eq!(self.dist.len(), weights.len());
        self.dist
            .iter()
            .zip(weights)
            .map(|(&d, &w)| w as f64 * obj.cost_of(d))
            .sum()
    }

    pub fn cost_unit(&self, obj: Objective) -> f64 {
        self.dist.iter().map(|&d| obj.cost_of(d)).sum()
    }
}

/// A metric over a fixed set of stored points, addressed by index.
///
/// Implementors MUST charge `counter` for every query: `dist` charges 1
/// and bulk overrides charge `pts.len() * centers.len()` (the defaults
/// inherit charging from the scalar `dist` they call).
pub trait MetricSpace: Send + Sync {
    /// Number of stored points (valid indices are `0..n_points()`).
    fn n_points(&self) -> usize;

    /// Distance between stored points `i` and `j`. Must satisfy the
    /// metric axioms (identity, symmetry, triangle inequality).
    fn dist(&self, i: u32, j: u32) -> f64;

    fn name(&self) -> &'static str;

    /// Name of the kernel backend serving this space's bulk queries
    /// (recorded in `RunReport`/trace metadata so runs stay
    /// self-describing). Spaces without a pluggable backend report the
    /// scalar reference path.
    fn kernel_name(&self) -> &'static str {
        "scalar"
    }

    /// Bulk distances to one stored point: `out[i] = d(pts[i], c)`.
    /// The workhorse primitive the other bulk defaults reduce to;
    /// override it to batch per-center work (row staging, DP buffers).
    fn dist_batch(&self, pts: &[u32], c: u32, out: &mut [f64]) {
        assert_eq!(pts.len(), out.len());
        for (o, &p) in out.iter_mut().zip(pts) {
            *o = self.dist(p, c);
        }
    }

    /// Bounds-aware bulk distances — the geometry-pruned variant of
    /// [`Self::dist_batch`]. `lower[i]` must be a valid lower bound on
    /// `d(pts[i], c)` (callers derive it from the triangle inequality
    /// over distances they already hold, e.g. `|d(x,t) − d(c,t)|` for a
    /// shared reference point `t`). For every `i` with
    /// `lower[i] > cutoff[i]` the implementation may skip the
    /// evaluation and store `f64::INFINITY` in `out[i]`. An
    /// implementation may also store the `INFINITY` sentinel for an
    /// entry whose *exact* distance provably exceeds `cutoff[i]` even
    /// though the caller's bound did not decide it — the banded
    /// Levenshtein path detects band overflow mid-DP and reports the
    /// pair that way. Every other entry holds the exact distance,
    /// bit-identical to what `dist_batch` would produce. Callers must
    /// therefore only consume `out[i]` through comparisons of the form
    /// `out[i] <= cutoff[i]` — exactly the comparisons the bound (or
    /// the band) has already decided — which is what keeps pruned
    /// algorithms bit-identical to their unpruned references. Returns
    /// the number of distances actually computed.
    ///
    /// Counter contract: unlike the other bulk queries (which charge
    /// `|pts| · |centers|` regardless of early-exit tricks), this
    /// primitive charges [`counter`] only for the evaluations it
    /// performs — a pruned pair touches no coordinates, so reporting it
    /// as work would make `dist_evals` lie about savings.
    ///
    /// The default ignores the bounds and falls back to `dist_batch`
    /// (computing — and charging — everything), so implementations stay
    /// correct with no override; the dense vector spaces override it to
    /// actually skip.
    fn dist_batch_pruned(
        &self,
        pts: &[u32],
        c: u32,
        lower: &[f64],
        cutoff: &[f64],
        out: &mut [f64],
    ) -> usize {
        debug_assert_eq!(pts.len(), lower.len());
        debug_assert_eq!(pts.len(), cutoff.len());
        self.dist_batch(pts, c, out);
        pts.len()
    }

    /// Whether this space's bulk queries return distances precise enough
    /// (uniform precision across block sizes, relative error well below
    /// 1e-12) for callers to assemble triangle-inequality pruning bounds
    /// from previously returned values — the contract
    /// [`Self::dist_batch_pruned`] callers rely on. Default true. Report
    /// false when that fails and pruned callers fall back to their exact
    /// unpruned code paths: the Euclidean space does so while an
    /// accelerator engine is attached (engine blocks are f32 while small
    /// blocks are f64), and the angular space always (`acos` is
    /// ill-conditioned near 0, with absolute error far above the
    /// margin).
    fn uniform_precision(&self) -> bool {
        true
    }

    /// Nearest-center assignment of `pts` against `centers` — the bulk
    /// Voronoi query. Ties break toward the earlier center position.
    /// The default makes one `dist_batch` pass per center; dense spaces
    /// override by dispatching to their [`kernel::DistKernel`] backend.
    fn nearest_batch(&self, pts: &[u32], centers: &[u32]) -> Assignment {
        assert!(!centers.is_empty(), "nearest_batch: empty center set");
        let n = pts.len();
        let mut dist = vec![f64::INFINITY; n];
        let mut idx = vec![0u32; n];
        let mut buf = vec![0.0f64; n];
        for (j, &c) in centers.iter().enumerate() {
            self.dist_batch(pts, c, &mut buf);
            for i in 0..n {
                if buf[i] < dist[i] {
                    dist[i] = buf[i];
                    idx[i] = j as u32;
                }
            }
        }
        Assignment { dist, idx }
    }

    /// Nearest-center assignment (alias of [`Self::nearest_batch`], the
    /// name the original call sites use). Override `nearest_batch`, not
    /// this.
    fn assign(&self, pts: &[u32], centers: &[u32]) -> Assignment {
        self.nearest_batch(pts, centers)
    }

    /// Fold one new center into a running per-point min-distance vector:
    /// `cur[i] = min(cur[i], d(pts[i], c))`. The greedy inner step of
    /// CoverWithBalls, k-means++ and Gonzalez.
    fn min_update(&self, pts: &[u32], c: u32, cur: &mut [f64]) {
        assert_eq!(pts.len(), cur.len());
        let mut buf = vec![0.0f64; pts.len()];
        self.dist_batch(pts, c, &mut buf);
        for (o, d) in cur.iter_mut().zip(buf) {
            if d < *o {
                *o = d;
            }
        }
    }

    /// Weighted clustering cost of `centers` over (`pts`, `weights`).
    fn weighted_cost(&self, obj: Objective, pts: &[u32], weights: &[u64], centers: &[u32]) -> f64 {
        self.nearest_batch(pts, centers).cost(obj, weights)
    }
}

/// Convenience: unit-weight cost.
pub fn cost_unit(space: &dyn MetricSpace, obj: Objective, pts: &[u32], centers: &[u32]) -> f64 {
    space.nearest_batch(pts, centers).cost_unit(obj)
}

#[cfg(test)]
mod tests {
    use super::dense::EuclideanSpace;
    use super::*;
    use crate::points::VectorData;
    use std::sync::Arc;

    fn line_space() -> EuclideanSpace {
        // points 0,1,2,3,4 at x = 0,1,2,3,10
        let v = VectorData::from_rows(&[
            vec![0.0],
            vec![1.0],
            vec![2.0],
            vec![3.0],
            vec![10.0],
        ]);
        EuclideanSpace::new(Arc::new(v))
    }

    #[test]
    fn default_assign_picks_nearest() {
        let s = line_space();
        let a = s.assign(&[0, 1, 2, 3, 4], &[0, 3]);
        assert_eq!(a.idx, vec![0, 0, 1, 1, 1]);
        assert_eq!(a.dist, vec![0.0, 1.0, 1.0, 0.0, 7.0]);
    }

    #[test]
    fn objective_costs() {
        let s = line_space();
        let a = s.assign(&[0, 1, 4], &[0]);
        assert_eq!(a.cost_unit(Objective::Median), 0.0 + 1.0 + 10.0);
        assert_eq!(a.cost_unit(Objective::Means), 0.0 + 1.0 + 100.0);
        assert_eq!(a.cost(Objective::Median, &[1, 2, 1]), 0.0 + 2.0 + 10.0);
    }

    #[test]
    fn min_update_monotone() {
        let s = line_space();
        let pts = [0, 1, 2, 3, 4];
        let mut cur = vec![f64::INFINITY; 5];
        s.min_update(&pts, 4, &mut cur);
        assert_eq!(cur, vec![10.0, 9.0, 8.0, 7.0, 0.0]);
        s.min_update(&pts, 0, &mut cur);
        assert_eq!(cur, vec![0.0, 1.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    fn dist_batch_matches_scalar_dist() {
        let s = line_space();
        let pts = [4u32, 2, 0, 3];
        let mut out = vec![0.0f64; 4];
        s.dist_batch(&pts, 1, &mut out);
        for (o, &p) in out.iter().zip(&pts) {
            assert_eq!(*o, s.dist(p, 1));
        }
    }

    #[test]
    fn nearest_batch_is_assign() {
        let s = line_space();
        let pts = [0u32, 1, 2, 3, 4];
        let a = s.assign(&pts, &[1, 4]);
        let b = s.nearest_batch(&pts, &[1, 4]);
        assert_eq!(a.dist, b.dist);
        assert_eq!(a.idx, b.idx);
    }

    #[test]
    fn pruned_batch_skips_only_decided_pairs() {
        let s = line_space();
        let pts = [0u32, 1, 2, 3, 4];
        // distances to center 0 are 0,1,2,3,10; give exact lower bounds
        // and a cutoff of 2.5: pairs with lower > cutoff may be skipped.
        let lower = [0.0, 1.0, 2.0, 3.0, 10.0];
        let cutoff = [2.5; 5];
        let mut out = vec![0.0f64; 5];
        let (computed, evals) =
            counter::counted(|| s.dist_batch_pruned(&pts, 0, &lower, &cutoff, &mut out));
        assert_eq!(computed as u64, evals, "charge == computed count");
        assert!(computed <= 5);
        let mut reference = vec![0.0f64; 5];
        s.dist_batch(&pts, 0, &mut reference);
        for i in 0..5 {
            if lower[i] > cutoff[i] {
                // skipped entries must still decide the comparison the
                // same way the exact distance would
                assert!(out[i] > cutoff[i], "i={i}");
                assert!(reference[i] > cutoff[i], "i={i}");
            } else {
                assert_eq!(out[i].to_bits(), reference[i].to_bits(), "i={i}");
            }
        }
    }

    #[test]
    fn pruned_batch_with_slack_bounds_computes_everything_it_must() {
        let s = line_space();
        let pts = [0u32, 1, 2, 3, 4];
        // all-zero lower bounds: nothing may be pruned
        let lower = [0.0; 5];
        let cutoff = [0.5; 5];
        let mut out = vec![0.0f64; 5];
        let computed = s.dist_batch_pruned(&pts, 2, &lower, &cutoff, &mut out);
        assert_eq!(computed, 5);
        let mut reference = vec![0.0f64; 5];
        s.dist_batch(&pts, 2, &mut reference);
        for i in 0..5 {
            assert_eq!(out[i].to_bits(), reference[i].to_bits(), "i={i}");
        }
    }

    #[test]
    fn bulk_ops_charge_point_center_pairs() {
        let s = line_space();
        let pts = [0u32, 1, 2, 3, 4];
        let (_, e) = counter::counted(|| s.nearest_batch(&pts, &[0, 3]));
        assert_eq!(e, 10, "nearest_batch charges |pts|*|centers|");
        let mut out = vec![0.0f64; 5];
        let (_, e) = counter::counted(|| s.dist_batch(&pts, 2, &mut out));
        assert_eq!(e, 5, "dist_batch charges |pts|");
        let mut cur = vec![f64::INFINITY; 5];
        let (_, e) = counter::counted(|| s.min_update(&pts, 2, &mut cur));
        assert_eq!(e, 5, "min_update charges |pts|");
        let (_, e) = counter::counted(|| s.dist(0, 4));
        assert_eq!(e, 1, "dist charges 1");
    }
}
