//! Deterministic PRNG (no `rand` crate offline): SplitMix64 seeding a
//! xoshiro256** core, plus the sampling utilities the algorithms need.
//!
//! Every stochastic component in the crate takes an explicit seed so runs
//! (and failures) are exactly reproducible.

/// xoshiro256** seeded via SplitMix64. Not cryptographic; statistical
/// quality is more than sufficient for sampling/seeding experiments.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-adversarial) use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; generation is not a hot path).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (m <= n), in random order.
    pub fn sample_distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "sample_distinct: m={m} > n={n}");
        if m * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(m);
            idx
        } else {
            // Floyd's algorithm for sparse samples.
            let mut chosen = std::collections::HashSet::with_capacity(m);
            let mut out = Vec::with_capacity(m);
            for j in (n - m)..n {
                let t = self.below(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }

    /// Weighted index sampling: probability proportional to weights[i].
    /// Returns None if all weights are zero/non-finite.
    pub fn weighted_index(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().copied().filter(|w| w.is_finite()).sum();
        if total <= 0.0 {
            return None;
        }
        let mut target = self.f64() * total;
        let mut last_valid = None;
        for (i, &w) in weights.iter().enumerate() {
            if !w.is_finite() || w <= 0.0 {
                continue;
            }
            last_valid = Some(i);
            if target < w {
                return Some(i);
            }
            target -= w;
        }
        last_valid // float round-off fell off the end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(13);
        for (n, m) in [(10, 10), (100, 3), (50, 25), (1, 1), (5, 0)] {
            let s = r.sample_distinct(n, m);
            assert_eq!(s.len(), m);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), m, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(17);
        let w = [0.0, 10.0, 0.0, 1.0];
        let mut counts = [0usize; 4];
        for _ in 0..5000 {
            counts[r.weighted_index(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 0);
        assert!(counts[1] > counts[3] * 5, "{counts:?}");
    }

    #[test]
    fn weighted_index_zero_total() {
        let mut r = Rng::new(19);
        assert_eq!(r.weighted_index(&[0.0, 0.0]), None);
        assert_eq!(r.weighted_index(&[]), None);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
