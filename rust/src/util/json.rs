//! Minimal JSON document model: a serializer and a strict parser
//! (serde is unavailable in the offline image). Shared by the
//! observability subsystem (`obs::event` JSONL lines,
//! `RunReport::to_json`), the bench metadata in `util::bench`, and the
//! `mrcoreset report` / `bench-diff` CLI readers — every JSON document
//! this crate writes must round-trip through this parser, which the
//! obs schema tests pin.
//!
//! Objects preserve insertion order on write and parse order on read,
//! so a serialize → parse → serialize round trip is byte-stable for the
//! documents this crate produces (numbers are emitted via Rust's
//! shortest-roundtrip `f64` formatting, with integral values written
//! without a fractional part).

use std::fmt;

/// A parsed or under-construction JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (documents here are small; linear key
    /// lookup is fine and keeps ordering deterministic).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object. Panics on non-objects —
    /// builder misuse, not data-dependent.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(entries) => {
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = value;
                } else {
                    entries.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup (None for absent keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(entries) => Some(entries),
            _ => None,
        }
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Parse a complete JSON document (trailing non-whitespace is an
    /// error — JSONL readers split on newlines before calling this).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no Inf/NaN; writers assert finiteness, so
                    // this is a belt-and-braces null, not a silent lie.
                    f.write_str("null")
                } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(entries) => {
                f.write_str("{")?;
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_str(c.encode_utf8(&mut [0u8; 4]))?,
        }
    }
    f.write_str("\"")
}

/// Parse error with a byte offset, enough to locate a bad JSONL line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs are not produced by this
                            // crate's writers; reject rather than mangle
                            let c = char::from_u32(code)
                                .ok_or_else(|| self.err("unsupported \\u code point"))?;
                            out.push(c);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy the full UTF-8 scalar (input came from &str,
                    // so the byte stream is valid UTF-8)
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0b1100_0000) == 0b1000_0000
                    {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_serializes_objects_in_order() {
        let mut o = Json::obj();
        o.set("b", Json::num(2.0)).set("a", Json::str("x")).set("b", Json::num(3.0));
        assert_eq!(o.to_string(), "{\"b\":3,\"a\":\"x\"}");
    }

    #[test]
    fn parses_nested_documents() {
        let doc = r#"{"name":"r1","vals":[1, 2.5, -3e2],"ok":true,"none":null}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("r1"));
        let vals = v.get("vals").unwrap().as_arr().unwrap();
        assert_eq!(vals[0].as_u64(), Some(1));
        assert_eq!(vals[1].as_f64(), Some(2.5));
        assert_eq!(vals[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn round_trip_is_byte_stable() {
        let doc = r#"{"a":{"b":[1,2,{"c":"d \" e\\"}]},"n":1.25,"big":123456789}"#;
        let v = Json::parse(doc).unwrap();
        let s1 = v.to_string();
        let v2 = Json::parse(&s1).unwrap();
        assert_eq!(v, v2);
        assert_eq!(s1, v2.to_string());
    }

    #[test]
    fn escapes_and_unescapes_strings() {
        let v = Json::Str("line\nquote\" tab\t back\\ \u{1}".to_string());
        let s = v.to_string();
        assert_eq!(s, "\"line\\nquote\\\" tab\\t back\\\\ \\u0001\"");
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn integral_floats_written_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(-7.0).to_string(), "-7");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"open", "1 2", "{\"a\":1}x"] {
            assert!(Json::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn as_u64_rejects_fractional_and_negative() {
        assert_eq!(Json::num(3.0).as_u64(), Some(3));
        assert_eq!(Json::num(3.5).as_u64(), None);
        assert_eq!(Json::num(-1.0).as_u64(), None);
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"µs ≤ ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("µs ≤ ∞"));
    }
}
