//! Scoped data-parallel helpers on std threads (tokio/rayon unavailable
//! offline). The MapReduce simulator runs each round's reducers through
//! `scoped_map`; worker panics are propagated to the caller.

/// Run `f(i)` for i in 0..n on up to `threads` OS threads and collect the
/// results in order. Panics in workers are re-raised here.
pub fn scoped_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(threads > 0);
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.min(n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots_ptr = &slots_ptr;
            handles.push(scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index i is claimed by exactly one worker via
                // the atomic counter, so writes to disjoint slots never alias;
                // the scope guarantees the buffer outlives all workers.
                unsafe { slots_ptr.0.add(i).write(Some(v)) };
            }));
        }
        for h in handles {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("worker missed slot")).collect()
}

struct SendPtr<T>(*mut T);
// SAFETY: raw pointer shared across scoped workers that write disjoint slots.
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Default worker count: physical parallelism, capped (the simulator's
/// reducers are memory-metered, not latency-sensitive).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = scoped_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = scoped_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = scoped_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = scoped_map(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn propagates_panics() {
        scoped_map(10, 4, |i| {
            if i == 7 {
                panic!("boom");
            }
            i
        });
    }
}
