//! Fixed-capacity membership bitset over `u32` ids.
//!
//! The local-search pass loops (plain and outlier-robust) test every
//! swap-in candidate against the current center set; a `Vec::contains`
//! there is an O(k) scan per candidate. Centers are global point indices
//! `< n_points`, so a word-packed bitset gives O(1) membership with one
//! bit per point.

/// A set of `u32` ids below a fixed capacity, packed 64 per word.
#[derive(Clone, Debug)]
pub struct Bitset {
    words: Vec<u64>,
}

impl Bitset {
    /// Empty set able to hold ids in `0..capacity`.
    pub fn new(capacity: usize) -> Bitset {
        Bitset { words: vec![0u64; capacity.div_ceil(64)] }
    }

    /// Build directly from a slice of member ids.
    pub fn from_members(capacity: usize, members: &[u32]) -> Bitset {
        let mut s = Bitset::new(capacity);
        for &m in members {
            s.insert(m);
        }
        s
    }

    #[inline]
    pub fn insert(&mut self, id: u32) {
        self.words[id as usize / 64] |= 1u64 << (id % 64);
    }

    #[inline]
    pub fn remove(&mut self, id: u32) {
        self.words[id as usize / 64] &= !(1u64 << (id % 64));
    }

    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        self.words[id as usize / 64] >> (id % 64) & 1 != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains() {
        let mut s = Bitset::new(200);
        assert!(!s.contains(0));
        assert!(!s.contains(199));
        s.insert(0);
        s.insert(63);
        s.insert(64);
        s.insert(199);
        for id in [0u32, 63, 64, 199] {
            assert!(s.contains(id), "{id}");
        }
        assert!(!s.contains(1));
        assert!(!s.contains(128));
        s.remove(64);
        assert!(!s.contains(64));
        assert!(s.contains(63) && s.contains(199));
        // removing an absent id is a no-op
        s.remove(100);
        assert!(s.contains(0));
    }

    #[test]
    fn from_members_matches_linear_scan() {
        let members = [3u32, 17, 64, 65, 127];
        let s = Bitset::from_members(128, &members);
        for id in 0..128u32 {
            assert_eq!(s.contains(id), members.contains(&id), "{id}");
        }
    }

    #[test]
    fn capacity_rounds_up_to_word() {
        let mut s = Bitset::new(1);
        s.insert(0);
        assert!(s.contains(0));
        let s0 = Bitset::new(0);
        assert_eq!(s0.words.len(), 0);
    }
}
