//! Seeded property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs the property over `cases`
//! independently-seeded RNGs; on failure it reports the failing case seed
//! so `check_one(seed, ...)` reproduces it exactly. Coordinator and
//! coreset invariants (routing, batching, weight conservation, cover
//! guarantees) are tested through this.

use super::rng::Rng;

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `cases` seeded cases derived from `base_seed`.
/// Panics with the failing seed + message on the first failure.
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(
    name: &str,
    base_seed: u64,
    cases: usize,
    mut prop: F,
) {
    for case in 0..cases {
        let seed = derive_seed(base_seed, case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single case by seed (for debugging a reported failure).
pub fn check_one<F: FnMut(&mut Rng) -> CaseResult>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

fn derive_seed(base: u64, case: u64) -> u64 {
    base ^ case.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17)
}

/// Assert helper producing `CaseResult`s.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("below-bound", 1, 50, |rng| {
            let n = 1 + rng.below(100);
            let v = rng.below(n);
            prop_assert!(v < n, "v={v} n={n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn reports_failures_with_seed() {
        check("always-fails", 2, 10, |_rng| Err("nope".to_string()));
    }

    #[test]
    fn seeds_differ_across_cases() {
        assert_ne!(derive_seed(1, 0), derive_seed(1, 1));
        assert_ne!(derive_seed(1, 1), derive_seed(2, 1));
    }
}
