//! Markdown table builder for experiment and benchmark reports.

#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity != header arity");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_markdown(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let body: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("| {} |\n", sep.join(" | ")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        let _ = ncols;
        out
    }
}

/// Format a float compactly for tables.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1e6 || v.abs() < 1e-3 {
        format!("{v:.3e}")
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]).row(vec!["333", "4"]);
        let md = t.to_markdown();
        assert!(md.starts_with("| a   | b |\n"));
        assert!(md.contains("| 333 | 4 |"));
        assert_eq!(md.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1"]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234567.0), "1.235e6");
        assert_eq!(fnum(0.25), "0.2500");
        assert_eq!(fnum(123.456), "123.5");
    }
}
