//! Minimal CLI argument parser (clap is unavailable in the offline image).
//!
//! Supports `program <subcommand> --flag value --bool-flag positional...`.
//! Typed getters parse on access and produce uniform error messages.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]). Flags may be written
    /// `--key value` or `--key=value`; a flag with no following value (or
    /// followed by another flag) is boolean. Single-dash tokens (`-v`,
    /// `-q`) are boolean short flags unless they parse as a number
    /// (`--shift -3.5` still works). A bare token following a flag is
    /// consumed as that flag's value, so positionals must precede flags
    /// (or boolean flags must be written last / with `=`).
    pub fn parse(raw: &[String]) -> Args {
        // A flag-shaped token: dashed and not a bare negative number.
        fn is_flag(tok: &str) -> bool {
            tok.starts_with('-') && tok.len() > 1 && tok.parse::<f64>().is_err()
        }
        let mut it = raw.iter().peekable();
        let mut subcommand = None;
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        let mut positional = Vec::new();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !is_flag(next) => {
                            flags.insert(stripped.to_string(), it.next().unwrap().clone());
                        }
                        _ => bools.push(stripped.to_string()),
                    }
                }
            } else if is_flag(tok) {
                // single-dash short flag: always boolean, never takes a value
                bools.push(tok[1..].to_string());
            } else {
                positional.push(tok.clone());
            }
        }
        Args { subcommand, flags, bools, positional }
    }

    pub fn from_env() -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    /// Look up a value-taking flag. `Ok(None)` when absent, `Err` when
    /// the flag was written without a value — `--key` as the last token
    /// or directly followed by another flag parses as boolean, and
    /// accessing it through a value getter is a usage error that must
    /// name the flag, not silently read as "not given".
    pub fn try_get(&self, key: &str) -> Result<Option<&str>, String> {
        if let Some(v) = self.flags.get(key) {
            return Ok(Some(v.as_str()));
        }
        if self.bools.iter().any(|b| b == key) {
            return Err(format!("flag --{key} requires a value"));
        }
        Ok(None)
    }

    /// String flag with a fallback. All typed getters are `Result`s so
    /// library and test consumers can handle usage errors; only the
    /// top-level command layer turns an `Err` into exit(2).
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> Result<&'a str, String> {
        Ok(self.try_get(key)?.unwrap_or(default))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.try_get(key)? {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} {v}: {e}")),
        }
    }

    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.try_get(key)? {
            Some(v) => v.parse().map_err(|e| format!("--{key} {v}: {e}")),
            None => Err(format!("missing required flag --{key}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        let raw: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&raw)
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args("run data.csv --n 1000 --eps=0.25 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.try_get("n"), Ok(Some("1000")));
        assert_eq!(a.try_get("eps"), Ok(Some("0.25")));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["data.csv"]);
    }

    #[test]
    fn typed_getters() {
        let a = args("run --n 1000");
        assert_eq!(a.parse_or("n", 5usize), Ok(1000));
        assert_eq!(a.parse_or("k", 5usize), Ok(5));
        assert!((a.parse_or("eps", 0.5f64).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(a.require::<usize>("n"), Ok(1000));
    }

    #[test]
    fn typed_getters_report_usage_errors_instead_of_exiting() {
        let a = args("run --n ten --flag");
        // bad parse, missing required flag, and value-less flag are all
        // recoverable Errs naming the flag — no exit path in the library
        let err = a.parse_or("n", 5usize).unwrap_err();
        assert!(err.contains("--n ten"), "{err}");
        let err = a.require::<f64>("eps").unwrap_err();
        assert!(err.contains("missing required flag --eps"), "{err}");
        let err = a.str_or("flag", "dflt").unwrap_err();
        assert!(err.contains("--flag requires a value"), "{err}");
        assert_eq!(a.str_or("absent", "dflt"), Ok("dflt"));
    }

    #[test]
    fn bool_flag_before_flag() {
        let a = args("run --fast --n 10");
        assert!(a.has("fast"));
        assert_eq!(a.try_get("n"), Ok(Some("10")));
    }

    #[test]
    fn no_subcommand() {
        let a = args("--n 10");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.try_get("n"), Ok(Some("10")));
    }

    #[test]
    fn negative_number_value() {
        let a = args("run --shift=-3.5");
        assert_eq!(a.try_get("shift"), Ok(Some("-3.5")));
        let a = args("run --shift -3.5");
        assert_eq!(a.try_get("shift"), Ok(Some("-3.5")));
    }

    #[test]
    fn short_flags_are_boolean() {
        let a = args("run -v --n 10");
        assert!(a.has("v"));
        assert_eq!(a.try_get("n"), Ok(Some("10")));
        // a short flag after a long flag is NOT consumed as its value
        let a = args("run --json -q");
        assert!(a.has("json"), "--json must stay boolean: {a:?}");
        assert!(a.has("q"));
        assert_eq!(a.try_get("json"), Err("flag --json requires a value".to_string()));
    }

    #[test]
    fn trailing_value_flag_is_a_usage_error_naming_the_flag() {
        // `--n` with nothing after it parses as boolean; reading it as a
        // value must surface a structured error, never a silent default
        let a = args("run --eps 0.5 --n");
        assert_eq!(a.try_get("eps"), Ok(Some("0.5")));
        let err = a.try_get("n").unwrap_err();
        assert!(err.contains("--n"), "error must name the flag: {err}");
        assert!(err.contains("requires a value"), "{err}");
        // absent flags stay a clean None
        assert_eq!(a.try_get("k"), Ok(None));
    }
}
