//! Small statistics helpers used by the experiment harness: summary
//! statistics, percentiles, and least-squares fits for the log-log
//! scaling experiments (E2/E6/E10).

/// Summary of a sample (not streaming; experiments keep all values).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub max: f64,
}

pub fn summarize(values: &[f64]) -> Summary {
    assert!(!values.is_empty(), "summarize: empty sample");
    let n = values.len();
    let mean = values.iter().sum::<f64>() / n as f64;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: percentile_sorted(&sorted, 50.0),
        p90: percentile_sorted(&sorted, 90.0),
        max: sorted[n - 1],
    }
}

/// Skew-oriented summary of a per-reducer sample (memory, dist_evals):
/// the three numbers the telemetry layer reports everywhere.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Distribution {
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Distribution {
    /// Summarize a sample; empty samples yield all-zero (a round with no
    /// reducers has no distribution to speak of).
    pub fn of(values: &[f64]) -> Distribution {
        if values.is_empty() {
            return Distribution { p50: 0.0, p95: 0.0, max: 0.0 };
        }
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Distribution {
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[sorted.len() - 1],
        }
    }

    /// Straggler ratio max/p50: 1.0 means perfectly balanced. An
    /// all-zero sample is balanced by convention; a zero median with
    /// nonzero max is unboundedly skewed.
    pub fn skew(&self) -> f64 {
        if self.p50 > 0.0 {
            self.max / self.p50
        } else if self.max > 0.0 {
            f64::INFINITY
        } else {
            1.0
        }
    }
}

/// Percentile with linear interpolation; input must be sorted ascending.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Ordinary least squares y = a + b x. Returns (a, b, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linear_fit needs >= 2 points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let a = my - b * mx;
    let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    (a, b, r2)
}

/// Fit y = C * x^e by OLS in log-log space. Returns (C, e, r2).
/// Ignores non-positive pairs (they carry no scaling information).
pub fn power_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    let pairs: (Vec<f64>, Vec<f64>) = xs
        .iter()
        .zip(ys)
        .filter(|(x, y)| **x > 0.0 && **y > 0.0)
        .map(|(x, y)| (x.ln(), y.ln()))
        .unzip();
    let (a, b, r2) = linear_fit(&pairs.0, &pairs.1);
    (a.exp(), b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn distribution_of_sample_and_skew() {
        let d = Distribution::of(&[1.0, 1.0, 1.0, 1.0, 9.0]);
        assert_eq!(d.p50, 1.0);
        assert_eq!(d.max, 9.0);
        assert!((d.skew() - 9.0).abs() < 1e-12);
        let balanced = Distribution::of(&[4.0, 4.0, 4.0]);
        assert_eq!(balanced.skew(), 1.0);
        let empty = Distribution::of(&[]);
        assert_eq!(empty, Distribution { p50: 0.0, p95: 0.0, max: 0.0 });
        assert_eq!(empty.skew(), 1.0);
        assert_eq!(Distribution { p50: 0.0, p95: 0.0, max: 2.0 }.skew(), f64::INFINITY);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn power_fit_recovers_exponent() {
        let xs = [10.0, 100.0, 1000.0, 10000.0];
        let ys: Vec<f64> = xs.iter().map(|x: &f64| 5.0 * x.powf(0.667)).collect();
        let (c, e, r2) = power_fit(&xs, &ys);
        assert!((e - 0.667).abs() < 1e-6, "e={e}");
        assert!((c - 5.0).abs() < 1e-6, "c={c}");
        assert!(r2 > 0.999);
    }
}
