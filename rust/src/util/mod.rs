//! Offline-image substitutions for common crates (see DESIGN.md §5):
//! PRNG (`rand`), CLI (`clap`), thread pool (`rayon`/`tokio`), bench
//! harness (`criterion`), property testing (`proptest`), plus stats and
//! markdown tables for the experiment harness.

pub mod bench;
pub mod bitset;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
