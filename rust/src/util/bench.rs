//! Criterion-less micro-benchmark harness (criterion is unavailable in
//! the offline image). Warmup + fixed sample count, reports median and
//! spread; used by `rust/benches/bench_main.rs` (`cargo bench`).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn throughput_per_sec(&self, items: usize) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

fn dur_from_secs(s: f64) -> Duration {
    Duration::from_secs_f64(s.max(0.0))
}

/// Time `f` with `samples` measured runs after `warmup` unmeasured runs.
/// `f` should return something cheap to drop; use `std::hint::black_box`
/// inside to defeat const-folding.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |p: f64| crate::util::stats::percentile_sorted(&times, p);
    BenchResult {
        name: name.to_string(),
        samples,
        median: dur_from_secs(pick(50.0)),
        p10: dur_from_secs(pick(10.0)),
        p90: dur_from_secs(pick(90.0)),
        mean: dur_from_secs(times.iter().sum::<f64>() / samples as f64),
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.p10),
            fmt_duration(self.p90),
            self.samples
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        assert!(r.median > Duration::ZERO);
        assert!(r.p10 <= r.median && r.median <= r.p90);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0 µs");
    }
}
