//! Criterion-less micro-benchmark harness (criterion is unavailable in
//! the offline image). Warmup + fixed sample count, reports median and
//! spread; used by `rust/benches/bench_main.rs` (`cargo bench`).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn throughput_per_sec(&self, items: usize) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

fn dur_from_secs(s: f64) -> Duration {
    Duration::from_secs_f64(s.max(0.0))
}

/// Time `f` with `samples` measured runs after `warmup` unmeasured runs.
/// `f` should return something cheap to drop; use `std::hint::black_box`
/// inside to defeat const-folding.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |p: f64| crate::util::stats::percentile_sorted(&times, p);
    BenchResult {
        name: name.to_string(),
        samples,
        median: dur_from_secs(pick(50.0)),
        p10: dur_from_secs(pick(10.0)),
        p90: dur_from_secs(pick(90.0)),
        mean: dur_from_secs(times.iter().sum::<f64>() / samples as f64),
    }
}

/// Serialize bench results to a minimal JSON document (no serde in the
/// offline image): `{"benchmarks":[{name, samples, median_s, p10_s,
/// p90_s, mean_s}, ...]}`. Written next to the bench output (e.g.
/// `BENCH_micro.json`, `BENCH_outliers.json`) so the perf trajectory is
/// machine-readable across PRs, not just printed.
pub fn to_json(results: &[BenchResult]) -> String {
    let mut out = String::from("{\"benchmarks\":[");
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"samples\":{},\"median_s\":{:.9},\"p10_s\":{:.9},\"p90_s\":{:.9},\"mean_s\":{:.9}}}",
            json_escape(&r.name),
            r.samples,
            r.median.as_secs_f64(),
            r.p10.as_secs_f64(),
            r.p90.as_secs_f64(),
            r.mean.as_secs_f64(),
        ));
    }
    out.push_str("]}");
    out
}

/// [`to_json`] plus a flat `"metrics"` object of named scalars (work
/// counts, savings ratios — the quantities a timing-only schema cannot
/// carry). Used by the pruning benches for `BENCH_pruning.json`, where
/// the headline number is distance evaluations saved, not seconds.
pub fn to_json_with_metrics(results: &[BenchResult], metrics: &[(&str, f64)]) -> String {
    let mut out = to_json(results);
    // hard asserts: this only ever runs in the bench profile, where
    // debug_assert! would be compiled out and corrupt JSON would ship
    // into the cross-PR artifact series silently
    assert!(out.ends_with("]}"), "to_json output format changed");
    out.truncate(out.len() - 1); // reopen the top-level object
    out.push_str(",\"metrics\":{");
    for (i, (k, v)) in metrics.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        assert!(v.is_finite(), "metric {k} must be finite for JSON");
        out.push_str(&format!("\"{}\":{}", json_escape(k), v));
    }
    out.push_str("}}");
    out
}

/// Version stamp for the bench JSON document layout; bump when keys
/// move or change meaning so `bench-diff` consumers can refuse to
/// compare across incompatible layouts.
pub const BENCH_SCHEMA_VERSION: u64 = 2;

/// Run metadata stamped into every bench JSON artifact: enough to tell
/// two documents in the cross-PR series apart without opening CI logs.
/// Timings vary by machine; the metadata says *which* machine state
/// (commit, thread count, smoke vs full sizes) produced them.
#[derive(Clone, Debug)]
pub struct BenchMeta {
    pub schema_version: u64,
    pub smoke: bool,
    pub threads: usize,
    pub git_sha: String,
}

impl BenchMeta {
    /// Collect from the environment: thread count from the simulator's
    /// default pool, commit from `GITHUB_SHA` (set by CI) or
    /// `git rev-parse HEAD`, `"unknown"` when neither is available.
    pub fn collect(smoke: bool) -> BenchMeta {
        BenchMeta {
            schema_version: BENCH_SCHEMA_VERSION,
            smoke,
            threads: crate::util::pool::default_threads(),
            git_sha: detect_git_sha(),
        }
    }
}

fn detect_git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Append a `"meta"` object to a document produced by [`to_json`] or
/// [`to_json_with_metrics`]. Kept separate so the measurement helpers
/// stay pure and the environment probe happens once per document.
pub fn with_meta(doc: String, meta: &BenchMeta) -> String {
    let mut out = doc;
    assert!(out.ends_with('}'), "bench JSON must be a top-level object");
    out.truncate(out.len() - 1);
    out.push_str(&format!(
        ",\"meta\":{{\"schema_version\":{},\"smoke\":{},\"threads\":{},\"git_sha\":\"{}\"}}}}",
        meta.schema_version,
        meta.smoke,
        meta.threads,
        json_escape(&meta.git_sha)
    ));
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.p10),
            fmt_duration(self.p90),
            self.samples
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(x);
        });
        assert!(r.median > Duration::ZERO);
        assert!(r.p10 <= r.median && r.median <= r.p90);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0 µs");
    }

    #[test]
    fn json_serialization_is_well_formed() {
        let r = BenchResult {
            name: "assign \"fast\" path".to_string(),
            samples: 3,
            median: Duration::from_millis(2),
            p10: Duration::from_millis(1),
            p90: Duration::from_millis(4),
            mean: Duration::from_millis(2),
        };
        let s = to_json(&[r.clone(), r]);
        assert!(s.starts_with("{\"benchmarks\":["));
        assert!(s.ends_with("]}"));
        assert!(s.contains("\\\"fast\\\""), "quotes must be escaped: {s}");
        assert!(s.contains("\"median_s\":0.002000000"));
        assert_eq!(s.matches("\"name\"").count(), 2);
        // balanced braces/brackets (cheap well-formedness check)
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn json_with_metrics_is_well_formed() {
        let r = BenchResult {
            name: "cover pruned".to_string(),
            samples: 2,
            median: Duration::from_millis(3),
            p10: Duration::from_millis(3),
            p90: Duration::from_millis(3),
            mean: Duration::from_millis(3),
        };
        let s = to_json_with_metrics(&[r], &[("evals_saved_ratio", 16.5), ("evals", 42.0)]);
        assert!(s.contains("\"metrics\":{\"evals_saved_ratio\":16.5,\"evals\":42}"), "{s}");
        assert!(s.starts_with("{\"benchmarks\":["));
        assert!(s.ends_with("}}"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn meta_appends_without_breaking_the_document() {
        let meta = BenchMeta {
            schema_version: BENCH_SCHEMA_VERSION,
            smoke: true,
            threads: 8,
            git_sha: "abc123".to_string(),
        };
        let s = with_meta(to_json(&[]), &meta);
        assert!(
            s.contains("\"meta\":{\"schema_version\":2,\"smoke\":true,\"threads\":8,\"git_sha\":\"abc123\"}"),
            "{s}"
        );
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        // and it must stay parseable by the in-tree JSON reader
        let v = crate::util::json::Json::parse(&s).unwrap();
        let m = v.get("meta").expect("meta object");
        assert_eq!(m.get("threads").and_then(|t| t.as_u64()), Some(8));
        assert_eq!(m.get("smoke").and_then(|t| t.as_bool()), Some(true));
        assert_eq!(m.get("git_sha").and_then(|t| t.as_str()), Some("abc123"));
    }

    #[test]
    fn collected_meta_has_a_sha_and_threads() {
        let meta = BenchMeta::collect(false);
        assert!(!meta.git_sha.is_empty());
        assert!(meta.threads >= 1);
        assert!(!meta.smoke);
        assert_eq!(meta.schema_version, BENCH_SCHEMA_VERSION);
    }

    #[test]
    fn json_escape_handles_control_chars() {
        assert_eq!(json_escape("a\nb"), "a\\u000ab");
        assert_eq!(json_escape("back\\slash"), "back\\\\slash");
        assert_eq!(json_escape("plain"), "plain");
    }
}
