//! Thread-local named counters for instrumented inner loops.
//!
//! The pruning engine (`metric::pruned`, `coreset::cover`) and the local
//! search loop charge counters here by static name (`pruned.evals_charged`,
//! `cover.give_up`, `local_search.swaps`, ...). Like
//! `metric::counter`, the storage is thread-local so worker reducers never
//! contend; the simulator snapshots before/after each reducer closure and
//! attaches the delta to that reducer's span. Deltas are name-sorted and
//! zero entries are dropped, so the attached vectors are deterministic
//! regardless of which loops ran in what order.

use std::cell::RefCell;
use std::collections::BTreeMap;

thread_local! {
    static COUNTERS: RefCell<BTreeMap<&'static str, u64>> = const { RefCell::new(BTreeMap::new()) };
}

/// Charge `n` to the counter `name` on this thread.
pub fn add(name: &'static str, n: u64) {
    if n == 0 {
        return;
    }
    COUNTERS.with(|c| {
        *c.borrow_mut().entry(name).or_insert(0) += n;
    });
}

/// Charge 1 to the counter `name` on this thread.
pub fn incr(name: &'static str) {
    add(name, 1);
}

/// Snapshot of this thread's cumulative counters, for later delta-taking.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    vals: BTreeMap<&'static str, u64>,
}

/// Capture this thread's current counter totals.
pub fn snapshot() -> Snapshot {
    Snapshot { vals: COUNTERS.with(|c| c.borrow().clone()) }
}

/// Counters charged on this thread since `since`, name-sorted, zero
/// deltas dropped. Counters only grow, so the subtraction is safe.
pub fn delta_since(since: &Snapshot) -> Vec<(String, u64)> {
    COUNTERS.with(|c| {
        c.borrow()
            .iter()
            .filter_map(|(name, now)| {
                let before = since.vals.get(name).copied().unwrap_or(0);
                let d = now.saturating_sub(before);
                (d > 0).then(|| (name.to_string(), d))
            })
            .collect()
    })
}

/// Merge per-reducer deltas into one name-sorted total (for round-level
/// aggregation in `RoundStats`).
pub fn merge(parts: &[Vec<(String, u64)>]) -> Vec<(String, u64)> {
    let mut total: BTreeMap<&str, u64> = BTreeMap::new();
    for part in parts {
        for (name, n) in part {
            *total.entry(name.as_str()).or_insert(0) += n;
        }
    }
    total.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

/// Reset this thread's counters to zero (tests only — production code
/// always works in deltas).
pub fn reset() {
    COUNTERS.with(|c| c.borrow_mut().clear());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_is_sorted_and_drops_zeros() {
        reset();
        let before = snapshot();
        add("z.late", 3);
        add("a.early", 2);
        add("m.zero", 0);
        incr("a.early");
        let d = delta_since(&before);
        assert_eq!(d, vec![("a.early".to_string(), 3), ("z.late".to_string(), 3)]);
    }

    #[test]
    fn delta_ignores_pre_snapshot_charges() {
        reset();
        add("x", 10);
        let before = snapshot();
        add("x", 5);
        assert_eq!(delta_since(&before), vec![("x".to_string(), 5)]);
    }

    #[test]
    fn merge_sums_across_parts() {
        let parts = vec![
            vec![("a".to_string(), 1), ("b".to_string(), 2)],
            vec![("b".to_string(), 3), ("c".to_string(), 4)],
        ];
        assert_eq!(
            merge(&parts),
            vec![("a".to_string(), 1), ("b".to_string(), 5), ("c".to_string(), 4)]
        );
    }
}
