//! Recorder implementations: no-op, in-memory, and JSONL file.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

use super::event::Event;
use super::Recorder;

/// The default recorder: drops everything, reports `enabled() == false`
/// so producers skip event assembly entirely.
pub struct NoopRecorder;

impl Recorder for NoopRecorder {
    fn enabled(&self) -> bool {
        false
    }

    fn record(&self, _ev: &Event) {}
}

/// In-memory recorder for tests and the determinism suite.
#[derive(Default)]
pub struct MemSink {
    events: Mutex<Vec<Event>>,
}

impl MemSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clone of everything recorded so far, in record order.
    pub fn snapshot(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Drain the recorded events.
    pub fn take(&self) -> Vec<Event> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

impl Recorder for MemSink {
    fn record(&self, ev: &Event) {
        self.events.lock().unwrap().push(ev.clone());
    }
}

/// JSONL file recorder: one event per line, buffered. Used by
/// `mrcoreset run --trace out.jsonl`.
pub struct JsonlSink {
    out: Mutex<BufWriter<File>>,
}

impl JsonlSink {
    pub fn create(path: &Path) -> io::Result<Self> {
        let file = File::create(path)?;
        Ok(Self { out: Mutex::new(BufWriter::new(file)) })
    }
}

impl Recorder for JsonlSink {
    fn record(&self, ev: &Event) {
        let mut out = self.out.lock().unwrap();
        // An unwritable trace shouldn't abort a clustering run mid-flight;
        // drop the line and let flush report persistent failure.
        let _ = writeln!(out, "{}", ev.to_json());
    }

    fn flush(&self) {
        let _ = self.out.lock().unwrap().flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_is_disabled() {
        let r = NoopRecorder;
        assert!(!r.enabled());
        r.record(&Event::RunEnd { rounds: 0, dist_evals: 0, max_local_memory: 0 });
    }

    #[test]
    fn mem_sink_preserves_record_order() {
        let sink = MemSink::new();
        assert!(sink.enabled());
        sink.record(&Event::RoundStart { round: 0, name: "a".into(), reducers: 1 });
        sink.record(&Event::RoundStart { round: 1, name: "b".into(), reducers: 2 });
        let evs = sink.take();
        assert_eq!(evs.len(), 2);
        assert!(matches!(&evs[0], Event::RoundStart { round: 0, .. }));
        assert!(matches!(&evs[1], Event::RoundStart { round: 1, .. }));
        assert!(sink.snapshot().is_empty(), "take drains");
    }

    #[test]
    fn jsonl_sink_writes_parseable_lines() {
        let dir = std::env::temp_dir().join("mrcoreset-obs-sink-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let sink = JsonlSink::create(&path).unwrap();
        sink.record(&Event::RunStart { schema: 1, label: "t".into() });
        sink.record(&Event::RunEnd { rounds: 3, dist_evals: 7, max_local_memory: 9 });
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            Event::parse(line).unwrap();
        }
        std::fs::remove_file(&path).ok();
    }
}
