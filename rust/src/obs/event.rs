//! Trace event schema (version [`TRACE_SCHEMA_VERSION`]).
//!
//! Every event serializes to one flat JSON object with an `"ev"`
//! discriminator; a trace file is JSONL (one event per line). Schema:
//!
//! | `ev`          | fields                                                                 |
//! |---------------|------------------------------------------------------------------------|
//! | `run_start`   | `schema`, `label`                                                      |
//! | `round_start` | `round`, `name`, `reducers`                                            |
//! | `reducer`     | `round`, `reducer`, `name`, `in_items`, `out_items`, `dist_evals`, `mem_peak`, `mem_bytes`, `wall_us`, `spill_read`, `spill_write`, `attempts`, `counters{}` |
//! | `round_end`   | `round`, `name`, `reducers`, `dist_evals`, `mem_max`, `mem_p50`, `mem_p95`, `bytes_max`, `evals_max`, `evals_p50`, `evals_p95`, `violations`, `wall_us` |
//! | `run_end`     | `rounds`, `dist_evals`, `max_local_memory`, `max_local_bytes`          |
//!
//! Schema v2 adds byte-level residency to the spans: `mem_bytes` /
//! `bytes_max` / `max_local_bytes` are the encoded shard footprints the
//! executors charge (identical across backends — part of the stable
//! form), while `spill_read` / `spill_write` are actual disk traffic
//! (backend-dependent, so gated like `wall_us`). v1 traces still parse;
//! the new numeric fields default to 0.
//!
//! Schema v3 adds fault recovery: `attempts` on reducer spans counts
//! executions of that reducer (1 = first try succeeded). It is emitted
//! only when > 1, so fault-free traces carry no extra bytes, and it is
//! part of the *full* and *stable* forms alike — under a deterministic
//! fault plan the retry pattern is itself deterministic. On parse the
//! field defaults to 1 when absent (v1/v2 traces).
//!
//! Checkpoint-resume caveat: a run resumed from a round-level
//! checkpoint emits span events only for the rounds it actually
//! re-executes. Replayed rounds restore their `RoundStats` into the run
//! report (which stays bit-identical to an uninterrupted run), but the
//! per-reducer item/counter breakdown needed to reconstruct their
//! `round_start`/`reducer`/`round_end` spans is not persisted, so a
//! resumed trace is a *suffix* of the uninterrupted trace. Compare
//! resumed runs by report, not by trace.
//!
//! Determinism contract: every field except `wall_us`, `spill_read` and
//! `spill_write` is a deterministic function of the run's inputs (seeded
//! RNGs, fixed partitioning, byte-parity executor charges), and events
//! are emitted in (round, reducer) order by the coordinator thread — so
//! [`Event::stable_json`] (which omits the gated fields) is
//! bit-identical across thread counts *and* execution backends.
//! `counters` keys are name-sorted on emission.

use crate::util::json::Json;

/// Version stamp written by `run_start`; bump on breaking field changes.
pub const TRACE_SCHEMA_VERSION: u64 = 3;

/// One telemetry event. See the module docs for the field schema.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    RunStart {
        schema: u64,
        label: String,
    },
    RoundStart {
        round: u32,
        name: String,
        reducers: u32,
    },
    /// Per-reducer span: the unit of skew analysis.
    Reducer {
        round: u32,
        reducer: u32,
        name: String,
        in_items: u64,
        out_items: u64,
        dist_evals: u64,
        mem_peak: u64,
        /// Peak resident encoded bytes (executor shard charges).
        mem_bytes: u64,
        wall_us: u64,
        /// Bytes read from / written to the spill store — 0 in-memory,
        /// so wall-gated out of the stable form like `wall_us`.
        spill_read: u64,
        spill_write: u64,
        /// Executions of this reducer (1 = no retries). Serialized only
        /// when > 1; deterministic under a seeded fault plan, so part
        /// of the stable form.
        attempts: u64,
        /// Name-sorted deltas of `obs::counters` charged by this reducer.
        counters: Vec<(String, u64)>,
    },
    RoundEnd {
        round: u32,
        name: String,
        reducers: u32,
        dist_evals: u64,
        mem_max: u64,
        mem_p50: f64,
        mem_p95: f64,
        /// Max over reducers of peak resident encoded bytes.
        bytes_max: u64,
        evals_max: u64,
        evals_p50: f64,
        evals_p95: f64,
        violations: u64,
        wall_us: u64,
    },
    RunEnd {
        rounds: u64,
        dist_evals: u64,
        max_local_memory: u64,
        max_local_bytes: u64,
    },
}

impl Event {
    /// The `"ev"` discriminator string.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::RunStart { .. } => "run_start",
            Event::RoundStart { .. } => "round_start",
            Event::Reducer { .. } => "reducer",
            Event::RoundEnd { .. } => "round_end",
            Event::RunEnd { .. } => "run_end",
        }
    }

    /// Full single-line JSON, wall-clock and spill traffic included.
    pub fn to_json(&self) -> String {
        self.build(true).to_string()
    }

    /// Deterministic single-line JSON: identical to [`Event::to_json`]
    /// minus the `wall_us` and `spill_read`/`spill_write` fields. This
    /// is the comparable form the determinism suite diffs across thread
    /// counts and execution backends.
    pub fn stable_json(&self) -> String {
        self.build(false).to_string()
    }

    fn build(&self, with_wall: bool) -> Json {
        let mut o = Json::obj();
        o.set("ev", Json::str(self.kind()));
        match self {
            Event::RunStart { schema, label } => {
                o.set("schema", Json::num(*schema as f64));
                o.set("label", Json::str(label.clone()));
            }
            Event::RoundStart { round, name, reducers } => {
                o.set("round", Json::num(*round as f64));
                o.set("name", Json::str(name.clone()));
                o.set("reducers", Json::num(*reducers as f64));
            }
            Event::Reducer {
                round,
                reducer,
                name,
                in_items,
                out_items,
                dist_evals,
                mem_peak,
                mem_bytes,
                wall_us,
                spill_read,
                spill_write,
                attempts,
                counters,
            } => {
                o.set("round", Json::num(*round as f64));
                o.set("reducer", Json::num(*reducer as f64));
                o.set("name", Json::str(name.clone()));
                o.set("in_items", Json::num(*in_items as f64));
                o.set("out_items", Json::num(*out_items as f64));
                o.set("dist_evals", Json::num(*dist_evals as f64));
                o.set("mem_peak", Json::num(*mem_peak as f64));
                o.set("mem_bytes", Json::num(*mem_bytes as f64));
                if with_wall {
                    o.set("wall_us", Json::num(*wall_us as f64));
                    o.set("spill_read", Json::num(*spill_read as f64));
                    o.set("spill_write", Json::num(*spill_write as f64));
                }
                if *attempts > 1 {
                    o.set("attempts", Json::num(*attempts as f64));
                }
                let mut c = Json::obj();
                for (k, v) in counters {
                    c.set(k, Json::num(*v as f64));
                }
                o.set("counters", c);
            }
            Event::RoundEnd {
                round,
                name,
                reducers,
                dist_evals,
                mem_max,
                mem_p50,
                mem_p95,
                bytes_max,
                evals_max,
                evals_p50,
                evals_p95,
                violations,
                wall_us,
            } => {
                o.set("round", Json::num(*round as f64));
                o.set("name", Json::str(name.clone()));
                o.set("reducers", Json::num(*reducers as f64));
                o.set("dist_evals", Json::num(*dist_evals as f64));
                o.set("mem_max", Json::num(*mem_max as f64));
                o.set("mem_p50", Json::num(*mem_p50));
                o.set("mem_p95", Json::num(*mem_p95));
                o.set("bytes_max", Json::num(*bytes_max as f64));
                o.set("evals_max", Json::num(*evals_max as f64));
                o.set("evals_p50", Json::num(*evals_p50));
                o.set("evals_p95", Json::num(*evals_p95));
                o.set("violations", Json::num(*violations as f64));
                if with_wall {
                    o.set("wall_us", Json::num(*wall_us as f64));
                }
            }
            Event::RunEnd { rounds, dist_evals, max_local_memory, max_local_bytes } => {
                o.set("rounds", Json::num(*rounds as f64));
                o.set("dist_evals", Json::num(*dist_evals as f64));
                o.set("max_local_memory", Json::num(*max_local_memory as f64));
                o.set("max_local_bytes", Json::num(*max_local_bytes as f64));
            }
        }
        o
    }

    /// Parse one JSONL line back into an event (`wall_us` and the other
    /// gated or v2-only numeric fields default to 0 when absent, so
    /// stable lines and v1 traces parse too). Errors name the missing
    /// or ill-typed field — this is the schema validator the round-trip
    /// test drives.
    pub fn parse(line: &str) -> Result<Event, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let kind = field_str(&v, "ev")?;
        let ev = match kind.as_str() {
            "run_start" => Event::RunStart {
                schema: field_u64(&v, "schema")?,
                label: field_str(&v, "label")?,
            },
            "round_start" => Event::RoundStart {
                round: field_u64(&v, "round")? as u32,
                name: field_str(&v, "name")?,
                reducers: field_u64(&v, "reducers")? as u32,
            },
            "reducer" => {
                let counters = match v.get("counters") {
                    Some(c) => c
                        .as_obj()
                        .ok_or("field `counters` must be an object")?
                        .iter()
                        .map(|(k, val)| {
                            val.as_u64()
                                .map(|n| (k.clone(), n))
                                .ok_or_else(|| format!("counter `{k}` must be a u64"))
                        })
                        .collect::<Result<Vec<_>, _>>()?,
                    None => return Err("missing field `counters`".to_string()),
                };
                Event::Reducer {
                    round: field_u64(&v, "round")? as u32,
                    reducer: field_u64(&v, "reducer")? as u32,
                    name: field_str(&v, "name")?,
                    in_items: field_u64(&v, "in_items")?,
                    out_items: field_u64(&v, "out_items")?,
                    dist_evals: field_u64(&v, "dist_evals")?,
                    mem_peak: field_u64(&v, "mem_peak")?,
                    mem_bytes: opt_u64(&v, "mem_bytes"),
                    wall_us: opt_u64(&v, "wall_us"),
                    spill_read: opt_u64(&v, "spill_read"),
                    spill_write: opt_u64(&v, "spill_write"),
                    attempts: opt_u64(&v, "attempts").max(1),
                    counters,
                }
            }
            "round_end" => Event::RoundEnd {
                round: field_u64(&v, "round")? as u32,
                name: field_str(&v, "name")?,
                reducers: field_u64(&v, "reducers")? as u32,
                dist_evals: field_u64(&v, "dist_evals")?,
                mem_max: field_u64(&v, "mem_max")?,
                mem_p50: field_f64(&v, "mem_p50")?,
                mem_p95: field_f64(&v, "mem_p95")?,
                bytes_max: opt_u64(&v, "bytes_max"),
                evals_max: field_u64(&v, "evals_max")?,
                evals_p50: field_f64(&v, "evals_p50")?,
                evals_p95: field_f64(&v, "evals_p95")?,
                violations: field_u64(&v, "violations")?,
                wall_us: opt_u64(&v, "wall_us"),
            },
            "run_end" => Event::RunEnd {
                rounds: field_u64(&v, "rounds")?,
                dist_evals: field_u64(&v, "dist_evals")?,
                max_local_memory: field_u64(&v, "max_local_memory")?,
                max_local_bytes: opt_u64(&v, "max_local_bytes"),
            },
            other => return Err(format!("unknown event kind `{other}`")),
        };
        Ok(ev)
    }

    /// Copy with the gated fields (`wall_us`, `spill_read`,
    /// `spill_write`) zeroed — the canonical comparable form.
    pub fn without_wall(&self) -> Event {
        let mut e = self.clone();
        match &mut e {
            Event::Reducer { wall_us, spill_read, spill_write, .. } => {
                *wall_us = 0;
                *spill_read = 0;
                *spill_write = 0;
            }
            Event::RoundEnd { wall_us, .. } => *wall_us = 0,
            _ => {}
        }
        e
    }
}

fn field_str(v: &Json, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(|f| f.as_str())
        .map(str::to_string)
        .ok_or_else(|| format!("missing or non-string field `{key}`"))
}

fn field_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(|f| f.as_u64())
        .ok_or_else(|| format!("missing or non-u64 field `{key}`"))
}

fn field_f64(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(|f| f.as_f64())
        .ok_or_else(|| format!("missing or non-numeric field `{key}`"))
}

fn opt_u64(v: &Json, key: &str) -> u64 {
    v.get(key).and_then(|f| f.as_u64()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_reducer() -> Event {
        Event::Reducer {
            round: 2,
            reducer: 5,
            name: "coreset-r1-local".to_string(),
            in_items: 1000,
            out_items: 42,
            dist_evals: 123456,
            mem_peak: 1100,
            mem_bytes: 4408,
            wall_us: 777,
            spill_read: 4008,
            spill_write: 400,
            attempts: 1,
            counters: vec![("cover.iterations".to_string(), 42), ("pruned.give_up".to_string(), 1)],
        }
    }

    #[test]
    fn every_kind_round_trips() {
        let events = vec![
            Event::RunStart { schema: TRACE_SCHEMA_VERSION, label: "test".to_string() },
            Event::RoundStart { round: 0, name: "r1".to_string(), reducers: 8 },
            sample_reducer(),
            Event::RoundEnd {
                round: 2,
                name: "coreset-r1-local".to_string(),
                reducers: 8,
                dist_evals: 999,
                mem_max: 1100,
                mem_p50: 1000.5,
                mem_p95: 1090.0,
                bytes_max: 4408,
                evals_max: 200,
                evals_p50: 150.0,
                evals_p95: 190.0,
                violations: 0,
                wall_us: 88,
            },
            Event::RunEnd {
                rounds: 3,
                dist_evals: 5000,
                max_local_memory: 1100,
                max_local_bytes: 4408,
            },
        ];
        for ev in events {
            let parsed = Event::parse(&ev.to_json()).unwrap();
            assert_eq!(parsed, ev, "full json must round-trip");
        }
    }

    #[test]
    fn stable_json_omits_gated_fields_only() {
        let ev = sample_reducer();
        let full = ev.to_json();
        let stable = ev.stable_json();
        assert!(full.contains("\"wall_us\":777"));
        assert!(full.contains("\"spill_read\":4008"));
        assert!(full.contains("\"spill_write\":400"));
        assert!(!stable.contains("wall_us"));
        assert!(!stable.contains("spill_read"));
        assert!(!stable.contains("spill_write"));
        // the byte residency is part of the stable (backend-invariant) form
        assert!(stable.contains("\"mem_bytes\":4408"));
        // stable lines still parse, with the gated fields zeroed
        assert_eq!(Event::parse(&stable).unwrap(), ev.without_wall());
    }

    #[test]
    fn v1_reducer_lines_still_parse() {
        // a line written by schema v1 (no byte or spill fields)
        let line = "{\"ev\":\"reducer\",\"round\":0,\"reducer\":1,\"name\":\"r\",\"in_items\":3,\
                    \"out_items\":1,\"dist_evals\":9,\"mem_peak\":3,\"wall_us\":5,\"counters\":{}}";
        match Event::parse(line).unwrap() {
            Event::Reducer { mem_bytes, spill_read, spill_write, wall_us, .. } => {
                assert_eq!((mem_bytes, spill_read, spill_write, wall_us), (0, 0, 0, 5));
            }
            other => panic!("expected reducer, got {other:?}"),
        }
    }

    #[test]
    fn parse_rejects_missing_fields_and_unknown_kinds() {
        assert!(Event::parse("{\"ev\":\"nope\"}").unwrap_err().contains("unknown event kind"));
        assert!(Event::parse("{\"round\":1}").unwrap_err().contains("`ev`"));
        let err = Event::parse("{\"ev\":\"round_start\",\"round\":0,\"name\":\"x\"}").unwrap_err();
        assert!(err.contains("`reducers`"), "{err}");
        assert!(Event::parse("not json at all").is_err());
    }

    #[test]
    fn attempts_emitted_only_when_retried() {
        let clean = sample_reducer();
        assert!(!clean.to_json().contains("attempts"), "attempts=1 must stay implicit");
        let mut retried = clean;
        if let Event::Reducer { attempts, .. } = &mut retried {
            *attempts = 3;
        }
        let full = retried.to_json();
        let stable = retried.stable_json();
        assert!(full.contains("\"attempts\":3"), "{full}");
        assert!(stable.contains("\"attempts\":3"), "retries are part of the stable form: {stable}");
        assert_eq!(Event::parse(&full).unwrap(), retried);
        // v2 lines without the field parse as a single attempt
        let line = "{\"ev\":\"reducer\",\"round\":0,\"reducer\":1,\"name\":\"r\",\"in_items\":3,\
                    \"out_items\":1,\"dist_evals\":9,\"mem_peak\":3,\"counters\":{}}";
        match Event::parse(line).unwrap() {
            Event::Reducer { attempts, .. } => assert_eq!(attempts, 1),
            other => panic!("expected reducer, got {other:?}"),
        }
    }

    #[test]
    fn counters_serialize_as_nested_object() {
        let s = sample_reducer().to_json();
        assert!(s.contains("\"counters\":{\"cover.iterations\":42,\"pruned.give_up\":1}"), "{s}");
    }
}
