//! Structured run telemetry.
//!
//! The paper's claims are *distributional*: local memory per reducer,
//! distance-evaluation work per round, skew across machines. A single
//! `max_local_peak` number cannot show stragglers, and the pruning
//! engine's adaptive give-up decisions (`metric::pruned`,
//! `coreset::cover`) are invisible from outside. This module makes a run
//! observable without touching its semantics:
//!
//! - [`Recorder`] — the event sink the [`crate::mapreduce::Simulator`]
//!   drives per round and per reducer. Implementations:
//!   [`sink::JsonlSink`] (one JSON object per line, for
//!   `mrcoreset run --trace`), [`sink::MemSink`] (in-memory, for tests
//!   and the determinism suite), [`sink::NoopRecorder`] (the default —
//!   `enabled()` is false and the simulator skips event assembly
//!   entirely, so an untraced run pays one branch per round).
//! - [`event::Event`] — the trace schema (see `event` module docs).
//!   Events are emitted by the coordinator thread **keyed and ordered by
//!   (round, reducer index)**, never by arrival order, so a trace is
//!   bit-identical across simulator thread counts; wall-clock lives in
//!   dedicated `wall_us` fields that [`event::Event::stable_json`]
//!   omits, keeping every comparable byte deterministic.
//! - [`counters`] — thread-local named counters charged by the pruning
//!   and search loops (`pruned.*`, `cover.*`, `local_search.*`). The
//!   simulator snapshots them around each reducer closure — exactly as
//!   it does `metric::counter` — and attaches the per-reducer deltas to
//!   the reducer's span event and to `RoundStats::counters`.
//! - [`log`] — the human sink: global verbosity (`-v` / `--quiet`) and
//!   leveled progress output, replacing ad-hoc `eprintln!` notes.
//!
//! The schema contract is pinned by `tests/obs_trace.rs`: every event
//! round-trips through `to_json` → JSONL → [`event::Event::parse`], and
//! `mrcoreset report` renders any trace this module wrote.

pub mod counters;
pub mod event;
pub mod log;
pub mod sink;

pub use event::{Event, TRACE_SCHEMA_VERSION};
pub use sink::{JsonlSink, MemSink, NoopRecorder};

use std::sync::Arc;

/// An event sink for structured run telemetry. Implementations must be
/// cheap to call (the simulator invokes `record` once per reducer per
/// round, from the coordinator thread only) and thread-safe (`solve`
/// may run on any thread).
pub trait Recorder: Send + Sync {
    /// False for the no-op recorder: producers skip event assembly.
    fn enabled(&self) -> bool {
        true
    }

    /// Record one event. Events arrive in deterministic
    /// (round, reducer) order from a single thread per run.
    fn record(&self, ev: &Event);

    /// Flush buffered output (JSONL sink); default no-op.
    fn flush(&self) {}
}

/// The shared disabled recorder (the default everywhere).
pub fn noop() -> Arc<dyn Recorder> {
    Arc::new(NoopRecorder)
}
