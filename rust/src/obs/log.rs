//! The human sink: leveled progress output on stderr.
//!
//! Verbosity is a process-global (`--quiet` = 0, default = 1, `-v` = 2);
//! structured data goes through [`super::Recorder`] — this module is only
//! for messages meant to be read by a person, replacing the ad-hoc
//! `eprintln!` notes scattered through the runtime and metric layers.

use std::sync::atomic::{AtomicU8, Ordering};

/// Suppress everything except hard errors.
pub const QUIET: u8 = 0;
/// Default: warnings and one-line progress notes.
pub const NORMAL: u8 = 1;
/// `-v`: per-phase detail.
pub const VERBOSE: u8 = 2;

static VERBOSITY: AtomicU8 = AtomicU8::new(NORMAL);

pub fn set_verbosity(level: u8) {
    VERBOSITY.store(level, Ordering::Relaxed);
}

pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

/// Warning: shown unless `--quiet`.
pub fn warn(msg: &str) {
    if verbosity() >= NORMAL {
        eprintln!("warning: {msg}");
    }
}

/// Progress note: shown unless `--quiet`.
pub fn info(msg: &str) {
    if verbosity() >= NORMAL {
        eprintln!("{msg}");
    }
}

/// Detail shown only with `-v`.
pub fn debug(msg: &str) {
    if verbosity() >= VERBOSE {
        eprintln!("{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verbosity_round_trips() {
        let prev = verbosity();
        set_verbosity(QUIET);
        assert_eq!(verbosity(), QUIET);
        set_verbosity(VERBOSE);
        assert_eq!(verbosity(), VERBOSE);
        set_verbosity(prev);
    }
}
