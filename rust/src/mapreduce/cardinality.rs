//! Item counts for reducer inputs and outputs.
//!
//! The simulator reports `in_items` / `out_items` on every reducer span
//! (see `obs::event`), so traces can show shuffle skew without the
//! drivers computing anything. The convention follows the codebase's
//! types: a `u32`/`u64` is a point id or weight (1 item), a `usize` or
//! `f64` is a label or scalar statistic (0 items), containers count
//! their elements, tuples sum.

use crate::algorithms::Solution;
use crate::coreset::local::LocalCoresetOut;
use crate::metric::Assignment;
use crate::points::WeightedSet;

/// Number of logical items a reducer input/output carries.
pub trait Cardinality {
    fn cardinality(&self) -> u64;
}

impl Cardinality for () {
    fn cardinality(&self) -> u64 {
        0
    }
}

/// Labels and indices (partition numbers, counts) are not shuffled data.
impl Cardinality for usize {
    fn cardinality(&self) -> u64 {
        0
    }
}

/// Scalar statistics (costs, radii) are not shuffled data.
impl Cardinality for f64 {
    fn cardinality(&self) -> u64 {
        0
    }
}

/// A point id.
impl Cardinality for u32 {
    fn cardinality(&self) -> u64 {
        1
    }
}

/// A weight or count.
impl Cardinality for u64 {
    fn cardinality(&self) -> u64 {
        1
    }
}

impl<T> Cardinality for Vec<T> {
    fn cardinality(&self) -> u64 {
        self.len() as u64
    }
}

impl<A: Cardinality, B: Cardinality> Cardinality for (A, B) {
    fn cardinality(&self) -> u64 {
        self.0.cardinality() + self.1.cardinality()
    }
}

impl<A: Cardinality, B: Cardinality, C: Cardinality> Cardinality for (A, B, C) {
    fn cardinality(&self) -> u64 {
        self.0.cardinality() + self.1.cardinality() + self.2.cardinality()
    }
}

impl Cardinality for WeightedSet {
    fn cardinality(&self) -> u64 {
        self.len() as u64
    }
}

impl Cardinality for Solution {
    fn cardinality(&self) -> u64 {
        self.centers.len() as u64
    }
}

/// Round-1 local output ships T_ℓ plus the local cover C_{w,ℓ}.
impl Cardinality for LocalCoresetOut {
    fn cardinality(&self) -> u64 {
        (self.t.len() + self.cover.set.len()) as u64
    }
}

impl Cardinality for Assignment {
    fn cardinality(&self) -> u64 {
        self.dist.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn containers_count_elements_scalars_follow_convention() {
        assert_eq!(().cardinality(), 0);
        assert_eq!(7usize.cardinality(), 0);
        assert_eq!(1.5f64.cardinality(), 0);
        assert_eq!(7u32.cardinality(), 1);
        assert_eq!(7u64.cardinality(), 1);
        assert_eq!(vec![1u32, 2, 3].cardinality(), 3);
        assert_eq!((2usize, vec![1u32, 2]).cardinality(), 2);
        assert_eq!((vec![1u32], vec![1.0f64, 2.0], vec![9u32, 9]).cardinality(), 5);
    }

    #[test]
    fn domain_types_count_their_payload() {
        let ws = WeightedSet::new(vec![1, 2, 3], vec![1, 1, 2]);
        assert_eq!(ws.cardinality(), 3);
        let sol = Solution { centers: vec![4, 5], cost: 0.5 };
        assert_eq!(sol.cardinality(), 2);
        let a = Assignment { dist: vec![0.0, 1.0], idx: vec![0, 0] };
        assert_eq!(a.cardinality(), 2);
    }
}
