//! Local-memory accounting for the MapReduce executors.
//!
//! The MapReduce model (paper §2) bounds two quantities: M_L, the local
//! memory of each reducer, and M_A, the aggregate memory. Two ledgers
//! coexist in one meter:
//!
//! - **Items** (`charge`/`release`): drivers charge one unit per
//!   point-sized record a real reducer would hold (its partition,
//!   broadcast state, output). Peak item usage is what Theorem 3.14
//!   bounds as O(|P|^{2/3} k^{1/3} (c/ε)^{2D} log² |P|). The item budget
//!   is *soft*: exceeding it latches a violation flag that experiments
//!   assert on, but the round keeps running.
//! - **Bytes** (`try_charge_bytes`/`release_bytes`): executors charge
//!   the encoded size of every shard before materializing it. The byte
//!   budget is *hard*: a charge that would exceed it fails with
//!   [`OverBudget`] — without charging — so an out-of-core run degrades
//!   into a structured error instead of an OOM kill. Transient codec
//!   buffers and broadcast state are item-metered only.

/// A byte charge was refused because it would exceed the hard budget.
///
/// Returned by [`MemoryMeter::try_charge_bytes`]; the failed charge is
/// *not* applied, so `resident` is the usage at the moment of refusal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OverBudget {
    /// Size of the refused charge.
    pub needed: u64,
    /// The configured hard budget.
    pub budget: u64,
    /// Bytes already resident when the charge was refused.
    pub resident: u64,
}

/// Per-reducer memory meter (items = point-sized records, plus bytes).
#[derive(Clone, Debug, Default)]
pub struct MemoryMeter {
    current: usize,
    peak: usize,
    /// Optional soft budget: exceeding it marks a violation (experiments
    /// assert none occur at the theory-predicted budget).
    budget: Option<usize>,
    violated: bool,
    bytes_current: u64,
    bytes_peak: u64,
    /// Optional hard budget on resident bytes; see [`OverBudget`].
    byte_budget: Option<u64>,
}

impl MemoryMeter {
    pub fn new() -> MemoryMeter {
        MemoryMeter::default()
    }

    pub fn with_budget(budget: usize) -> MemoryMeter {
        MemoryMeter { budget: Some(budget), ..Default::default() }
    }

    pub fn with_budgets(budget: Option<usize>, byte_budget: Option<u64>) -> MemoryMeter {
        MemoryMeter { budget, byte_budget, ..Default::default() }
    }

    /// Charge `items` resident records.
    pub fn charge(&mut self, items: usize) {
        self.current += items;
        if self.current > self.peak {
            self.peak = self.current;
        }
        if let Some(b) = self.budget {
            if self.current > b {
                self.violated = true;
            }
        }
    }

    /// Release `items` records (e.g. partition dropped after processing).
    pub fn release(&mut self, items: usize) {
        self.current = self.current.saturating_sub(items);
    }

    /// Charge `bytes` of resident shard data, refusing (without charging)
    /// any charge that would push residency past the hard byte budget.
    pub fn try_charge_bytes(&mut self, bytes: u64) -> Result<(), OverBudget> {
        let next = self.bytes_current.saturating_add(bytes);
        if let Some(b) = self.byte_budget {
            if next > b {
                return Err(OverBudget { needed: bytes, budget: b, resident: self.bytes_current });
            }
        }
        self.bytes_current = next;
        if next > self.bytes_peak {
            self.bytes_peak = next;
        }
        Ok(())
    }

    /// Release `bytes` of resident shard data.
    pub fn release_bytes(&mut self, bytes: u64) {
        self.bytes_current = self.bytes_current.saturating_sub(bytes);
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn current(&self) -> usize {
        self.current
    }

    pub fn violated(&self) -> bool {
        self.violated
    }

    pub fn bytes_peak(&self) -> u64 {
        self.bytes_peak
    }

    pub fn bytes_current(&self) -> u64 {
        self.bytes_current
    }

    pub fn byte_budget(&self) -> Option<u64> {
        self.byte_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak() {
        let mut m = MemoryMeter::new();
        m.charge(10);
        m.charge(5);
        m.release(12);
        m.charge(4);
        assert_eq!(m.peak(), 15);
        assert_eq!(m.current(), 7);
        assert!(!m.violated());
    }

    #[test]
    fn budget_violation_latches() {
        let mut m = MemoryMeter::with_budget(10);
        m.charge(8);
        assert!(!m.violated());
        m.charge(5);
        assert!(m.violated());
        m.release(13);
        assert!(m.violated(), "violation must latch");
    }

    #[test]
    fn release_saturates() {
        let mut m = MemoryMeter::new();
        m.charge(3);
        m.release(100);
        assert_eq!(m.current(), 0);
    }

    #[test]
    fn bytes_track_peak_independently_of_items() {
        let mut m = MemoryMeter::new();
        m.try_charge_bytes(100).unwrap();
        m.try_charge_bytes(50).unwrap();
        m.release_bytes(120);
        m.try_charge_bytes(40).unwrap();
        assert_eq!(m.bytes_peak(), 150);
        assert_eq!(m.bytes_current(), 70);
        assert_eq!(m.peak(), 0, "byte charges must not touch the item ledger");
    }

    #[test]
    fn byte_charge_to_exactly_the_budget_is_allowed() {
        let mut m = MemoryMeter::with_budgets(None, Some(64));
        m.try_charge_bytes(40).unwrap();
        m.try_charge_bytes(24).unwrap();
        assert_eq!(m.bytes_current(), 64);
        assert_eq!(m.bytes_peak(), 64);
    }

    #[test]
    fn over_budget_charge_fails_without_charging() {
        let mut m = MemoryMeter::with_budgets(None, Some(64));
        m.try_charge_bytes(60).unwrap();
        let err = m.try_charge_bytes(5).unwrap_err();
        assert_eq!(err, OverBudget { needed: 5, budget: 64, resident: 60 });
        // the refused charge left the ledger untouched: after releasing,
        // a charge that fits succeeds
        assert_eq!(m.bytes_current(), 60);
        assert_eq!(m.bytes_peak(), 60);
        m.release_bytes(60);
        m.try_charge_bytes(64).unwrap();
        assert_eq!(m.bytes_current(), 64);
    }

    #[test]
    fn single_oversized_charge_reports_zero_resident() {
        let mut m = MemoryMeter::with_budgets(None, Some(10));
        let err = m.try_charge_bytes(11).unwrap_err();
        assert_eq!(err, OverBudget { needed: 11, budget: 10, resident: 0 });
    }

    #[test]
    fn byte_release_saturates() {
        let mut m = MemoryMeter::new();
        m.try_charge_bytes(8).unwrap();
        m.release_bytes(1000);
        assert_eq!(m.bytes_current(), 0);
        assert_eq!(m.bytes_peak(), 8);
    }

    #[test]
    fn no_byte_budget_means_unbounded() {
        let mut m = MemoryMeter::new();
        m.try_charge_bytes(u64::MAX).unwrap();
        m.try_charge_bytes(u64::MAX).unwrap(); // saturates, must not panic
        assert_eq!(m.bytes_current(), u64::MAX);
    }

    #[test]
    fn item_budget_and_byte_budget_are_independent() {
        let mut m = MemoryMeter::with_budgets(Some(10), Some(100));
        m.charge(50); // item violation latches, but items stay soft
        assert!(m.violated());
        m.try_charge_bytes(100).unwrap(); // bytes at the boundary: fine
        assert!(m.try_charge_bytes(1).is_err());
    }
}
