//! Local-memory accounting for the MapReduce simulator.
//!
//! The MapReduce model (paper §2) bounds two quantities: M_L, the local
//! memory of each reducer, and M_A, the aggregate memory. The simulator
//! cannot introspect allocations, so drivers *charge* the meter for every
//! object a real reducer would hold (its partition, broadcast state,
//! output), in units of points; peak local usage is what Theorem 3.14
//! bounds as O(|P|^{2/3} k^{1/3} (c/ε)^{2D} log² |P|).

/// Per-reducer memory meter (units: points / point-sized records).
#[derive(Clone, Debug, Default)]
pub struct MemoryMeter {
    current: usize,
    peak: usize,
    /// Optional hard budget: exceeding it marks a violation (experiments
    /// assert none occur at the theory-predicted budget).
    budget: Option<usize>,
    violated: bool,
}

impl MemoryMeter {
    pub fn new() -> MemoryMeter {
        MemoryMeter::default()
    }

    pub fn with_budget(budget: usize) -> MemoryMeter {
        MemoryMeter { budget: Some(budget), ..Default::default() }
    }

    /// Charge `items` resident records.
    pub fn charge(&mut self, items: usize) {
        self.current += items;
        if self.current > self.peak {
            self.peak = self.current;
        }
        if let Some(b) = self.budget {
            if self.current > b {
                self.violated = true;
            }
        }
    }

    /// Release `items` records (e.g. partition dropped after processing).
    pub fn release(&mut self, items: usize) {
        self.current = self.current.saturating_sub(items);
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn current(&self) -> usize {
        self.current
    }

    pub fn violated(&self) -> bool {
        self.violated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak() {
        let mut m = MemoryMeter::new();
        m.charge(10);
        m.charge(5);
        m.release(12);
        m.charge(4);
        assert_eq!(m.peak(), 15);
        assert_eq!(m.current(), 7);
        assert!(!m.violated());
    }

    #[test]
    fn budget_violation_latches() {
        let mut m = MemoryMeter::with_budget(10);
        m.charge(8);
        assert!(!m.violated());
        m.charge(5);
        assert!(m.violated());
        m.release(13);
        assert!(m.violated(), "violation must latch");
    }

    #[test]
    fn release_saturates() {
        let mut m = MemoryMeter::new();
        m.charge(3);
        m.release(100);
        assert_eq!(m.current(), 0);
    }
}
