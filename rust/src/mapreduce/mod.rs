//! Thread-backed MapReduce round simulator (substrate S1, DESIGN.md §5).
//!
//! The paper's model (§2): a MapReduce algorithm runs in a sequence of
//! rounds; in each round, reducers independently process disjoint groups
//! of key-value pairs under a local memory budget M_L, with aggregate
//! memory M_A across all reducers. This simulator executes each round's
//! reducers as real parallel threads, and — what the theory actually
//! bounds — *measures* per-reducer peak local memory, aggregate memory,
//! and shuffle volumes, via `MemoryMeter` charges from the drivers.
//!
//! Next to memory, each round also accounts **distance evaluations** —
//! the work measure that dominates every algorithm in this family. Every
//! reducer closure runs entirely on one thread, so `Simulator::round`
//! brackets it with `metric::counter::thread_count()` reads and records
//! the per-reducer deltas in `RoundStats::reducer_dist_evals` (summed in
//! `dist_evals`); no instrumentation is needed in the drivers.
//!
//! Rounds are explicit (`Simulator::round`), so the round count of an
//! algorithm is simply the number of `round` calls it makes (E7 asserts
//! the paper's 3 rounds).

pub mod cardinality;
pub mod memory;
pub mod partition;

pub use cardinality::Cardinality;
pub use memory::MemoryMeter;
pub use partition::{default_l, partition, PartitionStrategy};

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metric::counter;
use crate::obs::{self, counters as obs_counters, Event, Recorder};
use crate::util::pool::{default_threads, scoped_map};
use crate::util::stats::Distribution;

/// Statistics for one executed round.
#[derive(Clone, Debug)]
pub struct RoundStats {
    pub name: String,
    pub reducers: usize,
    /// max over reducers of peak local memory (points)
    pub max_local_peak: usize,
    /// sum over reducers of peak local memory (points) — the round's M_A
    pub aggregate_peak: usize,
    /// peak local memory (points) of each reducer (input order) — the
    /// per-machine distribution behind `max_local_peak`
    pub reducer_mem_peaks: Vec<usize>,
    /// distance evaluations charged by each reducer (input order)
    pub reducer_dist_evals: Vec<u64>,
    /// Σ over reducers — the round's distance-evaluation work
    pub dist_evals: u64,
    /// Σ over reducers of input/output item counts (`Cardinality`)
    pub in_items: u64,
    pub out_items: u64,
    /// named `obs::counters` charged by this round's reducers, summed
    /// and name-sorted (e.g. `pruned.give_up`, `cover.iterations`)
    pub counters: Vec<(String, u64)>,
    pub wall: std::time::Duration,
    pub budget_violations: usize,
}

impl RoundStats {
    /// Per-reducer peak-memory distribution (p50/p95/max, in points).
    pub fn mem_distribution(&self) -> Distribution {
        let v: Vec<f64> = self.reducer_mem_peaks.iter().map(|&m| m as f64).collect();
        Distribution::of(&v)
    }

    /// Per-reducer distance-evaluation distribution.
    pub fn evals_distribution(&self) -> Distribution {
        let v: Vec<f64> = self.reducer_dist_evals.iter().map(|&e| e as f64).collect();
        Distribution::of(&v)
    }

    /// Value of one named counter in this round (0 if never charged).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(k, _)| k == name).map(|(_, v)| *v).unwrap_or(0)
    }
}

/// Whole-job statistics.
#[derive(Clone, Debug, Default)]
pub struct JobStats {
    pub rounds: Vec<RoundStats>,
}

impl JobStats {
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// The job's M_L: max over rounds of max-over-reducers peak memory.
    pub fn max_local_memory(&self) -> usize {
        self.rounds.iter().map(|r| r.max_local_peak).max().unwrap_or(0)
    }

    /// The job's M_A: max over rounds of aggregate peak memory.
    pub fn aggregate_memory(&self) -> usize {
        self.rounds.iter().map(|r| r.aggregate_peak).max().unwrap_or(0)
    }

    pub fn total_violations(&self) -> usize {
        self.rounds.iter().map(|r| r.budget_violations).sum()
    }

    /// Total distance evaluations across all rounds and reducers.
    pub fn total_dist_evals(&self) -> u64 {
        self.rounds.iter().map(|r| r.dist_evals).sum()
    }

    /// Distance evaluations attributed to rounds with the given name
    /// (summed over repeats; 0 if no such round ran). Lets experiments
    /// break a job's work down by stage — e.g. E12 attributes the
    /// outlier pipeline's oversampling overhead per round.
    pub fn dist_evals_for(&self, name: &str) -> u64 {
        self.rounds.iter().filter(|r| r.name == name).map(|r| r.dist_evals).sum()
    }

    /// Total of one named `obs` counter across all rounds (0 if never
    /// charged) — e.g. `counter_total("pruned.give_up")` tells whether
    /// the adaptive bounds ledger ever bailed during the job.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.rounds.iter().map(|r| r.counter(name)).sum()
    }
}

/// The simulator: runs rounds, accumulates stats.
pub struct Simulator {
    threads: usize,
    /// Optional per-reducer local-memory budget (points); reducers
    /// exceeding it are *recorded* (not killed), so experiments can
    /// assert the theoretical budget holds.
    local_budget: Option<usize>,
    /// Telemetry sink; `obs::noop()` (disabled) by default. All events
    /// are emitted by the coordinator thread in (round, reducer) order,
    /// so traces are bit-identical across `threads` settings.
    recorder: Arc<dyn Recorder>,
    stats: Mutex<JobStats>,
}

impl Simulator {
    pub fn new() -> Simulator {
        Simulator {
            threads: default_threads(),
            local_budget: None,
            recorder: obs::noop(),
            stats: Mutex::new(JobStats::default()),
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Simulator {
        self.threads = threads.max(1);
        self
    }

    pub fn with_local_budget(mut self, budget: usize) -> Simulator {
        self.local_budget = Some(budget);
        self
    }

    pub fn with_recorder(mut self, recorder: Arc<dyn Recorder>) -> Simulator {
        self.recorder = recorder;
        self
    }

    /// Execute one parallel round: `f(reducer_index, input, meter)` runs
    /// for each input group on the thread pool. Returns reducer outputs
    /// in input order.
    pub fn round<I, O, F>(&self, name: &str, inputs: Vec<I>, f: F) -> Vec<O>
    where
        I: Send + Sync + Cardinality,
        O: Send + Cardinality,
        F: Fn(usize, &I, &mut MemoryMeter) -> O + Sync,
    {
        let t0 = Instant::now();
        let reducers = inputs.len();
        // round index within the current job (take_stats resets it)
        let round_idx = self.stats.lock().unwrap().rounds.len() as u32;
        let traced = self.recorder.enabled();
        if traced {
            self.recorder.record(&Event::RoundStart {
                round: round_idx,
                name: name.to_string(),
                reducers: reducers as u32,
            });
        }
        let in_cards: Vec<u64> = inputs.iter().map(Cardinality::cardinality).collect();
        let results = scoped_map(reducers, self.threads, |i| {
            let mut meter = match self.local_budget {
                Some(b) => MemoryMeter::with_budget(b),
                None => MemoryMeter::new(),
            };
            // the reducer runs entirely on this thread, so the tally
            // deltas (dist_evals and named obs counters) are exactly its
            // own work
            let evals0 = counter::thread_count();
            let obs0 = obs_counters::snapshot();
            let rt0 = Instant::now();
            let out = f(i, &inputs[i], &mut meter);
            let wall_us = rt0.elapsed().as_micros() as u64;
            // every charge must be released by the time the reducer
            // returns — a leak here inflates cross-round peaks and turns
            // the M_L scaling stats into nonsense
            debug_assert_eq!(
                meter.current(),
                0,
                "reducer {i} of round '{name}' returned with unreleased memory charges"
            );
            let evals = counter::thread_count() - evals0;
            let cnt = obs_counters::delta_since(&obs0);
            (out, meter, evals, cnt, wall_us)
        });
        let mut outs = Vec::with_capacity(reducers);
        let mut max_peak = 0usize;
        let mut agg = 0usize;
        let mut violations = 0usize;
        let mut reducer_mem_peaks = Vec::with_capacity(reducers);
        let mut reducer_dist_evals = Vec::with_capacity(reducers);
        let mut dist_evals = 0u64;
        let mut out_items = 0u64;
        let mut per_counters = Vec::with_capacity(reducers);
        // collection (and hence event emission) is in input order on
        // this thread — never in worker arrival order
        for (i, (o, meter, evals, cnt, wall_us)) in results.into_iter().enumerate() {
            let out_card = o.cardinality();
            max_peak = max_peak.max(meter.peak());
            agg += meter.peak();
            violations += usize::from(meter.violated());
            reducer_mem_peaks.push(meter.peak());
            reducer_dist_evals.push(evals);
            dist_evals += evals;
            out_items += out_card;
            if traced {
                self.recorder.record(&Event::Reducer {
                    round: round_idx,
                    reducer: i as u32,
                    name: name.to_string(),
                    in_items: in_cards[i],
                    out_items: out_card,
                    dist_evals: evals,
                    mem_peak: meter.peak() as u64,
                    wall_us,
                    counters: cnt.clone(),
                });
            }
            per_counters.push(cnt);
            outs.push(o);
        }
        let stats = RoundStats {
            name: name.to_string(),
            reducers,
            max_local_peak: max_peak,
            aggregate_peak: agg,
            reducer_mem_peaks,
            reducer_dist_evals,
            dist_evals,
            in_items: in_cards.iter().sum(),
            out_items,
            counters: obs_counters::merge(&per_counters),
            wall: t0.elapsed(),
            budget_violations: violations,
        };
        if traced {
            let md = stats.mem_distribution();
            let ed = stats.evals_distribution();
            self.recorder.record(&Event::RoundEnd {
                round: round_idx,
                name: name.to_string(),
                reducers: reducers as u32,
                dist_evals,
                mem_max: max_peak as u64,
                mem_p50: md.p50,
                mem_p95: md.p95,
                evals_max: stats.reducer_dist_evals.iter().copied().max().unwrap_or(0),
                evals_p50: ed.p50,
                evals_p95: ed.p95,
                violations: violations as u64,
                wall_us: t0.elapsed().as_micros() as u64,
            });
        }
        self.stats.lock().unwrap().rounds.push(stats);
        outs
    }

    /// Take the accumulated job statistics (resets the simulator).
    pub fn take_stats(&self) -> JobStats {
        std::mem::take(&mut self.stats.lock().unwrap())
    }
}

impl Default for Simulator {
    fn default() -> Self {
        Simulator::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_rounds_and_collects_stats() {
        let sim = Simulator::new().with_threads(4);
        let parts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6]];
        let sums = sim.round("sum", parts, |_, part, meter| {
            meter.charge(part.len());
            let s: u32 = part.iter().sum();
            meter.release(part.len());
            s
        });
        assert_eq!(sums, vec![6, 9, 6]);
        let stats = sim.take_stats();
        assert_eq!(stats.num_rounds(), 1);
        assert_eq!(stats.rounds[0].reducers, 3);
        assert_eq!(stats.rounds[0].max_local_peak, 3);
        assert_eq!(stats.rounds[0].aggregate_peak, 6);
        assert_eq!(stats.rounds[0].reducer_mem_peaks, vec![3, 2, 1]);
        assert_eq!(stats.rounds[0].in_items, 6, "three parts of 3+2+1 input items");
        assert_eq!(stats.rounds[0].out_items, 3, "one scalar sum per reducer");
    }

    /// Tracing: events arrive in (round, reducer) order on the
    /// coordinator thread regardless of worker thread count, and carry
    /// the same numbers as `RoundStats`.
    #[test]
    fn traced_round_emits_ordered_events() {
        use crate::obs::MemSink;

        let sink = Arc::new(MemSink::new());
        let rec: Arc<dyn crate::obs::Recorder> = sink.clone();
        let sim = Simulator::new().with_threads(4).with_recorder(rec);
        let parts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6]];
        let _ = sim.round("sum", parts, |_, part, meter| {
            meter.charge(part.len());
            let s: u32 = part.iter().sum();
            meter.release(part.len());
            s
        });
        let stats = sim.take_stats();
        let evs = sink.take();
        assert_eq!(evs.len(), 5, "round_start + 3 reducers + round_end");
        assert!(matches!(&evs[0], Event::RoundStart { round: 0, reducers: 3, .. }));
        for (j, ev) in evs[1..4].iter().enumerate() {
            match ev {
                Event::Reducer { round, reducer, in_items, out_items, mem_peak, .. } => {
                    assert_eq!(*round, 0);
                    assert_eq!(*reducer, j as u32, "input order, not arrival order");
                    assert_eq!(*in_items, [3, 2, 1][j]);
                    assert_eq!(*out_items, 1);
                    assert_eq!(*mem_peak, stats.rounds[0].reducer_mem_peaks[j] as u64);
                }
                other => panic!("expected reducer span, got {other:?}"),
            }
        }
        match &evs[4] {
            Event::RoundEnd { round: 0, reducers: 3, mem_max, .. } => {
                assert_eq!(*mem_max, stats.rounds[0].max_local_peak as u64);
            }
            other => panic!("expected round_end, got {other:?}"),
        }
    }

    /// The default recorder is disabled and rounds skip event assembly.
    #[test]
    fn untraced_round_records_nothing_but_full_stats() {
        let sim = Simulator::new();
        let _ = sim.round("r", vec![vec![1u32, 2]], |_, part, m| {
            m.charge(part.len());
            m.release(part.len());
            part.len()
        });
        let stats = sim.take_stats();
        assert_eq!(stats.rounds[0].in_items, 2);
        assert_eq!(stats.rounds[0].out_items, 0, "usize outputs are labels");
        assert!(stats.rounds[0].counters.is_empty());
    }

    #[test]
    fn budget_violations_counted() {
        let sim = Simulator::new().with_local_budget(2);
        let parts: Vec<Vec<u32>> = vec![vec![1], vec![2, 3, 4]];
        let _ = sim.round("r", parts, |_, part, meter| {
            meter.charge(part.len());
            meter.release(part.len());
            part.len()
        });
        let stats = sim.take_stats();
        assert_eq!(stats.total_violations(), 1);
    }

    #[test]
    fn multi_round_job_stats() {
        let sim = Simulator::new();
        for r in 0..3 {
            let _ = sim.round(&format!("r{r}"), vec![()], |_, _, meter| {
                meter.charge(r + 1);
                meter.release(r + 1);
            });
        }
        let stats = sim.take_stats();
        assert_eq!(stats.num_rounds(), 3);
        assert_eq!(stats.max_local_memory(), 3);
    }

    #[test]
    fn take_stats_resets() {
        let sim = Simulator::new();
        let _ = sim.round("r", vec![()], |_, _, m| {
            m.charge(1);
            m.release(1);
        });
        assert_eq!(sim.take_stats().num_rounds(), 1);
        assert_eq!(sim.take_stats().num_rounds(), 0);
    }

    /// Regression (meter leaks): reducers that charge without releasing
    /// used to leak `current()` silently across rounds; the round now
    /// debug-asserts a balanced meter on return.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "unreleased memory charges")]
    fn unbalanced_reducer_is_rejected() {
        let sim = Simulator::new().with_threads(1);
        let _ = sim.round("leaky", vec![()], |_, _, m| m.charge(3));
    }

    /// Distance accounting: per-reducer counts are attributed to the
    /// right reducer (|part|·|centers| each for a bulk assign), sum to
    /// the round total, and aggregate across rounds — under real
    /// parallelism and with more reducers than threads.
    #[test]
    fn dist_evals_sum_across_reducers() {
        use crate::metric::dense::EuclideanSpace;
        use crate::metric::MetricSpace;
        use crate::points::VectorData;
        use std::sync::Arc;

        let rows: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32]).collect();
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let parts: Vec<Vec<u32>> =
            vec![(0..4).collect(), (4..10).collect(), (10..15).collect(), vec![15]];
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        let centers = vec![0u32, 8];
        for threads in [1usize, 2, 8] {
            let sim = Simulator::new().with_threads(threads);
            let space_ref = &space;
            let centers_ref = &centers;
            let _ = sim.round("assign", parts.clone(), move |_, part, meter| {
                meter.charge(part.len());
                let a = space_ref.assign(part, centers_ref);
                meter.release(part.len());
                a
            });
            let stats = sim.take_stats();
            let r = &stats.rounds[0];
            assert_eq!(r.reducer_dist_evals.len(), 4, "threads={threads}");
            for (e, s) in r.reducer_dist_evals.iter().zip(&sizes) {
                assert_eq!(*e, (*s * centers.len()) as u64, "threads={threads}");
            }
            assert_eq!(r.dist_evals, r.reducer_dist_evals.iter().sum::<u64>());
            assert_eq!(stats.total_dist_evals(), (16 * centers.len()) as u64);
        }
    }

    /// Per-name attribution: repeated names sum, absent names are 0.
    #[test]
    fn dist_evals_for_filters_by_round_name() {
        use crate::metric::dense::EuclideanSpace;
        use crate::metric::MetricSpace;
        use crate::points::VectorData;
        use std::sync::Arc;

        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32]).collect();
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let sim = Simulator::new();
        let pts: Vec<u32> = (0..8).collect();
        for _ in 0..2 {
            let _ = sim.round("assign", vec![pts.clone()], |_, part, m| {
                m.charge(part.len());
                let a = space.assign(part, &[0]);
                m.release(part.len());
                a
            });
        }
        let _ = sim.round("noop", vec![()], |_, _, m| {
            m.charge(1);
            m.release(1);
        });
        let stats = sim.take_stats();
        assert_eq!(stats.dist_evals_for("assign"), 16);
        assert_eq!(stats.dist_evals_for("noop"), 0);
        assert_eq!(stats.dist_evals_for("missing"), 0);
        assert_eq!(stats.total_dist_evals(), 16);
    }

    /// Rounds with no distance work report zero; multi-round jobs sum.
    #[test]
    fn dist_evals_zero_without_distance_work() {
        let sim = Simulator::new();
        let _ = sim.round("noop", vec![(), ()], |_, _, m| {
            m.charge(1);
            m.release(1);
        });
        let stats = sim.take_stats();
        assert_eq!(stats.rounds[0].dist_evals, 0);
        assert_eq!(stats.rounds[0].reducer_dist_evals, vec![0, 0]);
        assert_eq!(stats.total_dist_evals(), 0);
    }
}
