//! Thread-backed MapReduce execution layer (substrate S1, DESIGN.md §5).
//!
//! The paper's model (§2): a MapReduce algorithm runs in a sequence of
//! rounds; in each round, reducers independently process disjoint groups
//! of key-value pairs under a local memory budget M_L, with aggregate
//! memory M_A across all reducers. Execution is pluggable behind the
//! [`executor::Executor`] trait:
//!
//! - [`Simulator`] (alias [`executor::InMemoryExecutor`]) runs each
//!   round's reducers as real parallel threads with every input resident
//!   in RAM, and — what the theory actually bounds — *measures*
//!   per-reducer peak local memory, aggregate memory, and shuffle
//!   volumes, via `MemoryMeter` charges from the drivers.
//! - [`executor::SpillExecutor`] keeps round inputs/outputs on disk
//!   ([`spill`]) and materializes one shard at a time under a hard
//!   per-reducer byte budget — same results bit-for-bit, bounded RAM.
//!
//! Next to memory, each round also accounts **distance evaluations** —
//! the work measure that dominates every algorithm in this family. Every
//! reducer closure runs entirely on one thread, so the round engine
//! brackets it with `metric::counter::thread_count()` reads and records
//! the per-reducer deltas in `RoundStats::reducer_dist_evals` (summed in
//! `dist_evals`); no instrumentation is needed in the drivers.
//!
//! Rounds are explicit (`Simulator::round` / `Executor::round`), so the
//! round count of an algorithm is simply the number of `round` calls it
//! makes (E7 asserts the paper's 3 rounds).

pub mod cardinality;
pub mod checkpoint;
pub mod executor;
pub mod faults;
pub mod memory;
pub mod partition;
pub mod spill;

pub use cardinality::Cardinality;
pub use checkpoint::CheckpointStore;
pub use executor::{
    parse_bytes, ExecBackend, ExecError, Executor, ExecutorCfg, ExecutorHandle, InMemoryExecutor,
    Manifest, Shard, SpillExecutor, DEFAULT_RETRIES,
};
pub use faults::{FaultKind, FaultPlan};
pub use memory::{MemoryMeter, OverBudget};
pub use partition::{default_l, partition, partition_reported, PartitionStrategy};
pub use spill::{CodecError, Decoder, ShardRef, SpillError, SpillStore, Spillable};

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metric::counter;
use crate::obs::{self, counters as obs_counters, Event, Recorder};
use crate::util::pool::{default_threads, scoped_map};
use crate::util::stats::Distribution;

/// Statistics for one executed round.
#[derive(Clone, Debug)]
pub struct RoundStats {
    pub name: String,
    pub reducers: usize,
    /// max over reducers of peak local memory (points)
    pub max_local_peak: usize,
    /// sum over reducers of peak local memory (points) — the round's M_A
    pub aggregate_peak: usize,
    /// peak local memory (points) of each reducer (input order) — the
    /// per-machine distribution behind `max_local_peak`
    pub reducer_mem_peaks: Vec<usize>,
    /// peak resident *bytes* of each reducer (input order): the encoded
    /// sizes charged by the executor for shards held at once. All-zero
    /// for rounds driven through the item-only legacy `round` API.
    pub reducer_mem_bytes: Vec<u64>,
    /// max over reducers of peak resident bytes — the measured M_L in
    /// bytes that the spill backend's hard budget bounds
    pub max_local_bytes: u64,
    /// bytes actually read from / written to the spill store by this
    /// round (0 under the in-memory backend)
    pub spill_read_bytes: u64,
    pub spill_write_bytes: u64,
    /// distance evaluations charged by each reducer (input order)
    pub reducer_dist_evals: Vec<u64>,
    /// Σ over reducers — the round's distance-evaluation work
    pub dist_evals: u64,
    /// Σ over reducers of input/output item counts (`Cardinality`)
    pub in_items: u64,
    pub out_items: u64,
    /// named `obs::counters` charged by this round's reducers, summed
    /// and name-sorted (e.g. `pruned.give_up`, `cover.iterations`)
    pub counters: Vec<(String, u64)>,
    pub wall: std::time::Duration,
    pub budget_violations: usize,
}

impl RoundStats {
    /// Per-reducer peak-memory distribution (p50/p95/max, in points).
    pub fn mem_distribution(&self) -> Distribution {
        let v: Vec<f64> = self.reducer_mem_peaks.iter().map(|&m| m as f64).collect();
        Distribution::of(&v)
    }

    /// Per-reducer peak resident-bytes distribution.
    pub fn bytes_distribution(&self) -> Distribution {
        let v: Vec<f64> = self.reducer_mem_bytes.iter().map(|&m| m as f64).collect();
        Distribution::of(&v)
    }

    /// Per-reducer distance-evaluation distribution.
    pub fn evals_distribution(&self) -> Distribution {
        let v: Vec<f64> = self.reducer_dist_evals.iter().map(|&e| e as f64).collect();
        Distribution::of(&v)
    }

    /// Value of one named counter in this round (0 if never charged).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(k, _)| k == name).map(|(_, v)| *v).unwrap_or(0)
    }
}

/// Whole-job statistics.
#[derive(Clone, Debug, Default)]
pub struct JobStats {
    pub rounds: Vec<RoundStats>,
}

impl JobStats {
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// The job's M_L: max over rounds of max-over-reducers peak memory.
    pub fn max_local_memory(&self) -> usize {
        self.rounds.iter().map(|r| r.max_local_peak).max().unwrap_or(0)
    }

    /// The job's M_A: max over rounds of aggregate peak memory.
    pub fn aggregate_memory(&self) -> usize {
        self.rounds.iter().map(|r| r.aggregate_peak).max().unwrap_or(0)
    }

    /// The job's measured M_L in bytes: max over rounds of the largest
    /// per-reducer resident encoded footprint.
    pub fn max_local_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.max_local_bytes).max().unwrap_or(0)
    }

    /// Total bytes spilled to disk across the job (0 in-memory).
    pub fn spill_write_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.spill_write_bytes).sum()
    }

    pub fn total_violations(&self) -> usize {
        self.rounds.iter().map(|r| r.budget_violations).sum()
    }

    /// Total distance evaluations across all rounds and reducers.
    pub fn total_dist_evals(&self) -> u64 {
        self.rounds.iter().map(|r| r.dist_evals).sum()
    }

    /// Distance evaluations attributed to rounds with the given name
    /// (summed over repeats; 0 if no such round ran). Lets experiments
    /// break a job's work down by stage — e.g. E12 attributes the
    /// outlier pipeline's oversampling overhead per round.
    pub fn dist_evals_for(&self, name: &str) -> u64 {
        self.rounds.iter().filter(|r| r.name == name).map(|r| r.dist_evals).sum()
    }

    /// Total of one named `obs` counter across all rounds (0 if never
    /// charged) — e.g. `counter_total("pruned.give_up")` tells whether
    /// the adaptive bounds ledger ever bailed during the job.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.rounds.iter().map(|r| r.counter(name)).sum()
    }
}

/// One reducer's result inside the round engine: the output value plus
/// the byte/item accounting the backend measured for it. Backends build
/// this in their worker closures; `round_impl` folds it into
/// `RoundStats` and trace events.
pub(crate) struct SlotOut<R> {
    pub out: R,
    pub in_card: u64,
    pub out_card: u64,
    pub in_bytes: u64,
    pub out_bytes: u64,
    pub spill_read: u64,
    pub spill_write: u64,
}

/// The in-memory executor: runs rounds on a thread pool, accumulates
/// stats. (Kept under its historical name; `InMemoryExecutor` is an
/// alias.)
pub struct Simulator {
    threads: usize,
    /// Optional per-reducer local-memory budget (points); reducers
    /// exceeding it are *recorded* (not killed), so experiments can
    /// assert the theoretical budget holds.
    local_budget: Option<usize>,
    /// Optional hard per-reducer byte budget, enforced by executors on
    /// every shard charge; see `MemoryMeter::try_charge_bytes`.
    byte_budget: Option<u64>,
    /// Telemetry sink; `obs::noop()` (disabled) by default. All events
    /// are emitted by the coordinator thread in (round, reducer) order,
    /// so traces are bit-identical across `threads` settings.
    recorder: Arc<dyn Recorder>,
    /// Deterministic fault schedule consulted at every (round, reducer,
    /// attempt) site; `None` injects nothing.
    faults: Option<Arc<FaultPlan>>,
    /// Attempts per reducer (1 = no recovery, the historical behavior:
    /// reducer panics propagate and transient errors fail the round).
    max_attempts: u32,
    stats: Mutex<JobStats>,
}

impl Simulator {
    pub fn new() -> Simulator {
        Simulator {
            threads: default_threads(),
            local_budget: None,
            byte_budget: None,
            recorder: obs::noop(),
            faults: None,
            max_attempts: 1,
            stats: Mutex::new(JobStats::default()),
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Simulator {
        self.threads = threads.max(1);
        self
    }

    pub fn with_local_budget(mut self, budget: usize) -> Simulator {
        self.local_budget = Some(budget);
        self
    }

    pub fn with_byte_budget(mut self, budget: u64) -> Simulator {
        self.byte_budget = Some(budget);
        self
    }

    pub fn with_recorder(mut self, recorder: Arc<dyn Recorder>) -> Simulator {
        self.recorder = recorder;
        self
    }

    /// Attach a deterministic fault schedule (see [`faults`]). Also
    /// installs the process-wide quiet panic hook so injected panics
    /// don't spray backtraces.
    pub fn with_faults(mut self, plan: FaultPlan) -> Simulator {
        faults::install_quiet_hook();
        self.faults = Some(Arc::new(plan));
        self
    }

    /// Allow up to `attempts` executions per reducer (min 1). Anything
    /// above 1 enables recovery: reducer panics are caught and
    /// transient [`ExecError`]s are retried with a fresh meter and
    /// fresh counter snapshots, so a recovered run's accounting is
    /// bit-identical to a fault-free run's.
    pub fn with_max_attempts(mut self, attempts: u32) -> Simulator {
        self.max_attempts = attempts.max(1);
        self
    }

    /// Execute one parallel round: `f(reducer_index, input, meter)` runs
    /// for each input group on the thread pool. Returns reducer outputs
    /// in input order.
    ///
    /// This is the legacy owned-`Vec` API (no byte accounting, never
    /// fails); executor-driven rounds go through `round_impl` with shard
    /// manifests and hard byte budgets instead.
    pub fn round<I, O, F>(&self, name: &str, inputs: Vec<I>, f: F) -> Vec<O>
    where
        I: Send + Sync + Cardinality,
        O: Send + Cardinality,
        F: Fn(usize, &I, &mut MemoryMeter) -> O + Sync,
    {
        let res = self.round_impl(name, inputs.len(), |i, meter| {
            let input = &inputs[i];
            let in_card = input.cardinality();
            let out = f(i, input, meter);
            let out_card = out.cardinality();
            Ok(SlotOut {
                out,
                in_card,
                out_card,
                in_bytes: 0,
                out_bytes: 0,
                spill_read: 0,
                spill_write: 0,
            })
        });
        match res {
            Ok(outs) => outs,
            // legacy rounds never charge bytes, so the only reachable
            // errors are injected faults that exhausted their retries
            Err(e) => panic!("round '{name}' failed: {e}"),
        }
    }

    /// The round engine shared by every backend: schedules `work` per
    /// reducer on the thread pool, brackets it with distance/counter
    /// tallies, emits trace events in (round, reducer) input order on
    /// this thread, and folds `SlotOut` accounting into `RoundStats`.
    ///
    /// Recovery: when `max_attempts > 1` (or a fault plan is attached),
    /// each reducer runs inside `catch_unwind` and transient failures —
    /// I/O errors, shard corruption, reducer panics — are re-executed
    /// idempotently from the input manifest, up to the attempt bound.
    /// Every attempt starts with a *fresh* memory meter and fresh
    /// distance/counter snapshots, so the recorded numbers come from
    /// the successful attempt alone and a recovered run's stats are
    /// bit-identical to a fault-free run's; the recovery itself is
    /// visible only in the span's `attempts` field and the `faults.*`
    /// counters. Backoff is simulated (recorded, never slept).
    ///
    /// Failure is deterministic: all workers run to completion, then the
    /// error of the lowest-indexed failing reducer is returned — never
    /// the one that happened to lose the wall-clock race. A failed round
    /// records no `RoundStats` and no `RoundEnd` event, so a trace that
    /// ends after a `round_start` marks the failing round.
    pub(crate) fn round_impl<R, W>(
        &self,
        name: &str,
        reducers: usize,
        work: W,
    ) -> Result<Vec<R>, ExecError>
    where
        R: Send,
        W: Fn(usize, &mut MemoryMeter) -> Result<SlotOut<R>, ExecError> + Sync,
    {
        let t0 = Instant::now();
        // round index within the current job (take_stats resets it)
        let round_idx = self.stats.lock().unwrap().rounds.len() as u32;
        let traced = self.recorder.enabled();
        if traced {
            self.recorder.record(&Event::RoundStart {
                round: round_idx,
                name: name.to_string(),
                reducers: reducers as u32,
            });
        }
        // catching panics changes observable behavior (a poisoned
        // process vs a structured error), so it is strictly opt-in via
        // recovery config; the default simulator propagates as always
        let recovery = self.max_attempts > 1 || self.faults.is_some();
        let results = scoped_map(reducers, self.threads, |i| {
            let mut injected: BTreeMap<&'static str, u64> = BTreeMap::new();
            let mut backoff_us = 0u64;
            let mut attempt = 0u32;
            loop {
                attempt += 1;
                let mut meter = MemoryMeter::with_budgets(self.local_budget, self.byte_budget);
                // the reducer runs entirely on this thread, so the tally
                // deltas (dist_evals and named obs counters) are exactly
                // its own work — snapshotted per attempt, so failed
                // attempts never leak into the recorded numbers
                let evals0 = counter::thread_count();
                let obs0 = obs_counters::snapshot();
                let rt0 = Instant::now();
                let fault = self.faults.as_ref().and_then(|p| p.fault_at(round_idx, i, attempt));
                let mut fired = fault;
                let slot: Result<SlotOut<R>, ExecError> = match fault {
                    Some(FaultKind::ReadErr) => Err(ExecError::Io {
                        context: format!(
                            "injected read fault at round '{name}' reducer {i} attempt {attempt}"
                        ),
                        source: std::io::Error::other("injected fault"),
                    }),
                    Some(FaultKind::BitFlip) => Err(ExecError::Corrupt {
                        round: name.to_string(),
                        reducer: i,
                        shard: format!("injected@attempt{attempt}"),
                        detail: "injected shard bit-flip (checksum mismatch)".to_string(),
                    }),
                    _ => {
                        let res = if recovery {
                            catch_unwind(AssertUnwindSafe(|| {
                                if matches!(fault, Some(FaultKind::Panic)) {
                                    faults::raise_injected(round_idx, i, attempt);
                                }
                                work(i, &mut meter)
                            }))
                            .unwrap_or_else(|payload| {
                                Err(ExecError::ReducerPanic {
                                    round: name.to_string(),
                                    reducer: i,
                                    detail: faults::panic_detail(payload.as_ref()),
                                })
                            })
                        } else {
                            work(i, &mut meter)
                        };
                        match res {
                            Ok(s) if matches!(fault, Some(FaultKind::WriteErr)) => {
                                drop(s);
                                Err(ExecError::Io {
                                    context: format!(
                                        "injected write fault at round '{name}' reducer {i} \
                                         attempt {attempt}"
                                    ),
                                    source: std::io::Error::other("injected fault"),
                                })
                            }
                            other => {
                                // a write fault only fires once the work
                                // actually produced output to lose
                                if other.is_err() && matches!(fault, Some(FaultKind::WriteErr)) {
                                    fired = None;
                                }
                                other
                            }
                        }
                    }
                };
                let wall_us = rt0.elapsed().as_micros() as u64;
                match slot {
                    Ok(s) => {
                        // every charge must be released by the time the
                        // reducer returns — a leak here inflates
                        // cross-round peaks and turns the M_L scaling
                        // stats into nonsense
                        debug_assert_eq!(
                            meter.current(),
                            0,
                            "reducer {i} of round '{name}' returned with unreleased memory charges"
                        );
                        debug_assert_eq!(
                            meter.bytes_current(),
                            0,
                            "reducer {i} of round '{name}' returned with unreleased byte charges"
                        );
                        let evals = counter::thread_count() - evals0;
                        let mut cnt = obs_counters::delta_since(&obs0);
                        if attempt > 1 {
                            cnt = merge_fault_counters(cnt, &injected, attempt - 1, backoff_us);
                        }
                        return (Ok(s), meter, evals, cnt, wall_us, attempt);
                    }
                    Err(e) => {
                        if let Some(kind) = fired {
                            *injected.entry(kind.counter_name()).or_insert(0) += 1;
                        }
                        if e.is_transient() && attempt < self.max_attempts {
                            backoff_us += faults::sim_backoff_us(attempt);
                            obs::log::debug(&format!(
                                "round '{name}' reducer {i}: attempt {attempt} failed ({e}); \
                                 retrying"
                            ));
                            continue;
                        }
                        return (Err(e), meter, 0, Vec::new(), wall_us, attempt);
                    }
                }
            }
        });
        // deterministic failure: first error in input order wins
        let mut slots = Vec::with_capacity(reducers);
        for (slot, meter, evals, cnt, wall_us, attempts) in results {
            slots.push((slot?, meter, evals, cnt, wall_us, attempts));
        }
        let mut outs = Vec::with_capacity(reducers);
        let mut max_peak = 0usize;
        let mut agg = 0usize;
        let mut violations = 0usize;
        let mut reducer_mem_peaks = Vec::with_capacity(reducers);
        let mut reducer_mem_bytes = Vec::with_capacity(reducers);
        let mut reducer_dist_evals = Vec::with_capacity(reducers);
        let mut dist_evals = 0u64;
        let mut in_items = 0u64;
        let mut out_items = 0u64;
        let mut spill_read_bytes = 0u64;
        let mut spill_write_bytes = 0u64;
        let mut per_counters = Vec::with_capacity(reducers);
        // collection (and hence event emission) is in input order on
        // this thread — never in worker arrival order
        for (i, (slot, meter, evals, cnt, wall_us, attempts)) in slots.into_iter().enumerate() {
            max_peak = max_peak.max(meter.peak());
            agg += meter.peak();
            violations += usize::from(meter.violated());
            reducer_mem_peaks.push(meter.peak());
            reducer_mem_bytes.push(meter.bytes_peak());
            reducer_dist_evals.push(evals);
            dist_evals += evals;
            in_items += slot.in_card;
            out_items += slot.out_card;
            spill_read_bytes += slot.spill_read;
            spill_write_bytes += slot.spill_write;
            if traced {
                self.recorder.record(&Event::Reducer {
                    round: round_idx,
                    reducer: i as u32,
                    name: name.to_string(),
                    in_items: slot.in_card,
                    out_items: slot.out_card,
                    dist_evals: evals,
                    mem_peak: meter.peak() as u64,
                    mem_bytes: meter.bytes_peak(),
                    spill_read: slot.spill_read,
                    spill_write: slot.spill_write,
                    wall_us,
                    attempts: attempts as u64,
                    counters: cnt.clone(),
                });
            }
            per_counters.push(cnt);
            outs.push(slot.out);
        }
        let max_bytes = reducer_mem_bytes.iter().copied().max().unwrap_or(0);
        let stats = RoundStats {
            name: name.to_string(),
            reducers,
            max_local_peak: max_peak,
            aggregate_peak: agg,
            reducer_mem_peaks,
            reducer_mem_bytes,
            max_local_bytes: max_bytes,
            spill_read_bytes,
            spill_write_bytes,
            reducer_dist_evals,
            dist_evals,
            in_items,
            out_items,
            counters: obs_counters::merge(&per_counters),
            wall: t0.elapsed(),
            budget_violations: violations,
        };
        if traced {
            let md = stats.mem_distribution();
            let ed = stats.evals_distribution();
            self.recorder.record(&Event::RoundEnd {
                round: round_idx,
                name: name.to_string(),
                reducers: reducers as u32,
                dist_evals,
                mem_max: max_peak as u64,
                mem_p50: md.p50,
                mem_p95: md.p95,
                bytes_max: max_bytes,
                evals_max: stats.reducer_dist_evals.iter().copied().max().unwrap_or(0),
                evals_p50: ed.p50,
                evals_p95: ed.p95,
                violations: violations as u64,
                wall_us: t0.elapsed().as_micros() as u64,
            });
        }
        self.stats.lock().unwrap().rounds.push(stats);
        Ok(outs)
    }

    /// Take the accumulated job statistics (resets the simulator).
    pub fn take_stats(&self) -> JobStats {
        std::mem::take(&mut self.stats.lock().unwrap())
    }

    /// Number of rounds recorded so far in the current job. Used by the
    /// spill executor to index checkpoint entries.
    pub(crate) fn rounds_so_far(&self) -> usize {
        self.stats.lock().unwrap().rounds.len()
    }

    /// Append externally produced round statistics — the checkpoint
    /// replay path, where a round is restored rather than re-executed.
    pub(crate) fn push_stats(&self, stats: RoundStats) {
        self.stats.lock().unwrap().rounds.push(stats);
    }

    /// The statistics of the most recently completed round.
    ///
    /// Panics if no round has completed; callers invoke this right
    /// after a successful `round_impl`.
    pub(crate) fn last_round_stats(&self) -> RoundStats {
        self.stats
            .lock()
            .unwrap()
            .rounds
            .last()
            .expect("last_round_stats called before any round completed")
            .clone()
    }
}

/// Fold the fault-recovery tallies of a reducer into its name-sorted
/// counter vector. `faults.*` names slot in at their alphabetical
/// position so the vector stays sorted (the merge in
/// `obs::counters::merge` relies on that ordering).
fn merge_fault_counters(
    cnt: Vec<(String, u64)>,
    injected: &BTreeMap<&'static str, u64>,
    retries: u32,
    backoff_us: u64,
) -> Vec<(String, u64)> {
    let mut extra: Vec<(String, u64)> = injected
        .iter()
        .map(|(name, n)| (name.to_string(), *n))
        .collect();
    extra.push(("faults.backoff_sim_us".to_string(), backoff_us));
    extra.push(("faults.retries".to_string(), u64::from(retries)));
    extra.sort_by(|a, b| a.0.cmp(&b.0));
    let mut out = Vec::with_capacity(cnt.len() + extra.len());
    let mut a = cnt.into_iter().peekable();
    let mut b = extra.into_iter().peekable();
    loop {
        match (a.peek(), b.peek()) {
            (Some(x), Some(y)) => {
                if x.0 <= y.0 {
                    out.push(a.next().unwrap());
                } else {
                    out.push(b.next().unwrap());
                }
            }
            (Some(_), None) => out.push(a.next().unwrap()),
            (None, Some(_)) => out.push(b.next().unwrap()),
            (None, None) => break,
        }
    }
    out
}

impl Default for Simulator {
    fn default() -> Self {
        Simulator::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_rounds_and_collects_stats() {
        let sim = Simulator::new().with_threads(4);
        let parts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6]];
        let sums = sim.round("sum", parts, |_, part, meter| {
            meter.charge(part.len());
            let s: u32 = part.iter().sum();
            meter.release(part.len());
            s
        });
        assert_eq!(sums, vec![6, 9, 6]);
        let stats = sim.take_stats();
        assert_eq!(stats.num_rounds(), 1);
        assert_eq!(stats.rounds[0].reducers, 3);
        assert_eq!(stats.rounds[0].max_local_peak, 3);
        assert_eq!(stats.rounds[0].aggregate_peak, 6);
        assert_eq!(stats.rounds[0].reducer_mem_peaks, vec![3, 2, 1]);
        assert_eq!(stats.rounds[0].in_items, 6, "three parts of 3+2+1 input items");
        assert_eq!(stats.rounds[0].out_items, 3, "one scalar sum per reducer");
        // the legacy API does no byte metering
        assert_eq!(stats.rounds[0].reducer_mem_bytes, vec![0, 0, 0]);
        assert_eq!(stats.max_local_bytes(), 0);
    }

    /// Tracing: events arrive in (round, reducer) order on the
    /// coordinator thread regardless of worker thread count, and carry
    /// the same numbers as `RoundStats`.
    #[test]
    fn traced_round_emits_ordered_events() {
        use crate::obs::MemSink;

        let sink = Arc::new(MemSink::new());
        let rec: Arc<dyn crate::obs::Recorder> = sink.clone();
        let sim = Simulator::new().with_threads(4).with_recorder(rec);
        let parts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6]];
        let _ = sim.round("sum", parts, |_, part, meter| {
            meter.charge(part.len());
            let s: u32 = part.iter().sum();
            meter.release(part.len());
            s
        });
        let stats = sim.take_stats();
        let evs = sink.take();
        assert_eq!(evs.len(), 5, "round_start + 3 reducers + round_end");
        assert!(matches!(&evs[0], Event::RoundStart { round: 0, reducers: 3, .. }));
        for (j, ev) in evs[1..4].iter().enumerate() {
            match ev {
                Event::Reducer { round, reducer, in_items, out_items, mem_peak, .. } => {
                    assert_eq!(*round, 0);
                    assert_eq!(*reducer, j as u32, "input order, not arrival order");
                    assert_eq!(*in_items, [3, 2, 1][j]);
                    assert_eq!(*out_items, 1);
                    assert_eq!(*mem_peak, stats.rounds[0].reducer_mem_peaks[j] as u64);
                }
                other => panic!("expected reducer span, got {other:?}"),
            }
        }
        match &evs[4] {
            Event::RoundEnd { round: 0, reducers: 3, mem_max, .. } => {
                assert_eq!(*mem_max, stats.rounds[0].max_local_peak as u64);
            }
            other => panic!("expected round_end, got {other:?}"),
        }
    }

    /// The default recorder is disabled and rounds skip event assembly.
    #[test]
    fn untraced_round_records_nothing_but_full_stats() {
        let sim = Simulator::new();
        let _ = sim.round("r", vec![vec![1u32, 2]], |_, part, m| {
            m.charge(part.len());
            m.release(part.len());
            part.len()
        });
        let stats = sim.take_stats();
        assert_eq!(stats.rounds[0].in_items, 2);
        assert_eq!(stats.rounds[0].out_items, 0, "usize outputs are labels");
        assert!(stats.rounds[0].counters.is_empty());
    }

    #[test]
    fn budget_violations_counted() {
        let sim = Simulator::new().with_local_budget(2);
        let parts: Vec<Vec<u32>> = vec![vec![1], vec![2, 3, 4]];
        let _ = sim.round("r", parts, |_, part, meter| {
            meter.charge(part.len());
            meter.release(part.len());
            part.len()
        });
        let stats = sim.take_stats();
        assert_eq!(stats.total_violations(), 1);
    }

    #[test]
    fn multi_round_job_stats() {
        let sim = Simulator::new();
        for r in 0..3 {
            let _ = sim.round(&format!("r{r}"), vec![()], |_, _, meter| {
                meter.charge(r + 1);
                meter.release(r + 1);
            });
        }
        let stats = sim.take_stats();
        assert_eq!(stats.num_rounds(), 3);
        assert_eq!(stats.max_local_memory(), 3);
    }

    #[test]
    fn take_stats_resets() {
        let sim = Simulator::new();
        let _ = sim.round("r", vec![()], |_, _, m| {
            m.charge(1);
            m.release(1);
        });
        assert_eq!(sim.take_stats().num_rounds(), 1);
        assert_eq!(sim.take_stats().num_rounds(), 0);
    }

    #[test]
    fn fault_counters_merge_in_sorted_position() {
        let cnt = vec![("cover.iterations".to_string(), 2), ("pruned.give_up".to_string(), 1)];
        let mut injected = BTreeMap::new();
        injected.insert(FaultKind::ReadErr.counter_name(), 1u64);
        let merged = merge_fault_counters(cnt, &injected, 1, 1000);
        let names: Vec<&str> = merged.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "cover.iterations",
                "faults.backoff_sim_us",
                "faults.injected.read",
                "faults.retries",
                "pruned.give_up"
            ]
        );
        assert!(names.windows(2).all(|w| w[0] < w[1]), "must stay name-sorted");
    }

    /// A recovered round's accounting is bit-identical to a fault-free
    /// run's; the recovery itself shows up only in the `faults.*`
    /// counters (and the span `attempts` field).
    #[test]
    fn injected_faults_recover_with_clean_accounting() {
        let plan = FaultPlan::parse("read@0.0x2; panic@0.1").unwrap();
        let faulty = Simulator::new().with_threads(2).with_faults(plan).with_max_attempts(3);
        let parts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![4, 5]];
        let work = |_: usize, part: &Vec<u32>, m: &mut MemoryMeter| {
            m.charge(part.len());
            let s: u32 = part.iter().sum();
            m.release(part.len());
            s
        };
        let sums = faulty.round("sum", parts.clone(), work);
        assert_eq!(sums, vec![6, 9], "results must survive recovery");
        let fs = faulty.take_stats();

        let clean = Simulator::new().with_threads(2);
        let _ = clean.round("sum", parts, work);
        let cs = clean.take_stats();
        assert_eq!(fs.rounds[0].reducer_mem_peaks, cs.rounds[0].reducer_mem_peaks);
        assert_eq!(fs.rounds[0].in_items, cs.rounds[0].in_items);
        assert_eq!(fs.rounds[0].out_items, cs.rounds[0].out_items);

        // reducer 0: read fails attempts 1+2 (2 retries); reducer 1:
        // panic on attempt 1 (1 retry)
        assert_eq!(fs.counter_total("faults.retries"), 3);
        assert_eq!(fs.counter_total("faults.injected.read"), 2);
        assert_eq!(fs.counter_total("faults.injected.panic"), 1);
        assert_eq!(fs.counter_total("faults.backoff_sim_us"), 1000 + 2000 + 1000);
        assert_eq!(cs.counter_total("faults.retries"), 0, "fault-free runs stay counter-free");
    }

    /// Exhausting the attempt bound surfaces the injected failure as a
    /// structured error through the manifest API — never a panic.
    #[test]
    fn exhausted_retries_surface_structured_errors() {
        let plan = FaultPlan::parse("read@0.0x9").unwrap();
        let sim = Simulator::new().with_threads(1).with_faults(plan).with_max_attempts(2);
        let inputs = sim.scatter(vec![vec![1u32]]).expect("scatter");
        let err = match Executor::round(&sim, "r", &inputs, |_, p: &Vec<u32>, _| p.clone()) {
            Ok(_) => panic!("attempts must be exhausted"),
            Err(e) => e,
        };
        assert!(err.is_transient(), "injected I/O faults are transient: {err}");
        assert!(matches!(err, ExecError::Io { .. }), "{err}");
    }

    /// Injected panics are caught and converted; a fault plan alone
    /// (without extra attempts) still yields the structured error.
    #[test]
    fn injected_panic_without_retries_is_structured() {
        let plan = FaultPlan::parse("panic@0.0").unwrap();
        let sim = Simulator::new().with_threads(1).with_faults(plan);
        let inputs = sim.scatter(vec![vec![1u32]]).expect("scatter");
        let err = match Executor::round(&sim, "r", &inputs, |_, p: &Vec<u32>, _| p.clone()) {
            Ok(_) => panic!("max_attempts is 1, the panic must surface"),
            Err(e) => e,
        };
        match err {
            ExecError::ReducerPanic { round, reducer, detail } => {
                assert_eq!((round.as_str(), reducer), ("r", 0));
                assert!(detail.contains("injected panic"), "{detail}");
            }
            other => panic!("expected ReducerPanic, got {other}"),
        }
    }

    /// Regression (meter leaks): reducers that charge without releasing
    /// used to leak `current()` silently across rounds; the round now
    /// debug-asserts a balanced meter on return.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "unreleased memory charges")]
    fn unbalanced_reducer_is_rejected() {
        let sim = Simulator::new().with_threads(1);
        let _ = sim.round("leaky", vec![()], |_, _, m| m.charge(3));
    }

    /// The byte ledger has the same balanced-at-return contract as the
    /// item ledger (executors release every shard charge before the
    /// reducer returns).
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "unreleased byte charges")]
    fn unbalanced_byte_charges_are_rejected() {
        let sim = Simulator::new().with_threads(1);
        let inputs = sim.scatter(vec![vec![1u32]]).expect("in-memory scatter");
        // UFCS: `sim.round` would resolve to the inherent legacy method
        let _ = Executor::round(&sim, "byte-leaky", &inputs, |_, p: &Vec<u32>, m| {
            m.try_charge_bytes(10).expect("no budget set");
            p.clone()
        });
    }

    /// Distance accounting: per-reducer counts are attributed to the
    /// right reducer (|part|·|centers| each for a bulk assign), sum to
    /// the round total, and aggregate across rounds — under real
    /// parallelism and with more reducers than threads.
    #[test]
    fn dist_evals_sum_across_reducers() {
        use crate::metric::dense::EuclideanSpace;
        use crate::metric::MetricSpace;
        use crate::points::VectorData;
        use std::sync::Arc;

        let rows: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32]).collect();
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let parts: Vec<Vec<u32>> =
            vec![(0..4).collect(), (4..10).collect(), (10..15).collect(), vec![15]];
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        let centers = vec![0u32, 8];
        for threads in [1usize, 2, 8] {
            let sim = Simulator::new().with_threads(threads);
            let space_ref = &space;
            let centers_ref = &centers;
            let _ = sim.round("assign", parts.clone(), move |_, part, meter| {
                meter.charge(part.len());
                let a = space_ref.assign(part, centers_ref);
                meter.release(part.len());
                a
            });
            let stats = sim.take_stats();
            let r = &stats.rounds[0];
            assert_eq!(r.reducer_dist_evals.len(), 4, "threads={threads}");
            for (e, s) in r.reducer_dist_evals.iter().zip(&sizes) {
                assert_eq!(*e, (*s * centers.len()) as u64, "threads={threads}");
            }
            assert_eq!(r.dist_evals, r.reducer_dist_evals.iter().sum::<u64>());
            assert_eq!(stats.total_dist_evals(), (16 * centers.len()) as u64);
        }
    }

    /// Per-name attribution: repeated names sum, absent names are 0.
    #[test]
    fn dist_evals_for_filters_by_round_name() {
        use crate::metric::dense::EuclideanSpace;
        use crate::metric::MetricSpace;
        use crate::points::VectorData;
        use std::sync::Arc;

        let rows: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32]).collect();
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let sim = Simulator::new();
        let pts: Vec<u32> = (0..8).collect();
        for _ in 0..2 {
            let _ = sim.round("assign", vec![pts.clone()], |_, part, m| {
                m.charge(part.len());
                let a = space.assign(part, &[0]);
                m.release(part.len());
                a
            });
        }
        let _ = sim.round("noop", vec![()], |_, _, m| {
            m.charge(1);
            m.release(1);
        });
        let stats = sim.take_stats();
        assert_eq!(stats.dist_evals_for("assign"), 16);
        assert_eq!(stats.dist_evals_for("noop"), 0);
        assert_eq!(stats.dist_evals_for("missing"), 0);
        assert_eq!(stats.total_dist_evals(), 16);
    }

    /// Rounds with no distance work report zero; multi-round jobs sum.
    #[test]
    fn dist_evals_zero_without_distance_work() {
        let sim = Simulator::new();
        let _ = sim.round("noop", vec![(), ()], |_, _, m| {
            m.charge(1);
            m.release(1);
        });
        let stats = sim.take_stats();
        assert_eq!(stats.rounds[0].dist_evals, 0);
        assert_eq!(stats.rounds[0].reducer_dist_evals, vec![0, 0]);
        assert_eq!(stats.total_dist_evals(), 0);
    }
}
