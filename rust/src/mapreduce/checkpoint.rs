//! Round-level checkpoint/resume for the spill backend.
//!
//! MapReduce round boundaries are natural checkpoints: each round's
//! output is a self-contained shard manifest, and the mergeable-coreset
//! structure means no cross-shard state ever needs re-deriving. A
//! [`CheckpointStore`] persists, for every completed round, the round's
//! output shards (CRC-framed, same codec as the spill store) plus a
//! JSON manifest carrying the full [`RoundStats`] — so a resumed run
//! replays completed rounds *with their original accounting* and the
//! final `RunReport` is bit-identical to an uninterrupted run's.
//!
//! Resume validation is strict: a checkpoint is only replayed when its
//! `meta.json` fingerprint matches the resuming run (the driver passes
//! its full run fingerprint — every result-affecting config field plus
//! a content hash of the input data), the round name and shard count
//! match what the executor is about to run, and every persisted shard
//! passes its checksum. Anything else — a missing round file, a
//! flipped bit, a different config — truncates the usable prefix and
//! the run simply re-executes from there. Truncation is durable: the
//! manifests and shards of every round past the divergence point are
//! *deleted from disk*, never just skipped in memory — otherwise a
//! resume killed after re-executing part of the divergent suffix
//! could, on the next open, splice a stale round from the
//! pre-divergence run back into the fresh prefix (its checksums still
//! pass; only the delete makes the divergence unrecoverable).
//!
//! Layout under the checkpoint dir:
//!
//! ```text
//! meta.json          {"version":1,"fingerprint":"..."}
//! round-<idx>.json   {"round":i,"name":...,"shards":[...],"stats":{...}}
//! ckpt-r<idx>-<slot>.shard   CRC-framed shard payloads
//! ```
//!
//! Manifest writes are atomic (tmp + rename), so a run killed mid-write
//! leaves at worst a missing round, never a half-valid one.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;

use super::executor::ExecError;
use super::spill::{ShardRef, SpillStore};
use super::RoundStats;

const META_FILE: &str = "meta.json";
const CHECKPOINT_VERSION: u64 = 1;

/// One persisted round: enough to splice it back into a resumed job.
#[derive(Clone, Debug)]
pub struct CheckpointRound {
    pub name: String,
    pub shards: Vec<ShardRef>,
    pub stats: RoundStats,
}

/// Durable store of completed rounds (see module docs).
pub struct CheckpointStore {
    dir: PathBuf,
    store: Arc<SpillStore>,
    /// Validated contiguous prefix of completed rounds, loaded at open;
    /// truncated when a resume finds a mismatching round.
    rounds: Mutex<Vec<CheckpointRound>>,
}

fn ck_err(context: &str, detail: impl std::fmt::Display) -> ExecError {
    ExecError::Checkpoint { context: context.to_string(), detail: detail.to_string() }
}

impl CheckpointStore {
    /// Open (or create) a checkpoint store at `dir` for a run with the
    /// given `fingerprint`. A pre-existing store with a *different*
    /// fingerprint is a hard error — a checkpoint must never be
    /// replayed into a different job. On success, the validated
    /// contiguous prefix of completed rounds is loaded (every shard is
    /// re-read and checksum-verified up front, so a resume decision is
    /// never made on bytes that would later fail).
    pub fn open(dir: &Path, fingerprint: &str) -> Result<CheckpointStore, ExecError> {
        fs::create_dir_all(dir).map_err(|e| ck_err("create checkpoint dir", e))?;
        let meta_path = dir.join(META_FILE);
        match fs::read_to_string(&meta_path) {
            Ok(text) => {
                let v = Json::parse(&text).map_err(|e| ck_err("parse meta.json", e))?;
                let have = v.get("fingerprint").and_then(|f| f.as_str()).unwrap_or("");
                if have != fingerprint {
                    return Err(ck_err(
                        "fingerprint mismatch",
                        format!(
                            "checkpoint at {} was written by run `{have}`, \
                             refusing to resume run `{fingerprint}`",
                            dir.display()
                        ),
                    ));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let mut meta = Json::obj();
                meta.set("version", Json::num(CHECKPOINT_VERSION as f64));
                meta.set("fingerprint", Json::str(fingerprint));
                write_atomic(&meta_path, meta.to_string().as_bytes())
                    .map_err(|e| ck_err("write meta.json", e))?;
            }
            Err(e) => return Err(ck_err("read meta.json", e)),
        }
        let store = Arc::new(
            SpillStore::create(Some(dir)).map_err(|e| ck_err("open checkpoint shards", e))?,
        );
        let mut rounds = Vec::new();
        loop {
            let idx = rounds.len();
            let path = dir.join(format!("round-{idx}.json"));
            let text = match fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => break,
                Err(e) => return Err(ck_err("read round manifest", e)),
            };
            match parse_round(&text, idx) {
                Ok(r) => {
                    // verify every shard now: a corrupt checkpoint is a
                    // shorter usable prefix, not a later hard failure
                    let ok = r.shards.iter().all(|s| store.read(s).is_ok());
                    if !ok {
                        crate::obs::log::warn(&format!(
                            "checkpoint: round {idx} has corrupt shards; resuming from round {idx}"
                        ));
                        break;
                    }
                    rounds.push(r);
                }
                Err(e) => {
                    crate::obs::log::warn(&format!(
                        "checkpoint: round {idx} manifest invalid ({e}); \
                         resuming from round {idx}"
                    ));
                    break;
                }
            }
        }
        // everything past the validated prefix is unusable (corrupt,
        // invalid, or orphaned beyond a gap) — delete it now so a later
        // partial re-execution can never splice it back in
        purge_from(dir, rounds.len())
            .map_err(|e| ck_err("purge stale checkpoint rounds", e))?;
        if !rounds.is_empty() {
            crate::obs::log::info(&format!(
                "checkpoint: {} completed round(s) available at {}",
                rounds.len(),
                dir.display()
            ));
        }
        Ok(CheckpointStore { dir: dir.to_path_buf(), store, rounds: Mutex::new(rounds) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of validated completed rounds available for replay.
    pub fn rounds_available(&self) -> usize {
        self.rounds.lock().unwrap().len()
    }

    /// Shard store backing the persisted rounds (for replayed
    /// manifests).
    pub(crate) fn shard_store(&self) -> Arc<SpillStore> {
        Arc::clone(&self.store)
    }

    /// The persisted round at `idx`, if it matches what the executor is
    /// about to run. A name or shard-count mismatch truncates the
    /// usable prefix at `idx` — in memory *and on disk*: the job
    /// diverged, and stale manifests left behind would pass their
    /// checksums on a later resume and replay data from a run already
    /// known to be wrong. A failed delete is therefore a hard error,
    /// not a warning.
    pub(crate) fn take_resumable(
        &self,
        idx: usize,
        name: &str,
        n_shards: usize,
    ) -> Result<Option<CheckpointRound>, ExecError> {
        let mut rounds = self.rounds.lock().unwrap();
        if idx >= rounds.len() {
            return Ok(None);
        }
        let r = &rounds[idx];
        if r.name != name || r.shards.len() != n_shards {
            crate::obs::log::warn(&format!(
                "checkpoint: round {idx} was '{}' with {} shard(s), job wants '{name}' \
                 with {n_shards}; re-executing from round {idx}",
                r.name,
                r.shards.len()
            ));
            rounds.truncate(idx);
            purge_from(&self.dir, idx)
                .map_err(|e| ck_err("purge diverged checkpoint rounds", e))?;
            return Ok(None);
        }
        Ok(Some(r.clone()))
    }

    /// Persist one completed round: copy its output shards out of the
    /// run's spill store (re-reading them checksum-verified) and write
    /// the round manifest atomically.
    pub(crate) fn persist(
        &self,
        idx: usize,
        name: &str,
        stats: &RoundStats,
        src: &SpillStore,
        shards: &[ShardRef],
    ) -> Result<(), ExecError> {
        let mut persisted = Vec::with_capacity(shards.len());
        for (slot, s) in shards.iter().enumerate() {
            let payload = src
                .read(s)
                .map_err(|e| ck_err("copy shard into checkpoint", e))?;
            let tag = format!("ckpt-r{idx}-{slot}");
            let sref = self
                .store
                .write(&tag, &payload)
                .map_err(|e| ck_err("write checkpoint shard", e))?;
            persisted.push(sref);
        }
        let mut o = Json::obj();
        o.set("round", Json::num(idx as f64));
        o.set("name", Json::str(name));
        let shard_arr: Vec<Json> = persisted
            .iter()
            .map(|s| {
                let mut sj = Json::obj();
                sj.set("tag", Json::str(s.tag.clone()));
                sj.set("bytes", Json::num(s.bytes as f64));
                sj
            })
            .collect();
        o.set("shards", Json::Arr(shard_arr));
        o.set("stats", stats_to_json(stats));
        write_atomic(&self.dir.join(format!("round-{idx}.json")), o.to_string().as_bytes())
            .map_err(|e| ck_err("write round manifest", e))?;
        Ok(())
    }
}

fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension("json.tmp");
    fs::write(&tmp, bytes)?;
    fs::rename(&tmp, path)
}

/// Round index of a `round-<idx>.json` manifest file name.
fn round_file_idx(name: &str) -> Option<usize> {
    name.strip_prefix("round-")?.strip_suffix(".json")?.parse().ok()
}

/// Round index of a `ckpt-r<idx>-<slot>.shard` payload file name.
fn shard_file_idx(name: &str) -> Option<usize> {
    let rest = name.strip_prefix("ckpt-r")?.strip_suffix(".shard")?;
    rest.split_once('-')?.0.parse().ok()
}

/// Delete every persisted round at index >= `from` — manifests and
/// shard payloads both. Files that are not checkpoint artifacts
/// (`meta.json`, foreign shards) are left alone.
fn purge_from(dir: &Path, from: usize) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let stale = round_file_idx(name).is_some_and(|i| i >= from)
            || shard_file_idx(name).is_some_and(|i| i >= from);
        if stale {
            fs::remove_file(entry.path())?;
        }
    }
    Ok(())
}

fn parse_round(text: &str, idx: usize) -> Result<CheckpointRound, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    let stored_idx =
        v.get("round").and_then(|j| j.as_u64()).ok_or("missing `round` index")? as usize;
    if stored_idx != idx {
        return Err(format!("manifest claims round {stored_idx}, file name says {idx}"));
    }
    let name =
        v.get("name").and_then(|j| j.as_str()).ok_or("missing `name`")?.to_string();
    let shards = v
        .get("shards")
        .and_then(|j| j.as_arr())
        .ok_or("missing `shards`")?
        .iter()
        .map(|sj| {
            let tag = sj.get("tag").and_then(|t| t.as_str()).ok_or("shard without tag")?;
            let bytes = sj.get("bytes").and_then(|b| b.as_u64()).ok_or("shard without bytes")?;
            Ok(ShardRef { tag: tag.to_string(), bytes })
        })
        .collect::<Result<Vec<_>, String>>()?;
    let stats = stats_from_json(v.get("stats").ok_or("missing `stats`")?)?;
    Ok(CheckpointRound { name, shards, stats })
}

/// `RoundStats` → JSON with every deterministic field (`wall` is
/// wall-clock and restores as zero — the report never serializes it).
fn stats_to_json(s: &RoundStats) -> Json {
    fn arr_u64(v: &[u64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::num(x as f64)).collect())
    }
    let mut o = Json::obj();
    o.set("name", Json::str(s.name.clone()));
    o.set("reducers", Json::num(s.reducers as f64));
    o.set("max_local_peak", Json::num(s.max_local_peak as f64));
    o.set("aggregate_peak", Json::num(s.aggregate_peak as f64));
    o.set(
        "reducer_mem_peaks",
        Json::Arr(s.reducer_mem_peaks.iter().map(|&x| Json::num(x as f64)).collect()),
    );
    o.set("reducer_mem_bytes", arr_u64(&s.reducer_mem_bytes));
    o.set("max_local_bytes", Json::num(s.max_local_bytes as f64));
    o.set("spill_read_bytes", Json::num(s.spill_read_bytes as f64));
    o.set("spill_write_bytes", Json::num(s.spill_write_bytes as f64));
    o.set("reducer_dist_evals", arr_u64(&s.reducer_dist_evals));
    o.set("dist_evals", Json::num(s.dist_evals as f64));
    o.set("in_items", Json::num(s.in_items as f64));
    o.set("out_items", Json::num(s.out_items as f64));
    let mut cj = Json::obj();
    for (k, v) in &s.counters {
        cj.set(k, Json::num(*v as f64));
    }
    o.set("counters", cj);
    o.set("budget_violations", Json::num(s.budget_violations as f64));
    o
}

fn stats_from_json(v: &Json) -> Result<RoundStats, String> {
    fn u64s(v: &Json, key: &str) -> Result<Vec<u64>, String> {
        v.get(key)
            .and_then(|j| j.as_arr())
            .ok_or_else(|| format!("missing array `{key}`"))?
            .iter()
            .map(|x| x.as_u64().ok_or_else(|| format!("non-u64 entry in `{key}`")))
            .collect()
    }
    fn num(v: &Json, key: &str) -> Result<u64, String> {
        v.get(key).and_then(|j| j.as_u64()).ok_or_else(|| format!("missing field `{key}`"))
    }
    let counters = v
        .get("counters")
        .and_then(|j| j.as_obj())
        .ok_or("missing `counters`")?
        .iter()
        .map(|(k, val)| {
            val.as_u64().map(|n| (k.clone(), n)).ok_or_else(|| format!("non-u64 counter `{k}`"))
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok(RoundStats {
        name: v.get("name").and_then(|j| j.as_str()).ok_or("missing `name`")?.to_string(),
        reducers: num(v, "reducers")? as usize,
        max_local_peak: num(v, "max_local_peak")? as usize,
        aggregate_peak: num(v, "aggregate_peak")? as usize,
        reducer_mem_peaks: u64s(v, "reducer_mem_peaks")?.into_iter().map(|x| x as usize).collect(),
        reducer_mem_bytes: u64s(v, "reducer_mem_bytes")?,
        max_local_bytes: num(v, "max_local_bytes")?,
        spill_read_bytes: num(v, "spill_read_bytes")?,
        spill_write_bytes: num(v, "spill_write_bytes")?,
        reducer_dist_evals: u64s(v, "reducer_dist_evals")?,
        dist_evals: num(v, "dist_evals")?,
        in_items: num(v, "in_items")?,
        out_items: num(v, "out_items")?,
        counters,
        wall: Duration::ZERO,
        budget_violations: num(v, "budget_violations")? as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stats() -> RoundStats {
        RoundStats {
            name: "r0".to_string(),
            reducers: 2,
            max_local_peak: 5,
            aggregate_peak: 8,
            reducer_mem_peaks: vec![5, 3],
            reducer_mem_bytes: vec![40, 24],
            max_local_bytes: 40,
            spill_read_bytes: 64,
            spill_write_bytes: 64,
            reducer_dist_evals: vec![10, 4],
            dist_evals: 14,
            in_items: 6,
            out_items: 6,
            counters: vec![("cover.iterations".to_string(), 3), ("faults.retries".to_string(), 1)],
            wall: Duration::from_millis(7),
            budget_violations: 0,
        }
    }

    #[test]
    fn round_stats_round_trip_through_json() {
        let s = sample_stats();
        let back = stats_from_json(&stats_to_json(&s)).expect("parse");
        assert_eq!(back.name, s.name);
        assert_eq!(back.reducer_mem_peaks, s.reducer_mem_peaks);
        assert_eq!(back.reducer_mem_bytes, s.reducer_mem_bytes);
        assert_eq!(back.reducer_dist_evals, s.reducer_dist_evals);
        assert_eq!(back.counters, s.counters);
        assert_eq!(back.dist_evals, s.dist_evals);
        assert_eq!(back.wall, Duration::ZERO, "wall-clock is not persisted");
    }

    #[test]
    fn open_persist_reload_and_validate() {
        let dir = std::env::temp_dir().join(format!("mrc-ckpt-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let src = SpillStore::create(None).expect("src store");
        let shard = src.write("r0-0", &[1, 2, 3, 4]).expect("write");

        let ck = CheckpointStore::open(&dir, "fp-a").expect("open");
        assert_eq!(ck.rounds_available(), 0);
        ck.persist(0, "round-zero", &sample_stats(), &src, &[shard]).expect("persist");

        // reopen with the same fingerprint: the round replays
        let ck2 = CheckpointStore::open(&dir, "fp-a").expect("reopen");
        assert_eq!(ck2.rounds_available(), 1);
        let r = ck2.take_resumable(0, "round-zero", 1).expect("no purge").expect("resumable");
        assert_eq!(r.stats.dist_evals, 14);
        assert_eq!(ck2.shard_store().read(&r.shards[0]).expect("shard"), vec![1, 2, 3, 4]);

        // a different fingerprint refuses to open at all
        let err = CheckpointStore::open(&dir, "fp-b").expect_err("mismatch");
        assert!(matches!(err, ExecError::Checkpoint { .. }), "{err}");

        // corrupting a persisted shard shortens the usable prefix —
        // and deletes the now-unusable round from disk
        let shard_path = dir.join("ckpt-r0-0.shard");
        let mut bytes = fs::read(&shard_path).expect("raw");
        let n = bytes.len();
        bytes[n - 5] ^= 0x80;
        fs::write(&shard_path, &bytes).expect("corrupt");
        let ck3 = CheckpointStore::open(&dir, "fp-a").expect("reopen");
        assert_eq!(ck3.rounds_available(), 0, "corrupt shard must not be replayed");
        assert!(!dir.join("round-0.json").exists(), "corrupt round purged from disk");
        assert!(!shard_path.exists(), "corrupt shard purged from disk");

        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn divergence_deletes_stale_rounds_from_disk() {
        let dir = std::env::temp_dir().join(format!("mrc-ckpt-purge-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let src = SpillStore::create(None).expect("src store");
        let s0 = src.write("a", &[1, 2]).expect("write");
        let s1 = src.write("b", &[3, 4]).expect("write");

        let ck = CheckpointStore::open(&dir, "fp").expect("open");
        ck.persist(0, "r-zero", &sample_stats(), &src, &[s0]).expect("persist 0");
        ck.persist(1, "r-one", &sample_stats(), &src, &[s1]).expect("persist 1");

        let ck2 = CheckpointStore::open(&dir, "fp").expect("reopen");
        assert_eq!(ck2.rounds_available(), 2);
        // the job diverged at round 0: BOTH rounds must vanish from
        // disk, or a resume killed mid-suffix could splice the stale
        // round 1 back into a fresh prefix on the next open
        assert!(ck2.take_resumable(0, "different", 1).expect("purge ok").is_none());
        assert_eq!(ck2.rounds_available(), 0);
        for f in ["round-0.json", "round-1.json", "ckpt-r0-0.shard", "ckpt-r1-0.shard"] {
            assert!(!dir.join(f).exists(), "{f} must be deleted at divergence");
        }
        let ck3 = CheckpointStore::open(&dir, "fp").expect("reopen after purge");
        assert_eq!(ck3.rounds_available(), 0, "nothing stale left to splice back");
        assert!(dir.join("meta.json").is_file(), "meta survives the purge");

        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_deletes_rounds_past_a_corrupt_prefix() {
        let dir = std::env::temp_dir().join(format!("mrc-ckpt-prefix-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let src = SpillStore::create(None).expect("src store");
        let s0 = src.write("a", &[1, 2]).expect("write");
        let s1 = src.write("b", &[3, 4]).expect("write");

        let ck = CheckpointStore::open(&dir, "fp").expect("open");
        ck.persist(0, "r-zero", &sample_stats(), &src, &[s0]).expect("persist 0");
        ck.persist(1, "r-one", &sample_stats(), &src, &[s1]).expect("persist 1");
        drop(ck);

        // round 0 goes bad: the prefix ends there, and round 1 —
        // though its own checksums still pass — must not survive
        let shard_path = dir.join("ckpt-r0-0.shard");
        let mut bytes = fs::read(&shard_path).expect("raw");
        let n = bytes.len();
        bytes[n - 5] ^= 0x80;
        fs::write(&shard_path, &bytes).expect("corrupt");

        let ck2 = CheckpointStore::open(&dir, "fp").expect("reopen");
        assert_eq!(ck2.rounds_available(), 0);
        assert!(!dir.join("round-1.json").exists(), "orphaned round 1 must be deleted");
        assert!(!dir.join("ckpt-r1-0.shard").exists());

        let _ = fs::remove_dir_all(&dir);
    }
}
