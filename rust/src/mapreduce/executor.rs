//! Pluggable execution backends for the MapReduce rounds.
//!
//! The coreset pipelines and the driver run against the [`Executor`]
//! trait instead of a concrete simulator. Two backends exist:
//!
//! - [`InMemoryExecutor`] (= [`Simulator`]): every manifest is a plain
//!   `Vec` in RAM — today's behavior, bit for bit.
//! - [`SpillExecutor`]: every manifest is a set of on-disk shards
//!   ([`SpillStore`]); a reducer materializes exactly one input shard,
//!   runs, encodes its output back to disk, and drops both.
//!
//! **Byte parity is the determinism contract.** Both backends charge the
//! same byte sequence per reducer — the encoded size of the input shard
//! *before* loading it, then the encoded size of the output (computed
//! arithmetically via [`Spillable::encoded_len`], before any encoding) —
//! and release both at the end. Peaks, traces, `RunReport`s and
//! `dist_evals` are therefore bit-identical across backends and thread
//! counts; the only backend-dependent numbers are the wall-gated
//! `spill_read`/`spill_write` span fields, which the stable trace form
//! omits. Because every charge precedes the corresponding
//! materialization, a run under budget B either completes with peak
//! resident bytes ≤ B or fails with a structured [`ExecError::OverBudget`]
//! — never an OOM kill. Transient codec buffers and broadcast state
//! (e.g. the r2 C_w) are item-metered only.
//!
//! Backend selection is configuration, not code: [`ExecutorCfg::default`]
//! reads `MRCORESET_EXECUTOR` (`mem`|`spill`) and `MRCORESET_MEM_BUDGET`
//! (bytes, `k`/`m`/`g` suffixes), which is how CI re-runs the whole
//! suite out-of-core with a tight budget and zero code changes.

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::obs::Recorder;

use super::checkpoint::CheckpointStore;
use super::faults::FaultPlan;
use super::memory::MemoryMeter;
use super::spill::{ShardRef, SpillError, SpillStore, Spillable};
use super::{Cardinality, JobStats, Simulator, SlotOut};

/// Structured executor failure. Every variant names its site, so a run
/// that does not fit its budget, hits bad disk, or exhausts its retries
/// dies with an actionable error instead of an OOM kill or a panic.
///
/// Retry semantics (see `Simulator::round_impl`): `Io`, `Corrupt`, and
/// `ReducerPanic` are transient — a fresh idempotent re-execution from
/// the input manifest can clear them, so the round engine retries them
/// up to its attempt bound. `OverBudget` is deterministic (the same
/// charges refuse again) and `Checkpoint` is a coordinator-side setup
/// failure; neither is retried.
#[derive(Debug)]
pub enum ExecError {
    OverBudget { round: String, reducer: usize, needed: u64, budget: u64, resident: u64 },
    Io { context: String, source: std::io::Error },
    Codec { context: String, detail: String },
    /// A shard's bytes failed integrity validation (truncation, bad
    /// frame, CRC-32 mismatch). `round` is `"<manifest>"` for
    /// coordinator-side reads outside any round.
    Corrupt { round: String, reducer: usize, shard: String, detail: String },
    /// A reducer closure panicked; the payload is summarized in
    /// `detail`. Only produced when recovery is enabled (a fault plan
    /// or retry budget is configured) — otherwise panics propagate.
    ReducerPanic { round: String, reducer: usize, detail: String },
    /// Checkpoint store setup or persistence failed (not retryable).
    Checkpoint { context: String, detail: String },
}

impl ExecError {
    /// True when an idempotent re-execution of the failing reducer can
    /// clear the error.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            ExecError::Io { .. } | ExecError::Corrupt { .. } | ExecError::ReducerPanic { .. }
        )
    }

    /// Fill in the (round, reducer) site on corruption errors that were
    /// detected by a coordinator-side manifest read inside a round.
    pub(crate) fn at_site(mut self, round: &str, reducer: usize) -> ExecError {
        if let ExecError::Corrupt { round: r, reducer: rd, .. } = &mut self {
            if r == MANIFEST_SITE {
                *r = round.to_string();
                *rd = reducer;
            }
        }
        self
    }
}

/// Placeholder round name for shard reads outside any round.
const MANIFEST_SITE: &str = "<manifest>";

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::OverBudget { round, reducer, needed, budget, resident } => write!(
                f,
                "memory budget exceeded in round '{round}' reducer {reducer}: needs {needed} \
                 more bytes with {resident} resident against a budget of {budget}"
            ),
            ExecError::Io { context, source } => write!(f, "spill I/O failed ({context}): {source}"),
            ExecError::Codec { context, detail } => {
                write!(f, "corrupt spill shard ({context}): {detail}")
            }
            ExecError::Corrupt { round, reducer, shard, detail } => write!(
                f,
                "shard integrity failure in round '{round}' reducer {reducer} \
                 (shard {shard}): {detail}"
            ),
            ExecError::ReducerPanic { round, reducer, detail } => {
                write!(f, "reducer {reducer} of round '{round}' panicked: {detail}")
            }
            ExecError::Checkpoint { context, detail } => {
                write!(f, "checkpoint failure ({context}): {detail}")
            }
        }
    }
}

impl std::error::Error for ExecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExecError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// One round's worth of reducer values, owned by a backend: either
/// resident vectors (in-memory) or per-value disk shards (spill). The
/// key operation is [`Manifest::shard_bytes`] — the exact encoded size
/// of slot `i`, known *without* touching the disk, so executors can
/// charge the byte budget before materializing anything.
pub enum Manifest<T> {
    Mem(Vec<T>),
    Spill { store: Arc<SpillStore>, shards: Vec<ShardRef> },
}

/// A materialized manifest slot: borrowed straight out of an in-memory
/// manifest, or owned freshly-decoded bytes from a spill shard.
pub enum Shard<'a, T> {
    Borrowed(&'a T),
    Owned(T),
}

impl<T> std::ops::Deref for Shard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        match self {
            Shard::Borrowed(t) => t,
            Shard::Owned(t) => t,
        }
    }
}

fn decode_shard<T: Spillable>(store: &SpillStore, shard: &ShardRef) -> Result<T, ExecError> {
    let payload = store.read(shard).map_err(|e| match e {
        SpillError::Io(source) => {
            ExecError::Io { context: format!("read shard {}", shard.tag), source }
        }
        SpillError::Corrupt { detail } => ExecError::Corrupt {
            round: MANIFEST_SITE.to_string(),
            reducer: 0,
            shard: shard.tag.clone(),
            detail,
        },
    })?;
    let mut d = super::spill::Decoder::new(&payload);
    let value = T::decode(&mut d).map_err(|e| ExecError::Codec {
        context: format!("decode shard {}", shard.tag),
        detail: e.0,
    })?;
    d.finish().map_err(|e| ExecError::Codec {
        context: format!("decode shard {}", shard.tag),
        detail: e.0,
    })?;
    Ok(value)
}

impl<T: Spillable> Manifest<T> {
    pub fn len(&self) -> usize {
        match self {
            Manifest::Mem(items) => items.len(),
            Manifest::Spill { shards, .. } => shards.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Exact encoded size of slot `i`, without any I/O.
    pub fn shard_bytes(&self, i: usize) -> u64 {
        match self {
            Manifest::Mem(items) => items[i].encoded_len(),
            Manifest::Spill { shards, .. } => shards[i].bytes,
        }
    }

    /// Total encoded size of the manifest (the round's shuffle volume).
    pub fn total_bytes(&self) -> u64 {
        match self {
            Manifest::Mem(items) => items.iter().map(Spillable::encoded_len).sum(),
            Manifest::Spill { shards, .. } => shards.iter().map(|s| s.bytes).sum(),
        }
    }

    /// Materialize slot `i` (borrow in memory, read + decode on spill).
    pub fn load(&self, i: usize) -> Result<Shard<'_, T>, ExecError> {
        match self {
            Manifest::Mem(items) => Ok(Shard::Borrowed(&items[i])),
            Manifest::Spill { store, shards } => {
                Ok(Shard::Owned(decode_shard(store, &shards[i])?))
            }
        }
    }

    /// Visit every value in slot order, materializing one at a time —
    /// the coordinator-side streaming fold (e.g. merging per-partition
    /// coresets) that never holds more than one shard resident.
    pub fn for_each(&self, mut f: impl FnMut(&T)) -> Result<(), ExecError> {
        match self {
            Manifest::Mem(items) => {
                for t in items {
                    f(t);
                }
                Ok(())
            }
            Manifest::Spill { store, shards } => {
                for s in shards {
                    let item = decode_shard::<T>(store, s)?;
                    f(&item);
                }
                Ok(())
            }
        }
    }

    /// Own every value (decodes all shards on spill) — for terminal
    /// single-slot manifests like the final solution.
    pub fn into_items(self) -> Result<Vec<T>, ExecError> {
        match self {
            Manifest::Mem(items) => Ok(items),
            Manifest::Spill { store, shards } => {
                let mut out = Vec::with_capacity(shards.len());
                for s in &shards {
                    out.push(decode_shard(&store, s)?);
                }
                Ok(out)
            }
        }
    }
}

/// A pluggable MapReduce execution backend.
///
/// Note for concrete [`Simulator`] call sites: its inherent legacy
/// `round(Vec<I>)` shadows the trait method in method-call syntax; reach
/// the manifest-based round via generics or `Executor::round(&sim, ..)`.
pub trait Executor {
    /// Place the coordinator-built values under backend ownership (the
    /// scatter step of a round: in RAM, or encoded out to shards).
    fn scatter<T>(&self, parts: Vec<T>) -> Result<Manifest<T>, ExecError>
    where
        T: Spillable;

    /// Execute one parallel round over a manifest: `f(i, input, meter)`
    /// per slot, outputs returned as a new manifest in input order.
    fn round<I, O, F>(
        &self,
        name: &str,
        inputs: &Manifest<I>,
        f: F,
    ) -> Result<Manifest<O>, ExecError>
    where
        I: Spillable + Cardinality + Sync,
        O: Spillable + Cardinality + Send,
        F: Fn(usize, &I, &mut MemoryMeter) -> O + Sync;

    /// Take the accumulated job statistics (resets the backend).
    fn take_stats(&self) -> JobStats;
}

/// The in-RAM backend is the simulator itself.
pub type InMemoryExecutor = Simulator;

fn charge(
    meter: &mut MemoryMeter,
    round: &str,
    reducer: usize,
    bytes: u64,
) -> Result<(), ExecError> {
    meter.try_charge_bytes(bytes).map_err(|e| ExecError::OverBudget {
        round: round.to_string(),
        reducer,
        needed: e.needed,
        budget: e.budget,
        resident: e.resident,
    })
}

impl Executor for Simulator {
    fn scatter<T>(&self, parts: Vec<T>) -> Result<Manifest<T>, ExecError>
    where
        T: Spillable,
    {
        Ok(Manifest::Mem(parts))
    }

    fn round<I, O, F>(
        &self,
        name: &str,
        inputs: &Manifest<I>,
        f: F,
    ) -> Result<Manifest<O>, ExecError>
    where
        I: Spillable + Cardinality + Sync,
        O: Spillable + Cardinality + Send,
        F: Fn(usize, &I, &mut MemoryMeter) -> O + Sync,
    {
        let outs = self.round_impl(name, inputs.len(), |i, meter| {
            let in_bytes = inputs.shard_bytes(i);
            charge(meter, name, i, in_bytes)?;
            let shard = inputs.load(i).map_err(|e| e.at_site(name, i))?;
            let input: &I = &shard;
            let in_card = input.cardinality();
            let out = f(i, input, meter);
            let out_bytes = out.encoded_len();
            charge(meter, name, i, out_bytes)?;
            meter.release_bytes(in_bytes + out_bytes);
            let out_card = out.cardinality();
            Ok(SlotOut {
                out,
                in_card,
                out_card,
                in_bytes,
                out_bytes,
                spill_read: 0,
                spill_write: 0,
            })
        })?;
        Ok(Manifest::Mem(outs))
    }

    fn take_stats(&self) -> JobStats {
        Simulator::take_stats(self)
    }
}

/// Out-of-core backend: manifests live on disk, reducers materialize
/// one input shard at a time under the simulator's hard byte budget,
/// and outputs are encoded back out before the next slot runs. Stats,
/// traces and results are bit-identical to the in-memory backend.
pub struct SpillExecutor {
    sim: Simulator,
    store: Arc<SpillStore>,
    seq: AtomicU64,
    /// When set, every completed round is persisted (shards + stats)
    /// and a fresh run over the same checkpoint dir replays completed
    /// rounds instead of re-executing them.
    checkpoint: Option<Arc<CheckpointStore>>,
}

impl SpillExecutor {
    /// Wrap a configured simulator (threads / budgets / recorder) with a
    /// shard store at `dir`, or a fresh temp directory (removed when the
    /// last manifest referencing it drops) when `None`.
    pub fn new(sim: Simulator, dir: Option<&Path>) -> Result<SpillExecutor, ExecError> {
        let store = SpillStore::create(dir).map_err(|e| ExecError::Io {
            context: "create spill store".to_string(),
            source: e,
        })?;
        Ok(SpillExecutor { sim, store: Arc::new(store), seq: AtomicU64::new(0), checkpoint: None })
    }

    /// Enable round-level checkpoint/resume against `store` (see
    /// [`CheckpointStore::open`] for the validation a resume performs).
    pub fn with_checkpoint(mut self, store: CheckpointStore) -> SpillExecutor {
        self.checkpoint = Some(Arc::new(store));
        self
    }

    pub fn store_dir(&self) -> &Path {
        self.store.dir()
    }
}

impl Executor for SpillExecutor {
    fn scatter<T>(&self, parts: Vec<T>) -> Result<Manifest<T>, ExecError>
    where
        T: Spillable,
    {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut shards = Vec::with_capacity(parts.len());
        let mut buf = Vec::new();
        for (i, p) in parts.iter().enumerate() {
            buf.clear();
            p.encode(&mut buf);
            debug_assert_eq!(buf.len() as u64, p.encoded_len(), "encoded_len must be exact");
            let tag = format!("s{seq}-{i}");
            let shard = self.store.write(&tag, &buf).map_err(|e| ExecError::Io {
                context: format!("write shard {tag}"),
                source: e,
            })?;
            shards.push(shard);
        }
        Ok(Manifest::Spill { store: Arc::clone(&self.store), shards })
    }

    fn round<I, O, F>(
        &self,
        name: &str,
        inputs: &Manifest<I>,
        f: F,
    ) -> Result<Manifest<O>, ExecError>
    where
        I: Spillable + Cardinality + Sync,
        O: Spillable + Cardinality + Send,
        F: Fn(usize, &I, &mut MemoryMeter) -> O + Sync,
    {
        // Resume: a checkpoint that already holds this round (validated
        // name, shard count, checksums) is replayed — its stats enter
        // the job as if the round had run, and its shards become the
        // round's output manifest. No reducer executes, and no span
        // events are re-emitted for the replayed round (see the
        // checkpoint-resume caveat in `obs::event`).
        let round_idx = self.sim.rounds_so_far();
        if let Some(ck) = &self.checkpoint {
            if let Some(r) = ck.take_resumable(round_idx, name, inputs.len())? {
                crate::obs::log::info(&format!(
                    "checkpoint: replaying round {round_idx} '{name}' from {}",
                    ck.dir().display()
                ));
                self.sim.push_stats(r.stats);
                return Ok(Manifest::Spill { store: ck.shard_store(), shards: r.shards });
            }
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let store = &self.store;
        let from_disk = matches!(inputs, Manifest::Spill { .. });
        let shards = self.sim.round_impl(name, inputs.len(), |i, meter| {
            let in_bytes = inputs.shard_bytes(i);
            charge(meter, name, i, in_bytes)?;
            let shard = inputs.load(i).map_err(|e| e.at_site(name, i))?;
            let input: &I = &shard;
            let in_card = input.cardinality();
            let out = f(i, input, meter);
            let out_bytes = out.encoded_len();
            charge(meter, name, i, out_bytes)?;
            let out_card = out.cardinality();
            let mut buf = Vec::with_capacity(out_bytes as usize);
            out.encode(&mut buf);
            debug_assert_eq!(buf.len() as u64, out_bytes, "encoded_len must be exact");
            drop(out);
            let tag = format!("r{seq}-{i}");
            let sref = store.write(&tag, &buf).map_err(|e| ExecError::Io {
                context: format!("write shard {tag}"),
                source: e,
            })?;
            meter.release_bytes(in_bytes + out_bytes);
            Ok(SlotOut {
                out: sref,
                in_card,
                out_card,
                in_bytes,
                out_bytes,
                spill_read: if from_disk { in_bytes } else { 0 },
                spill_write: out_bytes,
            })
        })?;
        if let Some(ck) = &self.checkpoint {
            ck.persist(round_idx, name, &self.sim.last_round_stats(), &self.store, &shards)?;
        }
        Ok(Manifest::Spill { store: Arc::clone(&self.store), shards })
    }

    fn take_stats(&self) -> JobStats {
        self.sim.take_stats()
    }
}

/// Backend selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecBackend {
    InMemory,
    Spill,
}

/// Parse a byte count: a plain integer, optionally with a trailing
/// `k`/`m`/`g` (powers of 1024, case-insensitive). `parse_bytes("8m")`
/// is 8 MiB.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim();
    let (num, mult) = match t.chars().last()? {
        'k' | 'K' => (&t[..t.len() - 1], 1u64 << 10),
        'm' | 'M' => (&t[..t.len() - 1], 1u64 << 20),
        'g' | 'G' => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t, 1),
    };
    num.trim().parse::<u64>().ok().and_then(|n| n.checked_mul(mult))
}

/// Declarative executor choice carried by `ClusterConfig`.
///
/// The default reads `MRCORESET_EXECUTOR`, `MRCORESET_MEM_BUDGET`,
/// `MRCORESET_FAULTS`, and `MRCORESET_RETRIES` from the environment
/// (falling back to unbudgeted in-memory with no retries), so an entire
/// test suite or CI leg can be switched out-of-core — or run under a
/// chaos fault plan — without touching code. The explicit constructors
/// (`in_memory()` / `spill()`) ignore the environment, which is what
/// lets backend-pinning tests coexist with env-driven CI legs.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecutorCfg {
    pub backend: ExecBackend,
    /// Hard per-reducer byte budget (both backends enforce it).
    pub mem_budget: Option<u64>,
    /// Spill shard directory; fresh temp dir when `None`.
    pub spill_dir: Option<PathBuf>,
    /// Deterministic fault schedule injected into every round.
    pub faults: Option<FaultPlan>,
    /// Transient-failure retries per reducer (attempts = retries + 1).
    pub retries: u32,
    /// Round-level checkpoint directory (spill backend only): completed
    /// rounds are persisted there and replayed on resume.
    pub checkpoint_dir: Option<PathBuf>,
}

/// Default retries for executor-driven runs. Zero: recovery — and with
/// it the `catch_unwind` wrapper around reducers — is strictly opt-in,
/// so a genuine logic-bug panic propagates and deterministic failures
/// are not silently re-executed. CI chaos legs and fault-tolerance
/// tests opt in explicitly (`--retries` / `MRCORESET_RETRIES` /
/// `with_retries`).
pub const DEFAULT_RETRIES: u32 = 0;

impl Default for ExecutorCfg {
    fn default() -> ExecutorCfg {
        let backend = match std::env::var("MRCORESET_EXECUTOR").ok().as_deref() {
            Some("spill") => ExecBackend::Spill,
            _ => ExecBackend::InMemory,
        };
        let mem_budget =
            std::env::var("MRCORESET_MEM_BUDGET").ok().and_then(|s| parse_bytes(&s));
        let faults = std::env::var("MRCORESET_FAULTS").ok().and_then(|spec| {
            match FaultPlan::parse(&spec) {
                Ok(p) if !p.is_empty() => Some(p),
                Ok(_) => None,
                Err(e) => {
                    crate::obs::log::warn(&format!("ignoring MRCORESET_FAULTS: {e}"));
                    None
                }
            }
        });
        let retries = std::env::var("MRCORESET_RETRIES")
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(DEFAULT_RETRIES);
        ExecutorCfg {
            backend,
            mem_budget,
            spill_dir: None,
            faults,
            retries,
            checkpoint_dir: None,
        }
    }
}

impl ExecutorCfg {
    pub fn in_memory() -> ExecutorCfg {
        ExecutorCfg {
            backend: ExecBackend::InMemory,
            mem_budget: None,
            spill_dir: None,
            faults: None,
            retries: DEFAULT_RETRIES,
            checkpoint_dir: None,
        }
    }

    pub fn spill() -> ExecutorCfg {
        ExecutorCfg { backend: ExecBackend::Spill, ..ExecutorCfg::in_memory() }
    }

    pub fn with_budget(mut self, bytes: u64) -> ExecutorCfg {
        self.mem_budget = Some(bytes);
        self
    }

    pub fn with_faults(mut self, plan: FaultPlan) -> ExecutorCfg {
        self.faults = Some(plan);
        self
    }

    pub fn with_retries(mut self, retries: u32) -> ExecutorCfg {
        self.retries = retries;
        self
    }

    pub fn with_checkpoint_dir(mut self, dir: PathBuf) -> ExecutorCfg {
        self.checkpoint_dir = Some(dir);
        self
    }

    /// Build the backend around a simulator configured with `threads`
    /// and `recorder`.
    pub fn build(
        &self,
        threads: Option<usize>,
        recorder: Arc<dyn Recorder>,
    ) -> Result<ExecutorHandle, ExecError> {
        self.build_tagged(threads, recorder, "")
    }

    /// [`ExecutorCfg::build`] with a run fingerprint for the checkpoint
    /// store: a resumed run must present the same fingerprint that
    /// created the checkpoint (the driver passes its full run
    /// fingerprint — every result-affecting config field plus a content
    /// hash of the input), so a checkpoint can never be replayed into a
    /// different job's rounds.
    pub fn build_tagged(
        &self,
        threads: Option<usize>,
        recorder: Arc<dyn Recorder>,
        fingerprint: &str,
    ) -> Result<ExecutorHandle, ExecError> {
        let mut sim = Simulator::new().with_recorder(recorder).with_max_attempts(self.retries + 1);
        if let Some(t) = threads {
            sim = sim.with_threads(t);
        }
        if let Some(b) = self.mem_budget {
            sim = sim.with_byte_budget(b);
        }
        if let Some(plan) = &self.faults {
            sim = sim.with_faults(plan.clone());
        }
        match self.backend {
            ExecBackend::InMemory => {
                if self.checkpoint_dir.is_some() {
                    crate::obs::log::warn(
                        "checkpointing requires the spill backend; --checkpoint-dir ignored",
                    );
                }
                Ok(ExecutorHandle::Mem(sim))
            }
            ExecBackend::Spill => {
                let mut sp = SpillExecutor::new(sim, self.spill_dir.as_deref())?;
                if let Some(dir) = &self.checkpoint_dir {
                    sp = sp.with_checkpoint(CheckpointStore::open(dir, fingerprint)?);
                }
                Ok(ExecutorHandle::Spill(sp))
            }
        }
    }
}

/// A built backend, dispatched by enum so the driver stays object-safe
/// (the `Executor` trait has generic methods and cannot be boxed).
pub enum ExecutorHandle {
    Mem(Simulator),
    Spill(SpillExecutor),
}

impl Executor for ExecutorHandle {
    fn scatter<T>(&self, parts: Vec<T>) -> Result<Manifest<T>, ExecError>
    where
        T: Spillable,
    {
        match self {
            ExecutorHandle::Mem(sim) => sim.scatter(parts),
            ExecutorHandle::Spill(sp) => sp.scatter(parts),
        }
    }

    fn round<I, O, F>(
        &self,
        name: &str,
        inputs: &Manifest<I>,
        f: F,
    ) -> Result<Manifest<O>, ExecError>
    where
        I: Spillable + Cardinality + Sync,
        O: Spillable + Cardinality + Send,
        F: Fn(usize, &I, &mut MemoryMeter) -> O + Sync,
    {
        match self {
            ExecutorHandle::Mem(sim) => Executor::round(sim, name, inputs, f),
            ExecutorHandle::Spill(sp) => sp.round(name, inputs, f),
        }
    }

    fn take_stats(&self) -> JobStats {
        match self {
            ExecutorHandle::Mem(sim) => Executor::take_stats(sim),
            ExecutorHandle::Spill(sp) => sp.take_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doubling_round<E: Executor>(exec: &E, budget_ok: bool) {
        let parts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![4, 5], vec![6]];
        let inputs = exec.scatter(parts).expect("scatter");
        assert_eq!(inputs.len(), 3);
        assert_eq!(inputs.shard_bytes(0), 8 + 3 * 4);
        let out = exec.round("double", &inputs, |_, p: &Vec<u32>, m| {
            m.charge(p.len());
            let d: Vec<u32> = p.iter().map(|x| x * 2).collect();
            m.release(p.len());
            d
        });
        if !budget_ok {
            assert!(matches!(out, Err(ExecError::OverBudget { .. })), "tight budget must refuse");
            return;
        }
        let out = out.expect("round").into_items().expect("collect");
        assert_eq!(out, vec![vec![2, 4, 6], vec![8, 10], vec![12]]);
        let stats = exec.take_stats();
        // in 8+12, out 8+12 for the largest slot: peak 40 bytes
        assert_eq!(stats.rounds[0].max_local_bytes, 40);
        assert_eq!(stats.rounds[0].reducer_mem_bytes, vec![40, 32, 24]);
        assert_eq!(stats.rounds[0].in_items, 6);
        assert_eq!(stats.rounds[0].out_items, 6);
    }

    #[test]
    fn in_memory_round_meters_bytes() {
        let sim = Simulator::new().with_threads(2);
        doubling_round(&sim, true);
    }

    #[test]
    fn spill_round_matches_in_memory_accounting() {
        let sp = SpillExecutor::new(Simulator::new().with_threads(2), None).expect("store");
        doubling_round(&sp, true);
    }

    #[test]
    fn both_backends_refuse_over_budget_identically() {
        // largest slot needs 40 resident bytes; 39 must fail on both
        let sim = Simulator::new().with_threads(1).with_byte_budget(39);
        doubling_round(&sim, false);
        let sp = SpillExecutor::new(Simulator::new().with_threads(1).with_byte_budget(39), None)
            .expect("store");
        doubling_round(&sp, false);
        // ...and 40 exactly is enough
        let sim = Simulator::new().with_threads(1).with_byte_budget(40);
        doubling_round(&sim, true);
        let sp = SpillExecutor::new(Simulator::new().with_threads(1).with_byte_budget(40), None)
            .expect("store");
        doubling_round(&sp, true);
    }

    #[test]
    fn spill_round_reports_disk_traffic() {
        let sp = SpillExecutor::new(Simulator::new().with_threads(1), None).expect("store");
        let inputs = sp.scatter(vec![vec![7u32, 8]]).expect("scatter");
        let out = sp.round("id", &inputs, |_, p: &Vec<u32>, _| p.clone()).expect("round");
        assert!(matches!(out, Manifest::Spill { .. }));
        let stats = sp.take_stats();
        assert_eq!(stats.rounds[0].spill_read_bytes, 16);
        assert_eq!(stats.rounds[0].spill_write_bytes, 16);
        assert_eq!(stats.spill_write_bytes(), 16);
    }

    #[test]
    fn streaming_fold_visits_in_slot_order() {
        let sp = SpillExecutor::new(Simulator::new(), None).expect("store");
        let m = sp.scatter(vec![vec![1u32], vec![2], vec![3]]).expect("scatter");
        let mut seen = Vec::new();
        m.for_each(|v| seen.push(v[0])).expect("fold");
        assert_eq!(seen, vec![1, 2, 3]);
        assert_eq!(m.total_bytes(), 3 * 12);
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("1048576"), Some(1 << 20));
        assert_eq!(parse_bytes("64k"), Some(64 << 10));
        assert_eq!(parse_bytes("8M"), Some(8 << 20));
        assert_eq!(parse_bytes("2g"), Some(2 << 30));
        assert_eq!(parse_bytes(" 16m "), Some(16 << 20));
        assert_eq!(parse_bytes(""), None);
        assert_eq!(parse_bytes("m"), None);
        assert_eq!(parse_bytes("x12"), None);
    }

    #[test]
    fn executor_cfg_builds_both_backends() {
        let mem = ExecutorCfg::in_memory().build(Some(2), crate::obs::noop()).expect("mem");
        assert!(matches!(mem, ExecutorHandle::Mem(_)));
        let spill = ExecutorCfg::spill().with_budget(1 << 20);
        let h = spill.build(Some(2), crate::obs::noop()).expect("spill");
        assert!(matches!(h, ExecutorHandle::Spill(_)));
    }
}
