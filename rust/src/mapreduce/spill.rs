//! Disk-backed shard store and spill codec for out-of-core execution.
//!
//! The [`SpillExecutor`](super::executor::SpillExecutor) never holds a
//! round's full input or output in RAM: every reducer input and output
//! lives on disk as a *shard* — one file per value, framed as
//!
//! ```text
//! +----------+----------------+-----------------+
//! | b"MRCSPILL" | payload len (u64 LE) | payload |
//! +----------+----------------+-----------------+
//! ```
//!
//! — and is materialized one at a time, after its encoded size has been
//! charged against the hard byte budget. The codec is deliberately dumb:
//! fixed-width little-endian integers, `f64` via `to_bits` (bit-exact
//! round-trip, NaN payloads included), `u64` length prefixes on
//! sequences. [`Spillable::encoded_len`] must equal the exact encoded
//! size *without encoding* — executors use it to charge the meter before
//! any bytes are materialized, which is what makes "structured
//! over-budget error, never OOM" possible.

use std::fs::{self, File};
use std::io::{self, BufWriter, Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::algorithms::Solution;
use crate::coreset::cover::CoverResult;
use crate::coreset::local::LocalCoresetOut;
use crate::points::WeightedSet;

const MAGIC: &[u8; 8] = b"MRCSPILL";
const READ_CHUNK: usize = 1 << 20;

/// A shard failed to decode (truncated, trailing bytes, inconsistent
/// lengths). Carries a human-readable detail string.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodecError(pub String);

/// Cursor over an encoded payload.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Decoder<'a> {
        Decoder { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| CodecError("payload offset overflow".to_string()))?;
        let s = self.buf.get(self.pos..end).ok_or_else(|| {
            CodecError(format!("truncated payload: wanted {n} bytes at offset {}", self.pos))
        })?;
        self.pos = end;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    pub fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    pub fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Assert the payload is fully consumed — trailing bytes mean the
    /// shard was written by a different type than it is read as.
    pub fn finish(self) -> Result<(), CodecError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(CodecError(format!(
                "trailing bytes: consumed {} of {}",
                self.pos,
                self.buf.len()
            )))
        }
    }
}

/// A value that can round-trip through the spill format.
///
/// Contract: `decode(encode(v)) == v` bit-exactly, and
/// `encoded_len() == encode(v).len()` *computed arithmetically* — no
/// encoding allowed, since executors call it to pre-charge budgets.
pub trait Spillable: Sized {
    fn encode(&self, out: &mut Vec<u8>);
    fn decode(d: &mut Decoder) -> Result<Self, CodecError>;
    fn encoded_len(&self) -> u64;
}

impl Spillable for u32 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(d: &mut Decoder) -> Result<u32, CodecError> {
        d.u32()
    }

    fn encoded_len(&self) -> u64 {
        4
    }
}

impl Spillable for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(d: &mut Decoder) -> Result<u64, CodecError> {
        d.u64()
    }

    fn encoded_len(&self) -> u64 {
        8
    }
}

impl Spillable for f64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }

    fn decode(d: &mut Decoder) -> Result<f64, CodecError> {
        d.f64()
    }

    fn encoded_len(&self) -> u64 {
        8
    }
}

impl<T: Spillable> Spillable for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        for v in self {
            v.encode(out);
        }
    }

    fn decode(d: &mut Decoder) -> Result<Vec<T>, CodecError> {
        let n = d.u64()? as usize;
        // every element encodes to >= 1 byte, so a length beyond the
        // remaining payload is corrupt — refuse before allocating
        if n > d.remaining() {
            return Err(CodecError(format!(
                "sequence length {n} exceeds remaining payload {}",
                d.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::decode(d)?);
        }
        Ok(out)
    }

    fn encoded_len(&self) -> u64 {
        8 + self.iter().map(Spillable::encoded_len).sum::<u64>()
    }
}

impl Spillable for WeightedSet {
    fn encode(&self, out: &mut Vec<u8>) {
        self.indices.encode(out);
        self.weights.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<WeightedSet, CodecError> {
        let indices = Vec::<u32>::decode(d)?;
        let weights = Vec::<u64>::decode(d)?;
        if indices.len() != weights.len() {
            return Err(CodecError(format!(
                "weighted set with {} indices but {} weights",
                indices.len(),
                weights.len()
            )));
        }
        Ok(WeightedSet { indices, weights })
    }

    fn encoded_len(&self) -> u64 {
        self.indices.encoded_len() + self.weights.encoded_len()
    }
}

impl Spillable for Solution {
    fn encode(&self, out: &mut Vec<u8>) {
        self.centers.encode(out);
        self.cost.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<Solution, CodecError> {
        Ok(Solution { centers: Vec::<u32>::decode(d)?, cost: f64::decode(d)? })
    }

    fn encoded_len(&self) -> u64 {
        self.centers.encoded_len() + 8
    }
}

impl Spillable for CoverResult {
    fn encode(&self, out: &mut Vec<u8>) {
        self.set.encode(out);
        self.tau.encode(out);
        self.dist_to_t.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<CoverResult, CodecError> {
        Ok(CoverResult {
            set: WeightedSet::decode(d)?,
            tau: Vec::<u32>::decode(d)?,
            dist_to_t: Vec::<f64>::decode(d)?,
        })
    }

    fn encoded_len(&self) -> u64 {
        self.set.encoded_len() + self.tau.encoded_len() + self.dist_to_t.encoded_len()
    }
}

impl Spillable for LocalCoresetOut {
    fn encode(&self, out: &mut Vec<u8>) {
        self.cover.encode(out);
        self.r.encode(out);
        self.t.encode(out);
        self.t_cost.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<LocalCoresetOut, CodecError> {
        Ok(LocalCoresetOut {
            cover: CoverResult::decode(d)?,
            r: f64::decode(d)?,
            t: Vec::<u32>::decode(d)?,
            t_cost: f64::decode(d)?,
        })
    }

    fn encoded_len(&self) -> u64 {
        self.cover.encoded_len() + 8 + self.t.encoded_len() + 8
    }
}

/// Handle to one on-disk shard: its file tag and exact payload size.
///
/// `bytes` is authoritative — executors charge it against the byte
/// budget *before* reading the file, so the decision to materialize a
/// shard never requires touching the disk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardRef {
    pub tag: String,
    pub bytes: u64,
}

static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Directory of spill shards. Writes are append-only and single-shot
/// (one file per shard, unique tags); reads are chunked so the transient
/// I/O buffer stays bounded. Dropping an ephemeral store (one created
/// without an explicit directory) removes its files.
pub struct SpillStore {
    dir: PathBuf,
    ephemeral: bool,
}

impl SpillStore {
    /// Open a store at `dir`, or at a fresh unique directory under the
    /// system temp dir when `None` (removed again on drop).
    pub fn create(dir: Option<&Path>) -> io::Result<SpillStore> {
        let (dir, ephemeral) = match dir {
            Some(d) => (d.to_path_buf(), false),
            None => {
                let seq = STORE_SEQ.fetch_add(1, Ordering::Relaxed);
                let name = format!("mrcoreset-spill-{}-{seq}", std::process::id());
                (std::env::temp_dir().join(name), true)
            }
        };
        fs::create_dir_all(&dir)?;
        Ok(SpillStore { dir, ephemeral })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_of(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("{tag}.shard"))
    }

    /// Write one shard; `tag` must be unique within the store.
    pub fn write(&self, tag: &str, payload: &[u8]) -> io::Result<ShardRef> {
        let mut w = BufWriter::new(File::create(self.path_of(tag))?);
        w.write_all(MAGIC)?;
        w.write_all(&(payload.len() as u64).to_le_bytes())?;
        w.write_all(payload)?;
        w.flush()?;
        Ok(ShardRef { tag: tag.to_string(), bytes: payload.len() as u64 })
    }

    /// Read a shard's payload back, validating frame and length.
    pub fn read(&self, shard: &ShardRef) -> io::Result<Vec<u8>> {
        let mut f = File::open(self.path_of(&shard.tag))?;
        let mut header = [0u8; 16];
        f.read_exact(&mut header)?;
        if &header[..8] != MAGIC {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("shard {}: bad magic", shard.tag),
            ));
        }
        let len = u64::from_le_bytes(header[8..].try_into().expect("8-byte slice"));
        if len != shard.bytes {
            let detail =
                format!("shard {}: frame len {len} != manifest len {}", shard.tag, shard.bytes);
            return Err(io::Error::new(io::ErrorKind::InvalidData, detail));
        }
        let mut payload = Vec::with_capacity(len as usize);
        let mut chunk = vec![0u8; READ_CHUNK.min(len.max(1) as usize)];
        let mut left = len as usize;
        while left > 0 {
            let want = left.min(chunk.len());
            f.read_exact(&mut chunk[..want])?;
            payload.extend_from_slice(&chunk[..want]);
            left -= want;
        }
        Ok(payload)
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        if self.ephemeral {
            let _ = fs::remove_dir_all(&self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip<T: Spillable + PartialEq + std::fmt::Debug>(v: &T) {
        let mut buf = Vec::new();
        v.encode(&mut buf);
        assert_eq!(buf.len() as u64, v.encoded_len(), "encoded_len must be exact");
        let mut d = Decoder::new(&buf);
        let back = T::decode(&mut d).expect("decode");
        d.finish().expect("fully consumed");
        assert_eq!(&back, v);
    }

    #[test]
    fn scalars_and_vectors_round_trip() {
        round_trip(&7u32);
        round_trip(&u64::MAX);
        round_trip(&-0.0f64);
        round_trip(&f64::NAN.to_bits()); // NaN via bits: PartialEq-safe
        round_trip(&vec![1u32, 2, 3]);
        round_trip(&Vec::<f64>::new());
        round_trip(&vec![vec![1u32], vec![], vec![2, 3]]);
    }

    #[test]
    fn nan_payload_survives_bit_exactly() {
        let v = f64::from_bits(0x7ff8_0000_dead_beef);
        let mut buf = Vec::new();
        v.encode(&mut buf);
        let back = f64::decode(&mut Decoder::new(&buf)).unwrap();
        assert_eq!(back.to_bits(), v.to_bits());
    }

    #[test]
    fn weighted_set_round_trips_and_rejects_skew() {
        round_trip(&WeightedSet { indices: vec![5, 9], weights: vec![2, 7] });
        // hand-build a payload with mismatched lengths
        let mut buf = Vec::new();
        vec![1u32].encode(&mut buf);
        vec![1u64, 2].encode(&mut buf);
        let err = WeightedSet::decode(&mut Decoder::new(&buf)).unwrap_err();
        assert!(err.0.contains("1 indices but 2 weights"), "{err:?}");
    }

    #[test]
    fn local_coreset_out_round_trips() {
        let out = LocalCoresetOut {
            cover: CoverResult {
                set: WeightedSet { indices: vec![1, 4], weights: vec![3, 1] },
                tau: vec![0, 0, 1],
                dist_to_t: vec![0.5, 1.25, 0.0],
            },
            r: 2.5,
            t: vec![1, 4],
            t_cost: 9.75,
        };
        let mut buf = Vec::new();
        out.encode(&mut buf);
        assert_eq!(buf.len() as u64, out.encoded_len());
        let mut d = Decoder::new(&buf);
        let back = LocalCoresetOut::decode(&mut d).expect("decode");
        d.finish().expect("fully consumed");
        assert_eq!(back.cover.set, out.cover.set);
        assert_eq!(back.cover.tau, out.cover.tau);
        assert_eq!(back.r, out.r);
        assert_eq!(back.t, out.t);
        assert_eq!(back.t_cost, out.t_cost);
    }

    #[test]
    fn truncated_and_trailing_payloads_are_rejected() {
        let mut buf = Vec::new();
        vec![1u32, 2, 3].encode(&mut buf);
        assert!(Vec::<u32>::decode(&mut Decoder::new(&buf[..buf.len() - 1])).is_err());
        let mut d = Decoder::new(&buf);
        let _ = Vec::<u32>::decode(&mut d).unwrap();
        assert!(d.finish().is_ok());
        buf.push(0);
        let mut d = Decoder::new(&buf);
        let _ = Vec::<u32>::decode(&mut d).unwrap();
        assert!(d.finish().is_err(), "trailing byte must be rejected");
    }

    #[test]
    fn corrupt_length_prefix_fails_before_allocating() {
        let buf = u64::MAX.to_le_bytes().to_vec();
        let err = Vec::<u32>::decode(&mut Decoder::new(&buf)).unwrap_err();
        assert!(err.0.contains("exceeds remaining payload"), "{err:?}");
    }

    #[test]
    fn store_round_trips_shards_and_validates_frames() {
        let store = SpillStore::create(None).expect("temp store");
        let mut buf = Vec::new();
        vec![10u32, 20, 30].encode(&mut buf);
        let shard = store.write("t-0", &buf).expect("write");
        assert_eq!(shard.bytes, buf.len() as u64);
        let payload = store.read(&shard).expect("read");
        assert_eq!(payload, buf);
        let back = Vec::<u32>::decode(&mut Decoder::new(&payload)).unwrap();
        assert_eq!(back, vec![10, 20, 30]);
        // a manifest/frame length mismatch is surfaced, not trusted
        let lying = ShardRef { tag: "t-0".to_string(), bytes: shard.bytes + 1 };
        assert!(store.read(&lying).is_err());
    }

    #[test]
    fn ephemeral_store_cleans_up_on_drop() {
        let dir;
        {
            let store = SpillStore::create(None).expect("temp store");
            dir = store.dir().to_path_buf();
            store.write("x", &[1, 2, 3]).expect("write");
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "ephemeral spill dir must be removed on drop");
    }
}
