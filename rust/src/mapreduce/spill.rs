//! Disk-backed shard store and spill codec for out-of-core execution.
//!
//! The [`SpillExecutor`](super::executor::SpillExecutor) never holds a
//! round's full input or output in RAM: every reducer input and output
//! lives on disk as a *shard* — one file per value, framed as
//!
//! ```text
//! +-------------+----------------------+---------+---------------+
//! | b"MRCSPILL" | payload len (u64 LE) | payload | crc32 (u32 LE)|
//! +-------------+----------------------+---------+---------------+
//! ```
//!
//! — and is materialized one at a time, after its encoded size has been
//! charged against the hard byte budget. The footer is a CRC-32
//! (ISO-HDLC, the zlib polynomial) over the payload: a truncated file,
//! a bad magic, a length mismatch, or any flipped payload bit surfaces
//! as [`SpillError::Corrupt`] on read — never as garbage handed to the
//! decoder, and never as a panic. The codec is deliberately dumb:
//! fixed-width little-endian integers, `f64` via `to_bits` (bit-exact
//! round-trip, NaN payloads included), `u64` length prefixes on
//! sequences. [`Spillable::encoded_len`] must equal the exact encoded
//! size *without encoding* — executors use it to charge the meter before
//! any bytes are materialized, which is what makes "structured
//! over-budget error, never OOM" possible.

use std::fs::{self, File};
use std::io::{self, BufWriter, Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::algorithms::Solution;
use crate::coreset::cover::CoverResult;
use crate::coreset::local::LocalCoresetOut;
use crate::points::WeightedSet;

const MAGIC: &[u8; 8] = b"MRCSPILL";
const READ_CHUNK: usize = 1 << 20;

/// CRC-32/ISO-HDLC lookup table (reflected 0xEDB88320 polynomial).
const CRC_TABLE: [u32; 256] = {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
};

/// CRC-32 (ISO-HDLC / zlib) of `data` — the shard footer checksum.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Incremental twin of [`crc32`] for chunked reads.
fn crc32_update(mut c: u32, data: &[u8]) -> u32 {
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c
}

/// A shard read failed: either the file system did (`Io`) or the bytes
/// on disk are not the bytes that were written (`Corrupt` — truncation,
/// bad magic, length mismatch, checksum mismatch). The distinction
/// matters to the executor: both are retryable, but `Corrupt` is
/// reported as integrity loss, not as an I/O failure.
#[derive(Debug)]
pub enum SpillError {
    Io(io::Error),
    Corrupt { detail: String },
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpillError::Io(e) => write!(f, "{e}"),
            SpillError::Corrupt { detail } => f.write_str(detail),
        }
    }
}

impl std::error::Error for SpillError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SpillError::Io(e) => Some(e),
            SpillError::Corrupt { .. } => None,
        }
    }
}

/// A shard failed to decode (truncated, trailing bytes, inconsistent
/// lengths). Carries a human-readable detail string.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodecError(pub String);

/// Cursor over an encoded payload.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Decoder<'a> {
        Decoder { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| CodecError("payload offset overflow".to_string()))?;
        let s = self.buf.get(self.pos..end).ok_or_else(|| {
            CodecError(format!("truncated payload: wanted {n} bytes at offset {}", self.pos))
        })?;
        self.pos = end;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    pub fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    pub fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Assert the payload is fully consumed — trailing bytes mean the
    /// shard was written by a different type than it is read as.
    pub fn finish(self) -> Result<(), CodecError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(CodecError(format!(
                "trailing bytes: consumed {} of {}",
                self.pos,
                self.buf.len()
            )))
        }
    }
}

/// A value that can round-trip through the spill format.
///
/// Contract: `decode(encode(v)) == v` bit-exactly, and
/// `encoded_len() == encode(v).len()` *computed arithmetically* — no
/// encoding allowed, since executors call it to pre-charge budgets.
pub trait Spillable: Sized {
    fn encode(&self, out: &mut Vec<u8>);
    fn decode(d: &mut Decoder) -> Result<Self, CodecError>;
    fn encoded_len(&self) -> u64;
}

impl Spillable for u32 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(d: &mut Decoder) -> Result<u32, CodecError> {
        d.u32()
    }

    fn encoded_len(&self) -> u64 {
        4
    }
}

impl Spillable for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(d: &mut Decoder) -> Result<u64, CodecError> {
        d.u64()
    }

    fn encoded_len(&self) -> u64 {
        8
    }
}

impl Spillable for f64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }

    fn decode(d: &mut Decoder) -> Result<f64, CodecError> {
        d.f64()
    }

    fn encoded_len(&self) -> u64 {
        8
    }
}

impl<T: Spillable> Spillable for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        for v in self {
            v.encode(out);
        }
    }

    fn decode(d: &mut Decoder) -> Result<Vec<T>, CodecError> {
        let n = d.u64()? as usize;
        // every element encodes to >= 1 byte, so a length beyond the
        // remaining payload is corrupt — refuse before allocating
        if n > d.remaining() {
            return Err(CodecError(format!(
                "sequence length {n} exceeds remaining payload {}",
                d.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::decode(d)?);
        }
        Ok(out)
    }

    fn encoded_len(&self) -> u64 {
        8 + self.iter().map(Spillable::encoded_len).sum::<u64>()
    }
}

impl Spillable for WeightedSet {
    fn encode(&self, out: &mut Vec<u8>) {
        self.indices.encode(out);
        self.weights.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<WeightedSet, CodecError> {
        let indices = Vec::<u32>::decode(d)?;
        let weights = Vec::<u64>::decode(d)?;
        if indices.len() != weights.len() {
            return Err(CodecError(format!(
                "weighted set with {} indices but {} weights",
                indices.len(),
                weights.len()
            )));
        }
        Ok(WeightedSet { indices, weights })
    }

    fn encoded_len(&self) -> u64 {
        self.indices.encoded_len() + self.weights.encoded_len()
    }
}

impl Spillable for Solution {
    fn encode(&self, out: &mut Vec<u8>) {
        self.centers.encode(out);
        self.cost.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<Solution, CodecError> {
        Ok(Solution { centers: Vec::<u32>::decode(d)?, cost: f64::decode(d)? })
    }

    fn encoded_len(&self) -> u64 {
        self.centers.encoded_len() + 8
    }
}

impl Spillable for CoverResult {
    fn encode(&self, out: &mut Vec<u8>) {
        self.set.encode(out);
        self.tau.encode(out);
        self.dist_to_t.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<CoverResult, CodecError> {
        Ok(CoverResult {
            set: WeightedSet::decode(d)?,
            tau: Vec::<u32>::decode(d)?,
            dist_to_t: Vec::<f64>::decode(d)?,
        })
    }

    fn encoded_len(&self) -> u64 {
        self.set.encoded_len() + self.tau.encoded_len() + self.dist_to_t.encoded_len()
    }
}

impl Spillable for LocalCoresetOut {
    fn encode(&self, out: &mut Vec<u8>) {
        self.cover.encode(out);
        self.r.encode(out);
        self.t.encode(out);
        self.t_cost.encode(out);
    }

    fn decode(d: &mut Decoder) -> Result<LocalCoresetOut, CodecError> {
        Ok(LocalCoresetOut {
            cover: CoverResult::decode(d)?,
            r: f64::decode(d)?,
            t: Vec::<u32>::decode(d)?,
            t_cost: f64::decode(d)?,
        })
    }

    fn encoded_len(&self) -> u64 {
        self.cover.encoded_len() + 8 + self.t.encoded_len() + 8
    }
}

/// Handle to one on-disk shard: its file tag and exact payload size.
///
/// `bytes` is authoritative — executors charge it against the byte
/// budget *before* reading the file, so the decision to materialize a
/// shard never requires touching the disk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardRef {
    pub tag: String,
    pub bytes: u64,
}

static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Directory of spill shards. Writes are append-only and single-shot
/// (one file per shard, unique tags); reads are chunked so the transient
/// I/O buffer stays bounded. Dropping an ephemeral store (one created
/// without an explicit directory) removes its files.
pub struct SpillStore {
    dir: PathBuf,
    ephemeral: bool,
}

impl SpillStore {
    /// Open a store at `dir`, or at a fresh unique directory under the
    /// system temp dir when `None` (removed again on drop).
    ///
    /// Ephemeral names carry a per-process random suffix next to the
    /// pid/sequence pair, and creation retries on collision: a stale
    /// directory left by a killed run (same pid recycled, same
    /// sequence) can therefore never be silently adopted and
    /// cross-contaminate shards between runs.
    pub fn create(dir: Option<&Path>) -> io::Result<SpillStore> {
        let Some(d) = dir else {
            let seq = STORE_SEQ.fetch_add(1, Ordering::Relaxed);
            let mut nonce = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0)
                ^ ((std::process::id() as u64) << 32)
                ^ seq;
            for _ in 0..16 {
                // splitmix64 finalizer: cheap, well-mixed suffixes
                nonce = nonce.wrapping_add(0x9E3779B97F4A7C15);
                let mut z = nonce;
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
                z ^= z >> 31;
                let name =
                    format!("mrcoreset-spill-{}-{seq}-{z:016x}", std::process::id());
                let path = std::env::temp_dir().join(name);
                // create_dir (not _all): an existing dir is a collision,
                // not a success — pick a new suffix instead of adopting
                match fs::create_dir(&path) {
                    Ok(()) => return Ok(SpillStore { dir: path, ephemeral: true }),
                    Err(e) if e.kind() == io::ErrorKind::AlreadyExists => continue,
                    Err(e) => return Err(e),
                }
            }
            return Err(io::Error::new(
                io::ErrorKind::AlreadyExists,
                "could not create a unique spill temp dir after 16 attempts",
            ));
        };
        fs::create_dir_all(d)?;
        Ok(SpillStore { dir: d.to_path_buf(), ephemeral: false })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_of(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("{tag}.shard"))
    }

    /// Write one shard; `tag` must be unique within the store (retried
    /// reducer attempts reuse their tag — `File::create` truncates, so
    /// the successful attempt's bytes are what remains on disk).
    pub fn write(&self, tag: &str, payload: &[u8]) -> io::Result<ShardRef> {
        let mut w = BufWriter::new(File::create(self.path_of(tag))?);
        w.write_all(MAGIC)?;
        w.write_all(&(payload.len() as u64).to_le_bytes())?;
        w.write_all(payload)?;
        w.write_all(&crc32(payload).to_le_bytes())?;
        w.flush()?;
        Ok(ShardRef { tag: tag.to_string(), bytes: payload.len() as u64 })
    }

    /// Read a shard's payload back, validating frame, length, and the
    /// CRC-32 footer. A missing/unreadable file is [`SpillError::Io`];
    /// anything that means "these are not the written bytes" —
    /// truncation, bad magic, length mismatch, checksum mismatch — is
    /// [`SpillError::Corrupt`].
    pub fn read(&self, shard: &ShardRef) -> Result<Vec<u8>, SpillError> {
        fn exact(
            f: &mut File,
            buf: &mut [u8],
            tag: &str,
            what: &str,
        ) -> Result<(), SpillError> {
            f.read_exact(buf).map_err(|e| {
                if e.kind() == io::ErrorKind::UnexpectedEof {
                    SpillError::Corrupt { detail: format!("shard {tag}: truncated {what}") }
                } else {
                    SpillError::Io(e)
                }
            })
        }
        let mut f = File::open(self.path_of(&shard.tag)).map_err(SpillError::Io)?;
        let mut header = [0u8; 16];
        exact(&mut f, &mut header, &shard.tag, "frame header")?;
        if &header[..8] != MAGIC {
            return Err(SpillError::Corrupt {
                detail: format!("shard {}: bad magic", shard.tag),
            });
        }
        let len = u64::from_le_bytes(header[8..].try_into().expect("8-byte slice"));
        if len != shard.bytes {
            return Err(SpillError::Corrupt {
                detail: format!(
                    "shard {}: frame len {len} != manifest len {}",
                    shard.tag, shard.bytes
                ),
            });
        }
        let mut payload = Vec::with_capacity(len as usize);
        let mut chunk = vec![0u8; READ_CHUNK.min(len.max(1) as usize)];
        let mut left = len as usize;
        let mut crc = 0xFFFF_FFFFu32;
        while left > 0 {
            let want = left.min(chunk.len());
            exact(&mut f, &mut chunk[..want], &shard.tag, "payload")?;
            crc = crc32_update(crc, &chunk[..want]);
            payload.extend_from_slice(&chunk[..want]);
            left -= want;
        }
        crc ^= 0xFFFF_FFFF;
        let mut footer = [0u8; 4];
        exact(&mut f, &mut footer, &shard.tag, "checksum footer")?;
        let stored = u32::from_le_bytes(footer);
        if stored != crc {
            return Err(SpillError::Corrupt {
                detail: format!(
                    "shard {}: checksum mismatch (stored {stored:08x}, computed {crc:08x})",
                    shard.tag
                ),
            });
        }
        Ok(payload)
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        if self.ephemeral {
            if let Err(e) = fs::remove_dir_all(&self.dir) {
                // leftover shards are disk leakage worth a warning —
                // except when the dir is already gone, which is clean
                if e.kind() != io::ErrorKind::NotFound {
                    crate::obs::log::warn(&format!(
                        "failed to clean up spill dir {}: {e}",
                        self.dir.display()
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip<T: Spillable + PartialEq + std::fmt::Debug>(v: &T) {
        let mut buf = Vec::new();
        v.encode(&mut buf);
        assert_eq!(buf.len() as u64, v.encoded_len(), "encoded_len must be exact");
        let mut d = Decoder::new(&buf);
        let back = T::decode(&mut d).expect("decode");
        d.finish().expect("fully consumed");
        assert_eq!(&back, v);
    }

    #[test]
    fn scalars_and_vectors_round_trip() {
        round_trip(&7u32);
        round_trip(&u64::MAX);
        round_trip(&-0.0f64);
        round_trip(&f64::NAN.to_bits()); // NaN via bits: PartialEq-safe
        round_trip(&vec![1u32, 2, 3]);
        round_trip(&Vec::<f64>::new());
        round_trip(&vec![vec![1u32], vec![], vec![2, 3]]);
    }

    #[test]
    fn nan_payload_survives_bit_exactly() {
        let v = f64::from_bits(0x7ff8_0000_dead_beef);
        let mut buf = Vec::new();
        v.encode(&mut buf);
        let back = f64::decode(&mut Decoder::new(&buf)).unwrap();
        assert_eq!(back.to_bits(), v.to_bits());
    }

    #[test]
    fn weighted_set_round_trips_and_rejects_skew() {
        round_trip(&WeightedSet { indices: vec![5, 9], weights: vec![2, 7] });
        // hand-build a payload with mismatched lengths
        let mut buf = Vec::new();
        vec![1u32].encode(&mut buf);
        vec![1u64, 2].encode(&mut buf);
        let err = WeightedSet::decode(&mut Decoder::new(&buf)).unwrap_err();
        assert!(err.0.contains("1 indices but 2 weights"), "{err:?}");
    }

    #[test]
    fn local_coreset_out_round_trips() {
        let out = LocalCoresetOut {
            cover: CoverResult {
                set: WeightedSet { indices: vec![1, 4], weights: vec![3, 1] },
                tau: vec![0, 0, 1],
                dist_to_t: vec![0.5, 1.25, 0.0],
            },
            r: 2.5,
            t: vec![1, 4],
            t_cost: 9.75,
        };
        let mut buf = Vec::new();
        out.encode(&mut buf);
        assert_eq!(buf.len() as u64, out.encoded_len());
        let mut d = Decoder::new(&buf);
        let back = LocalCoresetOut::decode(&mut d).expect("decode");
        d.finish().expect("fully consumed");
        assert_eq!(back.cover.set, out.cover.set);
        assert_eq!(back.cover.tau, out.cover.tau);
        assert_eq!(back.r, out.r);
        assert_eq!(back.t, out.t);
        assert_eq!(back.t_cost, out.t_cost);
    }

    #[test]
    fn truncated_and_trailing_payloads_are_rejected() {
        let mut buf = Vec::new();
        vec![1u32, 2, 3].encode(&mut buf);
        assert!(Vec::<u32>::decode(&mut Decoder::new(&buf[..buf.len() - 1])).is_err());
        let mut d = Decoder::new(&buf);
        let _ = Vec::<u32>::decode(&mut d).unwrap();
        assert!(d.finish().is_ok());
        buf.push(0);
        let mut d = Decoder::new(&buf);
        let _ = Vec::<u32>::decode(&mut d).unwrap();
        assert!(d.finish().is_err(), "trailing byte must be rejected");
    }

    #[test]
    fn corrupt_length_prefix_fails_before_allocating() {
        let buf = u64::MAX.to_le_bytes().to_vec();
        let err = Vec::<u32>::decode(&mut Decoder::new(&buf)).unwrap_err();
        assert!(err.0.contains("exceeds remaining payload"), "{err:?}");
    }

    #[test]
    fn store_round_trips_shards_and_validates_frames() {
        let store = SpillStore::create(None).expect("temp store");
        let mut buf = Vec::new();
        vec![10u32, 20, 30].encode(&mut buf);
        let shard = store.write("t-0", &buf).expect("write");
        assert_eq!(shard.bytes, buf.len() as u64);
        let payload = store.read(&shard).expect("read");
        assert_eq!(payload, buf);
        let back = Vec::<u32>::decode(&mut Decoder::new(&payload)).unwrap();
        assert_eq!(back, vec![10, 20, 30]);
        // a manifest/frame length mismatch is surfaced, not trusted
        let lying = ShardRef { tag: "t-0".to_string(), bytes: shard.bytes + 1 };
        assert!(store.read(&lying).is_err());
    }

    #[test]
    fn crc32_matches_the_iso_hdlc_check_value() {
        // the standard check value for CRC-32/ISO-HDLC
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn bit_flip_and_truncation_surface_as_corrupt() {
        let store = SpillStore::create(None).expect("temp store");
        let mut buf = Vec::new();
        vec![10u32, 20, 30].encode(&mut buf);
        let shard = store.write("c-0", &buf).expect("write");
        let path = store.dir().join("c-0.shard");
        let clean = fs::read(&path).expect("raw file");

        // flip one payload bit: the footer no longer matches
        let mut flipped = clean.clone();
        flipped[MAGIC.len() + 8] ^= 0x01;
        fs::write(&path, &flipped).expect("rewrite");
        match store.read(&shard) {
            Err(SpillError::Corrupt { detail }) => {
                assert!(detail.contains("checksum mismatch"), "{detail}")
            }
            other => panic!("expected checksum corruption, got {other:?}"),
        }

        // truncate mid-payload: corrupt, not a bare I/O error
        fs::write(&path, &clean[..clean.len() - 6]).expect("truncate");
        match store.read(&shard) {
            Err(SpillError::Corrupt { detail }) => {
                assert!(detail.contains("truncated"), "{detail}")
            }
            other => panic!("expected truncation corruption, got {other:?}"),
        }

        // a missing file stays an I/O error (retry may recreate it)
        fs::remove_file(&path).expect("remove");
        assert!(matches!(store.read(&shard), Err(SpillError::Io(_))));
    }

    #[test]
    fn ephemeral_dirs_are_unique_even_with_equal_sequence_starts() {
        let a = SpillStore::create(None).expect("store a");
        let b = SpillStore::create(None).expect("store b");
        assert_ne!(a.dir(), b.dir());
        let name = a.dir().file_name().unwrap().to_string_lossy().to_string();
        assert!(name.starts_with("mrcoreset-spill-"), "{name}");
        // pid, sequence, and a 16-hex-digit random suffix
        assert!(name.rsplit('-').next().unwrap().len() == 16, "{name}");
    }

    #[test]
    fn ephemeral_store_cleans_up_on_drop() {
        let dir;
        {
            let store = SpillStore::create(None).expect("temp store");
            dir = store.dir().to_path_buf();
            store.write("x", &[1, 2, 3]).expect("write");
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "ephemeral spill dir must be removed on drop");
    }
}
