//! Deterministic fault injection for the MapReduce executor layer.
//!
//! A [`FaultPlan`] names exactly which (round, reducer, attempt) sites
//! fail and how: a reducer panic, a spill-read I/O error, a spill-write
//! I/O error, or a shard bit-flip (surfacing as a checksum failure).
//! The round engine (`Simulator::round_impl`) consults the plan *before
//! and after* running each reducer attempt, so injection is completely
//! backend-agnostic — the same plan fires at the same sites whether the
//! manifests live in RAM or on disk, at any thread count.
//!
//! # Determinism contract
//!
//! Same plan (same spec string, including chaos seeds) ⇒ same injected
//! sites ⇒ same retry schedule ⇒ same final report. Concretely:
//!
//! - [`FaultPlan::fault_at`] is a pure function of
//!   `(round, reducer, attempt)` — no interior mutability, no wall
//!   clock, no global RNG. Chaos entries hash the site with splitmix64
//!   under a caller-chosen seed.
//! - Every retry attempt starts from the reducer's *input manifest*
//!   (reducers are idempotent) with a fresh memory meter and fresh
//!   distance/counter snapshots, so the numbers recorded for a
//!   recovered reducer come from its successful attempt alone and are
//!   bit-identical to a fault-free run's.
//! - The only values a fault leaves behind are the explicitly-labelled
//!   `attempts` span field and the `faults.*` round counters; backoff
//!   is *simulated* (a deterministic function of the attempt number,
//!   recorded in `faults.backoff_sim_us`, never slept).
//!
//! # Plan grammar
//!
//! A spec is `;`- or `,`-separated entries (CLI `--faults`, env
//! `MRCORESET_FAULTS`):
//!
//! ```text
//! entry := KIND '@' ROUND '.' REDUCER ['x' COUNT]   deterministic site
//!        | 'chaos:' KIND ':' PERMILLE ':' SEED      seeded random sites
//! KIND  := 'panic' | 'read' | 'write' | 'flip'
//! ```
//!
//! `panic@0.2` panics reducer 2 of round 0 on its first attempt;
//! `read@1.0x2` fails the first *two* attempts of reducer 0 in round 1
//! (so recovery needs at least 2 retries); `chaos:flip:50:7` flips a
//! shard in ~5% of (round, reducer) sites chosen by seed 7. The first
//! matching entry wins; chaos entries only ever fire on attempt 1, so a
//! single retry always clears them.

use std::any::Any;
use std::fmt;
use std::panic;
use std::sync::Once;

/// What kind of failure to inject at a site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The reducer closure panics mid-work (caught by the round engine).
    Panic,
    /// Reading the reducer's input shard fails with an I/O error.
    ReadErr,
    /// Writing the reducer's output shard fails with an I/O error
    /// (after the work ran — the expensive case for retry accounting).
    WriteErr,
    /// The reducer's input shard arrives corrupted (checksum mismatch).
    BitFlip,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "panic" => Some(FaultKind::Panic),
            "read" => Some(FaultKind::ReadErr),
            "write" => Some(FaultKind::WriteErr),
            "flip" => Some(FaultKind::BitFlip),
            _ => None,
        }
    }

    /// Round-counter name charged when this kind fires.
    pub(crate) fn counter_name(self) -> &'static str {
        match self {
            FaultKind::Panic => "faults.injected.panic",
            FaultKind::ReadErr => "faults.injected.read",
            FaultKind::WriteErr => "faults.injected.write",
            FaultKind::BitFlip => "faults.injected.flip",
        }
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FaultKind::Panic => "panic",
            FaultKind::ReadErr => "read",
            FaultKind::WriteErr => "write",
            FaultKind::BitFlip => "flip",
        };
        f.write_str(s)
    }
}

/// One deterministic site: fires on attempts `1..=count`.
#[derive(Clone, Debug, PartialEq, Eq)]
struct FaultSite {
    kind: FaultKind,
    round: u32,
    reducer: usize,
    count: u32,
}

/// Seeded random sites: fires on attempt 1 at ~`permille`/1000 of all
/// (round, reducer) pairs, chosen by hashing the site under `seed`.
#[derive(Clone, Debug, PartialEq, Eq)]
struct ChaosRule {
    kind: FaultKind,
    permille: u64,
    seed: u64,
}

/// A parsed, immutable fault schedule. See the module docs for the
/// grammar and the determinism contract.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    sites: Vec<FaultSite>,
    chaos: Vec<ChaosRule>,
}

impl FaultPlan {
    /// Parse a plan spec; `Err` carries a message naming the bad entry.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for entry in spec.split([';', ',']).map(str::trim).filter(|e| !e.is_empty()) {
            if let Some(rest) = entry.strip_prefix("chaos:") {
                let mut it = rest.split(':');
                let kind = it
                    .next()
                    .and_then(FaultKind::parse)
                    .ok_or_else(|| format!("bad fault kind in chaos entry `{entry}`"))?;
                let permille: u64 = it
                    .next()
                    .and_then(|p| p.parse().ok())
                    .ok_or_else(|| format!("bad permille in chaos entry `{entry}`"))?;
                let seed: u64 = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("bad seed in chaos entry `{entry}`"))?;
                if it.next().is_some() {
                    return Err(format!("trailing fields in chaos entry `{entry}`"));
                }
                plan.chaos.push(ChaosRule { kind, permille: permille.min(1000), seed });
                continue;
            }
            let (kind_s, site) = entry
                .split_once('@')
                .ok_or_else(|| format!("fault entry `{entry}` is not KIND@ROUND.REDUCER[xN]"))?;
            let kind = FaultKind::parse(kind_s)
                .ok_or_else(|| format!("unknown fault kind `{kind_s}` in `{entry}`"))?;
            let (rr, count) = match site.split_once('x') {
                Some((rr, c)) => {
                    let count: u32 = c
                        .parse()
                        .ok()
                        .filter(|&c| c >= 1)
                        .ok_or_else(|| format!("bad repeat count in `{entry}`"))?;
                    (rr, count)
                }
                None => (site, 1),
            };
            let (round_s, reducer_s) = rr
                .split_once('.')
                .ok_or_else(|| format!("fault entry `{entry}` is missing ROUND.REDUCER"))?;
            let round: u32 = round_s
                .parse()
                .map_err(|_| format!("bad round index in `{entry}`"))?;
            let reducer: usize = reducer_s
                .parse()
                .map_err(|_| format!("bad reducer index in `{entry}`"))?;
            plan.sites.push(FaultSite { kind, round, reducer, count });
        }
        Ok(plan)
    }

    /// The fault (if any) scheduled at this site on this attempt
    /// (attempts are 1-based). First matching entry wins; deterministic
    /// sites before chaos rules.
    pub fn fault_at(&self, round: u32, reducer: usize, attempt: u32) -> Option<FaultKind> {
        for s in &self.sites {
            if s.round == round && s.reducer == reducer && attempt <= s.count {
                return Some(s.kind);
            }
        }
        if attempt == 1 {
            for c in &self.chaos {
                let h = site_hash(c.seed, round, reducer);
                if h % 1000 < c.permille {
                    return Some(c.kind);
                }
            }
        }
        None
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty() && self.chaos.is_empty()
    }
}

/// splitmix64 over the (seed, round, reducer) site — the same finalizer
/// `util::rng` seeds from, giving well-mixed site selection with zero
/// state.
fn site_hash(seed: u64, round: u32, reducer: usize) -> u64 {
    let mut z = seed ^ ((round as u64) << 32) ^ (reducer as u64);
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Deterministic *simulated* exponential backoff before retry `attempt`
/// (microseconds). Recorded in `faults.backoff_sim_us`, never slept —
/// wall time stays out of the deterministic surface.
pub(crate) fn sim_backoff_us(attempt: u32) -> u64 {
    1000u64 << (attempt.min(16) - 1)
}

/// Panic payload used by [`FaultKind::Panic`] injection, recognized by
/// the quiet hook so injected panics don't spray backtraces over test
/// output. Genuine reducer panics keep the default hook behavior.
struct InjectedPanic {
    round: u32,
    reducer: usize,
    attempt: u32,
}

/// Raise an injected panic (called inside the round engine's
/// `catch_unwind` region).
pub(crate) fn raise_injected(round: u32, reducer: usize, attempt: u32) -> ! {
    panic::panic_any(InjectedPanic { round, reducer, attempt })
}

/// Human-readable description of a caught reducer-panic payload.
pub(crate) fn panic_detail(payload: &(dyn Any + Send)) -> String {
    if let Some(p) = payload.downcast_ref::<InjectedPanic>() {
        format!("injected panic at round {} reducer {} attempt {}", p.round, p.reducer, p.attempt)
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

static QUIET_HOOK: Once = Once::new();

/// Install (once per process) a panic hook that suppresses the default
/// stderr report for [`InjectedPanic`] payloads only. Called when a
/// simulator is configured with a fault plan; all other panics are
/// reported exactly as before.
pub(crate) fn install_quiet_hook() {
    QUIET_HOOK.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<InjectedPanic>().is_none() {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sites_counts_and_chaos() {
        let p = FaultPlan::parse("panic@0.2; read@1.0x3, flip@2.1 ;chaos:write:250:9").unwrap();
        assert_eq!(p.fault_at(0, 2, 1), Some(FaultKind::Panic));
        assert_eq!(p.fault_at(0, 2, 2), None, "count defaults to 1");
        for a in 1..=3 {
            assert_eq!(p.fault_at(1, 0, a), Some(FaultKind::ReadErr));
        }
        assert_eq!(p.fault_at(1, 0, 4), None);
        assert_eq!(p.fault_at(2, 1, 1), Some(FaultKind::BitFlip));
        assert_eq!(p.fault_at(5, 5, 1), p.fault_at(5, 5, 1), "pure function");
    }

    #[test]
    fn rejects_malformed_entries() {
        for bad in
            ["boom@0.1", "panic@x.1", "panic@0", "panic@0.1x0", "chaos:read:abc:1", "panic0.1"]
        {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` must be rejected");
        }
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; , ").unwrap().is_empty());
    }

    #[test]
    fn chaos_rate_is_roughly_permille_and_seed_dependent() {
        let p = FaultPlan::parse("chaos:panic:100:42").unwrap();
        let hits = (0..10u32)
            .flat_map(|r| (0..100usize).map(move |i| (r, i)))
            .filter(|&(r, i)| p.fault_at(r, i, 1).is_some())
            .count();
        // ~10% of 1000 sites; splitmix64 keeps this well inside [50, 200]
        assert!((50..200).contains(&hits), "hit rate {hits}/1000");
        let q = FaultPlan::parse("chaos:panic:100:43").unwrap();
        let differs = (0..10u32)
            .flat_map(|r| (0..100usize).map(move |i| (r, i)))
            .any(|(r, i)| p.fault_at(r, i, 1) != q.fault_at(r, i, 1));
        assert!(differs, "different seeds must pick different sites");
        // chaos never fires past the first attempt: one retry clears it
        for r in 0..10u32 {
            for i in 0..100usize {
                assert_eq!(p.fault_at(r, i, 2), None);
            }
        }
    }

    #[test]
    fn deterministic_sites_shadow_chaos() {
        let p = FaultPlan::parse("read@0.0; chaos:panic:1000:1").unwrap();
        assert_eq!(p.fault_at(0, 0, 1), Some(FaultKind::ReadErr));
        assert_eq!(p.fault_at(0, 1, 1), Some(FaultKind::Panic), "permille 1000 = every site");
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        assert_eq!(sim_backoff_us(1), 1000);
        assert_eq!(sim_backoff_us(2), 2000);
        assert_eq!(sim_backoff_us(3), 4000);
        assert_eq!(sim_backoff_us(40), sim_backoff_us(16), "shift is clamped");
    }

    #[test]
    fn panic_detail_names_injected_sites() {
        let d = panic_detail(&InjectedPanic { round: 1, reducer: 3, attempt: 2 });
        assert!(d.contains("round 1 reducer 3 attempt 2"), "{d}");
        assert_eq!(panic_detail(&"boom"), "boom");
        assert_eq!(panic_detail(&"boom".to_string()), "boom");
    }
}
