//! Deterministic partitioning of point-index sets into L reducer inputs.

use crate::obs::log;
use crate::util::rng::Rng;

/// Partitioning strategy for splitting P across L reducers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// i-th point to reducer i mod L (equally-sized, the paper's setup).
    RoundRobin,
    /// Contiguous chunks (stresses heterogeneity for trace workloads:
    /// consecutive trace points are correlated).
    Contiguous,
    /// Seeded random permutation, then contiguous chunks.
    Shuffled(u64),
}

/// Split `pts` into `l` parts (sizes differ by at most 1).
pub fn partition(pts: &[u32], l: usize, strategy: PartitionStrategy) -> Vec<Vec<u32>> {
    assert!(l >= 1, "need at least one partition");
    let l = l.min(pts.len().max(1));
    match strategy {
        PartitionStrategy::RoundRobin => {
            let mut parts = vec![Vec::with_capacity(pts.len() / l + 1); l];
            for (i, &p) in pts.iter().enumerate() {
                parts[i % l].push(p);
            }
            parts
        }
        PartitionStrategy::Contiguous => chunks(pts.to_vec(), l),
        PartitionStrategy::Shuffled(seed) => {
            let mut v = pts.to_vec();
            Rng::new(seed).shuffle(&mut v);
            chunks(v, l)
        }
    }
}

/// [`partition`], but loud about the silent-shrink edge: when `l`
/// exceeds |P| the split runs with |P| partitions, and callers used to
/// discover that only by counting `parts`. This wrapper warns through
/// `obs::log` and leaves the effective L visible as `parts.len()`, which
/// pipelines carry into `part_sizes` (and the driver into
/// `RunReport::{l, l_requested}` and the round's `reducers` field).
pub fn partition_reported(
    pts: &[u32],
    l: usize,
    strategy: PartitionStrategy,
    ctx: &str,
) -> Vec<Vec<u32>> {
    let parts = partition(pts, l, strategy);
    if parts.len() < l {
        log::warn(&format!(
            "{ctx}: requested L={l} exceeds |P|={}; running {} partitions",
            pts.len(),
            parts.len()
        ));
    }
    parts
}

fn chunks(v: Vec<u32>, l: usize) -> Vec<Vec<u32>> {
    let n = v.len();
    let base = n / l;
    let extra = n % l;
    let mut parts = Vec::with_capacity(l);
    let mut off = 0;
    for i in 0..l {
        let sz = base + usize::from(i < extra);
        parts.push(v[off..off + sz].to_vec());
        off += sz;
    }
    parts
}

/// The paper's default L = ∛(|P| / k) (§3.4), clamped to [1, n].
pub fn default_l(n: usize, k: usize) -> usize {
    let l = ((n as f64 / k.max(1) as f64).cbrt()).round() as usize;
    l.clamp(1, n.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_balanced_and_complete() {
        let pts: Vec<u32> = (0..103).collect();
        let parts = partition(&pts, 4, PartitionStrategy::RoundRobin);
        assert_eq!(parts.len(), 4);
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        let mut all: Vec<u32> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, pts);
    }

    #[test]
    fn contiguous_preserves_order() {
        let pts: Vec<u32> = (0..10).collect();
        let parts = partition(&pts, 3, PartitionStrategy::Contiguous);
        assert_eq!(parts[0], vec![0, 1, 2, 3]);
        assert_eq!(parts[1], vec![4, 5, 6]);
        assert_eq!(parts[2], vec![7, 8, 9]);
    }

    #[test]
    fn shuffled_is_deterministic_permutation() {
        let pts: Vec<u32> = (0..50).collect();
        let a = partition(&pts, 5, PartitionStrategy::Shuffled(9));
        let b = partition(&pts, 5, PartitionStrategy::Shuffled(9));
        assert_eq!(a, b);
        let mut all: Vec<u32> = a.concat();
        all.sort_unstable();
        assert_eq!(all, pts);
    }

    #[test]
    fn l_larger_than_n() {
        let pts: Vec<u32> = (0..3).collect();
        let parts = partition(&pts, 10, PartitionStrategy::RoundRobin);
        assert_eq!(parts.len(), 3);
    }

    #[test]
    fn reported_partition_matches_silent_one() {
        let pts: Vec<u32> = (0..3).collect();
        let loud = partition_reported(&pts, 10, PartitionStrategy::RoundRobin, "test");
        let quiet = partition(&pts, 10, PartitionStrategy::RoundRobin);
        assert_eq!(loud, quiet);
        assert_eq!(loud.len(), 3, "effective L is |P| when l > |P|");
    }

    #[test]
    fn default_l_formula() {
        assert_eq!(default_l(1000, 1), 10);
        assert_eq!(default_l(8000, 8), 10);
        assert_eq!(default_l(10, 10), 1);
        assert_eq!(default_l(0, 5), 1);
    }
}
