//! Human-readable run reports.

use crate::util::table::{fnum, Table};

use super::driver::RunReport;

impl RunReport {
    /// Multi-line summary for CLI output.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "solution: k={} cost(full input)={}\n",
            self.solution.centers.len(),
            fnum(self.full_cost)
        ));
        if self.outliers > 0 {
            s.push_str(&format!(
                "robust:   z={} cost(inliers)={} excluded={} pts\n",
                self.outliers,
                fnum(self.robust_full_cost),
                self.excluded.len()
            ));
        }
        s.push_str(&format!(
            "coreset:  |E_w|={} (|C_w|={}), L={}, m={}\n",
            self.coreset_size, self.cw_size, self.l, self.m
        ));
        s.push_str(&format!(
            "mapreduce: rounds={} M_L={} pts M_A={} pts dist_evals={} wall={:.3}s\n",
            self.rounds,
            self.max_local_memory,
            self.aggregate_memory,
            self.dist_evals,
            self.wall.as_secs_f64()
        ));
        for r in &self.stats.rounds {
            s.push_str(&format!(
                "  round {:22} reducers={:4} peak_local={:8} dist={:12} wall={:.3}s\n",
                r.name,
                r.reducers,
                r.max_local_peak,
                r.dist_evals,
                r.wall.as_secs_f64()
            ));
        }
        s
    }

    /// One row for experiment tables:
    /// (eps, L, coreset, M_L, rounds, cost).
    pub fn table_row(&self, eps: f64) -> Vec<String> {
        vec![
            fnum(eps),
            self.l.to_string(),
            self.coreset_size.to_string(),
            self.max_local_memory.to_string(),
            self.rounds.to_string(),
            fnum(self.full_cost),
        ]
    }

    pub fn table_header() -> Table {
        Table::new(vec!["eps", "L", "|E_w|", "M_L", "rounds", "cost"])
    }
}

#[cfg(test)]
mod tests {
    use crate::coordinator::{solve, ClusterConfig};
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use crate::metric::Objective;
    use std::sync::Arc;

    #[test]
    fn summary_contains_key_fields() {
        let (data, _) =
            GaussianMixtureSpec { n: 500, d: 2, k: 3, seed: 1, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..500).collect();
        let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, 3, 0.5));
        let s = rep.summary();
        assert!(s.contains("rounds=3"));
        assert!(s.contains("coreset:"));
        assert!(!s.contains("robust:"), "z=0 runs must not print a robust line");
        let row = rep.table_row(0.5);
        assert_eq!(row.len(), 6);
    }

    #[test]
    fn summary_reports_robust_line_when_outliers_enabled() {
        let (data, _) =
            GaussianMixtureSpec { n: 500, d: 2, k: 3, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..500).collect();
        let mut cfg = ClusterConfig::new(Objective::Median, 3, 0.5);
        cfg.outliers = 10;
        let rep = solve(&space, &pts, &cfg);
        let s = rep.summary();
        assert!(s.contains("robust:   z=10"), "summary:\n{s}");
        assert!(s.contains("excluded=10 pts"), "summary:\n{s}");
    }
}
