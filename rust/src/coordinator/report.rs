//! Human-readable and JSON run reports.

use crate::util::json::Json;
use crate::util::table::{fnum, Table};

use super::driver::RunReport;

impl RunReport {
    /// Multi-line summary for CLI output.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "solution: k={} cost(full input)={}\n",
            self.solution.centers.len(),
            fnum(self.full_cost)
        ));
        if self.outliers > 0 {
            s.push_str(&format!(
                "robust:   z={} cost(inliers)={} excluded={} pts\n",
                self.outliers,
                fnum(self.robust_full_cost),
                self.excluded.len()
            ));
        }
        let l_note = if self.l != self.l_requested {
            format!(" (requested {})", self.l_requested)
        } else {
            String::new()
        };
        s.push_str(&format!(
            "coreset:  |E_w|={} (|C_w|={}), L={}{}, m={}\n",
            self.coreset_size, self.cw_size, self.l, l_note, self.m
        ));
        s.push_str(&format!(
            "mapreduce: rounds={} M_L={} pts M_A={} pts M_B={} B dist_evals={} kernel={} \
             wall={:.3}s\n",
            self.rounds,
            self.max_local_memory,
            self.aggregate_memory,
            self.max_local_bytes,
            self.dist_evals,
            self.kernel,
            self.wall.as_secs_f64()
        ));
        if self.retries > 0 {
            s.push_str(&format!(
                "recovery: retries={} backoff_sim={}us (injected faults: panic={} read={} \
                 write={} flip={})\n",
                self.retries,
                self.stats.counter_total("faults.backoff_sim_us"),
                self.stats.counter_total("faults.injected.panic"),
                self.stats.counter_total("faults.injected.read"),
                self.stats.counter_total("faults.injected.write"),
                self.stats.counter_total("faults.injected.flip"),
            ));
        }
        for r in &self.stats.rounds {
            let md = r.mem_distribution();
            s.push_str(&format!(
                "  round {:22} reducers={:4} peak_local={:8} mem_p50={:8.0} mem_p95={:8.0} \
                 bytes={:9} dist={:12} wall={:.3}s\n",
                r.name,
                r.reducers,
                r.max_local_peak,
                md.p50,
                md.p95,
                r.max_local_bytes,
                r.dist_evals,
                r.wall.as_secs_f64()
            ));
        }
        s
    }

    /// Deterministic JSON twin of [`RunReport::summary`]: everything the
    /// run measured except wall-clock, so two runs of the same seeded
    /// config — at any thread count — serialize byte-identically (the
    /// determinism suite diffs exactly this string).
    pub fn to_json(&self) -> String {
        let mut o = Json::obj();
        let mut sol = Json::obj();
        sol.set("k", Json::num(self.solution.centers.len() as f64));
        sol.set(
            "centers",
            Json::Arr(self.solution.centers.iter().map(|&c| Json::num(c as f64)).collect()),
        );
        sol.set("coreset_cost", Json::num(self.solution.cost));
        o.set("solution", sol);
        o.set("full_cost", Json::num(self.full_cost));
        o.set("outliers", Json::num(self.outliers as f64));
        if self.outliers > 0 {
            o.set("robust_full_cost", Json::num(self.robust_full_cost));
            o.set(
                "excluded",
                Json::Arr(self.excluded.iter().map(|&p| Json::num(p as f64)).collect()),
            );
        }
        o.set("coreset_size", Json::num(self.coreset_size as f64));
        o.set("cw_size", Json::num(self.cw_size as f64));
        o.set("l", Json::num(self.l as f64));
        o.set("l_requested", Json::num(self.l_requested as f64));
        o.set("m", Json::num(self.m as f64));
        o.set("rounds", Json::num(self.rounds as f64));
        o.set("max_local_memory", Json::num(self.max_local_memory as f64));
        o.set("aggregate_memory", Json::num(self.aggregate_memory as f64));
        // Byte peaks are backend-invariant (the executors' byte-parity
        // contract), so they belong in the determinism-diffed JSON; the
        // backend-dependent spill read/write volumes deliberately do not.
        o.set("max_local_bytes", Json::num(self.max_local_bytes as f64));
        o.set("dist_evals", Json::num(self.dist_evals as f64));
        // Backend identity, not a measurement: lets archived reports say
        // which kernel produced them. Exact kernels serialize identical
        // metrics, so this never masks a real determinism diff.
        o.set("kernel", Json::str(self.kernel));
        // Gated like the outlier keys: a fault-free run's JSON is
        // byte-identical to one produced before fault tolerance existed
        // (and to a recovered run's modulo this key and the faults.*
        // counters — the acceptance diff strips exactly those).
        if self.retries > 0 {
            o.set("retries", Json::num(self.retries as f64));
        }
        let rounds: Vec<Json> = self
            .stats
            .rounds
            .iter()
            .map(|r| {
                let md = r.mem_distribution();
                let ed = r.evals_distribution();
                let mut rj = Json::obj();
                rj.set("name", Json::str(r.name.clone()));
                rj.set("reducers", Json::num(r.reducers as f64));
                rj.set("mem_max", Json::num(r.max_local_peak as f64));
                rj.set("mem_p50", Json::num(md.p50));
                rj.set("mem_p95", Json::num(md.p95));
                rj.set("mem_bytes_max", Json::num(r.max_local_bytes as f64));
                rj.set("aggregate", Json::num(r.aggregate_peak as f64));
                rj.set("dist_evals", Json::num(r.dist_evals as f64));
                rj.set("evals_p50", Json::num(ed.p50));
                rj.set("evals_p95", Json::num(ed.p95));
                rj.set("in_items", Json::num(r.in_items as f64));
                rj.set("out_items", Json::num(r.out_items as f64));
                rj.set("violations", Json::num(r.budget_violations as f64));
                let mut cj = Json::obj();
                for (k, v) in &r.counters {
                    cj.set(k, Json::num(*v as f64));
                }
                rj.set("counters", cj);
                rj
            })
            .collect();
        o.set("round_stats", Json::Arr(rounds));
        o.to_string()
    }

    /// One row for experiment tables:
    /// (eps, L, coreset, M_L, rounds, cost).
    pub fn table_row(&self, eps: f64) -> Vec<String> {
        vec![
            fnum(eps),
            self.l.to_string(),
            self.coreset_size.to_string(),
            self.max_local_memory.to_string(),
            self.rounds.to_string(),
            fnum(self.full_cost),
        ]
    }

    pub fn table_header() -> Table {
        Table::new(vec!["eps", "L", "|E_w|", "M_L", "rounds", "cost"])
    }
}

#[cfg(test)]
mod tests {
    use crate::coordinator::{solve, ClusterConfig};
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use crate::metric::Objective;
    use std::sync::Arc;

    #[test]
    fn summary_contains_key_fields() {
        let (data, _) =
            GaussianMixtureSpec { n: 500, d: 2, k: 3, seed: 1, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..500).collect();
        let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, 3, 0.5));
        let s = rep.summary();
        assert!(s.contains("rounds=3"));
        assert!(s.contains("coreset:"));
        assert!(!s.contains("robust:"), "z=0 runs must not print a robust line");
        let row = rep.table_row(0.5);
        assert_eq!(row.len(), 6);
    }

    #[test]
    fn summary_reports_robust_line_when_outliers_enabled() {
        let (data, _) =
            GaussianMixtureSpec { n: 500, d: 2, k: 3, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..500).collect();
        let mut cfg = ClusterConfig::new(Objective::Median, 3, 0.5);
        cfg.outliers = 10;
        let rep = solve(&space, &pts, &cfg);
        let s = rep.summary();
        assert!(s.contains("robust:   z=10"), "summary:\n{s}");
        assert!(s.contains("excluded=10 pts"), "summary:\n{s}");
    }
}
