//! The 3-round MapReduce solver (paper §3.4, Theorem 3.14).
//!
//! Round 1 + Round 2: the two-round coreset construction of §3.2
//! (k-median) / §3.3 (k-means) produces E_w, which is simultaneously an
//! O(ε)-bounded coreset and an O(ε)-centroid set.
//! Round 3: a single reducer runs a sequential α-approximation on the
//! weighted instance (E_w, k); Theorems 3.9/3.13 give α + O(ε) overall.
//!
//! With L = ∛(|P|/k) the per-reducer memory is
//! O(|P|^{2/3} k^{1/3} (c/ε)^{2D} log² |P|) — substantially sublinear
//! for small doubling dimension D.
//!
//! The driver solves against an [`Executor`] handle built from
//! `ClusterConfig::executor`: the in-memory backend replays the
//! historical simulator behaviour bit for bit, while the spill backend
//! stages every round's shards on disk and enforces a hard per-reducer
//! byte budget. Budget violations and I/O failures surface as
//! [`ExecError`] through [`try_solve_traced`]; the panicking wrappers
//! [`solve`]/[`solve_traced`] keep the historical infallible signatures.

use std::sync::Arc;
use std::time::Instant;

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::pam::{pam, PamCfg};
use crate::algorithms::{Instance, Solution};
use crate::coreset::pipeline::{one_round_coreset, two_round_coreset, CoresetConfig};
use crate::coreset::TlAlgo;
use crate::mapreduce::{
    default_l, ExecError, Executor, ExecutorCfg, JobStats, PartitionStrategy,
};
use crate::metric::{MetricSpace, Objective};
use crate::obs::{self, Event, Recorder, TRACE_SCHEMA_VERSION};
use crate::outliers::{
    local_search_outliers, outlier_coreset, robust_cost_of_dists, OutlierCoresetConfig,
};

/// Final-round sequential solver choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinalAlgo {
    /// Sampled-candidate local search (default; scales to large coresets).
    LocalSearch,
    /// PAM (exhaustive swaps; small coresets only).
    Pam,
    /// Outlier-robust local search over the (k, z) objective (selected
    /// automatically when `ClusterConfig::outliers > 0`; with z = 0 it
    /// degenerates to the plain robust objective).
    RobustLocalSearch,
}

/// Full configuration of a 3-round run.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub objective: Objective,
    pub k: usize,
    /// Precision parameter ε (trades coreset size for accuracy).
    pub eps: f64,
    /// Number of partitions L; `None` = the paper's ∛(|P|/k).
    pub l: Option<usize>,
    /// Oversampling for the per-partition rough solutions T_ℓ; `None` = 2k.
    pub m: Option<usize>,
    /// Assumed approximation factor of the T_ℓ algorithm.
    pub beta: f64,
    pub tl: TlAlgo,
    pub final_algo: FinalAlgo,
    /// Number of outliers z the solver may write off (0 = plain
    /// clustering). When positive, rounds 1–2 run the outlier-aware
    /// coreset construction (`outliers::pipeline`) and round 3 solves the
    /// weighted (k, z) instance (`outliers::finisher`).
    pub outliers: usize,
    pub strategy: PartitionStrategy,
    /// Use the 1-round construction of §3.1 instead of the 2-round one
    /// (ablation: costs a factor ~2 in the approximation).
    pub one_round: bool,
    pub seed: u64,
    /// Worker threads for the executor (None = auto).
    pub threads: Option<usize>,
    /// Execution backend + per-reducer byte budget (defaults honour the
    /// `MRCORESET_EXECUTOR` / `MRCORESET_MEM_BUDGET` environment
    /// variables, so whole test suites can be replayed out of core).
    pub executor: ExecutorCfg,
}

impl ClusterConfig {
    pub fn new(objective: Objective, k: usize, eps: f64) -> ClusterConfig {
        ClusterConfig {
            objective,
            k,
            eps,
            l: None,
            m: None,
            beta: 2.0,
            tl: TlAlgo::DppSeeding,
            final_algo: FinalAlgo::LocalSearch,
            outliers: 0,
            strategy: PartitionStrategy::RoundRobin,
            one_round: false,
            seed: 0xD15C0,
            threads: None,
            executor: ExecutorCfg::default(),
        }
    }
}

/// Everything a run produces: the solution plus the measured quantities
/// the theory speaks about.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub solution: Solution,
    /// Solution cost evaluated on the FULL input (not just the coreset).
    pub full_cost: f64,
    /// Number of outliers z the solver was allowed to write off.
    pub outliers: usize,
    /// Full-input cost with the z most expensive points excluded
    /// (== `full_cost` when `outliers == 0`).
    pub robust_full_cost: f64,
    /// Global indices of the z excluded input points, most expensive
    /// first (empty when `outliers == 0`).
    pub excluded: Vec<u32>,
    pub coreset_size: usize,
    pub cw_size: usize,
    /// Effective number of round-1 partitions (= number of reducers that
    /// actually ran; see `l_requested` when the input was too small).
    pub l: usize,
    /// The L that was asked for. `partition()` silently caps L at |P|;
    /// the gap between this and `l` surfaces that cap.
    pub l_requested: usize,
    pub m: usize,
    pub rounds: usize,
    pub max_local_memory: usize,
    pub aggregate_memory: usize,
    /// Peak executor-materialised bytes in any single reducer slot
    /// (identical across backends by the byte-parity contract).
    pub max_local_bytes: u64,
    /// Total distance evaluations charged inside the MapReduce rounds
    /// (per-round and per-reducer breakdowns live in `stats.rounds`).
    pub dist_evals: u64,
    /// Distance-kernel backend the metric space resolved to for this run
    /// (`scalar`/`blocked`/`simd`/`engine`/`bitparallel`, see
    /// [`crate::metric::kernel`]).
    pub kernel: &'static str,
    /// Reducer re-executions recovered by the fault-tolerant round
    /// engine (sum of `faults.retries` across rounds; 0 in a fault-free
    /// run). Retried work is charged like first-attempt work, so every
    /// other field is unaffected by recovery.
    pub retries: u64,
    pub wall: std::time::Duration,
    pub stats: JobStats,
}

/// Run the full 3-round algorithm on (pts, k).
pub fn solve(space: &dyn MetricSpace, pts: &[u32], cfg: &ClusterConfig) -> RunReport {
    solve_traced(space, pts, cfg, obs::noop())
}

/// [`solve`] with a telemetry recorder attached to the executor: every
/// round emits span events (see `obs::event`), bracketed by
/// `run_start`/`run_end`. `solve` is exactly this with the disabled
/// recorder, so traced and untraced runs compute identical reports.
///
/// Panics on executor failures (over-budget, spill I/O); use
/// [`try_solve_traced`] to handle those as values.
pub fn solve_traced(
    space: &dyn MetricSpace,
    pts: &[u32],
    cfg: &ClusterConfig,
    recorder: Arc<dyn Recorder>,
) -> RunReport {
    try_solve_traced(space, pts, cfg, recorder)
        .unwrap_or_else(|e| panic!("mapreduce execution failed: {e}"))
}

/// Fallible core of [`solve_traced`]: builds the executor backend from
/// `cfg.executor` and returns a structured [`ExecError`] when a reducer
/// exceeds its byte budget or spill I/O fails — instead of aborting the
/// process. A failed run leaves a trace with `run_start` (and any
/// completed rounds) but no `run_end`.
pub fn try_solve_traced(
    space: &dyn MetricSpace,
    pts: &[u32],
    cfg: &ClusterConfig,
    recorder: Arc<dyn Recorder>,
) -> Result<RunReport, ExecError> {
    assert!(cfg.k >= 1 && cfg.k <= pts.len(), "require 1 <= k <= |P|");
    assert!(cfg.eps > 0.0, "eps must be positive");
    let t0 = Instant::now();
    let n = pts.len();
    let l = cfg.l.unwrap_or_else(|| default_l(n, cfg.k));
    let m = cfg.m.unwrap_or(2 * cfg.k).max(cfg.k);
    let label = format!(
        "{} k={} n={} eps={} seed={} kernel={}",
        cfg.objective,
        cfg.k,
        n,
        cfg.eps,
        cfg.seed,
        space.kernel_name()
    );
    if recorder.enabled() {
        recorder.record(&Event::RunStart { schema: TRACE_SCHEMA_VERSION, label: label.clone() });
    }
    // The checkpoint fingerprint must cover *every* result-affecting
    // input — resuming under different parameters (or a different
    // dataset of the same size) must be refused, not silently mixed —
    // so it extends the display label with the remaining config fields
    // and a content hash of the input. The data probe costs a handful
    // of distance evaluations, so it runs only when checkpointing is on.
    let fingerprint = if cfg.executor.checkpoint_dir.is_some() {
        format!(
            "{label} l={l} m={m} beta={} tl={:?} final={:?} z={} strategy={:?} \
             one_round={} data={:016x}",
            cfg.beta,
            cfg.tl,
            cfg.final_algo,
            cfg.outliers,
            cfg.strategy,
            cfg.one_round,
            data_fingerprint(space, pts)
        )
    } else {
        label.clone()
    };
    let exec = cfg.executor.build_tagged(cfg.threads, recorder.clone(), &fingerprint)?;
    let ccfg = CoresetConfig { eps: cfg.eps, beta: cfg.beta, m, tl: cfg.tl, seed: cfg.seed };
    let use_robust = cfg.outliers > 0 || cfg.final_algo == FinalAlgo::RobustLocalSearch;

    // Rounds 1–2: coreset construction. Robust runs use the outlier
    // pipeline's own center count k + z′ (cfg.m and cfg.one_round do not
    // apply there); `m_used` is what actually ran, for the report.
    let (pipe, m_used) = if use_robust {
        let ocfg = OutlierCoresetConfig {
            eps: cfg.eps,
            beta: cfg.beta,
            k: cfg.k,
            z: cfg.outliers,
            oversample: 2,
            tl: cfg.tl,
            seed: cfg.seed,
        };
        let m_local = ocfg.m_local(l.min(n));
        (outlier_coreset(space, cfg.objective, pts, l, cfg.strategy, &ocfg, &exec)?, m_local)
    } else if cfg.one_round {
        (one_round_coreset(space, cfg.objective, pts, l, cfg.strategy, &ccfg, &exec)?, m)
    } else {
        (two_round_coreset(space, cfg.objective, pts, l, cfg.strategy, &ccfg, &exec)?, m)
    };
    let coreset = pipe.coreset;

    // Round 3: sequential solve on the weighted coreset instance
    // (single reducer holding E_w).
    let cs_input = exec.scatter(vec![coreset.clone()])?;
    let solutions = exec.round("final-solve", &cs_input, |_, cs, meter| {
        meter.charge(cs.len());
        let inst = Instance::new(&cs.indices, &cs.weights);
        if use_robust {
            // Weighted (k, z) local search; the finisher seeds with the
            // robust-better of D^p-seeding and farthest-first itself.
            let ls = LocalSearchCfg { seed: cfg.seed ^ 0xF1A1, ..Default::default() };
            let rs = local_search_outliers(
                space,
                cfg.objective,
                inst,
                cfg.k,
                cfg.outliers as u64,
                None,
                &ls,
            );
            meter.release(cs.len());
            return Solution { centers: rs.centers, cost: rs.cost };
        }
        let sol = match cfg.final_algo {
            FinalAlgo::LocalSearch => {
                // init = better of D^p-seeding and farthest-first: the
                // former nails dense structure, the latter provably covers
                // rare far clusters (which the coreset preserved and the
                // solver must not re-lose).
                let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0x1217);
                let dpp = crate::algorithms::seeding::dpp_seeding(
                    space,
                    cfg.objective,
                    inst,
                    cfg.k,
                    &mut rng,
                );
                let gon = crate::algorithms::seeding::gonzalez(space, inst, cfg.k, 0);
                let gon_cost = inst.cost(space, cfg.objective, &gon);
                let init = if gon_cost < dpp.cost { gon } else { dpp.centers };
                let ls = LocalSearchCfg { seed: cfg.seed ^ 0xF1A1, ..Default::default() };
                local_search(space, cfg.objective, inst, cfg.k, Some(init), &ls)
            }
            FinalAlgo::Pam => {
                let pc = PamCfg { max_n: cs.len().max(1), ..Default::default() };
                pam(space, cfg.objective, inst, cfg.k, &pc)
            }
            FinalAlgo::RobustLocalSearch => unreachable!("handled by the robust branch above"),
        };
        meter.release(cs.len());
        sol
    })?;
    let solution = solutions.into_items()?.into_iter().next().expect("one reducer");

    // Evaluation (outside the MR job): cost on the full input, plus the
    // robust (z-excluded) cost when outliers are enabled.
    let assign = space.assign(pts, &solution.centers);
    let full_cost = assign.cost_unit(cfg.objective);
    let (robust_full_cost, excluded) = if cfg.outliers > 0 {
        let unit = vec![1u64; pts.len()];
        let rc = robust_cost_of_dists(cfg.objective, &assign.dist, &unit, cfg.outliers as u64);
        let excluded: Vec<u32> = rc.excluded.iter().map(|&p| pts[p as usize]).collect();
        (rc.cost, excluded)
    } else {
        (full_cost, Vec::new())
    };

    let stats = exec.take_stats();
    if recorder.enabled() {
        recorder.record(&Event::RunEnd {
            rounds: stats.num_rounds() as u64,
            dist_evals: stats.total_dist_evals(),
            max_local_memory: stats.max_local_memory() as u64,
            max_local_bytes: stats.max_local_bytes(),
        });
        recorder.flush();
    }
    Ok(RunReport {
        full_cost,
        outliers: cfg.outliers,
        robust_full_cost,
        excluded,
        coreset_size: coreset.len(),
        cw_size: pipe.cw_size,
        l: pipe.part_sizes.len(),
        l_requested: l,
        m: m_used,
        rounds: stats.num_rounds(),
        max_local_memory: stats.max_local_memory(),
        aggregate_memory: stats.aggregate_memory(),
        max_local_bytes: stats.max_local_bytes(),
        dist_evals: stats.total_dist_evals(),
        kernel: space.kernel_name(),
        retries: stats.counter_total("faults.retries"),
        wall: t0.elapsed(),
        stats,
        solution,
    })
}

/// Content identity of the input instance for the checkpoint
/// fingerprint: FNV-1a over the point-id list plus a deterministic
/// sample of pairwise distances. The distance probes make two datasets
/// that merely share a size hash differently — the failure mode a
/// size-only fingerprint cannot catch — while staying O(|P|) cheap
/// (the id fold) with at most ~128 distance evaluations.
fn data_fingerprint(space: &dyn MetricSpace, pts: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |h: &mut u64, x: u64| {
        *h ^= x;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    let n = pts.len();
    mix(&mut h, n as u64);
    for &p in pts {
        mix(&mut h, u64::from(p));
    }
    if n > 0 {
        let step = (n / 64).max(1);
        for i in (0..n).step_by(step) {
            let j = (i + n / 2) % n;
            mix(&mut h, space.dist(pts[0], pts[i]).to_bits());
            mix(&mut h, space.dist(pts[i], pts[j]).to_bits());
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::local_search::{local_search, LocalSearchCfg};
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    fn mixture(n: usize, k: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let (data, _) = GaussianMixtureSpec { n, d: 4, k, seed, ..Default::default() }.generate();
        (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
    }

    #[test]
    fn three_rounds_and_k_centers() {
        let (space, pts) = mixture(2000, 5, 1);
        for obj in [Objective::Median, Objective::Means] {
            let cfg = ClusterConfig::new(obj, 5, 0.5);
            let rep = solve(&space, &pts, &cfg);
            assert_eq!(rep.rounds, 3, "{obj}: paper promises exactly 3 rounds");
            assert_eq!(rep.solution.centers.len(), 5);
            assert!(rep.full_cost.is_finite() && rep.full_cost > 0.0);
            assert!(rep.coreset_size < 2000);
            assert!(rep.dist_evals > 0, "{obj}: distance work must be accounted");
            assert_eq!(rep.dist_evals, rep.stats.total_dist_evals());
        }
    }

    #[test]
    fn data_fingerprint_separates_same_size_datasets() {
        let (a, pts) = mixture(500, 4, 1);
        let (b, _) = mixture(500, 4, 2);
        assert_eq!(data_fingerprint(&a, &pts), data_fingerprint(&a, &pts), "deterministic");
        assert_ne!(
            data_fingerprint(&a, &pts),
            data_fingerprint(&b, &pts),
            "two datasets of the same size must fingerprint differently"
        );
        assert_ne!(
            data_fingerprint(&a, &pts),
            data_fingerprint(&a, &pts[..499]),
            "a subset must fingerprint differently"
        );
    }

    #[test]
    fn close_to_sequential_reference() {
        let (space, pts) = mixture(3000, 5, 2);
        let w = vec![1u64; pts.len()];
        let seq = local_search(
            &space,
            Objective::Median,
            Instance::new(&pts, &w),
            5,
            None,
            &LocalSearchCfg::default(),
        );
        let cfg = ClusterConfig::new(Objective::Median, 5, 0.25);
        let rep = solve(&space, &pts, &cfg);
        let ratio = rep.full_cost / seq.cost;
        assert!(ratio < 1.35, "MR/seq cost ratio {ratio}");
    }

    #[test]
    fn one_round_ablation_runs_two_rounds_total() {
        let (space, pts) = mixture(1000, 4, 3);
        let mut cfg = ClusterConfig::new(Objective::Means, 4, 0.5);
        cfg.one_round = true;
        let rep = solve(&space, &pts, &cfg);
        assert_eq!(rep.rounds, 2, "1-round coreset + 1 solve round");
        assert_eq!(rep.solution.centers.len(), 4);
    }

    #[test]
    fn local_memory_sublinear() {
        // low-dimensional workload: the ball-cover compresses (size is
        // exponential in D, so D=1 keeps the test fast and decisive)
        let (data, _) = GaussianMixtureSpec { n: 8000, d: 1, k: 8, seed: 4, ..Default::default() }
            .generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..8000).collect();
        let cfg = ClusterConfig::new(Objective::Median, 8, 0.8);
        let rep = solve(&space, &pts, &cfg);
        assert!(
            rep.max_local_memory < pts.len() / 2,
            "M_L {} vs n {}",
            rep.max_local_memory,
            pts.len()
        );
        assert!(rep.aggregate_memory >= pts.len(), "M_A covers the input");
    }

    #[test]
    fn pam_final_works_on_small_instances() {
        let (space, pts) = mixture(400, 3, 5);
        let mut cfg = ClusterConfig::new(Objective::Median, 3, 0.6);
        cfg.final_algo = FinalAlgo::Pam;
        let rep = solve(&space, &pts, &cfg);
        assert_eq!(rep.solution.centers.len(), 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let (space, pts) = mixture(1000, 4, 6);
        let cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
        let a = solve(&space, &pts, &cfg);
        let b = solve(&space, &pts, &cfg);
        assert_eq!(a.solution.centers, b.solution.centers);
        assert_eq!(a.coreset_size, b.coreset_size);
    }

    #[test]
    fn effective_l_is_reported_when_partitioning_shrinks() {
        // Request more partitions than points: partition() caps L at |P|
        // and the report must expose both the requested and effective L.
        let (space, pts) = mixture(60, 2, 23);
        let mut cfg = ClusterConfig::new(Objective::Median, 2, 0.5);
        cfg.l = Some(600);
        let rep = solve(&space, &pts, &cfg);
        assert_eq!(rep.l_requested, 600);
        assert_eq!(rep.l, 60, "effective L is the reducer count that ran");
    }

    #[test]
    fn executor_reports_materialised_bytes() {
        let (space, pts) = mixture(600, 3, 29);
        let cfg = ClusterConfig::new(Objective::Median, 3, 0.5);
        let rep = solve(&space, &pts, &cfg);
        // round-1 shards alone are 8 + 4·|P_ℓ| bytes, so the peak is
        // comfortably positive on any non-trivial input.
        assert!(rep.max_local_bytes > 0, "byte metering must be wired through");
        assert_eq!(rep.max_local_bytes, rep.stats.max_local_bytes());
    }

    #[test]
    fn over_budget_is_a_structured_error_not_a_crash() {
        let (space, pts) = mixture(500, 3, 31);
        let mut cfg = ClusterConfig::new(Objective::Median, 3, 0.5);
        cfg.executor = ExecutorCfg::in_memory().with_budget(16);
        let err = try_solve_traced(&space, &pts, &cfg, obs::noop())
            .expect_err("16-byte budget cannot hold a partition");
        match err {
            ExecError::OverBudget { round, needed, budget, .. } => {
                assert_eq!(budget, 16);
                assert!(needed > 16);
                assert_eq!(round, "coreset-r1-local", "first round must trip first");
            }
            other => panic!("expected OverBudget, got {other}"),
        }
    }

    /// Clusters in a small box plus a far uniform noise blob — the
    /// regime where a plain solver provably distorts: dedicating a
    /// center to the blob saves far more than abandoning a cluster
    /// costs, so the z = 0 solution sacrifices real structure.
    fn noisy(n: usize, noise: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        use crate::data::synth::NoiseSpec;
        let spec = GaussianMixtureSpec { n, d: 2, k: 4, spread: 30.0, seed, ..Default::default() };
        let (data, _) = spec.generate_with_noise(&NoiseSpec {
            count: noise,
            expanse: 10.0,
            offset: 40.0,
            seed: seed ^ 0x77,
        });
        let total = data.n() as u32;
        (EuclideanSpace::new(Arc::new(data)), (0..total).collect())
    }

    #[test]
    fn outlier_solve_three_rounds_and_exclusions() {
        let (space, pts) = noisy(1200, 30, 11);
        let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
        cfg.outliers = 30;
        let rep = solve(&space, &pts, &cfg);
        assert_eq!(rep.rounds, 3, "outlier pipeline keeps the 3-round shape");
        assert_eq!(rep.solution.centers.len(), 4);
        assert_eq!(rep.outliers, 30);
        assert_eq!(rep.excluded.len(), 30, "unit weights: exactly z excluded points");
        assert!(rep.robust_full_cost < rep.full_cost);
        assert!(rep.robust_full_cost.is_finite() && rep.robust_full_cost > 0.0);
        assert!(rep.dist_evals > 0);
    }

    /// The subsystem's reason to exist: with z = 50 on a noisy mixture
    /// the inlier (z-excluded) objective is strictly better than what
    /// the plain z = 0 solver achieves on the same instance.
    #[test]
    fn robust_solver_beats_plain_on_inlier_objective() {
        let (space, pts) = noisy(1200, 50, 13);
        let mut rcfg = ClusterConfig::new(Objective::Median, 4, 0.5);
        rcfg.outliers = 50;
        let robust = solve(&space, &pts, &rcfg);
        let plain = solve(&space, &pts, &ClusterConfig::new(Objective::Median, 4, 0.5));
        // evaluate the plain solution under the same z-excluded objective
        let assign = space.assign(&pts, &plain.solution.centers);
        let unit = vec![1u64; pts.len()];
        let plain_robust = crate::outliers::robust_cost_of_dists(
            Objective::Median,
            &assign.dist,
            &unit,
            50,
        );
        assert!(
            robust.robust_full_cost < plain_robust.cost,
            "robust {} vs plain-evaluated-robust {}",
            robust.robust_full_cost,
            plain_robust.cost
        );
        // the excluded set is (essentially) the injected noise: noise
        // indices sit at the end of the store
        let noise_start = pts.len() as u32 - 50;
        let recall = robust.excluded.iter().filter(|&&i| i >= noise_start).count() as f64 / 50.0;
        assert!(recall >= 0.9, "outlier recall {recall}");
    }

    #[test]
    fn robust_final_algo_with_z_zero_matches_plain_shape() {
        let (space, pts) = mixture(800, 4, 17);
        let mut cfg = ClusterConfig::new(Objective::Means, 4, 0.5);
        cfg.final_algo = FinalAlgo::RobustLocalSearch;
        let rep = solve(&space, &pts, &cfg);
        assert_eq!(rep.rounds, 3);
        assert_eq!(rep.solution.centers.len(), 4);
        assert!(rep.excluded.is_empty());
        assert_eq!(rep.robust_full_cost.to_bits(), rep.full_cost.to_bits());
    }

    #[test]
    #[should_panic(expected = "1 <= k <= |P|")]
    fn rejects_bad_k() {
        let (space, pts) = mixture(50, 2, 7);
        let cfg = ClusterConfig::new(Objective::Median, 0, 0.5);
        let _ = solve(&space, &pts, &cfg);
    }
}
