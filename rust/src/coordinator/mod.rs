//! L3 coordinator: the paper's end-to-end 3-round MapReduce algorithms
//! (§3.4) — two coreset-construction rounds followed by a sequential
//! solve of the weighted coreset instance — plus run configuration and
//! reporting.

pub mod driver;
pub mod report;

pub use driver::{solve, solve_traced, try_solve_traced, ClusterConfig, FinalAlgo, RunReport};
