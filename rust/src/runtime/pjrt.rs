//! The real PJRT-backed engine (`pjrt` feature): parses HLO *text* with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and executes with concrete buffers (see /opt/xla-example/load_hlo/).
//! Python never runs here: the artifacts are compiled once at build time
//! (python/compile/aot.py).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::metric::dense::{BulkEngine, DEFAULT_DISPATCH_THRESHOLD};
use crate::points::VectorData;

use super::manifest::{ArtifactKind, Manifest, ManifestEntry};
use super::PAD_CENTER_VALUE;

struct EngineInner {
    client: xla::PjRtClient,
    /// Lazily compiled executables keyed by manifest entry.
    cache: HashMap<ManifestEntry, xla::PjRtLoadedExecutable>,
}

// SAFETY: PjRtClient wraps an Rc over a thread-safe C++ PJRT CPU client;
// the Rc (and every executable handle) is only ever touched while holding
// the XlaEngine mutex, so refcount updates and executions are serialized.
unsafe impl Send for EngineInner {}

/// The engine: manifest + lazily-compiled executable cache + PJRT client.
pub struct XlaEngine {
    dir: PathBuf,
    manifest: Manifest,
    inner: Mutex<EngineInner>,
    /// Problems below this many distance pairs use the scalar path.
    threshold: usize,
}

impl XlaEngine {
    /// Load from an artifacts directory (expects `manifest.txt`).
    pub fn load(dir: &Path) -> Result<XlaEngine> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        if manifest.entries.is_empty() {
            bail!("manifest at {} lists no artifacts", dir.display());
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(XlaEngine {
            dir: dir.to_path_buf(),
            manifest,
            inner: Mutex::new(EngineInner { client, cache: HashMap::new() }),
            // see BulkEngine::dispatch_threshold; override via env for
            // experiments or backends with different dispatch overheads
            threshold: std::env::var("MRCORESET_ENGINE_THRESHOLD")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(DEFAULT_DISPATCH_THRESHOLD),
        })
    }

    /// Load from the conventional location (`$MRCORESET_ARTIFACTS` or
    /// `./artifacts`), returning None (with a note) if unavailable —
    /// callers fall back to the scalar path.
    pub fn load_default() -> Option<XlaEngine> {
        let dir = std::env::var("MRCORESET_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        match XlaEngine::load(Path::new(&dir)) {
            Ok(e) => Some(e),
            Err(err) => {
                crate::obs::log::info(&format!(
                    "note: XLA engine unavailable ({err}); using scalar distance path"
                ));
                None
            }
        }
    }

    pub fn set_dispatch_threshold(&mut self, t: usize) {
        self.threshold = t;
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of executables compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.inner.lock().unwrap().cache.len()
    }

    fn execute(&self, entry: &ManifestEntry, args: &[xla::Literal]) -> Result<xla::Literal> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.cache.contains_key(entry) {
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            inner.cache.insert(entry.clone(), exe);
        }
        let exe = inner.cache.get(entry).unwrap();
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", entry.file))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {}: {e:?}", entry.file))?;
        Ok(lit)
    }

    /// One padded assign_cost dispatch for a chunk that fits a bucket.
    fn assign_chunk(
        &self,
        x: &VectorData,
        c: &VectorData,
        entry: &ManifestEntry,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let (nb, db, kb) = (entry.n, entry.d, entry.k);
        let n = x.n();
        let mut xbuf = vec![0f32; nb * db];
        pad_rows(x, &mut xbuf, db);
        let mut cbuf = vec![0f32; kb * db];
        pad_rows_value(c, &mut cbuf, db, PAD_CENTER_VALUE);
        let wbuf = vec![0f32; nb]; // weights unused by this caller; zeros keep nu/mu finite
        let xl = literal_f32(&xbuf, &[nb, db])?;
        let cl = literal_f32(&cbuf, &[kb, db])?;
        let wl = literal_f32(&wbuf, &[nb])?;
        let out = self.execute(entry, &[xl, cl, wl])?;
        let (_nu, _mu, dmin, idx) =
            out.to_tuple4().map_err(|e| anyhow!("assign_cost result shape: {e:?}"))?;
        let mut dmin = dmin.to_vec::<f32>().map_err(|e| anyhow!("dmin: {e:?}"))?;
        let mut idx = idx.to_vec::<i32>().map_err(|e| anyhow!("idx: {e:?}"))?;
        dmin.truncate(n);
        idx.truncate(n);
        Ok((dmin, idx))
    }

    fn min_update_chunk(
        &self,
        x: &VectorData,
        c: &VectorData,
        cur: &mut [f32],
        entry: &ManifestEntry,
    ) -> Result<()> {
        let (nb, db) = (entry.n, entry.d);
        let n = x.n();
        let mut xbuf = vec![0f32; nb * db];
        pad_rows(x, &mut xbuf, db);
        let mut cbuf = vec![0f32; db];
        cbuf[..c.d()].copy_from_slice(c.row(0));
        let mut curbuf = vec![f32::INFINITY; nb];
        curbuf[..n].copy_from_slice(cur);
        let xl = literal_f32(&xbuf, &[nb, db])?;
        let cl = literal_f32(&cbuf, &[1, db])?;
        let curl = literal_f32(&curbuf, &[nb])?;
        let out = self.execute(entry, &[xl, cl, curl])?;
        let new_min = out.to_tuple1().map_err(|e| anyhow!("min_update result: {e:?}"))?;
        let v = new_min.to_vec::<f32>().map_err(|e| anyhow!("new_min: {e:?}"))?;
        cur.copy_from_slice(&v[..n]);
        Ok(())
    }
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("literal: {e:?}"))
}

/// Copy `src` rows into a zeroed (rows_b, db) buffer (zero row/dim pad).
fn pad_rows(src: &VectorData, dst: &mut [f32], db: usize) {
    for i in 0..src.n() {
        let row = src.row(i as u32);
        dst[i * db..i * db + src.d()].copy_from_slice(row);
    }
}

/// Pad center rows: real rows keep zero-extended features; absent rows
/// are entirely `value` (so they are far from everything).
fn pad_rows_value(src: &VectorData, dst: &mut [f32], db: usize, value: f32) {
    dst.fill(value);
    for i in 0..src.n() {
        let row = src.row(i as u32);
        dst[i * db..i * db + src.d()].copy_from_slice(row);
        dst[i * db + src.d()..(i + 1) * db].fill(0.0);
    }
}

impl BulkEngine for XlaEngine {
    fn assign_block(&self, x: &VectorData, c: &VectorData) -> Result<(Vec<f32>, Vec<i32>)> {
        assert_eq!(x.d(), c.d());
        // center-chunking: if k exceeds every bucket, assign against
        // center chunks and merge the argmins.
        let max_k = self
            .manifest
            .entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::AssignCost && e.d >= x.d())
            .map(|e| e.k)
            .max()
            .ok_or_else(|| anyhow!("no assign_cost bucket for d={}", x.d()))?;
        if c.n() > max_k {
            let mut best_d: Vec<f32> = vec![f32::INFINITY; x.n()];
            let mut best_i: Vec<i32> = vec![0; x.n()];
            let mut base = 0usize;
            while base < c.n() {
                let hi = (base + max_k).min(c.n());
                let ids: Vec<u32> = (base as u32..hi as u32).collect();
                let sub = c.gather(&ids);
                let (d, i) = self.assign_block(x, &sub)?;
                for r in 0..x.n() {
                    if d[r] < best_d[r] {
                        best_d[r] = d[r];
                        best_i[r] = i[r] + base as i32;
                    }
                }
                base = hi;
            }
            return Ok((best_d, best_i));
        }
        let entry = self
            .manifest
            .pick(ArtifactKind::AssignCost, x.n(), x.d(), c.n())
            .or_else(|| self.manifest.pick_chunked(ArtifactKind::AssignCost, x.d(), c.n()))
            .ok_or_else(|| anyhow!("no assign_cost bucket for d={} k={}", x.d(), c.n()))?;
        if x.n() <= entry.n {
            return self.assign_chunk(x, c, &entry);
        }
        // chunk over n
        let mut dmin = Vec::with_capacity(x.n());
        let mut idx = Vec::with_capacity(x.n());
        let chunk = entry.n;
        let mut row = 0usize;
        while row < x.n() {
            let hi = (row + chunk).min(x.n());
            let ids: Vec<u32> = (row as u32..hi as u32).collect();
            let sub = x.gather(&ids);
            let (d, i) = self.assign_chunk(&sub, c, &entry)?;
            dmin.extend(d);
            idx.extend(i);
            row = hi;
        }
        Ok((dmin, idx))
    }

    fn min_update_block(&self, x: &VectorData, c: &VectorData, cur: &mut [f32]) -> Result<()> {
        assert_eq!(x.d(), c.d());
        assert_eq!(c.n(), 1);
        assert_eq!(x.n(), cur.len());
        let entry = self
            .manifest
            .pick(ArtifactKind::MinUpdate, x.n(), x.d(), 1)
            .or_else(|| self.manifest.pick_chunked(ArtifactKind::MinUpdate, x.d(), 1))
            .ok_or_else(|| anyhow!("no min_update bucket for d={}", x.d()))?;
        if x.n() <= entry.n {
            return self.min_update_chunk(x, c, cur, &entry);
        }
        let chunk = entry.n;
        let mut row = 0usize;
        while row < x.n() {
            let hi = (row + chunk).min(x.n());
            let ids: Vec<u32> = (row as u32..hi as u32).collect();
            let sub = x.gather(&ids);
            self.min_update_chunk(&sub, c, &mut cur[row..hi], &entry)?;
            row = hi;
        }
        Ok(())
    }

    fn dispatch_threshold(&self) -> usize {
        self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::dense::{sq_euclidean, EuclideanSpace};
    use crate::metric::MetricSpace;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::env::var("MRCORESET_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        let p = PathBuf::from(dir);
        if p.join("manifest.txt").exists() {
            Some(p)
        } else {
            eprintln!("skipping runtime test: no artifacts (run `make artifacts`)");
            None
        }
    }

    fn rand_data(n: usize, d: usize, seed: u64, scale: f64) -> VectorData {
        let mut rng = Rng::new(seed);
        VectorData::new((0..n * d).map(|_| (rng.gaussian() * scale) as f32).collect(), d)
    }

    #[test]
    fn assign_block_matches_scalar() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = XlaEngine::load(&dir).unwrap();
        for (n, d, k) in [(100usize, 3usize, 7usize), (256, 4, 128), (300, 5, 9), (1500, 2, 40)] {
            let x = rand_data(n, d, 1, 10.0);
            let c = rand_data(k, d, 2, 10.0);
            let (dmin, idx) = engine.assign_block(&x, &c).unwrap();
            assert_eq!(dmin.len(), n);
            for i in 0..n {
                let mut best = f64::INFINITY;
                let mut bj = 0;
                for j in 0..k {
                    let dd = sq_euclidean(x.row(i as u32), c.row(j as u32));
                    if dd < best {
                        best = dd;
                        bj = j;
                    }
                }
                assert_eq!(idx[i] as usize, bj, "n={n} d={d} k={k} row {i}");
                let rel = ((dmin[i] as f64) - best).abs() / (1.0 + best);
                assert!(rel < 1e-4, "row {i}: {} vs {best}", dmin[i]);
            }
        }
    }

    #[test]
    fn min_update_matches_scalar() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = XlaEngine::load(&dir).unwrap();
        let (n, d) = (700usize, 6usize);
        let x = rand_data(n, d, 3, 5.0);
        let c = rand_data(1, d, 4, 5.0);
        let mut cur: Vec<f32> = (0..n).map(|i| (i % 50) as f32).collect();
        let want: Vec<f32> = (0..n)
            .map(|i| {
                let dd = sq_euclidean(x.row(i as u32), c.row(0)) as f32;
                dd.min(cur[i])
            })
            .collect();
        engine.min_update_block(&x, &c, &mut cur).unwrap();
        for i in 0..n {
            assert!((cur[i] - want[i]).abs() / (1.0 + want[i]) < 1e-4, "row {i}");
        }
    }

    #[test]
    fn chunking_large_n() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = XlaEngine::load(&dir).unwrap();
        let max_n = engine.manifest().max_n(ArtifactKind::AssignCost);
        let n = max_n + 123;
        let x = rand_data(n, 2, 5, 3.0);
        let c = rand_data(10, 2, 6, 3.0);
        let (dmin, idx) = engine.assign_block(&x, &c).unwrap();
        assert_eq!(dmin.len(), n);
        assert_eq!(idx.len(), n);
        // spot-check the tail (the chunk boundary region)
        for i in (n - 5)..n {
            let mut best = f64::INFINITY;
            let mut bj = 0;
            for j in 0..10 {
                let dd = sq_euclidean(x.row(i as u32), c.row(j as u32));
                if dd < best {
                    best = dd;
                    bj = j;
                }
            }
            assert_eq!(idx[i] as usize, bj);
        }
    }

    #[test]
    fn euclidean_space_with_engine_agrees() {
        let Some(dir) = artifacts_dir() else { return };
        let mut engine = XlaEngine::load(&dir).unwrap();
        engine.set_dispatch_threshold(1); // force the XLA path
        let data = Arc::new(rand_data(600, 4, 7, 8.0));
        let plain = EuclideanSpace::new(data.clone());
        let fast = EuclideanSpace::with_engine(data, Arc::new(engine));
        let pts: Vec<u32> = (0..600).collect();
        let centers: Vec<u32> = (0..20).collect();
        let a = plain.assign(&pts, &centers);
        let b = fast.assign(&pts, &centers);
        // The engine's ||x||²+||c||²−2xc kernel loses ~||x||²·f32eps to
        // cancellation (≈ (8√4)²·1e-7 ≈ 3e-5 on d², i.e. ~6e-3 on a
        // near-zero distance). Compare with that error model.
        for i in 0..600 {
            let d2_tol = 1e-4 * (1.0 + a.dist[i] * a.dist[i]).max(256.0 * 1e-4);
            let diff2 = (a.dist[i] * a.dist[i] - b.dist[i] * b.dist[i]).abs();
            assert!(diff2 <= d2_tol, "row {i}: {} vs {} (diff² {diff2})", a.dist[i], b.dist[i]);
            if a.idx[i] != b.idx[i] {
                // near-tie: both centers must be equidistant within tolerance
                let da = plain.dist(pts[i], centers[a.idx[i] as usize]);
                let db = plain.dist(pts[i], centers[b.idx[i] as usize]);
                assert!((da - db).abs() < 0.05, "row {i}: tie break too far: {da} vs {db}");
            }
        }
    }

    #[test]
    fn missing_artifacts_dir_is_error() {
        assert!(XlaEngine::load(Path::new("/nonexistent/artifacts")).is_err());
    }
}
