//! Artifact manifest parsing and shape-bucket selection.
//!
//! `artifacts/manifest.txt` (written by python/compile/aot.py) lists one
//! artifact per line: `kind n d k file`. The runtime picks, for a real
//! (n, d, k) problem, the smallest bucket with n_b ≥ n, d_b ≥ d, k_b ≥ k
//! (ties broken by padded volume); if no n-bucket is large enough the
//! biggest one is used and the problem is chunked over n.

use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    AssignCost,
    MinUpdate,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<ArtifactKind> {
        match s {
            "assign_cost" => Some(ArtifactKind::AssignCost),
            "min_update" => Some(ArtifactKind::MinUpdate),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ManifestEntry {
    pub kind: ArtifactKind,
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub file: String,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("manifest line {}: want 5 fields, got {}", i + 1, parts.len());
            }
            let Some(kind) = ArtifactKind::parse(parts[0]) else {
                bail!("manifest line {}: unknown kind {}", i + 1, parts[0]);
            };
            entries.push(ManifestEntry {
                kind,
                n: parts[1].parse().context("n")?,
                d: parts[2].parse().context("d")?,
                k: parts[3].parse().context("k")?,
                file: parts[4].to_string(),
            });
        }
        Ok(Manifest { entries })
    }

    /// Smallest bucket covering (n, d, k), by padded volume.
    pub fn pick(&self, kind: ArtifactKind, n: usize, d: usize, k: usize) -> Option<ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.n >= n && e.d >= d && e.k >= k)
            .min_by_key(|e| e.n * e.d * e.k.max(1))
            .cloned()
    }

    /// Largest-n bucket covering (d, k) — used to chunk oversized n.
    pub fn pick_chunked(&self, kind: ArtifactKind, d: usize, k: usize) -> Option<ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.d >= d && e.k >= k)
            .max_by_key(|e| (e.n, std::cmp::Reverse(e.d * e.k.max(1))))
            .cloned()
    }

    pub fn max_n(&self, kind: ArtifactKind) -> usize {
        self.entries.iter().filter(|e| e.kind == kind).map(|e| e.n).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# kind n d k file
assign_cost 256 4 128 assign_cost_256x4x128.hlo.txt
assign_cost 1024 4 128 assign_cost_1024x4x128.hlo.txt
assign_cost 1024 16 512 assign_cost_1024x16x512.hlo.txt
min_update 256 4 1 min_update_256x4.hlo.txt
min_update 1024 16 1 min_update_1024x16.hlo.txt
";

    #[test]
    fn parses_and_skips_comments() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 5);
        assert_eq!(m.entries[0].kind, ArtifactKind::AssignCost);
        assert_eq!(m.entries[0].n, 256);
    }

    #[test]
    fn picks_smallest_covering_bucket() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.pick(ArtifactKind::AssignCost, 200, 3, 100).unwrap();
        assert_eq!((e.n, e.d, e.k), (256, 4, 128));
        let e = m.pick(ArtifactKind::AssignCost, 300, 3, 100).unwrap();
        assert_eq!((e.n, e.d, e.k), (1024, 4, 128));
        let e = m.pick(ArtifactKind::AssignCost, 300, 10, 300).unwrap();
        assert_eq!((e.n, e.d, e.k), (1024, 16, 512));
    }

    #[test]
    fn none_when_not_coverable() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.pick(ArtifactKind::AssignCost, 100, 64, 10).is_none());
        assert!(m.pick(ArtifactKind::AssignCost, 5000, 4, 10).is_none());
    }

    #[test]
    fn chunked_pick_takes_biggest_n() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.pick_chunked(ArtifactKind::AssignCost, 4, 100).unwrap();
        assert_eq!(e.n, 1024);
        assert_eq!(m.max_n(ArtifactKind::AssignCost), 1024);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("assign_cost 1 2 3").is_err());
        assert!(Manifest::parse("bogus 1 2 3 f.txt").is_err());
        assert!(Manifest::parse("assign_cost x 2 3 f.txt").is_err());
    }
}
