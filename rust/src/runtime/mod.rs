//! PJRT runtime: loads the AOT-compiled JAX/Pallas distance kernels
//! (HLO text in `artifacts/`, built by `make artifacts`) and serves them
//! to the Euclidean hot path behind the `BulkEngine` trait.
//!
//! The real engine (`runtime/pjrt.rs`) needs the `xla` PJRT bindings
//! crate, which the offline image does not ship; it is therefore gated
//! behind the `pjrt` cargo feature. The default build uses the stub in
//! `runtime/stub.rs`, which keeps the whole `XlaEngine` API surface —
//! manifest parsing, lazy-failure semantics, threshold plumbing — but
//! reports every dispatch as an error so `EuclideanSpace` falls back to
//! its batched CPU paths. Either way, callers are engine-agnostic.
//!
//! Shape handling (pjrt build): artifacts exist for a fixed bucket grid
//! (manifest.txt); a real (n, d, k) problem is padded up to the smallest
//! covering bucket. Padded x-rows carry weight 0 and their outputs are
//! discarded; padded center slots hold PAD_CENTER_VALUE so they never
//! win an argmin; padded feature dims are zero in both operands (adds 0
//! to every distance). Problems larger than the biggest n-bucket are
//! chunked.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::XlaEngine;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::XlaEngine;

pub use manifest::{ArtifactKind, Manifest, ManifestEntry};

/// Pad coordinate for unused center slots (mirrors model.PAD_CENTER_VALUE).
pub const PAD_CENTER_VALUE: f32 = 3.0e18;
