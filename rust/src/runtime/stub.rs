//! Engine stub for builds without the `pjrt` feature (the offline image
//! has no XLA/PJRT bindings crate). The stub keeps the full `XlaEngine`
//! API surface so callers and the failure-injection tests are
//! feature-agnostic: manifests really parse (corrupt/empty manifests
//! error at `load`, like the real engine), but every kernel dispatch
//! returns an error, which makes `EuclideanSpace` fall back to its
//! batched CPU paths — the documented degradation mode for a broken
//! engine.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::metric::dense::{BulkEngine, DEFAULT_DISPATCH_THRESHOLD};
use crate::points::VectorData;

use super::manifest::Manifest;

const UNAVAILABLE: &str = "PJRT backend unavailable: crate built without the `pjrt` feature";

/// API-compatible stand-in for the PJRT engine.
pub struct XlaEngine {
    manifest: Manifest,
    /// Problems below this many distance pairs use the scalar path.
    threshold: usize,
}

impl XlaEngine {
    /// Load from an artifacts directory (expects `manifest.txt`). Only
    /// the manifest is validated — kernels are "lazily compiled", i.e.
    /// every later dispatch errors out.
    pub fn load(dir: &Path) -> Result<XlaEngine> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        if manifest.entries.is_empty() {
            bail!("manifest at {} lists no artifacts", dir.display());
        }
        // real default threshold (not usize::MAX): a loaded engine is
        // expected to dispatch, and the stub's dispatch error exercises
        // the documented fallback latch on the first big block
        Ok(XlaEngine { manifest, threshold: DEFAULT_DISPATCH_THRESHOLD })
    }

    /// The default engine is never available without the `pjrt` feature
    /// (artifacts may exist on disk, but there is no backend to run
    /// them); callers fall back to the scalar/batched CPU paths.
    pub fn load_default() -> Option<XlaEngine> {
        crate::obs::log::info(
            "note: XLA engine unavailable (built without `pjrt`); using CPU distance paths",
        );
        None
    }

    pub fn set_dispatch_threshold(&mut self, t: usize) {
        self.threshold = t;
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of executables compiled so far (always 0 in the stub).
    pub fn compiled_count(&self) -> usize {
        0
    }
}

impl BulkEngine for XlaEngine {
    fn assign_block(&self, _x: &VectorData, _c: &VectorData) -> Result<(Vec<f32>, Vec<i32>)> {
        bail!("{UNAVAILABLE}")
    }

    fn min_update_block(&self, _x: &VectorData, _c: &VectorData, _cur: &mut [f32]) -> Result<()> {
        bail!("{UNAVAILABLE}")
    }

    fn dispatch_threshold(&self) -> usize {
        self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mrcoreset_stub_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn valid_manifest_loads_but_dispatch_errors() {
        let d = tmpdir("ok");
        std::fs::write(
            d.join("manifest.txt"),
            "assign_cost 256 4 128 a.hlo.txt\nmin_update 256 4 1 m.hlo.txt\n",
        )
        .unwrap();
        let engine = XlaEngine::load(&d).unwrap();
        assert_eq!(engine.manifest().entries.len(), 2);
        assert_eq!(engine.compiled_count(), 0);
        let x = VectorData::new(vec![0.0; 8], 4);
        let c = VectorData::new(vec![0.0; 4], 4);
        let err = engine.assign_block(&x, &c).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn load_default_is_none_without_backend() {
        assert!(XlaEngine::load_default().is_none());
    }
}
