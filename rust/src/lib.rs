//! # mrcoreset
//!
//! Production-quality reproduction of *Accurate MapReduce Algorithms for
//! k-median and k-means in General Metric Spaces* (Mazzetto,
//! Pietracaprina, Pucci, 2019): composable coreset constructions
//! (CoverWithBalls) and 3-round MapReduce (α+O(ε))-approximation
//! algorithms for k-median and k-means, with a thread-backed MapReduce
//! simulator, sequential approximation algorithms, literature baselines,
//! and an XLA/Pallas-accelerated Euclidean distance hot path loaded via
//! PJRT (see `runtime`).
//!
//! Layout follows DESIGN.md: `coreset` + `coordinator` carry the paper's
//! contribution; everything else is substrate.

pub mod algorithms;
pub mod baselines;
pub mod coordinator;
pub mod coreset;
pub mod data;
pub mod eval;
pub mod mapreduce;
pub mod metric;
pub mod obs;
pub mod outliers;
pub mod points;
pub mod runtime;
pub mod util;
