//! The MapReduce coreset pipelines over a pluggable executor.
//!
//! - `one_round_coreset` (§3.1): partition → local coreset per reducer →
//!   union C_w. An α-approximation on C_w yields 2α+O(ε) (discrete) or
//!   α+O(ε) (continuous).
//! - `two_round_coreset` (§3.2 k-median / §3.3 k-means): round 1 as
//!   above; round 2 broadcasts C_w and all R_i to every reducer, which
//!   runs CoverWithBalls(P_ℓ, C_w, R, ·, ·) with the global tolerance
//!
//! ```text
//! R = Σ_i |P_i|·R_i / |P|            (k-median)
//! R = √(Σ_i |P_i|·R_i² / |P|)        (k-means)
//! ```
//!
//!   producing E_w = ∪ E_{w,ℓ}, which is both an O(ε)-bounded coreset
//!   and an O(ε)-centroid set (Lemmas 3.7/3.11) — the property that
//!   removes the factor 2 from the approximation ratio.
//!
//! The pipelines are generic over [`Executor`]: the in-memory backend
//! keeps every partition resident, while the spill backend materialises
//! one shard at a time from disk under a hard byte budget. Either way
//! round outputs come back as a [`Manifest`] and are folded into the
//! running coreset one partition at a time (`WeightedSet::merge`), so
//! the coordinator never holds more than one round-output shard beyond
//! the accumulated union.
//!
//! Item-memory accounting per reducer (charged to the executor's meter):
//! round 1 holds P_ℓ + T_ℓ + C_{w,ℓ}; round 2 holds P_ℓ + C_w (broadcast)
//! + E_{w,ℓ}. Byte accounting for executor-materialised shards is done
//! by the executor itself (see `mapreduce::executor`).

use crate::mapreduce::{partition_reported, ExecError, Executor, Manifest, PartitionStrategy};
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::rng::Rng;

use super::local::{cover_params, local_coreset, LocalCoresetOut, TlAlgo};

/// Configuration shared by the coreset pipelines and the 3-round solver.
#[derive(Clone, Debug)]
pub struct CoresetConfig {
    /// Precision parameter ε ∈ (0,1) (k-means theory additionally wants
    /// ε + ε² ≤ 1/8; larger values still run, with weaker guarantees).
    pub eps: f64,
    /// Assumed approximation factor β of the T_ℓ algorithm (enters the
    /// CoverWithBalls shrink factor ε/2β).
    pub beta: f64,
    /// Number of centers m ≥ k in each T_ℓ (oversampling allowed).
    pub m: usize,
    pub tl: TlAlgo,
    pub seed: u64,
}

impl CoresetConfig {
    pub fn new(k: usize, eps: f64) -> CoresetConfig {
        CoresetConfig { eps, beta: 2.0, m: 2 * k, tl: TlAlgo::DppSeeding, seed: 0x5EED }
    }
}

/// Output of a pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineOutput {
    /// The final coreset (C_w for one-round, E_w for two-round).
    pub coreset: WeightedSet,
    /// Per-partition local tolerance radii R_ℓ (round 1).
    pub radii: Vec<f64>,
    /// Partition sizes |P_ℓ|.
    pub part_sizes: Vec<usize>,
    /// Intermediate C_w size (== coreset for one-round).
    pub cw_size: usize,
    /// Global second-round tolerance R (None for one-round).
    pub global_r: Option<f64>,
}

/// Round 1 of every pipeline (shared with `outliers::pipeline`, which
/// passes its own round name, seed salt, and oversampled m through
/// `cfg`): per-partition local coresets, memory-metered. The reducer
/// index doubles as the partition index ℓ, so RNG streams match the
/// historical `(ℓ, P_ℓ)` tupled inputs bit for bit.
pub(crate) fn run_round1_named<E: Executor>(
    space: &dyn MetricSpace,
    obj: Objective,
    parts: &Manifest<Vec<u32>>,
    cfg: &CoresetConfig,
    exec: &E,
    name: &str,
    seed_salt: u64,
) -> Result<Manifest<LocalCoresetOut>, ExecError> {
    exec.round(name, parts, |ell, pts, meter| {
        meter.charge(pts.len()); // resident partition
        let mut rng = Rng::new(cfg.seed ^ (seed_salt + ell as u64));
        let out = local_coreset(space, obj, pts, cfg.m, cfg.eps, cfg.beta, cfg.tl, &mut rng);
        meter.charge(out.t.len() + out.cover.set.len()); // T_ℓ + C_{w,ℓ}
        meter.release(pts.len() + out.t.len() + out.cover.set.len());
        out
    })
}

fn run_round1<E: Executor>(
    space: &dyn MetricSpace,
    obj: Objective,
    parts: &Manifest<Vec<u32>>,
    cfg: &CoresetConfig,
    exec: &E,
) -> Result<Manifest<LocalCoresetOut>, ExecError> {
    run_round1_named(space, obj, parts, cfg, exec, "coreset-r1-local", 0xA5A5_0000)
}

/// Global tolerance radius R from the per-partition radii (step 1 of
/// round 2): |P_ℓ|-weighted mean for k-median, weighted quadratic mean
/// for k-means. Shared with the outliers pipeline.
pub(crate) fn global_radius(obj: Objective, radii: &[f64], part_sizes: &[usize]) -> f64 {
    let n_total: usize = part_sizes.iter().sum();
    match obj {
        Objective::Median => {
            radii
                .iter()
                .zip(part_sizes)
                .map(|(&r, &s)| r * s as f64)
                .sum::<f64>()
                / n_total as f64
        }
        Objective::Means => (radii
            .iter()
            .zip(part_sizes)
            .map(|(&r, &s)| r * r * s as f64)
            .sum::<f64>()
            / n_total as f64)
            .sqrt(),
    }
}

/// §3.1: 1-round construction, returns C_w.
pub fn one_round_coreset<E: Executor>(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    l: usize,
    strategy: PartitionStrategy,
    cfg: &CoresetConfig,
    exec: &E,
) -> Result<PipelineOutput, ExecError> {
    let parts = partition_reported(pts, l, strategy, "one_round_coreset");
    let part_sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
    let inputs = exec.scatter(parts)?;
    let locals = run_round1(space, obj, &inputs, cfg, exec)?;
    let mut coreset = WeightedSet::default();
    let mut radii = Vec::new();
    locals.for_each(|o| {
        coreset.merge(&o.cover.set);
        radii.push(o.r);
    })?;
    let cw_size = coreset.len();
    Ok(PipelineOutput { coreset, radii, part_sizes, cw_size, global_r: None })
}

/// §3.2 (k-median) / §3.3 (k-means): 2-round construction, returns E_w.
pub fn two_round_coreset<E: Executor>(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    l: usize,
    strategy: PartitionStrategy,
    cfg: &CoresetConfig,
    exec: &E,
) -> Result<PipelineOutput, ExecError> {
    let parts = partition_reported(pts, l, strategy, "two_round_coreset");
    let part_sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
    let inputs = exec.scatter(parts)?;
    let locals = run_round1(space, obj, &inputs, cfg, exec)?;
    let mut radii = Vec::new();
    let mut cw = WeightedSet::default();
    locals.for_each(|o| {
        radii.push(o.r);
        cw.merge(&o.cover.set);
    })?;

    // Global tolerance radius R (step 1 of round 2).
    let global_r = global_radius(obj, &radii, &part_sizes);

    // Round 2: every reducer receives its partition + broadcast C_w + R.
    // The partitions are reread from the round-1 input manifest (for the
    // spill backend that means a second pass over the same shards).
    let (ce, cb) = cover_params(obj, cfg.eps, cfg.beta);
    let cw_ref = &cw;
    let e_parts = exec.round("coreset-r2-refine", &inputs, move |_, pts_l, meter| {
        meter.charge(pts_l.len() + cw_ref.len()); // partition + broadcast C_w
        let res = super::cover::cover_with_balls(space, pts_l, &cw_ref.indices, global_r, ce, cb);
        meter.charge(res.set.len()); // E_{w,ℓ}
        meter.release(pts_l.len() + cw_ref.len() + res.set.len());
        res.set
    })?;
    let mut coreset = WeightedSet::default();
    e_parts.for_each(|s| coreset.merge(s))?;
    Ok(PipelineOutput {
        coreset,
        radii,
        part_sizes,
        cw_size: cw.len(),
        global_r: Some(global_r),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::mapreduce::Simulator;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    fn mixture(n: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let (data, _) =
            GaussianMixtureSpec { n, d: 4, k: 5, seed, ..Default::default() }.generate();
        (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
    }

    #[test]
    fn one_round_composes_partitions() {
        let (space, pts) = mixture(1500, 1);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.5);
        let out = one_round_coreset(
            &space,
            Objective::Median,
            &pts,
            5,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        assert_eq!(out.coreset.total_weight(), 1500);
        assert_eq!(out.radii.len(), 5);
        assert_eq!(sim.take_stats().num_rounds(), 1);
    }

    #[test]
    fn two_round_runs_two_rounds_and_conserves_weight() {
        let (space, pts) = mixture(2000, 2);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.5);
        for obj in [Objective::Median, Objective::Means] {
            let out = two_round_coreset(
                &space,
                obj,
                &pts,
                4,
                PartitionStrategy::RoundRobin,
                &cfg,
                &sim,
            )
            .expect("pipeline");
            assert_eq!(out.coreset.total_weight(), 2000, "{obj}");
            assert!(out.global_r.unwrap() > 0.0);
            let stats = sim.take_stats();
            assert_eq!(stats.num_rounds(), 2, "{obj}");
        }
    }

    #[test]
    fn second_round_refines_first() {
        // E_w is built by covering P with C_w as the reference set, so it
        // should not be dramatically larger than C_w, and must be ≤ n.
        let (space, pts) = mixture(2000, 3);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.4);
        let out = two_round_coreset(
            &space,
            Objective::Median,
            &pts,
            4,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        assert!(out.coreset.len() <= pts.len());
        assert!(out.cw_size > 0);
    }

    #[test]
    fn memory_charged_sublinearly_in_round1() {
        let (data, _) =
            GaussianMixtureSpec { n: 4000, d: 1, k: 5, seed: 4, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..4000).collect();
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.8);
        let _ = two_round_coreset(
            &space,
            Objective::Median,
            &pts,
            8,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        let stats = sim.take_stats();
        // round 1 reducers hold ~n/L + m + |C_ℓ| ≪ n
        assert!(
            stats.rounds[0].max_local_peak < 4000 / 4,
            "round-1 peak {} not sublinear",
            stats.rounds[0].max_local_peak
        );
    }

    #[test]
    fn single_partition_degenerates_gracefully() {
        let (space, pts) = mixture(500, 5);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.5);
        let out = two_round_coreset(
            &space,
            Objective::Means,
            &pts,
            1,
            PartitionStrategy::Contiguous,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        assert_eq!(out.part_sizes, vec![500]);
        assert_eq!(out.coreset.total_weight(), 500);
    }
}
