//! The MapReduce coreset pipelines over the simulator.
//!
//! - `one_round_coreset` (§3.1): partition → local coreset per reducer →
//!   union C_w. An α-approximation on C_w yields 2α+O(ε) (discrete) or
//!   α+O(ε) (continuous).
//! - `two_round_coreset` (§3.2 k-median / §3.3 k-means): round 1 as
//!   above; round 2 broadcasts C_w and all R_i to every reducer, which
//!   runs CoverWithBalls(P_ℓ, C_w, R, ·, ·) with the global tolerance
//!
//! ```text
//! R = Σ_i |P_i|·R_i / |P|            (k-median)
//! R = √(Σ_i |P_i|·R_i² / |P|)        (k-means)
//! ```
//!
//!   producing E_w = ∪ E_{w,ℓ}, which is both an O(ε)-bounded coreset
//!   and an O(ε)-centroid set (Lemmas 3.7/3.11) — the property that
//!   removes the factor 2 from the approximation ratio.
//!
//! Memory accounting per reducer (charged to the simulator's meter):
//! round 1 holds P_ℓ + T_ℓ + C_{w,ℓ}; round 2 holds P_ℓ + C_w (broadcast)
//! + E_{w,ℓ}.

use crate::mapreduce::{partition, PartitionStrategy, Simulator};
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::rng::Rng;

use super::local::{cover_params, local_coreset, LocalCoresetOut, TlAlgo};

/// Configuration shared by the coreset pipelines and the 3-round solver.
#[derive(Clone, Debug)]
pub struct CoresetConfig {
    /// Precision parameter ε ∈ (0,1) (k-means theory additionally wants
    /// ε + ε² ≤ 1/8; larger values still run, with weaker guarantees).
    pub eps: f64,
    /// Assumed approximation factor β of the T_ℓ algorithm (enters the
    /// CoverWithBalls shrink factor ε/2β).
    pub beta: f64,
    /// Number of centers m ≥ k in each T_ℓ (oversampling allowed).
    pub m: usize,
    pub tl: TlAlgo,
    pub seed: u64,
}

impl CoresetConfig {
    pub fn new(k: usize, eps: f64) -> CoresetConfig {
        CoresetConfig { eps, beta: 2.0, m: 2 * k, tl: TlAlgo::DppSeeding, seed: 0x5EED }
    }
}

/// Output of a pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineOutput {
    /// The final coreset (C_w for one-round, E_w for two-round).
    pub coreset: WeightedSet,
    /// Per-partition local tolerance radii R_ℓ (round 1).
    pub radii: Vec<f64>,
    /// Partition sizes |P_ℓ|.
    pub part_sizes: Vec<usize>,
    /// Intermediate C_w size (== coreset for one-round).
    pub cw_size: usize,
    /// Global second-round tolerance R (None for one-round).
    pub global_r: Option<f64>,
}

/// Round 1 of every pipeline (shared with `outliers::pipeline`, which
/// passes its own round name, seed salt, and oversampled m through
/// `cfg`): per-partition local coresets, memory-metered.
pub(crate) fn run_round1_named(
    space: &dyn MetricSpace,
    obj: Objective,
    parts: &[Vec<u32>],
    cfg: &CoresetConfig,
    sim: &Simulator,
    name: &str,
    seed_salt: u64,
) -> Vec<LocalCoresetOut> {
    let inputs: Vec<(usize, Vec<u32>)> = parts.iter().cloned().enumerate().collect();
    sim.round(name, inputs, |_, (ell, pts), meter| {
        meter.charge(pts.len()); // resident partition
        let mut rng = Rng::new(cfg.seed ^ (seed_salt + *ell as u64));
        let out = local_coreset(space, obj, pts, cfg.m, cfg.eps, cfg.beta, cfg.tl, &mut rng);
        meter.charge(out.t.len() + out.cover.set.len()); // T_ℓ + C_{w,ℓ}
        meter.release(pts.len() + out.t.len() + out.cover.set.len());
        out
    })
}

fn run_round1(
    space: &dyn MetricSpace,
    obj: Objective,
    parts: &[Vec<u32>],
    cfg: &CoresetConfig,
    sim: &Simulator,
) -> Vec<LocalCoresetOut> {
    run_round1_named(space, obj, parts, cfg, sim, "coreset-r1-local", 0xA5A5_0000)
}

/// Global tolerance radius R from the per-partition radii (step 1 of
/// round 2): |P_ℓ|-weighted mean for k-median, weighted quadratic mean
/// for k-means. Shared with the outliers pipeline.
pub(crate) fn global_radius(obj: Objective, radii: &[f64], part_sizes: &[usize]) -> f64 {
    let n_total: usize = part_sizes.iter().sum();
    match obj {
        Objective::Median => {
            radii
                .iter()
                .zip(part_sizes)
                .map(|(&r, &s)| r * s as f64)
                .sum::<f64>()
                / n_total as f64
        }
        Objective::Means => (radii
            .iter()
            .zip(part_sizes)
            .map(|(&r, &s)| r * r * s as f64)
            .sum::<f64>()
            / n_total as f64)
            .sqrt(),
    }
}

/// §3.1: 1-round construction, returns C_w.
pub fn one_round_coreset(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    l: usize,
    strategy: PartitionStrategy,
    cfg: &CoresetConfig,
    sim: &Simulator,
) -> PipelineOutput {
    let parts = partition(pts, l, strategy);
    let locals = run_round1(space, obj, &parts, cfg, sim);
    let coreset =
        WeightedSet::union(&locals.iter().map(|o| o.cover.set.clone()).collect::<Vec<_>>());
    let cw_size = coreset.len();
    PipelineOutput {
        coreset,
        radii: locals.iter().map(|o| o.r).collect(),
        part_sizes: parts.iter().map(Vec::len).collect(),
        cw_size,
        global_r: None,
    }
}

/// §3.2 (k-median) / §3.3 (k-means): 2-round construction, returns E_w.
pub fn two_round_coreset(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    l: usize,
    strategy: PartitionStrategy,
    cfg: &CoresetConfig,
    sim: &Simulator,
) -> PipelineOutput {
    let parts = partition(pts, l, strategy);
    let locals = run_round1(space, obj, &parts, cfg, sim);
    let radii: Vec<f64> = locals.iter().map(|o| o.r).collect();
    let part_sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
    let cw = WeightedSet::union(&locals.iter().map(|o| o.cover.set.clone()).collect::<Vec<_>>());

    // Global tolerance radius R (step 1 of round 2).
    let global_r = global_radius(obj, &radii, &part_sizes);

    // Round 2: every reducer receives its partition + broadcast C_w + R.
    let (ce, cb) = cover_params(obj, cfg.eps, cfg.beta);
    let cw_ref = &cw;
    let inputs: Vec<Vec<u32>> = parts;
    let e_parts = sim.round("coreset-r2-refine", inputs, move |_, pts_l, meter| {
        meter.charge(pts_l.len() + cw_ref.len()); // partition + broadcast C_w
        let res = super::cover::cover_with_balls(space, pts_l, &cw_ref.indices, global_r, ce, cb);
        meter.charge(res.set.len()); // E_{w,ℓ}
        meter.release(pts_l.len() + cw_ref.len() + res.set.len());
        res.set
    });
    let coreset = WeightedSet::union(&e_parts);
    PipelineOutput {
        coreset,
        radii,
        part_sizes,
        cw_size: cw.len(),
        global_r: Some(global_r),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    fn mixture(n: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let (data, _) =
            GaussianMixtureSpec { n, d: 4, k: 5, seed, ..Default::default() }.generate();
        (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
    }

    #[test]
    fn one_round_composes_partitions() {
        let (space, pts) = mixture(1500, 1);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.5);
        let out = one_round_coreset(
            &space,
            Objective::Median,
            &pts,
            5,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        );
        assert_eq!(out.coreset.total_weight(), 1500);
        assert_eq!(out.radii.len(), 5);
        assert_eq!(sim.take_stats().num_rounds(), 1);
    }

    #[test]
    fn two_round_runs_two_rounds_and_conserves_weight() {
        let (space, pts) = mixture(2000, 2);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.5);
        for obj in [Objective::Median, Objective::Means] {
            let out = two_round_coreset(
                &space,
                obj,
                &pts,
                4,
                PartitionStrategy::RoundRobin,
                &cfg,
                &sim,
            );
            assert_eq!(out.coreset.total_weight(), 2000, "{obj}");
            assert!(out.global_r.unwrap() > 0.0);
            let stats = sim.take_stats();
            assert_eq!(stats.num_rounds(), 2, "{obj}");
        }
    }

    #[test]
    fn second_round_refines_first() {
        // E_w is built by covering P with C_w as the reference set, so it
        // should not be dramatically larger than C_w, and must be ≤ n.
        let (space, pts) = mixture(2000, 3);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.4);
        let out = two_round_coreset(
            &space,
            Objective::Median,
            &pts,
            4,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        );
        assert!(out.coreset.len() <= pts.len());
        assert!(out.cw_size > 0);
    }

    #[test]
    fn memory_charged_sublinearly_in_round1() {
        let (data, _) =
            GaussianMixtureSpec { n: 4000, d: 1, k: 5, seed: 4, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..4000).collect();
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.8);
        let _ = two_round_coreset(
            &space,
            Objective::Median,
            &pts,
            8,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        );
        let stats = sim.take_stats();
        // round 1 reducers hold ~n/L + m + |C_ℓ| ≪ n
        assert!(
            stats.rounds[0].max_local_peak < 4000 / 4,
            "round-1 peak {} not sublinear",
            stats.rounds[0].max_local_peak
        );
    }

    #[test]
    fn single_partition_degenerates_gracefully() {
        let (space, pts) = mixture(500, 5);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(5, 0.5);
        let out = two_round_coreset(
            &space,
            Objective::Means,
            &pts,
            1,
            PartitionStrategy::Contiguous,
            &cfg,
            &sim,
        );
        assert_eq!(out.part_sizes, vec![500]);
        assert_eq!(out.coreset.total_weight(), 500);
    }
}
