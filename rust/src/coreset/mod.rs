//! Coreset constructions (paper §3) — the paper's primary contribution.
//!
//! - `cover`: CoverWithBalls (Algorithm 1), the selection primitive.
//! - `local`: the per-partition construction shared by all algorithms
//!   (steps 1–3 of §3.1/§3.2/§3.3 first rounds, both objectives).
//! - `pipeline`: the 1-round (§3.1) and 2-round (§3.2 k-median, §3.3
//!   k-means) MapReduce coreset constructions over the simulator.

pub mod cover;
pub mod kcenter;
pub mod local;
pub mod pipeline;

pub use cover::{
    cover_with_balls, cover_with_balls_weighted, cover_with_balls_weighted_unpruned, CoverResult,
};
pub use kcenter::{solve_kcenter, KCenterReport};
pub use local::{local_coreset, LocalCoresetOut, TlAlgo};
pub use pipeline::{one_round_coreset, two_round_coreset, CoresetConfig, PipelineOutput};
