//! CoverWithBalls (paper Algorithm 1) — the core selection procedure.
//!
//! Given points P, a rough center set T, a tolerance radius R, and
//! parameters (ε, β), greedily selects a weighted subset C_w ⊆ P such
//! that every x ∈ P has a representative τ(x) ∈ C_w with
//!
//! ```text
//! d(x, τ(x)) ≤ ε/(2β) · max{R, d(x, T)}          (Lemma 3.1)
//! ```
//!
//! and w(c) = |τ⁻¹(c)| (Definition 2.3). For doubling dimension D the
//! output size is ≤ |T| · (16β/ε)^D · (log₂ c + 2) where c·R bounds
//! max d(x, T) (Theorem 3.3).
//!
//! The greedy loop picks an arbitrary remaining point (we take the first
//! by index — the theory allows any order), adds it as a representative,
//! and discards every remaining point within its shrunken radius. The
//! hot spot is the per-iteration distance scan of remaining points
//! against the new representative — a single `dist_batch` bulk query,
//! which on the Euclidean fast path runs the staged-center scan (or the
//! XLA min_update kernel for engine-dispatched block sizes).

use crate::metric::MetricSpace;
use crate::points::WeightedSet;

/// Result of CoverWithBalls: the weighted cover + the map τ.
#[derive(Clone, Debug)]
pub struct CoverResult {
    /// C_w: selected representatives (global indices) with weights.
    pub set: WeightedSet,
    /// τ as positions: `tau[i]` is the index INTO `set.indices` of the
    /// representative of input point `pts[i]`.
    pub tau: Vec<u32>,
    /// d(x, T) computed during the run (reused by callers for bounds).
    pub dist_to_t: Vec<f64>,
}

impl CoverResult {
    /// Σ_x d(x, τ(x)) — the bounded-coreset quantity of Definition 2.3.
    pub fn proximity_sum(&self, space: &dyn MetricSpace, pts: &[u32]) -> f64 {
        pts.iter()
            .zip(&self.tau)
            .map(|(&x, &t)| space.dist(x, self.set.indices[t as usize]))
            .sum()
    }

    /// Σ_x d(x, τ(x))² — same, k-means flavour.
    pub fn proximity_sum_sq(&self, space: &dyn MetricSpace, pts: &[u32]) -> f64 {
        pts.iter()
            .zip(&self.tau)
            .map(|(&x, &t)| {
                let d = space.dist(x, self.set.indices[t as usize]);
                d * d
            })
            .sum()
    }
}

/// CoverWithBalls(P, T, R, ε, β). `pts` and `t` hold global point
/// indices; `t` need not be a subset of `pts`. Requires 0 < ε < 1, β ≥ 1
/// in the paper; we accept any positive values (the k-means construction
/// passes ε·√2 and √β).
pub fn cover_with_balls(
    space: &dyn MetricSpace,
    pts: &[u32],
    t: &[u32],
    r: f64,
    eps: f64,
    beta: f64,
) -> CoverResult {
    cover_with_balls_weighted(space, pts, None, t, r, eps, beta)
}

/// Weighted-instance CoverWithBalls (the paper's §2 note that all
/// constructions extend to weighted instances): representative weights
/// become `w(c) = Σ_{y: τ(y)=c} w_in(y)` — the natural generalization of
/// Definition 2.3, exactly equivalent to running the unweighted
/// algorithm on the multiset with each point replicated w_in times
/// (replicas sit at distance 0 and are absorbed with their original).
pub fn cover_with_balls_weighted(
    space: &dyn MetricSpace,
    pts: &[u32],
    in_weights: Option<&[u64]>,
    t: &[u32],
    r: f64,
    eps: f64,
    beta: f64,
) -> CoverResult {
    assert!(!pts.is_empty(), "CoverWithBalls: empty P");
    assert!(!t.is_empty(), "CoverWithBalls: empty T");
    assert!(eps > 0.0 && beta > 0.0 && r >= 0.0);
    let n = pts.len();
    if let Some(w) = in_weights {
        assert_eq!(w.len(), n, "weights/pts arity mismatch");
    }
    let shrink = eps / (2.0 * beta);

    // d(x, T) once, up front (bulk path).
    let dist_to_t = space.assign(pts, t).dist;
    // per-point removal threshold: shrink * max(R, d(x, T))
    let threshold: Vec<f64> = dist_to_t.iter().map(|&d| shrink * d.max(r)).collect();

    let mut alive: Vec<u32> = (0..n as u32).collect(); // positions into pts
    let mut tau = vec![u32::MAX; n];
    let mut centers: Vec<u32> = Vec::new();
    let mut weights: Vec<u64> = Vec::new();
    let mut dist_buf: Vec<f64> = Vec::new();

    while !alive.is_empty() {
        // arbitrary remaining point: smallest position (deterministic)
        let cpos = alive[0] as usize;
        let c = pts[cpos];
        let cidx = centers.len() as u32;
        centers.push(c);

        // distances of remaining points to the new representative
        // (one bulk query per greedy iteration)
        dist_buf.clear();
        dist_buf.resize(alive.len(), 0.0);
        let alive_pts: Vec<u32> = alive.iter().map(|&pos| pts[pos as usize]).collect();
        space.dist_batch(&alive_pts, c, &mut dist_buf);

        // partition alive into kept / removed; removed map to this center.
        // The selected point always removes itself, independent of the
        // computed distance: the engine's norm-expansion kernel can report
        // d(c,c) ≈ 1e-2 instead of 0, which must not leave c alive.
        let mut kept: Vec<u32> = Vec::with_capacity(alive.len());
        let mut w: u64 = 0;
        for (ai, &pos) in alive.iter().enumerate() {
            if pos as usize == cpos || dist_buf[ai] <= threshold[pos as usize] {
                tau[pos as usize] = cidx;
                w += in_weights.map_or(1, |ws| ws[pos as usize]);
            } else {
                kept.push(pos);
            }
        }
        debug_assert!(w >= 1, "the new representative must remove itself");
        weights.push(w);
        alive = kept;
    }

    CoverResult { set: WeightedSet::new(centers, weights), tau, dist_to_t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use crate::metric::Objective;
    use crate::points::VectorData;
    use std::sync::Arc;

    fn mixture(n: usize, d: usize, k: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let (data, _) = GaussianMixtureSpec { n, d, k, seed, ..Default::default() }.generate();
        (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
    }

    /// Lemma 3.1: every point's representative is within
    /// ε/(2β)·max{R, d(x,T)}.
    #[test]
    fn per_point_guarantee_holds() {
        let (space, pts) = mixture(800, 4, 6, 1);
        let t: Vec<u32> = (0..6).map(|i| i * 133).collect();
        let a = space.assign(&pts, &t);
        let r = a.dist.iter().sum::<f64>() / pts.len() as f64;
        for (eps, beta) in [(0.5, 1.0), (0.25, 4.0), (0.9, 2.0)] {
            let res = cover_with_balls(&space, &pts, &t, r, eps, beta);
            let shrink = eps / (2.0 * beta);
            for (i, &x) in pts.iter().enumerate() {
                let rep = res.set.indices[res.tau[i] as usize];
                let d = space.dist(x, rep);
                let bound = shrink * res.dist_to_t[i].max(r);
                assert!(
                    d <= bound + 1e-9,
                    "eps={eps} beta={beta} point {i}: d={d} > bound={bound}"
                );
            }
        }
    }

    /// Definition 2.3: weights are exactly the preimage sizes of τ and
    /// sum to |P|.
    #[test]
    fn weights_are_preimage_sizes() {
        let (space, pts) = mixture(500, 3, 4, 2);
        let t = vec![0u32, 100, 200, 300];
        let res = cover_with_balls(&space, &pts, &t, 1.0, 0.5, 2.0);
        assert_eq!(res.set.total_weight(), pts.len() as u64);
        let mut counts = vec![0u64; res.set.len()];
        for &ti in &res.tau {
            counts[ti as usize] += 1;
        }
        assert_eq!(counts, res.set.weights);
    }

    /// Representatives map to themselves (they remove themselves).
    #[test]
    fn centers_self_map() {
        let (space, pts) = mixture(300, 2, 3, 3);
        let t = vec![0u32, 150];
        let res = cover_with_balls(&space, &pts, &t, 0.5, 0.5, 1.0);
        for (ci, &c) in res.set.indices.iter().enumerate() {
            let pos = pts.iter().position(|&p| p == c).unwrap();
            assert_eq!(res.tau[pos] as usize, ci, "center {c} maps elsewhere");
        }
    }

    /// Smaller ε ⇒ finer cover ⇒ more representatives.
    #[test]
    fn size_monotone_in_eps() {
        let (space, pts) = mixture(1000, 4, 5, 4);
        let t: Vec<u32> = (0..5).map(|i| i * 200).collect();
        let a = space.assign(&pts, &t);
        let r = a.dist.iter().sum::<f64>() / pts.len() as f64;
        let big = cover_with_balls(&space, &pts, &t, r, 0.8, 1.0).set.len();
        let small = cover_with_balls(&space, &pts, &t, r, 0.2, 1.0).set.len();
        assert!(small >= big, "eps 0.2 gave {small} < eps 0.8 gave {big}");
    }

    /// Theorem 3.3 size bound (loose check on a low-dimensional set).
    #[test]
    fn size_bound_respected() {
        let (space, pts) = mixture(2000, 2, 4, 5);
        let t: Vec<u32> = (0..4).map(|i| i * 500).collect();
        let a = space.assign(&pts, &t);
        let r = a.dist.iter().sum::<f64>() / pts.len() as f64;
        let cmax = a.dist.iter().cloned().fold(0.0, f64::max) / r;
        let (eps, beta) = (0.5, 1.0);
        let res = cover_with_balls(&space, &pts, &t, r, eps, beta);
        // D=2 for planar data: bound |T|·(16β/ε)^D·(log2 c + 2)
        let bound = 4.0 * (16.0 * beta / eps).powi(2) * (cmax.log2() + 2.0);
        assert!(
            (res.set.len() as f64) <= bound,
            "size {} exceeds Theorem 3.3 bound {bound}",
            res.set.len()
        );
    }

    /// τ is total and proximity sums are finite and consistent.
    #[test]
    fn tau_total_and_proximity() {
        let (space, pts) = mixture(400, 3, 3, 6);
        let t = vec![5u32, 205];
        let res = cover_with_balls(&space, &pts, &t, 2.0, 0.4, 2.0);
        assert!(res.tau.iter().all(|&t| t != u32::MAX));
        let s1 = res.proximity_sum(&space, &pts);
        let s2 = res.proximity_sum_sq(&space, &pts);
        assert!(s1.is_finite() && s2.is_finite());
        assert!(s1 >= 0.0 && s2 >= 0.0);
        // Cauchy-Schwarz sanity: s1² ≤ n·s2
        assert!(s1 * s1 <= pts.len() as f64 * s2 + 1e-6);
    }

    /// Degenerate inputs: all-duplicate points collapse to one center;
    /// P = single point works.
    #[test]
    fn degenerate_inputs() {
        let v = VectorData::from_rows(&vec![vec![2.0f32, 2.0]; 40]);
        let space = EuclideanSpace::new(Arc::new(v));
        let pts: Vec<u32> = (0..40).collect();
        let res = cover_with_balls(&space, &pts, &[0], 1.0, 0.5, 1.0);
        assert_eq!(res.set.len(), 1);
        assert_eq!(res.set.weights[0], 40);

        let res1 = cover_with_balls(&space, &pts[..1], &[0], 0.0, 0.5, 1.0);
        assert_eq!(res1.set.len(), 1);
    }

    /// R = 0 forces exact-match removal only for points at distance 0
    /// from their representative when also d(x,T)=0.
    #[test]
    fn zero_radius_keeps_distinct_points() {
        let v = VectorData::from_rows(&[vec![0.0f32], vec![1.0], vec![2.0]]);
        let space = EuclideanSpace::new(Arc::new(v));
        let pts = vec![0u32, 1, 2];
        let res = cover_with_balls(&space, &pts, &[0], 0.0, 0.5, 1.0);
        // thresholds: shrink*max(0, d(x,T)) = 0.25*d(x,0): removal radius
        // around each selected center is small, distinct points survive
        assert_eq!(res.set.len(), 3);
        let _ = Objective::Median; // silence unused import in some cfgs
    }
}

#[cfg(test)]
mod weighted_tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use crate::points::VectorData;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// The defining equivalence: weighted CoverWithBalls == unweighted
    /// CoverWithBalls on the replicated multiset (replicas adjacent).
    #[test]
    fn weighted_equals_replicated() {
        let mut rng = Rng::new(42);
        let (base, _) =
            GaussianMixtureSpec { n: 120, d: 2, k: 3, seed: 9, ..Default::default() }.generate();
        let weights: Vec<u64> = (0..120).map(|_| 1 + rng.below(4) as u64).collect();
        // replicated multiset, replicas adjacent, remembering origin
        let mut rep_rows = Vec::new();
        let mut origin = Vec::new();
        for i in 0..120usize {
            for _ in 0..weights[i] {
                rep_rows.push(base.row(i as u32).to_vec());
                origin.push(i);
            }
        }
        let rep_data = VectorData::from_rows(&rep_rows);
        let sw = EuclideanSpace::new(Arc::new(base));
        let sr = EuclideanSpace::new(Arc::new(rep_data));
        let pts_w: Vec<u32> = (0..120).collect();
        let pts_r: Vec<u32> = (0..origin.len() as u32).collect();
        let t_w = vec![0u32, 40, 80];
        let t_r: Vec<u32> = t_w
            .iter()
            .map(|&tw| origin.iter().position(|&o| o == tw as usize).unwrap() as u32)
            .collect();

        let a = cover_with_balls_weighted(&sw, &pts_w, Some(&weights), &t_w, 1.0, 0.5, 2.0);
        let b = cover_with_balls(&sr, &pts_r, &t_r, 1.0, 0.5, 2.0);
        // same number of representatives at the same coordinates with the
        // same weights (replicas collapse onto their originals)
        assert_eq!(a.set.len(), b.set.len());
        for (ci, (&ca, &wa)) in a.set.indices.iter().zip(&a.set.weights).enumerate() {
            let cb = b.set.indices[ci];
            assert_eq!(origin[cb as usize], ca as usize, "center {ci} differs");
            assert_eq!(b.set.weights[ci], wa, "weight {ci} differs");
        }
        assert_eq!(a.set.total_weight(), weights.iter().sum::<u64>());
    }

    #[test]
    fn weighted_total_is_input_weight() {
        let (base, _) =
            GaussianMixtureSpec { n: 300, d: 2, k: 4, seed: 10, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(base));
        let pts: Vec<u32> = (0..300).collect();
        let weights: Vec<u64> = (0..300).map(|i| 1 + (i % 7) as u64).collect();
        let res =
            cover_with_balls_weighted(&space, &pts, Some(&weights), &[0, 150], 1.0, 0.6, 2.0);
        assert_eq!(res.set.total_weight(), weights.iter().sum::<u64>());
    }
}
