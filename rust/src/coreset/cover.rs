//! CoverWithBalls (paper Algorithm 1) — the core selection procedure.
//!
//! Given points P, a rough center set T, a tolerance radius R, and
//! parameters (ε, β), greedily selects a weighted subset C_w ⊆ P such
//! that every x ∈ P has a representative τ(x) ∈ C_w with
//!
//! ```text
//! d(x, τ(x)) ≤ ε/(2β) · max{R, d(x, T)}          (Lemma 3.1)
//! ```
//!
//! and w(c) = |τ⁻¹(c)| (Definition 2.3). For doubling dimension D the
//! output size is ≤ |T| · (16β/ε)^D · (log₂ c + 2) where c·R bounds
//! max d(x, T) (Theorem 3.3).
//!
//! The greedy loop picks an arbitrary remaining point (we take the first
//! by index — the theory allows any order), adds it as a representative,
//! and discards every remaining point within its shrunken radius.
//!
//! # Geometry pruning
//!
//! The naive loop re-scans every alive point per greedy iteration —
//! O(|C_w| · |P|) distance evaluations. The production path
//! ([`cover_with_balls_weighted`]) prunes that scan with triangle-
//! inequality bounds over distances it already holds:
//!
//! - every point x knows d(x, t_{j(x)}) to its nearest T-center (the
//!   up-front `assign` pass that also yields the thresholds);
//! - each new representative c computes d(c, t_j) for all j — |T| evals
//!   via one `dist_batch`;
//! - then d(x, c) ≥ |d(x, t_{j(x)}) − d(c, t_{j(x)})|, so x can only be
//!   removed (d(x,c) ≤ threshold[x]) if that bound admits it. Alive
//!   points are bucketed by nearest T-center: a whole bucket is skipped
//!   when d(c, t_j) falls outside [min_x(d(x,t_j) − threshold[x]),
//!   max_x(d(x,t_j) + threshold[x])], and within an admitted bucket the
//!   per-point bound is enforced by `MetricSpace::dist_batch_pruned`,
//!   which charges `metric::counter` only for distances actually
//!   computed (the counter contract: skipped pairs are work that never
//!   happened).
//!
//! Spaces that cannot guarantee bound-grade precision
//! (`MetricSpace::uniform_precision` reports false — the
//! engine-attached Euclidean path, the ill-conditioned angular metric)
//! take the unpruned reference path unchanged.
//!
//! Pruning only skips evaluations whose comparison against the threshold
//! the bound has already decided, so the output (representatives, τ,
//! weights) is bit-identical to the unpruned reference
//! ([`cover_with_balls_weighted_unpruned`]) — pinned by
//! `tests/prop_pruned_equivalence.rs` across Euclidean, Manhattan, and
//! Levenshtein spaces. Measured on the e2-style Gaussian-mixture
//! workload (20k points, d=4, |T|=16, ε=0.5, β=2) the pruned path
//! issues ~10-30× fewer distance evaluations (`cargo bench -- micro`
//! writes the current numbers to `BENCH_pruning.json`).
//!
//! # Threshold monotonicity
//!
//! Both paths rely on the per-point removal threshold being the *fixed*
//! monotone map x ↦ ε/(2β) · max{R, d(x, T)} for the whole run: fixed,
//! because bucket bounds and τ-decisions are made against thresholds
//! computed once up front; monotone non-decreasing in d(x, T), because
//! Lemma 3.1/Theorem 3.3 price each removal against the removed point's
//! own d(x, T). The constructor derives thresholds internally from that
//! formula and debug-asserts the monotone relation as an internal-
//! consistency check.

use crate::metric::MetricSpace;
use crate::obs::counters as obs;
use crate::points::WeightedSet;

/// Result of CoverWithBalls: the weighted cover + the map τ.
#[derive(Clone, Debug)]
pub struct CoverResult {
    /// C_w: selected representatives (global indices) with weights.
    pub set: WeightedSet,
    /// τ as positions: `tau[i]` is the index INTO `set.indices` of the
    /// representative of input point `pts[i]`.
    pub tau: Vec<u32>,
    /// d(x, T) computed during the run (reused by callers for bounds).
    pub dist_to_t: Vec<f64>,
}

impl CoverResult {
    /// Σ_x d(x, τ(x)) — the bounded-coreset quantity of Definition 2.3.
    pub fn proximity_sum(&self, space: &dyn MetricSpace, pts: &[u32]) -> f64 {
        pts.iter()
            .zip(&self.tau)
            .map(|(&x, &t)| space.dist(x, self.set.indices[t as usize]))
            .sum()
    }

    /// Σ_x d(x, τ(x))² — same, k-means flavour.
    pub fn proximity_sum_sq(&self, space: &dyn MetricSpace, pts: &[u32]) -> f64 {
        pts.iter()
            .zip(&self.tau)
            .map(|(&x, &t)| {
                let d = space.dist(x, self.set.indices[t as usize]);
                d * d
            })
            .sum()
    }
}

/// CoverWithBalls(P, T, R, ε, β). `pts` and `t` hold global point
/// indices; `t` need not be a subset of `pts`. Requires 0 < ε < 1, β ≥ 1
/// in the paper; we accept any positive values (the k-means construction
/// passes ε·√2 and √β).
pub fn cover_with_balls(
    space: &dyn MetricSpace,
    pts: &[u32],
    t: &[u32],
    r: f64,
    eps: f64,
    beta: f64,
) -> CoverResult {
    cover_with_balls_weighted(space, pts, None, t, r, eps, beta)
}

/// Weighted-instance CoverWithBalls (the paper's §2 note that all
/// constructions extend to weighted instances): representative weights
/// become `w(c) = Σ_{y: τ(y)=c} w_in(y)` — the natural generalization of
/// Definition 2.3, exactly equivalent to running the unweighted
/// algorithm on the multiset with each point replicated w_in times
/// (replicas sit at distance 0 and are absorbed with their original).
pub fn cover_with_balls_weighted(
    space: &dyn MetricSpace,
    pts: &[u32],
    in_weights: Option<&[u64]>,
    t: &[u32],
    r: f64,
    eps: f64,
    beta: f64,
) -> CoverResult {
    if !space.uniform_precision() {
        // Bulk distances not precise enough to back the pruning bounds
        // (engine-attached Euclidean mixes f32/f64 by block size; the
        // angular metric is ill-conditioned near 0). The reference loop
        // preserves the historical behavior — including the engine's
        // large-block dispatch — exactly.
        return cover_with_balls_weighted_unpruned(space, pts, in_weights, t, r, eps, beta);
    }
    let setup = CoverSetup::new(space, pts, in_weights, t, r, eps, beta);
    let n = pts.len();
    let mut tau = vec![u32::MAX; n];
    let mut centers: Vec<u32> = Vec::new();
    let mut weights: Vec<u64> = Vec::new();

    // Alive points bucketed by nearest T-center. Each bucket keeps its
    // positions (into `pts`) in ascending order; `head` marks consumed
    // representatives, survivors are `pos[head..]`. `lo`/`hi` bound
    // d(x, t_j) ∓ threshold[x] over the bucket's alive points — stale
    // (too-wide) bounds after a head pop are conservative and get
    // tightened at the next compaction.
    struct Bucket {
        pos: Vec<u32>,
        head: usize,
        lo: f64,
        hi: f64,
    }
    let mut buckets: Vec<Bucket> = (0..t.len())
        .map(|_| Bucket { pos: Vec::new(), head: 0, lo: f64::INFINITY, hi: f64::NEG_INFINITY })
        .collect();
    for (pos, &j) in setup.nearest_t.iter().enumerate() {
        let b = &mut buckets[j as usize];
        b.pos.push(pos as u32);
        b.lo = b.lo.min(setup.dist_to_t[pos] - setup.threshold[pos]);
        b.hi = b.hi.max(setup.dist_to_t[pos] + setup.threshold[pos]);
    }
    let mut alive_count = n;

    // Rounding margin: the triangle inequality holds for the true
    // metric, but the bound is assembled from floating-point distances,
    // so shave a relative hair off it before letting it veto an
    // evaluation. 1e-12 dwarfs the ~1e-15 accumulation error of every
    // in-tree metric that reports uniform precision, while only
    // admitting a negligible number of extra evaluations at
    // exact-threshold boundaries — pruning stays exact, never
    // clairvoyant. Spaces that cannot honor this error budget
    // report `uniform_precision() == false` and took the reference path
    // above.
    const LB_MARGIN: f64 = 1e-12;

    // Adaptive escape hatch: on data where the bounds decide nothing
    // (tightly overlapping clusters, or a metric whose default
    // `dist_batch_pruned` computes whole admitted buckets), the cached
    // d(c, T) rows would otherwise accumulate into a real regression
    // over the unpruned loop. Track what the unpruned reference would
    // have paid; once the pruned ledger falls behind by more than a
    // startup slack, stop consulting bounds — every later iteration
    // then computes exactly the alive scan the reference would, keeping
    // the total overhead bounded by the slack. The switch depends only
    // on deterministic counts, and both modes make identical removal
    // comparisons, so outputs are unaffected.
    let mut pruned_evals: u64 = 0;
    let mut baseline_evals: u64 = 0;
    let mut bounds_paying = true;
    let mut bucket_vetoes: u64 = 0;
    let give_up_slack = 16 * t.len() as u64 + n as u64;

    // Reused scratch for the per-bucket pruned batch.
    let mut dct = vec![0.0f64; t.len()]; // d(c, t_j) for the current rep
    let mut scr_pts: Vec<u32> = Vec::new();
    let mut scr_lower: Vec<f64> = Vec::new();
    let mut scr_cut: Vec<f64> = Vec::new();
    let mut scr_out: Vec<f64> = Vec::new();

    while alive_count > 0 {
        // Same selection rule as the reference: the smallest remaining
        // position overall (= the minimum over bucket heads).
        let mut cpos = u32::MAX;
        let mut jc = usize::MAX;
        for (j, b) in buckets.iter().enumerate() {
            if b.head < b.pos.len() && b.pos[b.head] < cpos {
                cpos = b.pos[b.head];
                jc = j;
            }
        }
        let cpos = cpos as usize;
        let c = pts[cpos];
        let cidx = centers.len() as u32;
        centers.push(c);
        // what the unpruned reference pays this iteration: one full scan
        // of the alive list (representative included)
        baseline_evals += alive_count as u64;
        // The representative removes itself unconditionally (the engine's
        // norm-expansion kernel can report d(c,c) ≈ 1e-2 instead of 0,
        // which must not leave c alive).
        tau[cpos] = cidx;
        let mut w: u64 = setup.weight_of(cpos);
        buckets[jc].head += 1;
        alive_count -= 1;

        // Cache d(c, t_j) once per representative: |T| evaluations buy
        // a lower bound on d(x, c) for every alive point. When |T| has
        // caught up with the alive count (late iterations, or round 2's
        // cover against a large C_w), the cache costs more than the scan
        // it prunes — fall back to computing every alive distance, which
        // bounds the pruned path's per-iteration evals by the unpruned
        // path's. Either branch makes the identical removal comparisons.
        let use_bounds = bounds_paying && t.len() < alive_count;
        if use_bounds {
            space.dist_batch(t, c, &mut dct);
            pruned_evals += t.len() as u64;
        }

        for (j, b) in buckets.iter_mut().enumerate() {
            if b.head >= b.pos.len() {
                continue;
            }
            let dcj = dct[j];
            if use_bounds {
                // Bucket-level bound: no x in this bucket can satisfy
                // d(x,c) ≤ threshold[x] unless d(c,t_j) lies within the
                // bucket's [lo, hi] interval (widened by the margin).
                let slack = LB_MARGIN * (dcj + b.hi);
                if dcj < b.lo - slack || dcj > b.hi + slack {
                    bucket_vetoes += 1;
                    continue;
                }
            }
            scr_pts.clear();
            scr_lower.clear();
            scr_cut.clear();
            for &pos in &b.pos[b.head..] {
                let pos = pos as usize;
                scr_pts.push(pts[pos]);
                let lb = if use_bounds {
                    let a = setup.dist_to_t[pos];
                    ((a - dcj).abs() - LB_MARGIN * (a + dcj)).max(0.0)
                } else {
                    0.0
                };
                scr_lower.push(lb);
                scr_cut.push(setup.threshold[pos]);
            }
            scr_out.clear();
            scr_out.resize(scr_pts.len(), 0.0);
            let computed = space.dist_batch_pruned(&scr_pts, c, &scr_lower, &scr_cut, &mut scr_out);
            pruned_evals += computed as u64;

            // Compact survivors in place (no per-iteration reallocation)
            // and tighten the bucket bounds while we are at it. The read
            // cursor `b.head + i` never trails the write cursor, so plain
            // forward indexing is aliasing-safe.
            let mut write = b.head;
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for i in 0..scr_pts.len() {
                let pos = b.pos[b.head + i];
                let posu = pos as usize;
                if scr_out[i] <= setup.threshold[posu] {
                    tau[posu] = cidx;
                    w += setup.weight_of(posu);
                    alive_count -= 1;
                } else {
                    b.pos[write] = pos;
                    write += 1;
                    lo = lo.min(setup.dist_to_t[posu] - setup.threshold[posu]);
                    hi = hi.max(setup.dist_to_t[posu] + setup.threshold[posu]);
                }
            }
            b.pos.truncate(write);
            b.lo = lo;
            b.hi = hi;
        }
        debug_assert!(w >= 1, "the new representative must remove itself");
        weights.push(w);
        if bounds_paying && pruned_evals > baseline_evals + give_up_slack {
            bounds_paying = false;
        }
    }

    // Flush per-call telemetry once (not per iteration): the simulator
    // snapshots these thread-locals around each reducer, so traces show
    // pruning effectiveness per reducer with no plumbing through here.
    obs::add("cover.points", n as u64);
    obs::add("cover.iterations", centers.len() as u64);
    obs::add("cover.evals_charged", pruned_evals);
    obs::add("cover.evals_baseline", baseline_evals);
    obs::add("cover.veto_bucket", bucket_vetoes);
    if !bounds_paying {
        obs::incr("cover.give_up");
    }

    CoverResult { set: WeightedSet::new(centers, weights), tau, dist_to_t: setup.dist_to_t }
}

/// Unpruned reference implementation of the weighted CoverWithBalls
/// greedy loop: one full `dist_batch` over the alive list per iteration,
/// with in-place compaction of the parallel alive/point arrays (the
/// historical per-iteration re-gather of `alive_pts` made the fallback
/// silently quadratic in allocations as well as evaluations). Kept
/// public as the bit-exact oracle the pruned path is pinned to and as
/// the baseline side of the `BENCH_pruning.json` comparison.
pub fn cover_with_balls_weighted_unpruned(
    space: &dyn MetricSpace,
    pts: &[u32],
    in_weights: Option<&[u64]>,
    t: &[u32],
    r: f64,
    eps: f64,
    beta: f64,
) -> CoverResult {
    let setup = CoverSetup::new(space, pts, in_weights, t, r, eps, beta);
    let n = pts.len();
    let mut tau = vec![u32::MAX; n];
    let mut centers: Vec<u32> = Vec::new();
    let mut weights: Vec<u64> = Vec::new();

    let mut alive: Vec<u32> = (0..n as u32).collect(); // positions into pts
    let mut alive_pts: Vec<u32> = pts.to_vec(); // pts[alive[i]], compacted in step
    let mut dist_buf = vec![0.0f64; n];
    let mut scans: u64 = 0;

    while !alive.is_empty() {
        // arbitrary remaining point: smallest position (deterministic)
        let cpos = alive[0] as usize;
        let c = pts[cpos];
        let cidx = centers.len() as u32;
        centers.push(c);

        // distances of remaining points to the new representative
        // (one bulk query per greedy iteration)
        let m = alive.len();
        space.dist_batch(&alive_pts[..m], c, &mut dist_buf[..m]);

        // partition alive into kept / removed; removed map to this center.
        // The selected point always removes itself, independent of the
        // computed distance (see the pruned path).
        let mut w: u64 = 0;
        let mut write = 0usize;
        for ai in 0..m {
            let pos = alive[ai] as usize;
            if pos == cpos || dist_buf[ai] <= setup.threshold[pos] {
                tau[pos] = cidx;
                w += setup.weight_of(pos);
            } else {
                alive[write] = alive[ai];
                alive_pts[write] = alive_pts[ai];
                write += 1;
            }
        }
        alive.truncate(write);
        alive_pts.truncate(write);
        debug_assert!(w >= 1, "the new representative must remove itself");
        weights.push(w);
        scans += m as u64;
    }

    obs::add("cover.points", n as u64);
    obs::add("cover.iterations", centers.len() as u64);
    obs::add("cover.evals_charged", scans);
    obs::add("cover.evals_baseline", scans);

    CoverResult { set: WeightedSet::new(centers, weights), tau, dist_to_t: setup.dist_to_t }
}

/// Shared input validation + up-front geometry of both cover paths:
/// the bulk d(x, T) pass, the nearest-T assignment (the pruned path's
/// bucketing key), and the fixed per-point removal thresholds.
struct CoverSetup<'a> {
    in_weights: Option<&'a [u64]>,
    dist_to_t: Vec<f64>,
    nearest_t: Vec<u32>,
    threshold: Vec<f64>,
}

impl<'a> CoverSetup<'a> {
    fn new(
        space: &dyn MetricSpace,
        pts: &[u32],
        in_weights: Option<&'a [u64]>,
        t: &[u32],
        r: f64,
        eps: f64,
        beta: f64,
    ) -> CoverSetup<'a> {
        assert!(!pts.is_empty(), "CoverWithBalls: empty P");
        assert!(!t.is_empty(), "CoverWithBalls: empty T");
        assert!(eps > 0.0 && beta > 0.0 && r >= 0.0);
        let n = pts.len();
        if let Some(w) = in_weights {
            assert_eq!(w.len(), n, "weights/pts arity mismatch");
        }
        let shrink = eps / (2.0 * beta);

        // d(x, T) once, up front (bulk path).
        let assign = space.assign(pts, t);
        // per-point removal threshold: shrink * max(R, d(x, T)) — fixed
        // for the whole run and monotone in d(x, T) (see module docs).
        let threshold: Vec<f64> = assign.dist.iter().map(|&d| shrink * d.max(r)).collect();
        debug_assert!(
            thresholds_monotone(&assign.dist, &threshold),
            "removal thresholds must be a monotone non-decreasing function of d(x, T)"
        );
        CoverSetup { in_weights, dist_to_t: assign.dist, nearest_t: assign.idx, threshold }
    }

    #[inline]
    fn weight_of(&self, pos: usize) -> u64 {
        self.in_weights.map_or(1, |ws| ws[pos])
    }
}

/// Debug-only check of the threshold monotonicity assumption: sorting by
/// d(x, T) must sort the thresholds too.
fn thresholds_monotone(dist_to_t: &[f64], threshold: &[f64]) -> bool {
    let mut order: Vec<u32> = (0..dist_to_t.len() as u32).collect();
    order.sort_unstable_by(|&a, &b| dist_to_t[a as usize].total_cmp(&dist_to_t[b as usize]));
    order.windows(2).all(|w| threshold[w[0] as usize] <= threshold[w[1] as usize])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use crate::metric::Objective;
    use crate::points::VectorData;
    use std::sync::Arc;

    fn mixture(n: usize, d: usize, k: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let (data, _) = GaussianMixtureSpec { n, d, k, seed, ..Default::default() }.generate();
        (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
    }

    /// Lemma 3.1: every point's representative is within
    /// ε/(2β)·max{R, d(x,T)}.
    #[test]
    fn per_point_guarantee_holds() {
        let (space, pts) = mixture(800, 4, 6, 1);
        let t: Vec<u32> = (0..6).map(|i| i * 133).collect();
        let a = space.assign(&pts, &t);
        let r = a.dist.iter().sum::<f64>() / pts.len() as f64;
        for (eps, beta) in [(0.5, 1.0), (0.25, 4.0), (0.9, 2.0)] {
            let res = cover_with_balls(&space, &pts, &t, r, eps, beta);
            let shrink = eps / (2.0 * beta);
            for (i, &x) in pts.iter().enumerate() {
                let rep = res.set.indices[res.tau[i] as usize];
                let d = space.dist(x, rep);
                let bound = shrink * res.dist_to_t[i].max(r);
                assert!(
                    d <= bound + 1e-9,
                    "eps={eps} beta={beta} point {i}: d={d} > bound={bound}"
                );
            }
        }
    }

    /// Definition 2.3: weights are exactly the preimage sizes of τ and
    /// sum to |P|.
    #[test]
    fn weights_are_preimage_sizes() {
        let (space, pts) = mixture(500, 3, 4, 2);
        let t = vec![0u32, 100, 200, 300];
        let res = cover_with_balls(&space, &pts, &t, 1.0, 0.5, 2.0);
        assert_eq!(res.set.total_weight(), pts.len() as u64);
        let mut counts = vec![0u64; res.set.len()];
        for &ti in &res.tau {
            counts[ti as usize] += 1;
        }
        assert_eq!(counts, res.set.weights);
    }

    /// Telemetry: each call flushes its `cover.*` counters to the
    /// thread-local obs ledger (the simulator snapshots them per
    /// reducer), and the pruned path's charges stay within the give-up
    /// slack of the reference cost.
    #[test]
    fn telemetry_counters_flushed_per_call() {
        let (space, pts) = mixture(500, 3, 4, 2);
        let t = vec![0u32, 100, 200, 300];
        let before = obs::snapshot();
        let res = cover_with_balls(&space, &pts, &t, 1.0, 0.5, 2.0);
        let delta = obs::delta_since(&before);
        let get = |k: &str| delta.iter().find(|(n, _)| n == k).map(|(_, v)| *v).unwrap_or(0);
        assert_eq!(get("cover.points"), 500);
        assert_eq!(get("cover.iterations"), res.set.len() as u64);
        assert!(get("cover.evals_charged") > 0);
        let slack = 16 * t.len() as u64 + pts.len() as u64;
        assert!(get("cover.evals_charged") <= get("cover.evals_baseline") + slack);
    }

    /// Representatives map to themselves (they remove themselves).
    #[test]
    fn centers_self_map() {
        let (space, pts) = mixture(300, 2, 3, 3);
        let t = vec![0u32, 150];
        let res = cover_with_balls(&space, &pts, &t, 0.5, 0.5, 1.0);
        for (ci, &c) in res.set.indices.iter().enumerate() {
            let pos = pts.iter().position(|&p| p == c).unwrap();
            assert_eq!(res.tau[pos] as usize, ci, "center {c} maps elsewhere");
        }
    }

    /// Smaller ε ⇒ finer cover ⇒ more representatives.
    #[test]
    fn size_monotone_in_eps() {
        let (space, pts) = mixture(1000, 4, 5, 4);
        let t: Vec<u32> = (0..5).map(|i| i * 200).collect();
        let a = space.assign(&pts, &t);
        let r = a.dist.iter().sum::<f64>() / pts.len() as f64;
        let big = cover_with_balls(&space, &pts, &t, r, 0.8, 1.0).set.len();
        let small = cover_with_balls(&space, &pts, &t, r, 0.2, 1.0).set.len();
        assert!(small >= big, "eps 0.2 gave {small} < eps 0.8 gave {big}");
    }

    /// Theorem 3.3 size bound (loose check on a low-dimensional set).
    #[test]
    fn size_bound_respected() {
        let (space, pts) = mixture(2000, 2, 4, 5);
        let t: Vec<u32> = (0..4).map(|i| i * 500).collect();
        let a = space.assign(&pts, &t);
        let r = a.dist.iter().sum::<f64>() / pts.len() as f64;
        let cmax = a.dist.iter().cloned().fold(0.0, f64::max) / r;
        let (eps, beta) = (0.5, 1.0);
        let res = cover_with_balls(&space, &pts, &t, r, eps, beta);
        // D=2 for planar data: bound |T|·(16β/ε)^D·(log2 c + 2)
        let bound = 4.0 * (16.0 * beta / eps).powi(2) * (cmax.log2() + 2.0);
        assert!(
            (res.set.len() as f64) <= bound,
            "size {} exceeds Theorem 3.3 bound {bound}",
            res.set.len()
        );
    }

    /// τ is total and proximity sums are finite and consistent.
    #[test]
    fn tau_total_and_proximity() {
        let (space, pts) = mixture(400, 3, 3, 6);
        let t = vec![5u32, 205];
        let res = cover_with_balls(&space, &pts, &t, 2.0, 0.4, 2.0);
        assert!(res.tau.iter().all(|&t| t != u32::MAX));
        let s1 = res.proximity_sum(&space, &pts);
        let s2 = res.proximity_sum_sq(&space, &pts);
        assert!(s1.is_finite() && s2.is_finite());
        assert!(s1 >= 0.0 && s2 >= 0.0);
        // Cauchy-Schwarz sanity: s1² ≤ n·s2
        assert!(s1 * s1 <= pts.len() as f64 * s2 + 1e-6);
    }

    /// Degenerate inputs: all-duplicate points collapse to one center;
    /// P = single point works.
    #[test]
    fn degenerate_inputs() {
        let v = VectorData::from_rows(&vec![vec![2.0f32, 2.0]; 40]);
        let space = EuclideanSpace::new(Arc::new(v));
        let pts: Vec<u32> = (0..40).collect();
        let res = cover_with_balls(&space, &pts, &[0], 1.0, 0.5, 1.0);
        assert_eq!(res.set.len(), 1);
        assert_eq!(res.set.weights[0], 40);

        let res1 = cover_with_balls(&space, &pts[..1], &[0], 0.0, 0.5, 1.0);
        assert_eq!(res1.set.len(), 1);
    }

    /// R = 0 forces exact-match removal only for points at distance 0
    /// from their representative when also d(x,T)=0.
    #[test]
    fn zero_radius_keeps_distinct_points() {
        let v = VectorData::from_rows(&[vec![0.0f32], vec![1.0], vec![2.0]]);
        let space = EuclideanSpace::new(Arc::new(v));
        let pts = vec![0u32, 1, 2];
        let res = cover_with_balls(&space, &pts, &[0], 0.0, 0.5, 1.0);
        // thresholds: shrink*max(0, d(x,T)) = 0.25*d(x,0): removal radius
        // around each selected center is small, distinct points survive
        assert_eq!(res.set.len(), 3);
        let _ = Objective::Median; // silence unused import in some cfgs
    }
}

#[cfg(test)]
mod weighted_tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use crate::points::VectorData;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// The defining equivalence: weighted CoverWithBalls == unweighted
    /// CoverWithBalls on the replicated multiset (replicas adjacent).
    #[test]
    fn weighted_equals_replicated() {
        let mut rng = Rng::new(42);
        let (base, _) =
            GaussianMixtureSpec { n: 120, d: 2, k: 3, seed: 9, ..Default::default() }.generate();
        let weights: Vec<u64> = (0..120).map(|_| 1 + rng.below(4) as u64).collect();
        // replicated multiset, replicas adjacent, remembering origin
        let mut rep_rows = Vec::new();
        let mut origin = Vec::new();
        for i in 0..120usize {
            for _ in 0..weights[i] {
                rep_rows.push(base.row(i as u32).to_vec());
                origin.push(i);
            }
        }
        let rep_data = VectorData::from_rows(&rep_rows);
        let sw = EuclideanSpace::new(Arc::new(base));
        let sr = EuclideanSpace::new(Arc::new(rep_data));
        let pts_w: Vec<u32> = (0..120).collect();
        let pts_r: Vec<u32> = (0..origin.len() as u32).collect();
        let t_w = vec![0u32, 40, 80];
        let t_r: Vec<u32> = t_w
            .iter()
            .map(|&tw| origin.iter().position(|&o| o == tw as usize).unwrap() as u32)
            .collect();

        let a = cover_with_balls_weighted(&sw, &pts_w, Some(&weights), &t_w, 1.0, 0.5, 2.0);
        let b = cover_with_balls(&sr, &pts_r, &t_r, 1.0, 0.5, 2.0);
        // same number of representatives at the same coordinates with the
        // same weights (replicas collapse onto their originals)
        assert_eq!(a.set.len(), b.set.len());
        for (ci, (&ca, &wa)) in a.set.indices.iter().zip(&a.set.weights).enumerate() {
            let cb = b.set.indices[ci];
            assert_eq!(origin[cb as usize], ca as usize, "center {ci} differs");
            assert_eq!(b.set.weights[ci], wa, "weight {ci} differs");
        }
        assert_eq!(a.set.total_weight(), weights.iter().sum::<u64>());
    }

    #[test]
    fn weighted_total_is_input_weight() {
        let (base, _) =
            GaussianMixtureSpec { n: 300, d: 2, k: 4, seed: 10, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(base));
        let pts: Vec<u32> = (0..300).collect();
        let weights: Vec<u64> = (0..300).map(|i| 1 + (i % 7) as u64).collect();
        let res =
            cover_with_balls_weighted(&space, &pts, Some(&weights), &[0, 150], 1.0, 0.6, 2.0);
        assert_eq!(res.set.total_weight(), weights.iter().sum::<u64>());
    }
}
