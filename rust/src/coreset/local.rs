//! Per-partition local coreset step (first round of §3.1/§3.2/§3.3):
//!
//! 1. T_ℓ ← β-approximate (bi-criteria, m ≥ k centers) solution on P_ℓ
//!   2. R_ℓ ← ν_{P_ℓ}(T_ℓ)/|P_ℓ|           (k-median)
//!      R_ℓ ← √(μ_{P_ℓ}(T_ℓ)/|P_ℓ|)        (k-means)
//!   3. C_{w,ℓ} ← CoverWithBalls(P_ℓ, T_ℓ, R_ℓ, ε, β)      (k-median)
//!      C_{w,ℓ} ← CoverWithBalls(P_ℓ, T_ℓ, R_ℓ, √2·ε, √β)  (k-means)
//!
//! Lemma 3.4 / 3.10: the result is an ε-bounded (resp. ε²-bounded)
//! coreset of the partition instance.

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::seeding::{dpp_seeding, gonzalez};
use crate::algorithms::Instance;
use crate::metric::{MetricSpace, Objective};
use crate::util::rng::Rng;

use super::cover::{cover_with_balls, CoverResult};

/// Algorithm used for the per-partition rough solution T_ℓ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TlAlgo {
    /// Weighted D^p-sampling (k-means++ family) with oversampling — the
    /// bi-criteria route the paper recommends for larger D (§3.4).
    DppSeeding,
    /// Local search (Arya et al. / Gupta–Tangwongsan) — the
    /// constant-β full-criteria route.
    LocalSearch,
    /// Farthest-first traversal (deterministic; k-center flavoured).
    Gonzalez,
}

/// Output of the local step.
#[derive(Clone, Debug)]
pub struct LocalCoresetOut {
    pub cover: CoverResult,
    /// Tolerance radius R_ℓ of step 2.
    pub r: f64,
    /// The rough solution T_ℓ.
    pub t: Vec<u32>,
    /// ν_{P_ℓ}(T_ℓ) or μ_{P_ℓ}(T_ℓ) under the objective.
    pub t_cost: f64,
}

/// The CoverWithBalls parameters the objective dictates (§3.3 adapts
/// (ε, β) → (√2·ε, √β) to account for squared distances).
pub fn cover_params(obj: Objective, eps: f64, beta: f64) -> (f64, f64) {
    match obj {
        Objective::Median => (eps, beta),
        Objective::Means => (std::f64::consts::SQRT_2 * eps, beta.sqrt()),
    }
}

/// Compute T_ℓ with `m` centers using the chosen algorithm.
pub fn rough_solution(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    m: usize,
    tl: TlAlgo,
    rng: &mut Rng,
) -> Vec<u32> {
    let weights = vec![1u64; pts.len()];
    let inst = Instance::new(pts, &weights);
    match tl {
        TlAlgo::DppSeeding => dpp_seeding(space, obj, inst, m, rng).centers,
        TlAlgo::LocalSearch => {
            let cfg = LocalSearchCfg { seed: rng.next_u64(), ..Default::default() };
            local_search(space, obj, inst, m, None, &cfg).centers
        }
        TlAlgo::Gonzalez => gonzalez(space, inst, m, 0),
    }
}

/// Run the full local step on one partition.
pub fn local_coreset(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    m: usize,
    eps: f64,
    beta: f64,
    tl: TlAlgo,
    rng: &mut Rng,
) -> LocalCoresetOut {
    assert!(!pts.is_empty());
    let t = rough_solution(space, obj, pts, m, tl, rng);
    let assign = space.assign(pts, &t);
    let t_cost = assign.cost_unit(obj);
    let n = pts.len() as f64;
    let r = match obj {
        Objective::Median => t_cost / n,
        Objective::Means => (t_cost / n).sqrt(),
    };
    let (ce, cb) = cover_params(obj, eps, beta);
    let cover = cover_with_balls(space, pts, &t, r, ce, cb);
    LocalCoresetOut { cover, r, t, t_cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    fn mixture(n: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let (data, _) =
            GaussianMixtureSpec { n, d: 4, k: 5, seed, ..Default::default() }.generate();
        (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
    }

    /// Lemma 3.4: Σ d(x, τ(x)) ≤ ε · ν(opt) — checked against the
    /// (upper-bounding) surrogate ν(T_ℓ)/β ≥ ν(opt)... we use the sound
    /// direction: Σ ≤ ε/(2β)(R·n + ν(T)) = ε/β · ν(T) ≤ ε·ν(opt)·(β/β),
    /// so Σ ≤ ε·ν(T)/β must hold unconditionally. That's what we assert.
    #[test]
    fn bounded_coreset_inequality_kmedian() {
        let (space, pts) = mixture(1200, 1);
        let mut rng = Rng::new(7);
        let eps = 0.4;
        let beta = 4.0;
        let tl = TlAlgo::DppSeeding;
        let out = local_coreset(&space, Objective::Median, &pts, 10, eps, beta, tl, &mut rng);
        let prox = out.cover.proximity_sum(&space, &pts);
        let bound = eps / beta * out.t_cost; // = ε/(2β)·(R·n + ν(T)) with R·n = ν(T)
        assert!(prox <= bound + 1e-6, "prox {prox} > bound {bound}");
    }

    #[test]
    fn bounded_coreset_inequality_kmeans() {
        let (space, pts) = mixture(1200, 2);
        let mut rng = Rng::new(8);
        let eps = 0.3;
        let beta = 4.0;
        let tl = TlAlgo::DppSeeding;
        let out = local_coreset(&space, Objective::Means, &pts, 10, eps, beta, tl, &mut rng);
        // Lemma 3.10: Σ d(x,τ(x))² ≤ (2ε²/2β)(R²n + μ(T)) = 2ε²·μ(T)/β... with
        // cover params (√2ε, √β): shrink² = 2ε²/(4β) = ε²/(2β); bound:
        // shrink²·Σ(max(R, d)²) ≤ shrink²·(R²·n + μ(T)) = ε²/(2β)·2μ(T) = ε²μ(T)/β
        let prox2 = out.cover.proximity_sum_sq(&space, &pts);
        let bound = eps * eps / beta * out.t_cost;
        assert!(prox2 <= bound + 1e-6, "prox² {prox2} > bound {bound}");
    }

    #[test]
    fn all_tl_algos_produce_valid_covers() {
        let (space, pts) = mixture(600, 3);
        for tl in [TlAlgo::DppSeeding, TlAlgo::LocalSearch, TlAlgo::Gonzalez] {
            let mut rng = Rng::new(9);
            let out = local_coreset(&space, Objective::Median, &pts, 8, 0.5, 4.0, tl, &mut rng);
            assert_eq!(out.cover.set.total_weight(), pts.len() as u64, "{tl:?}");
            assert!(out.r > 0.0);
            assert!(out.t.len() <= 8 && !out.t.is_empty());
        }
    }

    #[test]
    fn means_params_shrink_more_gently() {
        let (e, b) = cover_params(Objective::Means, 0.3, 4.0);
        assert!((e - 0.3 * std::f64::consts::SQRT_2).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        let (e2, b2) = cover_params(Objective::Median, 0.3, 4.0);
        assert_eq!((e2, b2), (0.3, 4.0));
    }

    #[test]
    fn coreset_smaller_than_input_on_clustered_data() {
        // D=1 so the ball cover compresses decisively (size ~ (16β/ε)^D)
        let (data, _) =
            GaussianMixtureSpec { n: 2000, d: 1, k: 5, seed: 4, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..2000).collect();
        let mut rng = Rng::new(10);
        let tl = TlAlgo::DppSeeding;
        let out = local_coreset(&space, Objective::Median, &pts, 10, 0.8, 2.0, tl, &mut rng);
        assert!(
            out.cover.set.len() < pts.len() / 2,
            "coreset {} not much smaller than n {}",
            out.cover.set.len(),
            pts.len()
        );
    }
}
