//! Extension: 2-round MapReduce k-center via the same composable
//! machinery (the paper's conclusions note the "uniform strategy"; the
//! companion work, Ceccarello–Pietracaprina–Pucci [7], solves k-center
//! this way). Included as the natural extension feature: per-partition
//! Gonzalez summaries compose, and a final Gonzalez pass on the union is
//! a provable O(1)-approximation for k-center.

use crate::algorithms::seeding::gonzalez;
use crate::algorithms::Instance;
use crate::mapreduce::{partition, PartitionStrategy, Simulator};
use crate::metric::MetricSpace;

/// Result of the distributed k-center solve.
#[derive(Clone, Debug)]
pub struct KCenterReport {
    pub centers: Vec<u32>,
    /// max_x d(x, centers) over the full input.
    pub radius: f64,
    pub summary_size: usize,
    pub rounds: usize,
}

/// 2-round MapReduce k-center: round 1 runs Gonzalez with `m ≥ k`
/// centers per partition; round 2 runs Gonzalez(k) on the union.
/// With m = k this is the classic 4-approximation; oversampling m > k
/// tightens it towards 2 + ε on doubling spaces.
pub fn solve_kcenter(
    space: &dyn MetricSpace,
    pts: &[u32],
    k: usize,
    m: usize,
    l: usize,
    sim: &Simulator,
) -> KCenterReport {
    assert!(k >= 1 && m >= k);
    let parts = partition(pts, l, PartitionStrategy::RoundRobin);
    let locals = sim.round("kcenter-r1-gonzalez", parts, |_, part, meter| {
        meter.charge(part.len());
        let w = vec![1u64; part.len()];
        let centers = gonzalez(space, Instance::new(part, &w), m, 0);
        meter.charge(centers.len());
        meter.release(part.len() + centers.len());
        centers
    });
    let union: Vec<u32> = locals.concat();
    let summary_size = union.len();
    let centers = sim
        .round("kcenter-r2-final", vec![union], |_, u, meter| {
            meter.charge(u.len());
            let w = vec![1u64; u.len()];
            let centers = gonzalez(space, Instance::new(u, &w), k, 0);
            meter.release(u.len());
            centers
        })
        .into_iter()
        .next()
        .unwrap();
    let radius = space.assign(pts, &centers).dist.iter().cloned().fold(0.0, f64::max);
    KCenterReport { centers, radius, summary_size, rounds: 2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    fn mixture(n: usize, k: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let (data, _) = GaussianMixtureSpec { n, d: 2, k, spread: 50.0, seed, ..Default::default() }
            .generate();
        (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
    }

    #[test]
    fn two_rounds_and_reasonable_radius() {
        let (space, pts) = mixture(4000, 6, 1);
        let sim = Simulator::new();
        let rep = solve_kcenter(&space, &pts, 6, 12, 8, &sim);
        assert_eq!(rep.rounds, 2);
        assert_eq!(rep.centers.len(), 6);
        // sequential Gonzalez reference
        let w = vec![1u64; pts.len()];
        let seq = gonzalez(&space, Instance::new(&pts, &w), 6, 0);
        let seq_r = space.assign(&pts, &seq).dist.iter().cloned().fold(0.0, f64::max);
        // MR radius within the 4x theory bound of the sequential 2-approx
        // (in practice close to 1x on separated data)
        assert!(rep.radius <= 4.0 * seq_r + 1e-9, "MR {} vs seq {seq_r}", rep.radius);
        assert_eq!(sim.take_stats().num_rounds(), 2);
    }

    #[test]
    fn oversampling_tightens_radius() {
        let (space, pts) = mixture(4000, 8, 2);
        let sim = Simulator::new();
        let tight = solve_kcenter(&space, &pts, 8, 32, 8, &sim);
        let loose = solve_kcenter(&space, &pts, 8, 8, 8, &sim);
        assert!(
            tight.radius <= loose.radius * 1.2,
            "tight {} loose {}",
            tight.radius,
            loose.radius
        );
        assert!(tight.summary_size > loose.summary_size);
    }

    #[test]
    fn covers_every_cluster() {
        let (space, pts) = mixture(3000, 5, 3);
        let sim = Simulator::new();
        let rep = solve_kcenter(&space, &pts, 5, 10, 6, &sim);
        // separated blobs (spread 50, sigma 1): radius must be intra-cluster
        assert!(rep.radius < 15.0, "radius {}", rep.radius);
    }
}
