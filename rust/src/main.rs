//! mrcoreset CLI — leader entrypoint for the 3-round MapReduce
//! k-median/k-means solver and its experiment suite.
//!
//! Subcommands:
//!   run         solve a clustering instance (synthetic or CSV); `--z Z`
//!               switches to the outlier-robust (k, z) pipeline;
//!               `--trace FILE` writes a JSONL telemetry trace and
//!               `--json` prints the run report as JSON
//!   exp         run experiments e1..e12 (or `all`) and print their tables
//!   gen         generate a synthetic dataset to CSV
//!   report      render a `--trace` JSONL file: per-round skew table plus
//!               a pruning-effectiveness breakdown
//!   bench-diff  compare the deterministic metrics of two bench JSON
//!               files; exit 1 on regression (the CI perf gate)
//!   info        report engine/artifact status
//!
//! Examples:
//!   mrcoreset run --alg kmedian --n 20000 --d 2 --k 8 --eps 0.4
//!   mrcoreset run --alg kmedian --k 8 --noise 200 --z 200
//!   mrcoreset run data.csv --alg kmeans --k 10 --eps 0.25
//!   mrcoreset run --k 8 --trace out.jsonl --json
//!   mrcoreset report out.jsonl
//!   mrcoreset bench-diff ../BENCH_baseline/BENCH_pruning.json BENCH_pruning.json
//!   mrcoreset exp e4 --full
//!   mrcoreset gen --n 10000 --d 4 --k 8 --out points.csv

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use mrcoreset::coordinator::{try_solve_traced, ClusterConfig, FinalAlgo};
use mrcoreset::coreset::TlAlgo;
use mrcoreset::data::csv;
use mrcoreset::data::synth::{GaussianMixtureSpec, NoiseSpec};
use mrcoreset::eval::{run_experiment, validate_ids, ALL_IDS};
use mrcoreset::mapreduce::{parse_bytes, ExecBackend, FaultPlan, PartitionStrategy};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::kernel::KernelKind;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::obs::{self, log, Event, JsonlSink, Recorder};
use mrcoreset::runtime::XlaEngine;
use mrcoreset::util::cli::Args;
use mrcoreset::util::json::Json;
use mrcoreset::util::table::{fnum, Table};

const USAGE: &str = "usage: mrcoreset <run|exp|gen|report|bench-diff|info> [flags]
  run  [file.csv] --alg kmedian|kmeans --k K --eps E [--z Z] [--n N --d D]
       [--noise N] [--l L] [--m M] [--beta B] [--tl dpp|local-search|gonzalez]
       [--final local-search|pam|robust] [--one-round]
       [--partition rr|contig|shuffle] [--seed S] [--no-engine]
       [--kernel auto|scalar|blocked|simd]
       [--executor mem|spill] [--mem-budget BYTES] [--spill-dir DIR]
       [--faults SPEC] [--retries N] [--checkpoint-dir DIR]
       [--trace FILE] [--json]
  exp  <e1..e12|all> [--full] [--kernel auto|scalar|blocked|simd]
  gen  --n N --d D --k K --out FILE [--spread S] [--outliers F] [--noise N]
       [--seed S]
  report      <trace.jsonl>
  bench-diff  <baseline.json> <current.json> [--tolerance 0.02]
  info

  global: -v/--verbose for detail, -q/--quiet to suppress progress notes

  --z Z       solve the (k, z) objective: write off the Z most expensive
              points as outliers (outlier-robust pipeline + finisher)
  --noise N   append N uniform noise points to the synthetic input
  --partition how points are split into the L reducers (rr = round-robin,
              contig = contiguous, shuffle = seeded shuffle); --strategy
              is accepted as an alias
  --kernel K  dense distance-kernel backend: auto (default; cache-blocked,
              or the XLA engine when one is loaded), scalar (exact f64
              reference), blocked (cache-blocked, bit-identical to
              scalar), simd (f32 SIMD rows, inexact — disables pruning).
              The MRCORESET_KERNEL env var sets the default; the flag
              wins. The resolved backend is logged and recorded in the
              run report/trace
  --executor  mem (default) keeps every shard in RAM; spill stages each
              round's shards on disk and materializes one per reducer
  --mem-budget B
              hard per-reducer byte budget (k/m/g suffixes, powers of
              1024); an overflowing run fails with a structured error
              instead of an OOM kill. Both executors enforce it
  --spill-dir D
              shard directory for --executor spill (default: fresh temp)
  --faults S  deterministic fault injection: `;`-separated entries, each
              KIND@ROUND.REDUCER[xCOUNT] (KIND = panic|read|write|flip)
              or chaos:KIND:PERMILLE:SEED. Same spec + same run config
              replays bit-identically on both executors. Env default:
              MRCORESET_FAULTS
  --retries N transient reducer failures retried up to N times (default
              0 — recovery is opt-in; simulated backoff, recorded not
              slept). Env default: MRCORESET_RETRIES
  --checkpoint-dir D
              (spill executor) persist each completed round to D and, on
              restart with the same config, resume at the first
              incomplete round — checksummed, parameter-fingerprinted
  --trace F   write per-round/per-reducer telemetry events to F (JSONL)
  --json      print the run report as deterministic JSON (no wall-clock)";

fn main() {
    let args = Args::from_env();
    if args.has("quiet") || args.has("q") {
        log::set_verbosity(log::QUIET);
    } else if args.has("verbose") || args.has("v") {
        log::set_verbosity(log::VERBOSE);
    }
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("exp") => cmd_exp(&args),
        Some("gen") => cmd_gen(&args),
        Some("report") => cmd_report(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Unwrap a CLI accessor result; a usage error prints and exits(2).
/// This is the only layer where flag errors terminate the process —
/// the `Args` getters themselves are `Result`-based library code.
fn usage<T>(r: Result<T, String>) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    })
}

/// Parse `--kernel` if present; a typo is a usage error, not a silent
/// fall-through (unlike the `MRCORESET_KERNEL` env override).
fn kernel_of(args: &Args) -> Option<KernelKind> {
    usage(args.try_get("kernel")).map(|s| match KernelKind::parse(s) {
        Some(kind) => kind,
        None => {
            eprintln!("error: unknown --kernel {s} (want auto, scalar, blocked, or simd)");
            std::process::exit(2);
        }
    })
}

fn objective_of(args: &Args) -> Objective {
    match usage(args.str_or("alg", "kmedian")) {
        "kmedian" | "k-median" | "median" => Objective::Median,
        "kmeans" | "k-means" | "means" => Objective::Means,
        other => {
            eprintln!("error: unknown --alg {other}");
            std::process::exit(2);
        }
    }
}

fn cmd_run(args: &Args) {
    let obj = objective_of(args);
    let k: usize = usage(args.parse_or("k", 8));
    let eps: f64 = usage(args.parse_or("eps", 0.5));

    // data: CSV positional, or synthetic with --n/--d
    let data = if let Some(file) = args.positional.first() {
        if args.has("noise") {
            log::warn(&format!(
                "--noise only applies to synthetic inputs; {file} is used as-is"
            ));
        }
        match csv::load_csv(Path::new(file)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    } else {
        let n: usize = usage(args.parse_or("n", 10_000));
        let d: usize = usage(args.parse_or("d", 2));
        let seed: u64 = usage(args.parse_or("data-seed", 1));
        let noise: usize = usage(args.parse_or("noise", 0));
        let spec = GaussianMixtureSpec { n, d, k: k.max(2), seed, ..Default::default() };
        if noise > 0 {
            let nspec = NoiseSpec { count: noise, seed: seed ^ 0xBAD, ..Default::default() };
            spec.generate_with_noise(&nspec).0
        } else {
            spec.generate().0
        }
    };
    let n = data.n();
    log::info(&format!("input: n={} d={} objective={}", n, data.d(), obj));

    let shared = Arc::new(data);
    // flag > MRCORESET_KERNEL > auto; an explicit non-auto kind
    // deliberately sidelines the engine (see `EuclideanSpace::has_engine`)
    let kind = KernelKind::resolve(kernel_of(args));
    let mut space = EuclideanSpace::with_kernel(shared, kind);
    if !args.has("no-engine") {
        if let Some(engine) = XlaEngine::load_default() {
            log::info(&format!(
                "engine: XLA/PJRT with {} artifacts",
                engine.manifest().entries.len()
            ));
            space.set_engine(Some(Arc::new(engine)));
        }
    }
    log::info(&format!("kernel: {}", space.kernel_name()));

    let mut cfg = ClusterConfig::new(obj, k, eps);
    if args.has("l") {
        cfg.l = Some(usage(args.parse_or("l", 0)));
    }
    if args.has("m") {
        cfg.m = Some(usage(args.parse_or("m", 2 * k)));
    }
    cfg.beta = usage(args.parse_or("beta", cfg.beta));
    cfg.seed = usage(args.parse_or("seed", cfg.seed));
    cfg.outliers = usage(args.parse_or("z", 0));
    cfg.one_round = args.has("one-round");
    cfg.tl = match usage(args.str_or("tl", "dpp")) {
        "dpp" => TlAlgo::DppSeeding,
        "local-search" => TlAlgo::LocalSearch,
        "gonzalez" => TlAlgo::Gonzalez,
        other => {
            eprintln!("error: unknown --tl {other}");
            std::process::exit(2);
        }
    };
    cfg.final_algo = match usage(args.str_or("final", "local-search")) {
        "local-search" => FinalAlgo::LocalSearch,
        "pam" => FinalAlgo::Pam,
        "robust" | "robust-local-search" => FinalAlgo::RobustLocalSearch,
        other => {
            eprintln!("error: unknown --final {other}");
            std::process::exit(2);
        }
    };
    // --partition is the documented name; --strategy stays as an alias
    let strat = match usage(args.try_get("partition")) {
        Some(s) => s,
        None => usage(args.str_or("strategy", "rr")),
    };
    cfg.strategy = match strat {
        "rr" => PartitionStrategy::RoundRobin,
        "contig" => PartitionStrategy::Contiguous,
        "shuffle" => PartitionStrategy::Shuffled(cfg.seed),
        other => {
            eprintln!("error: unknown --partition {other}");
            std::process::exit(2);
        }
    };
    if let Some(backend) = usage(args.try_get("executor")) {
        cfg.executor.backend = match backend {
            "mem" | "in-memory" => ExecBackend::InMemory,
            "spill" => ExecBackend::Spill,
            other => {
                eprintln!("error: unknown --executor {other} (want mem or spill)");
                std::process::exit(2);
            }
        };
    }
    if let Some(b) = usage(args.try_get("mem-budget")) {
        match parse_bytes(b) {
            Some(bytes) => cfg.executor.mem_budget = Some(bytes),
            None => {
                eprintln!("error: invalid --mem-budget {b} (bytes; k/m/g suffixes allowed)");
                std::process::exit(2);
            }
        }
    }
    if let Some(dir) = usage(args.try_get("spill-dir")) {
        cfg.executor.spill_dir = Some(std::path::PathBuf::from(dir));
    }
    if let Some(spec) = usage(args.try_get("faults")) {
        match FaultPlan::parse(spec) {
            Ok(plan) => cfg.executor.faults = Some(plan),
            Err(e) => {
                eprintln!("error: invalid --faults spec: {e}");
                std::process::exit(2);
            }
        }
    }
    if args.has("retries") {
        cfg.executor.retries = usage(args.require("retries"));
    }
    if let Some(dir) = usage(args.try_get("checkpoint-dir")) {
        cfg.executor.checkpoint_dir = Some(std::path::PathBuf::from(dir));
    }

    // the robust pipeline (--z, or --final robust on its own) has its
    // own round structure and center counts — tell the user which
    // knobs it overrides
    let robust_run = cfg.outliers > 0 || cfg.final_algo == FinalAlgo::RobustLocalSearch;
    if robust_run {
        if cfg.outliers > 0 && args.has("final") && cfg.final_algo != FinalAlgo::RobustLocalSearch
        {
            log::warn("--z overrides --final (robust local search is used)");
        }
        if cfg.one_round {
            log::warn("the robust pipeline ignores --one-round (it is 2-round)");
        }
        if args.has("m") {
            log::warn(
                "the robust pipeline sets per-partition centers to k + ceil(z/L)*2; \
                 --m is ignored",
            );
        }
    }

    let recorder: Arc<dyn Recorder> = match usage(args.try_get("trace")) {
        Some(path) => match JsonlSink::create(Path::new(path)) {
            Ok(sink) => {
                log::debug(&format!("trace: writing telemetry to {path}"));
                Arc::new(sink)
            }
            Err(e) => {
                eprintln!("error: cannot create trace file {path}: {e}");
                std::process::exit(1);
            }
        },
        None => obs::noop(),
    };

    let pts: Vec<u32> = (0..n as u32).collect();
    let rep = match try_solve_traced(&space, &pts, &cfg, recorder) {
        Ok(rep) => rep,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    if args.has("json") {
        println!("{}", rep.to_json());
    } else {
        print!("{}", rep.summary());
        println!("centers: {:?}", rep.solution.centers);
    }
}

fn cmd_exp(args: &Args) {
    // Experiments construct their own spaces via `::new`, which resolves
    // the environment override — routing the flag through the env var
    // applies it to every run the experiment performs.
    if let Some(kind) = kernel_of(args) {
        std::env::set_var("MRCORESET_KERNEL", kind.name());
    }
    let quick = !args.has("full");
    let ids: Vec<&str> = match args.positional.first().map(String::as_str) {
        Some("all") | None => ALL_IDS.to_vec(),
        Some(id) => vec![id],
    };
    // Validate up front (a typo costs nothing), then stream each
    // experiment's tables as soon as it completes.
    if let Err(e) = validate_ids(&ids) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    for id in ids {
        if let Some(res) = run_experiment(id, quick) {
            println!("{}", res.render());
        }
    }
}

fn cmd_gen(args: &Args) {
    // gen does no distance work, but validate the flag so a typo in a
    // scripted run/gen pipeline fails here, not at the next stage
    let _ = kernel_of(args);
    let spec = GaussianMixtureSpec {
        n: usage(args.parse_or("n", 10_000)),
        d: usage(args.parse_or("d", 2)),
        k: usage(args.parse_or("k", 8)),
        spread: usage(args.parse_or("spread", 20.0)),
        outlier_frac: usage(args.parse_or("outliers", 0.0)),
        seed: usage(args.parse_or("seed", 1)),
    };
    let out = usage(args.str_or("out", "points.csv"));
    let noise: usize = usage(args.parse_or("noise", 0));
    let (data, _) = if noise > 0 {
        spec.generate_with_noise(&NoiseSpec {
            count: noise,
            seed: spec.seed ^ 0xBAD,
            ..Default::default()
        })
    } else {
        spec.generate()
    };
    if let Err(e) = csv::save_csv(Path::new(out), &data) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
    println!("wrote {} points ({} dims) to {out}", data.n(), data.d());
}

fn cmd_report(args: &Args) {
    let path = match args.positional.first() {
        Some(p) => p,
        None => {
            eprintln!("usage: mrcoreset report <trace.jsonl>");
            std::process::exit(2);
        }
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {path}: {e}");
            std::process::exit(1);
        }
    };
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match Event::parse(line) {
            Ok(ev) => events.push(ev),
            Err(e) => {
                eprintln!("error: {path}:{}: {e}", i + 1);
                std::process::exit(1);
            }
        }
    }
    print!("{}", render_trace_report(&events));
}

/// Render a parsed trace: per-round skew table (from `round_end` spans)
/// plus a pruning-effectiveness breakdown aggregated over the per-reducer
/// counter deltas.
fn render_trace_report(events: &[Event]) -> String {
    let mut s = String::new();
    for ev in events {
        if let Event::RunStart { schema, label } = ev {
            s.push_str(&format!("trace: schema v{schema}  {label}\n"));
        }
    }
    let mut t = Table::new(vec![
        "round",
        "name",
        "reducers",
        "dist_evals",
        "evals_p95",
        "evals_max",
        "mem_p50",
        "mem_p95",
        "mem_max",
        "bytes_max",
        "skew",
    ]);
    for ev in events {
        if let Event::RoundEnd {
            round,
            name,
            reducers,
            dist_evals,
            mem_max,
            mem_p50,
            mem_p95,
            bytes_max,
            evals_max,
            evals_p95,
            ..
        } = ev
        {
            // straggler factor: the busiest reducer vs. the median one
            let skew = if *mem_p50 > 0.0 { *mem_max as f64 / *mem_p50 } else { 1.0 };
            t.row(vec![
                round.to_string(),
                name.clone(),
                reducers.to_string(),
                dist_evals.to_string(),
                fnum(*evals_p95),
                evals_max.to_string(),
                fnum(*mem_p50),
                fnum(*mem_p95),
                mem_max.to_string(),
                bytes_max.to_string(),
                format!("{skew:.2}"),
            ]);
        }
    }
    if !t.is_empty() {
        s.push_str(&t.to_markdown());
    }
    // spill traffic (wall-gated span fields; zero for the in-memory
    // backend, where nothing touches the disk)
    let (mut spill_read, mut spill_write) = (0u64, 0u64);
    for ev in events {
        if let Event::Reducer { spill_read: r, spill_write: w, .. } = ev {
            spill_read += r;
            spill_write += w;
        }
    }
    if spill_read + spill_write > 0 {
        s.push_str(&format!("spill: read={spill_read} B written={spill_write} B\n"));
    }
    let mut counters: BTreeMap<String, u64> = BTreeMap::new();
    for ev in events {
        if let Event::Reducer { counters: cs, .. } = ev {
            for (k, v) in cs {
                *counters.entry(k.clone()).or_insert(0) += v;
            }
        }
    }
    if !counters.is_empty() {
        s.push_str("counters (summed over reducers):\n");
        for (k, v) in &counters {
            s.push_str(&format!("  {k:28} {v}\n"));
        }
        for scope in ["pruned", "cover"] {
            let charged =
                counters.get(&format!("{scope}.evals_charged")).copied().unwrap_or(0);
            let baseline =
                counters.get(&format!("{scope}.evals_baseline")).copied().unwrap_or(0);
            if baseline > 0 {
                let saved = 100.0 * (1.0 - charged as f64 / baseline as f64);
                s.push_str(&format!(
                    "pruning[{scope}]: {charged} of {baseline} baseline evals charged \
                     ({saved:.1}% saved)\n"
                ));
            }
        }
    }
    for ev in events {
        if let Event::RunEnd { rounds, dist_evals, max_local_memory, max_local_bytes } = ev {
            s.push_str(&format!(
                "run: rounds={rounds} dist_evals={dist_evals} \
                 max_local_memory={max_local_memory} max_local_bytes={max_local_bytes}\n"
            ));
        }
    }
    s
}

fn cmd_bench_diff(args: &Args) {
    let (base_path, cur_path) = match (args.positional.first(), args.positional.get(1)) {
        (Some(b), Some(c)) => (b, c),
        _ => {
            eprintln!("usage: mrcoreset bench-diff <baseline.json> <current.json> [--tolerance T]");
            std::process::exit(2);
        }
    };
    let tolerance: f64 = usage(args.parse_or("tolerance", 0.02));
    let load = |p: &str| -> Json {
        let text = std::fs::read_to_string(p).unwrap_or_else(|e| {
            eprintln!("error: {p}: {e}");
            std::process::exit(1);
        });
        Json::parse(&text).unwrap_or_else(|e| {
            eprintln!("error: {p}: {e}");
            std::process::exit(1);
        })
    };
    let base = load(base_path);
    let cur = load(cur_path);
    let (text, regressions) = bench_diff(&base, &cur, tolerance);
    print!("{text}");
    if regressions > 0 {
        std::process::exit(1);
    }
}

/// Compare the `"metrics"` objects of two bench JSON files. Only raw
/// deterministic work counts are gated — `*_ratio` keys are derived and
/// skipped, and timings live under `"benchmarks"` which this never
/// reads (wall time is not comparable across machines). Every gated
/// metric is a cost (distance evaluations), so larger = worse; a
/// relative increase beyond `tolerance`, or a metric that disappeared,
/// counts as a regression.
fn bench_diff(base: &Json, cur: &Json, tolerance: f64) -> (String, usize) {
    let empty: Vec<(String, Json)> = Vec::new();
    let base_metrics = base.get("metrics").and_then(|m| m.as_obj()).unwrap_or(&empty);
    let cur_metrics = cur.get("metrics").and_then(|m| m.as_obj()).unwrap_or(&empty);
    if base_metrics.is_empty() {
        return (
            "bench-diff: baseline has no metrics to gate (seed it by copying a fresh \
             BENCH_pruning.json into BENCH_baseline/)\n"
                .to_string(),
            0,
        );
    }
    let mut text = String::new();
    let mut regressions = 0usize;
    let mut compared = 0usize;
    for (k, bv) in base_metrics {
        if k.ends_with("_ratio") {
            continue;
        }
        let b = match bv.as_f64() {
            Some(x) => x,
            None => continue,
        };
        compared += 1;
        let c = cur_metrics.iter().find(|(ck, _)| ck == k).and_then(|(_, v)| v.as_f64());
        let c = match c {
            Some(x) => x,
            None => {
                text.push_str(&format!("MISSING  {k:32} baseline {}\n", fnum(b)));
                regressions += 1;
                continue;
            }
        };
        let rel = if b != 0.0 {
            (c - b) / b
        } else if c == 0.0 {
            0.0
        } else {
            f64::INFINITY
        };
        let status = if rel > tolerance {
            regressions += 1;
            "REGRESS"
        } else if rel < -tolerance {
            "IMPROVE"
        } else {
            "ok"
        };
        text.push_str(&format!(
            "{status:8} {k:32} {} -> {}  ({:+.2}%)\n",
            fnum(b),
            fnum(c),
            rel * 100.0
        ));
    }
    text.push_str(&format!(
        "bench-diff: {compared} metric(s) compared, {regressions} regression(s), \
         tolerance {:.1}%\n",
        tolerance * 100.0
    ));
    (text, regressions)
}

fn cmd_info() {
    println!(
        "mrcoreset {} — 3-round MapReduce k-median/k-means (Mazzetto et al. 2019)",
        env!("CARGO_PKG_VERSION")
    );
    match XlaEngine::load_default() {
        Some(engine) => {
            let m = engine.manifest();
            println!("engine: available, {} artifacts", m.entries.len());
            println!(
                "  assign_cost max n = {}, min_update max n = {}",
                m.max_n(mrcoreset::runtime::ArtifactKind::AssignCost),
                m.max_n(mrcoreset::runtime::ArtifactKind::MinUpdate)
            );
        }
        None => println!("engine: unavailable (run `make artifacts`)"),
    }
    println!(
        "kernel: {} (default resolution; override with --kernel or MRCORESET_KERNEL)",
        KernelKind::resolve(None).name()
    );
    println!("threads: {}", mrcoreset::util::pool::default_threads());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_trace_report_covers_rounds_counters_and_pruning() {
        let events = vec![
            Event::RunStart { schema: 2, label: "median k=3 n=500 eps=0.5 seed=1".to_string() },
            Event::RoundStart { round: 0, name: "coreset-r1-local".to_string(), reducers: 2 },
            Event::Reducer {
                round: 0,
                reducer: 0,
                name: "coreset-r1-local".to_string(),
                in_items: 250,
                out_items: 20,
                dist_evals: 900,
                mem_peak: 260,
                mem_bytes: 1240,
                wall_us: 0,
                spill_read: 1008,
                spill_write: 232,
                attempts: 1,
                counters: vec![
                    ("cover.evals_baseline".to_string(), 1000),
                    ("cover.evals_charged".to_string(), 600),
                ],
            },
            Event::Reducer {
                round: 0,
                reducer: 1,
                name: "coreset-r1-local".to_string(),
                in_items: 250,
                out_items: 20,
                dist_evals: 800,
                mem_peak: 250,
                mem_bytes: 1200,
                wall_us: 0,
                spill_read: 1008,
                spill_write: 192,
                attempts: 1,
                counters: vec![("cover.evals_charged".to_string(), 200)],
            },
            Event::RoundEnd {
                round: 0,
                name: "coreset-r1-local".to_string(),
                reducers: 2,
                dist_evals: 1700,
                mem_max: 260,
                mem_p50: 255.0,
                mem_p95: 259.5,
                bytes_max: 1240,
                evals_max: 900,
                evals_p50: 850.0,
                evals_p95: 895.0,
                violations: 0,
                wall_us: 0,
            },
            Event::RunEnd {
                rounds: 1,
                dist_evals: 1700,
                max_local_memory: 260,
                max_local_bytes: 1240,
            },
        ];
        let s = render_trace_report(&events);
        assert!(s.contains("trace: schema v2"), "{s}");
        assert!(s.contains("coreset-r1-local"), "{s}");
        assert!(s.contains("cover.evals_charged"), "{s}");
        // 600 + 200 charged of 1000 baseline → 20% saved
        assert!(s.contains("pruning[cover]: 800 of 1000"), "{s}");
        assert!(s.contains("20.0% saved"), "{s}");
        assert!(s.contains("1240"), "bytes_max column missing: {s}");
        assert!(s.contains("spill: read=2016 B written=424 B"), "{s}");
        assert!(
            s.contains(
                "run: rounds=1 dist_evals=1700 max_local_memory=260 max_local_bytes=1240"
            ),
            "{s}"
        );
    }

    #[test]
    fn bench_diff_flags_regressions_and_skips_ratios() {
        let base = Json::parse(
            "{\"benchmarks\":[],\"metrics\":{\"cover_evals\":1000,\
             \"assign_evals\":500,\"gone_evals\":7,\"saved_ratio\":3.5}}",
        )
        .unwrap();
        let cur = Json::parse(
            "{\"benchmarks\":[],\"metrics\":{\"cover_evals\":1050,\
             \"assign_evals\":500,\"saved_ratio\":1.0}}",
        )
        .unwrap();
        let (text, regressions) = bench_diff(&base, &cur, 0.02);
        // cover_evals +5% regresses, gone_evals vanished, ratio ignored
        assert_eq!(regressions, 2, "{text}");
        assert!(text.contains("REGRESS  cover_evals"), "{text}");
        assert!(text.contains("MISSING  gone_evals"), "{text}");
        assert!(text.contains("ok       assign_evals"), "{text}");
        assert!(!text.contains("saved_ratio"), "{text}");
        assert!(text.contains("3 metric(s) compared, 2 regression(s)"), "{text}");

        let (text, regressions) = bench_diff(&base, &base, 0.02);
        assert_eq!(regressions, 0, "identical files must pass: {text}");
    }

    #[test]
    fn bench_diff_within_tolerance_passes() {
        let base = Json::parse("{\"metrics\":{\"evals\":10000}}").unwrap();
        let cur = Json::parse("{\"metrics\":{\"evals\":10100}}").unwrap();
        let (_, regressions) = bench_diff(&base, &cur, 0.02);
        assert_eq!(regressions, 0, "+1% is inside the 2% tolerance");
        let (_, regressions) = bench_diff(&base, &cur, 0.005);
        assert_eq!(regressions, 1, "+1% is outside a 0.5% tolerance");
    }
}
