//! mrcoreset CLI — leader entrypoint for the 3-round MapReduce
//! k-median/k-means solver and its experiment suite.
//!
//! Subcommands:
//!   run     solve a clustering instance (synthetic or CSV); `--z Z`
//!           switches to the outlier-robust (k, z) pipeline
//!   exp     run experiments e1..e12 (or `all`) and print their tables
//!   gen     generate a synthetic dataset to CSV
//!   info    report engine/artifact status
//!
//! Examples:
//!   mrcoreset run --alg kmedian --n 20000 --d 2 --k 8 --eps 0.4
//!   mrcoreset run --alg kmedian --k 8 --noise 200 --z 200
//!   mrcoreset run data.csv --alg kmeans --k 10 --eps 0.25
//!   mrcoreset exp e4 --full
//!   mrcoreset gen --n 10000 --d 4 --k 8 --out points.csv

use std::path::Path;
use std::sync::Arc;

use mrcoreset::coordinator::{solve, ClusterConfig, FinalAlgo};
use mrcoreset::coreset::TlAlgo;
use mrcoreset::data::csv;
use mrcoreset::data::synth::{GaussianMixtureSpec, NoiseSpec};
use mrcoreset::eval::{run_experiment, validate_ids, ALL_IDS};
use mrcoreset::mapreduce::PartitionStrategy;
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;
use mrcoreset::runtime::XlaEngine;
use mrcoreset::util::cli::Args;

const USAGE: &str = "usage: mrcoreset <run|exp|gen|info> [flags]
  run  [file.csv] --alg kmedian|kmeans --k K --eps E [--z Z] [--n N --d D]
       [--noise N] [--l L] [--m M] [--beta B] [--tl dpp|local-search|gonzalez]
       [--final local-search|pam|robust] [--one-round]
       [--strategy rr|contig|shuffle] [--seed S] [--no-engine]
  exp  <e1..e12|all> [--full]
  gen  --n N --d D --k K --out FILE [--spread S] [--outliers F] [--noise N]
       [--seed S]
  info

  --z Z      solve the (k, z) objective: write off the Z most expensive
             points as outliers (outlier-robust pipeline + finisher)
  --noise N  append N uniform noise points to the synthetic input";

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("exp") => cmd_exp(&args),
        Some("gen") => cmd_gen(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn objective_of(args: &Args) -> Objective {
    match args.str_or("alg", "kmedian") {
        "kmedian" | "k-median" | "median" => Objective::Median,
        "kmeans" | "k-means" | "means" => Objective::Means,
        other => {
            eprintln!("error: unknown --alg {other}");
            std::process::exit(2);
        }
    }
}

fn cmd_run(args: &Args) {
    let obj = objective_of(args);
    let k: usize = args.parse_or("k", 8);
    let eps: f64 = args.parse_or("eps", 0.5);

    // data: CSV positional, or synthetic with --n/--d
    let data = if let Some(file) = args.positional.first() {
        if args.has("noise") {
            eprintln!("note: --noise only applies to synthetic inputs; {file} is used as-is");
        }
        match csv::load_csv(Path::new(file)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    } else {
        let n: usize = args.parse_or("n", 10_000);
        let d: usize = args.parse_or("d", 2);
        let seed: u64 = args.parse_or("data-seed", 1);
        let noise: usize = args.parse_or("noise", 0);
        let spec = GaussianMixtureSpec { n, d, k: k.max(2), seed, ..Default::default() };
        if noise > 0 {
            let nspec = NoiseSpec { count: noise, seed: seed ^ 0xBAD, ..Default::default() };
            spec.generate_with_noise(&nspec).0
        } else {
            spec.generate().0
        }
    };
    let n = data.n();
    println!("input: n={} d={} objective={}", n, data.d(), obj);

    let shared = Arc::new(data);
    let space = if args.has("no-engine") {
        EuclideanSpace::new(shared)
    } else {
        match XlaEngine::load_default() {
            Some(engine) => {
                println!("engine: XLA/PJRT with {} artifacts", engine.manifest().entries.len());
                EuclideanSpace::with_engine(shared, Arc::new(engine))
            }
            None => EuclideanSpace::new(shared),
        }
    };

    let mut cfg = ClusterConfig::new(obj, k, eps);
    if args.has("l") {
        cfg.l = Some(args.parse_or("l", 0));
    }
    if args.has("m") {
        cfg.m = Some(args.parse_or("m", 2 * k));
    }
    cfg.beta = args.parse_or("beta", cfg.beta);
    cfg.seed = args.parse_or("seed", cfg.seed);
    cfg.outliers = args.parse_or("z", 0);
    cfg.one_round = args.has("one-round");
    cfg.tl = match args.str_or("tl", "dpp") {
        "dpp" => TlAlgo::DppSeeding,
        "local-search" => TlAlgo::LocalSearch,
        "gonzalez" => TlAlgo::Gonzalez,
        other => {
            eprintln!("error: unknown --tl {other}");
            std::process::exit(2);
        }
    };
    cfg.final_algo = match args.str_or("final", "local-search") {
        "local-search" => FinalAlgo::LocalSearch,
        "pam" => FinalAlgo::Pam,
        "robust" | "robust-local-search" => FinalAlgo::RobustLocalSearch,
        other => {
            eprintln!("error: unknown --final {other}");
            std::process::exit(2);
        }
    };
    cfg.strategy = match args.str_or("strategy", "rr") {
        "rr" => PartitionStrategy::RoundRobin,
        "contig" => PartitionStrategy::Contiguous,
        "shuffle" => PartitionStrategy::Shuffled(cfg.seed),
        other => {
            eprintln!("error: unknown --strategy {other}");
            std::process::exit(2);
        }
    };

    // the robust pipeline (--z, or --final robust on its own) has its
    // own round structure and center counts — tell the user which
    // knobs it overrides
    let robust_run = cfg.outliers > 0 || cfg.final_algo == FinalAlgo::RobustLocalSearch;
    if robust_run {
        if cfg.outliers > 0 && args.has("final") && cfg.final_algo != FinalAlgo::RobustLocalSearch
        {
            eprintln!("note: --z overrides --final (robust local search is used)");
        }
        if cfg.one_round {
            eprintln!("note: the robust pipeline ignores --one-round (it is 2-round)");
        }
        if args.has("m") {
            eprintln!(
                "note: the robust pipeline sets per-partition centers to k + ceil(z/L)*2; \
                 --m is ignored"
            );
        }
    }

    let pts: Vec<u32> = (0..n as u32).collect();
    let rep = solve(&space, &pts, &cfg);
    print!("{}", rep.summary());
    println!("centers: {:?}", rep.solution.centers);
}

fn cmd_exp(args: &Args) {
    let quick = !args.has("full");
    let ids: Vec<&str> = match args.positional.first().map(String::as_str) {
        Some("all") | None => ALL_IDS.to_vec(),
        Some(id) => vec![id],
    };
    // Validate up front (a typo costs nothing), then stream each
    // experiment's tables as soon as it completes.
    if let Err(e) = validate_ids(&ids) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    for id in ids {
        if let Some(res) = run_experiment(id, quick) {
            println!("{}", res.render());
        }
    }
}

fn cmd_gen(args: &Args) {
    let spec = GaussianMixtureSpec {
        n: args.parse_or("n", 10_000),
        d: args.parse_or("d", 2),
        k: args.parse_or("k", 8),
        spread: args.parse_or("spread", 20.0),
        outlier_frac: args.parse_or("outliers", 0.0),
        seed: args.parse_or("seed", 1),
    };
    let out = args.str_or("out", "points.csv");
    let noise: usize = args.parse_or("noise", 0);
    let (data, _) = if noise > 0 {
        spec.generate_with_noise(&NoiseSpec {
            count: noise,
            seed: spec.seed ^ 0xBAD,
            ..Default::default()
        })
    } else {
        spec.generate()
    };
    if let Err(e) = csv::save_csv(Path::new(out), &data) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
    println!("wrote {} points ({} dims) to {out}", data.n(), data.d());
}

fn cmd_info() {
    println!(
        "mrcoreset {} — 3-round MapReduce k-median/k-means (Mazzetto et al. 2019)",
        env!("CARGO_PKG_VERSION")
    );
    match XlaEngine::load_default() {
        Some(engine) => {
            let m = engine.manifest();
            println!("engine: available, {} artifacts", m.entries.len());
            println!(
                "  assign_cost max n = {}, min_update max n = {}",
                m.max_n(mrcoreset::runtime::ArtifactKind::AssignCost),
                m.max_n(mrcoreset::runtime::ArtifactKind::MinUpdate)
            );
        }
        None => println!("engine: unavailable (run `make artifacts`)"),
    }
    println!("threads: {}", mrcoreset::util::pool::default_threads());
}
