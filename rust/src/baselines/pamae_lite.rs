//! PAMAE-style baseline (Song, Lee, Han, KDD'17 — paper ref [24]):
//! parallel k-medoids via sampling + PAM + global refinement.
//!
//! Phase 1: draw S independent uniform samples of size s; run PAM on
//! each (in parallel, one MR round); keep the candidate solution with
//! the best *global* cost (second MR round evaluates all candidates).
//! Phase 2: assign all points to the winning medoids, then refine each
//! cluster's medoid by exact 1-median over a per-cluster sample (third
//! round). As the paper notes, PAMAE has strong practice but no tight
//! approximation analysis — E8 shows where it lands.
//!
//! Candidate evaluation, the phase-2 assignment, and the per-cluster
//! refinement all run bounds-pruned ([`assign_pruned`] /
//! [`exact_one_center_pruned`]); [`run_unpruned`] is the reference twin
//! paying the historical full scans, bit-identical by construction.

use crate::algorithms::brute::{exact_one_center, exact_one_center_pruned};
use crate::algorithms::pam::{pam, PamCfg};
use crate::algorithms::{Instance, Solution};
use crate::mapreduce::Simulator;
use crate::metric::pruned::{assign_pruned, assign_reference};
use crate::metric::{Assignment, MetricSpace, Objective};
use crate::util::rng::Rng;

use super::BaselineReport;

pub struct PamaeCfg {
    /// Number of parallel samples (candidate solutions).
    pub num_samples: usize,
    /// Sample size for each PAM run.
    pub sample_size: usize,
    /// Per-cluster refinement sample size (phase 2).
    pub refine_size: usize,
    pub seed: u64,
}

impl PamaeCfg {
    pub fn new(k: usize) -> PamaeCfg {
        PamaeCfg { num_samples: 5, sample_size: (40 * k).max(120), refine_size: 400, seed: 0x9A3 }
    }
}

/// Bounds-pruned PAMAE-lite (bit-identical to [`run_unpruned`]).
pub fn run(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &PamaeCfg,
    sim: &Simulator,
) -> BaselineReport {
    run_impl(space, obj, pts, k, cfg, sim, true)
}

/// Reference twin: identical structure and RNG stream, full scans.
pub fn run_unpruned(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &PamaeCfg,
    sim: &Simulator,
) -> BaselineReport {
    run_impl(space, obj, pts, k, cfg, sim, false)
}

fn assign_full(
    space: &dyn MetricSpace,
    pts: &[u32],
    centers: &[u32],
    pruned: bool,
) -> Assignment {
    if pruned {
        assign_pruned(space, pts, centers)
    } else {
        assign_reference(space, pts, centers)
    }
}

fn run_impl(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &PamaeCfg,
    sim: &Simulator,
    pruned: bool,
) -> BaselineReport {
    let mut rng = Rng::new(cfg.seed);
    let s = cfg.sample_size.min(pts.len());

    // Phase 1a: PAM on each sample (one parallel round)
    let samples: Vec<Vec<u32>> = (0..cfg.num_samples)
        .map(|_| rng.sample_distinct(pts.len(), s).into_iter().map(|i| pts[i]).collect())
        .collect();
    let candidates: Vec<Solution> = sim.round("pamae-pam", samples, |_, sample, meter| {
        meter.charge(sample.len());
        let w = vec![1u64; sample.len()];
        let pc = PamCfg { max_n: sample.len().max(1), max_iters: 20 };
        let sol = pam(space, obj, Instance::new(sample, &w), k, &pc);
        meter.release(sample.len());
        sol
    });

    // Phase 1b: global evaluation of every candidate (one round,
    // partition-parallel in a real deployment; here one pass each)
    let best = sim
        .round("pamae-eval", candidates, |_, cand, meter| {
            meter.charge(pts.len() / 8); // per-partition share in a real run
            let cost = assign_full(space, pts, &cand.centers, pruned).cost_unit(obj);
            meter.release(pts.len() / 8);
            (cand.centers.clone(), cost)
        })
        .into_iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .expect("at least one candidate");

    // Phase 2: per-cluster exact medoid over a refinement sample
    let assign = assign_full(space, pts, &best.0, pruned);
    let kk = best.0.len();
    let mut clusters: Vec<Vec<u32>> = vec![Vec::new(); kk];
    for (i, &p) in pts.iter().enumerate() {
        clusters[assign.idx[i] as usize].push(p);
    }
    let refined: Vec<u32> = sim.round("pamae-refine", clusters, |j, cluster, meter| {
        if cluster.is_empty() {
            return best.0[j];
        }
        let mut crng = Rng::new(cfg.seed ^ (j as u64 + 0x51));
        let take = cfg.refine_size.min(cluster.len());
        let sample: Vec<u32> =
            crng.sample_distinct(cluster.len(), take).into_iter().map(|i| cluster[i]).collect();
        meter.charge(sample.len());
        let w = vec![1u64; sample.len()];
        let inst = Instance::new(&sample, &w);
        let (c, _) = if pruned {
            exact_one_center_pruned(space, obj, inst)
        } else {
            exact_one_center(space, obj, inst)
        };
        meter.release(sample.len());
        c
    });

    // keep the better of (refined, phase-1 best) — refinement on a sample
    // can regress on adversarial weights
    let refined_cost = assign_full(space, pts, &refined, pruned).cost_unit(obj);
    let (centers, full_cost) =
        if refined_cost <= best.1 { (refined, refined_cost) } else { (best.0, best.1) };

    BaselineReport {
        name: "pamae-lite",
        solution: Solution { centers, cost: full_cost },
        full_cost,
        summary_size: cfg.num_samples * s,
        rounds: 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    #[test]
    fn solves_separated_mixture_well() {
        let spec = GaussianMixtureSpec {
            n: 1500,
            d: 2,
            k: 4,
            spread: 60.0,
            seed: 1,
            ..Default::default()
        };
        let (data, _) = spec.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..1500).collect();
        let sim = Simulator::new();
        let cfg = PamaeCfg { num_samples: 3, sample_size: 150, refine_size: 200, seed: 5 };
        let rep = run(&space, Objective::Median, &pts, 4, &cfg, &sim);
        assert_eq!(rep.solution.centers.len(), 4);
        // separated blobs: average distance to own center ~1.25 (d=2)
        assert!(rep.full_cost / 1500.0 < 2.5, "avg cost {}", rep.full_cost / 1500.0);
        assert_eq!(rep.rounds, 3);
    }

    #[test]
    fn refinement_never_hurts() {
        let (data, _) =
            GaussianMixtureSpec { n: 800, d: 2, k: 3, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..800).collect();
        let sim = Simulator::new();
        let cfg = PamaeCfg { num_samples: 2, sample_size: 80, refine_size: 100, seed: 6 };
        let rep = run(&space, Objective::Means, &pts, 3, &cfg, &sim);
        // phase-2 keeps the better of refined/unrefined by construction;
        // just assert the solve completed with finite cost
        assert!(rep.full_cost.is_finite());
    }
}
