//! k-means‖ (Bahmani, Moseley, Vattani, Kumar, Vassilvitskii, PVLDB'12 —
//! paper ref [5]): oversampled parallel seeding.
//!
//! Starting from one random center, run ~O(log n) rounds; in each round
//! every point joins the candidate set independently with probability
//! min(1, ℓ · cost(x) / total_cost). Candidates are then weighted by
//! Voronoi counts and reduced to k centers with a weighted sequential
//! algorithm. The candidate set is the "coreset" analogue (size ≈ ℓ ×
//! rounds), and the guarantee is O(α) — weaker than the paper's α+O(ε).

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::Instance;
use crate::mapreduce::{partition, PartitionStrategy, Simulator};
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::rng::Rng;

use super::BaselineReport;

pub struct KmeansParCfg {
    /// Oversampling factor ℓ (expected new candidates per round); the
    /// original paper suggests ℓ = Θ(k) (e.g. 2k).
    pub ell: f64,
    /// Sampling rounds (≈ 5 suffices in practice per the original paper).
    pub rounds: usize,
    pub seed: u64,
}

impl KmeansParCfg {
    pub fn new(k: usize) -> KmeansParCfg {
        KmeansParCfg { ell: 2.0 * k as f64, rounds: 5, seed: 0xBAA }
    }
}

pub fn run(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &KmeansParCfg,
    sim: &Simulator,
) -> BaselineReport {
    let mut rng = Rng::new(cfg.seed);
    let mut candidates: Vec<u32> = vec![pts[rng.below(pts.len())]];
    // running min cost(x, C): plain distances; objective decides the power
    let mut mind = vec![f64::INFINITY; pts.len()];
    space.min_update(pts, candidates[0], &mut mind);
    let mut mr_rounds = 0usize;

    for round in 0..cfg.rounds {
        let total: f64 = mind.iter().map(|&d| obj.cost_of(d)).sum();
        if total <= 0.0 {
            break; // all points are candidates already
        }
        // one MR round: each partition samples independently
        let parts = partition(pts, 8, PartitionStrategy::RoundRobin);
        let mind_ref = &mind;
        let round_seed = cfg.seed ^ ((round as u64 + 1) << 32);
        let new_parts = sim.round("kmeans||-sample", parts, move |ell_idx, part, meter| {
            meter.charge(part.len());
            let mut prng = Rng::new(round_seed ^ ell_idx as u64);
            let mut picked = Vec::new();
            for &p in part {
                // mind is indexed by position in pts == point id here
                let c = obj.cost_of(mind_ref[p as usize]);
                let prob = (cfg.ell * c / total).min(1.0);
                if prng.f64() < prob {
                    picked.push(p);
                }
            }
            meter.release(part.len());
            picked
        });
        mr_rounds += 1;
        let mut added = false;
        for np in new_parts {
            for p in np {
                if !candidates.contains(&p) {
                    candidates.push(p);
                    space.min_update(pts, p, &mut mind);
                    added = true;
                }
            }
        }
        if !added {
            break;
        }
    }

    // weight candidates by Voronoi counts and reduce to k
    let assign = space.assign(pts, &candidates);
    let mut w = vec![0u64; candidates.len()];
    for &j in &assign.idx {
        w[j as usize] += 1;
    }
    let mut idxs = Vec::new();
    let mut wts = Vec::new();
    for (i, &wi) in w.iter().enumerate() {
        if wi > 0 {
            idxs.push(candidates[i]);
            wts.push(wi);
        }
    }
    let cand = WeightedSet::new(idxs, wts);
    let sols = sim.round("kmeans||-reduce", vec![cand.clone()], |_, cs, meter| {
        meter.charge(cs.len());
        let ls = LocalSearchCfg { seed: cfg.seed ^ 0x88, ..Default::default() };
        local_search(space, obj, Instance::new(&cs.indices, &cs.weights), k, None, &ls)
    });
    mr_rounds += 1;
    let solution = sols.into_iter().next().unwrap();
    let full_cost = space.assign(pts, &solution.centers).cost_unit(obj);
    BaselineReport {
        name: "kmeans||",
        solution,
        full_cost,
        summary_size: cand.len(),
        rounds: mr_rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    #[test]
    fn finds_reasonable_centers() {
        let spec = GaussianMixtureSpec {
            n: 2000,
            d: 2,
            k: 5,
            spread: 50.0,
            seed: 1,
            ..Default::default()
        };
        let (data, _) = spec.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..2000).collect();
        let sim = Simulator::new();
        let rep = run(&space, Objective::Means, &pts, 5, &KmeansParCfg::new(5), &sim);
        assert_eq!(rep.solution.centers.len(), 5);
        // well-separated blobs (unit variance, spread 50): near-opt cost is
        // ~2n (d=2); allow generous slack
        assert!(rep.full_cost < 2000.0 * 2.0 * 4.0, "cost {}", rep.full_cost);
        assert!(rep.summary_size >= 5);
        assert!(rep.rounds <= 7);
    }

    #[test]
    fn candidate_set_grows_with_ell() {
        let (data, _) =
            GaussianMixtureSpec { n: 3000, d: 2, k: 6, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..3000).collect();
        let sim = Simulator::new();
        let small = run(
            &space,
            Objective::Means,
            &pts,
            6,
            &KmeansParCfg { ell: 6.0, rounds: 4, seed: 3 },
            &sim,
        );
        let big = run(
            &space,
            Objective::Means,
            &pts,
            6,
            &KmeansParCfg { ell: 30.0, rounds: 4, seed: 3 },
            &sim,
        );
        assert!(big.summary_size > small.summary_size);
    }
}
