//! k-means‖ (Bahmani, Moseley, Vattani, Kumar, Vassilvitskii, PVLDB'12 —
//! paper ref [5]): oversampled parallel seeding.
//!
//! Starting from one random center, run ~O(log n) rounds; in each round
//! every point joins the candidate set independently with probability
//! min(1, ℓ · cost(x) / total_cost). Candidates are then weighted by
//! Voronoi counts and reduced to k centers with a weighted sequential
//! algorithm. The candidate set is the "coreset" analogue (size ≈ ℓ ×
//! rounds), and the guarantee is O(α) — weaker than the paper's α+O(ε).
//!
//! The incremental cost tracking (fold each accepted candidate into the
//! running min) goes through [`NearestTracker`], so on uniform-precision
//! spaces most folds are vetoed by triangle-inequality bounds; the final
//! Voronoi weighting falls out of the same tracked state for free.
//! [`run_unpruned`] is the reference twin paying the historical full
//! folds — both produce bit-identical reports.

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::Instance;
use crate::mapreduce::{partition, PartitionStrategy, Simulator};
use crate::metric::pruned::{assign_pruned, assign_reference, NearestTracker};
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;

use super::BaselineReport;

pub struct KmeansParCfg {
    /// Oversampling factor ℓ (expected new candidates per round); the
    /// original paper suggests ℓ = Θ(k) (e.g. 2k).
    pub ell: f64,
    /// Sampling rounds (≈ 5 suffices in practice per the original paper).
    pub rounds: usize,
    pub seed: u64,
}

impl KmeansParCfg {
    pub fn new(k: usize) -> KmeansParCfg {
        KmeansParCfg { ell: 2.0 * k as f64, rounds: 5, seed: 0xBAA }
    }
}

/// O(1) membership-checked candidate append; returns whether `p` was new.
/// Replaces the old `Vec::contains` scan (O(|C|) per insert) without
/// changing which ids are appended or in what order.
#[inline]
fn dedup_push(member: &mut Bitset, candidates: &mut Vec<u32>, p: u32) -> bool {
    if member.contains(p) {
        return false;
    }
    member.insert(p);
    candidates.push(p);
    true
}

/// Bounds-pruned k-means‖ (bit-identical to [`run_unpruned`]).
pub fn run(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &KmeansParCfg,
    sim: &Simulator,
) -> BaselineReport {
    run_impl(space, obj, pts, k, cfg, sim, true)
}

/// Reference twin: identical structure and RNG stream, every candidate
/// fold and the final Voronoi pass computed in full.
pub fn run_unpruned(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &KmeansParCfg,
    sim: &Simulator,
) -> BaselineReport {
    run_impl(space, obj, pts, k, cfg, sim, false)
}

fn run_impl(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &KmeansParCfg,
    sim: &Simulator,
    pruned: bool,
) -> BaselineReport {
    let mut rng = Rng::new(cfg.seed);
    let first = pts[rng.below(pts.len())];
    // running min cost(x, C): plain distances; objective decides the power
    let mut tracker = NearestTracker::new(space, pts, pruned);
    tracker.push(first);
    let mut candidates: Vec<u32> = Vec::new();
    let mut member = Bitset::new(space.n_points());
    dedup_push(&mut member, &mut candidates, first);
    let mut mr_rounds = 0usize;
    // the samplers read per-point residuals, which live in `pts` order —
    // partition positions, not ids, so subset/permuted inputs index the
    // right residual
    let positions: Vec<u32> = (0..pts.len() as u32).collect();

    for round in 0..cfg.rounds {
        let total: f64 = tracker.dist().iter().map(|&d| obj.cost_of(d)).sum();
        if total <= 0.0 {
            break; // all points are candidates already
        }
        // one MR round: each partition samples independently
        let parts = partition(&positions, 8, PartitionStrategy::RoundRobin);
        let mind_ref = tracker.dist();
        let round_seed = cfg.seed ^ ((round as u64 + 1) << 32);
        let new_parts = sim.round("kmeans||-sample", parts, move |ell_idx, part, meter| {
            meter.charge(part.len());
            let mut prng = Rng::new(round_seed ^ ell_idx as u64);
            let mut picked = Vec::new();
            for &pos in part {
                let c = obj.cost_of(mind_ref[pos as usize]);
                let prob = (cfg.ell * c / total).min(1.0);
                if prng.f64() < prob {
                    picked.push(pts[pos as usize]);
                }
            }
            meter.release(part.len());
            picked
        });
        mr_rounds += 1;
        let mut added = false;
        for np in new_parts {
            for p in np {
                if dedup_push(&mut member, &mut candidates, p) {
                    tracker.push(p);
                    added = true;
                }
            }
        }
        if !added {
            break;
        }
    }

    // weight candidates by Voronoi counts and reduce to k; the pruned
    // path already holds the full-candidate assignment in the tracker,
    // the reference twin pays the historical full Voronoi pass
    let idx: Vec<u32> = if pruned {
        tracker.idx().to_vec()
    } else {
        assign_reference(space, pts, &candidates).idx
    };
    let mut w = vec![0u64; candidates.len()];
    for &j in &idx {
        w[j as usize] += 1;
    }
    let mut idxs = Vec::new();
    let mut wts = Vec::new();
    for (i, &wi) in w.iter().enumerate() {
        if wi > 0 {
            idxs.push(candidates[i]);
            wts.push(wi);
        }
    }
    let cand = WeightedSet::new(idxs, wts);
    let sols = sim.round("kmeans||-reduce", vec![cand.clone()], |_, cs, meter| {
        meter.charge(cs.len());
        let ls = LocalSearchCfg { seed: cfg.seed ^ 0x88, ..Default::default() };
        let sol = local_search(space, obj, Instance::new(&cs.indices, &cs.weights), k, None, &ls);
        meter.release(cs.len());
        sol
    });
    mr_rounds += 1;
    let solution = sols.into_iter().next().unwrap();
    let full_cost = if pruned {
        assign_pruned(space, pts, &solution.centers).cost_unit(obj)
    } else {
        assign_reference(space, pts, &solution.centers).cost_unit(obj)
    };
    BaselineReport {
        name: "kmeans||",
        solution,
        full_cost,
        summary_size: cand.len(),
        rounds: mr_rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    #[test]
    fn finds_reasonable_centers() {
        let spec = GaussianMixtureSpec {
            n: 2000,
            d: 2,
            k: 5,
            spread: 50.0,
            seed: 1,
            ..Default::default()
        };
        let (data, _) = spec.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..2000).collect();
        let sim = Simulator::new();
        let rep = run(&space, Objective::Means, &pts, 5, &KmeansParCfg::new(5), &sim);
        assert_eq!(rep.solution.centers.len(), 5);
        // well-separated blobs (unit variance, spread 50): near-opt cost is
        // ~2n (d=2); allow generous slack
        assert!(rep.full_cost < 2000.0 * 2.0 * 4.0, "cost {}", rep.full_cost);
        assert!(rep.summary_size >= 5);
        assert!(rep.rounds <= 7);
    }

    #[test]
    fn candidate_set_grows_with_ell() {
        let (data, _) =
            GaussianMixtureSpec { n: 3000, d: 2, k: 6, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..3000).collect();
        let sim = Simulator::new();
        let small = run(
            &space,
            Objective::Means,
            &pts,
            6,
            &KmeansParCfg { ell: 6.0, rounds: 4, seed: 3 },
            &sim,
        );
        let big = run(
            &space,
            Objective::Means,
            &pts,
            6,
            &KmeansParCfg { ell: 30.0, rounds: 4, seed: 3 },
            &sim,
        );
        assert!(big.summary_size > small.summary_size);
    }

    /// Regression (wrong-index read): the samplers used to index the
    /// residual vector with the point *id*, silently assuming `pts` is
    /// the identity `0..n`. A shuffled strict subset of ids made them
    /// read the wrong residual or run off the end of the vector.
    #[test]
    fn runs_on_shuffled_strict_subset_of_ids() {
        let (data, _) = GaussianMixtureSpec {
            n: 2000,
            d: 2,
            k: 5,
            spread: 40.0,
            seed: 8,
            ..Default::default()
        }
        .generate();
        let space = EuclideanSpace::new(Arc::new(data));
        // ids 1200..2000, shuffled: every id exceeds the residual length
        let mut pts: Vec<u32> = (1200..2000).collect();
        crate::util::rng::Rng::new(99).shuffle(&mut pts);
        let sim = Simulator::new();
        let rep = run(&space, Objective::Means, &pts, 4, &KmeansParCfg::new(4), &sim);
        assert_eq!(rep.solution.centers.len(), 4);
        assert!(rep.solution.centers.iter().all(|c| pts.contains(c)));
        assert!(rep.full_cost.is_finite() && rep.full_cost > 0.0);
    }

    /// Regression (dedup rewrite): bitset membership must accept exactly
    /// the ids `Vec::contains` accepted, in the same order, or seeded
    /// runs would drift.
    #[test]
    fn bitset_dedup_matches_contains_dedup_order() {
        let mut rng = crate::util::rng::Rng::new(0xDED0);
        for _ in 0..20 {
            let stream: Vec<u32> = (0..300).map(|_| rng.below(64) as u32).collect();
            let mut member = Bitset::new(64);
            let mut fast: Vec<u32> = Vec::new();
            let mut slow: Vec<u32> = Vec::new();
            for &p in &stream {
                dedup_push(&mut member, &mut fast, p);
                if !slow.contains(&p) {
                    slow.push(p);
                }
            }
            assert_eq!(fast, slow);
        }
    }
}
