//! Uniform-sampling coreset baseline (1 MapReduce round).
//!
//! Each reducer samples `s/L` of its points uniformly, weights each
//! sample point by the size of its Voronoi cell within the partition
//! (so total weight is conserved), and the union is the coreset. This is
//! the natural composable baseline: cheap, unbiased, but with no
//! per-point proximity guarantee — sparse regions are missed, which is
//! exactly what CoverWithBalls fixes. E8 quantifies the gap.

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::Instance;
use crate::mapreduce::{partition, PartitionStrategy, Simulator};
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::rng::Rng;

use super::BaselineReport;

pub struct UniformCfg {
    /// Total coreset size across all partitions.
    pub size: usize,
    pub l: usize,
    pub seed: u64,
}

pub fn run(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &UniformCfg,
    sim: &Simulator,
) -> BaselineReport {
    let parts = partition(pts, cfg.l, PartitionStrategy::RoundRobin);
    let per_part = (cfg.size / parts.len()).max(k).max(1);
    let inputs: Vec<(usize, Vec<u32>)> = parts.into_iter().enumerate().collect();
    let locals = sim.round("uniform-sample", inputs, |_, (ell, part), meter| {
        meter.charge(part.len());
        let mut rng = Rng::new(cfg.seed ^ (0x17 + *ell as u64));
        let s = per_part.min(part.len());
        let sample_pos = rng.sample_distinct(part.len(), s);
        let sample: Vec<u32> = sample_pos.iter().map(|&i| part[i]).collect();
        // weight by Voronoi counts within the partition
        let assign = space.assign(part, &sample);
        let mut w = vec![0u64; sample.len()];
        for &j in &assign.idx {
            w[j as usize] += 1;
        }
        // drop zero-weight samples (possible only with duplicate points)
        let mut idxs = Vec::new();
        let mut wts = Vec::new();
        for (i, &wi) in w.iter().enumerate() {
            if wi > 0 {
                idxs.push(sample[i]);
                wts.push(wi);
            }
        }
        meter.charge(idxs.len());
        meter.release(part.len() + idxs.len());
        WeightedSet::new(idxs, wts)
    });
    let coreset = WeightedSet::union(&locals);

    let sols = sim.round("uniform-solve", vec![coreset.clone()], |_, cs, meter| {
        meter.charge(cs.len());
        let ls = LocalSearchCfg { seed: cfg.seed ^ 0xBEE, ..Default::default() };
        let sol = local_search(space, obj, Instance::new(&cs.indices, &cs.weights), k, None, &ls);
        meter.release(cs.len());
        sol
    });
    let solution = sols.into_iter().next().unwrap();
    let full_cost = space.assign(pts, &solution.centers).cost_unit(obj);
    BaselineReport {
        name: "uniform",
        solution,
        full_cost,
        summary_size: coreset.len(),
        rounds: 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    #[test]
    fn produces_valid_solution_and_conserves_weight() {
        let (data, _) =
            GaussianMixtureSpec { n: 2000, d: 2, k: 4, seed: 1, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..2000).collect();
        let sim = Simulator::new();
        let cfg = UniformCfg { size: 200, l: 5, seed: 3 };
        let rep = run(&space, Objective::Median, &pts, 4, &cfg, &sim);
        assert_eq!(rep.solution.centers.len(), 4);
        assert!(rep.summary_size <= 200 + 5);
        assert!(rep.full_cost.is_finite());
        assert_eq!(sim.take_stats().num_rounds(), 2);
    }

    #[test]
    fn bigger_sample_no_worse_on_average() {
        let (data, _) =
            GaussianMixtureSpec { n: 3000, d: 2, k: 6, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..3000).collect();
        let sim = Simulator::new();
        let mut small_total = 0.0;
        let mut big_total = 0.0;
        for seed in 0..3 {
            let small = run(
                &space,
                Objective::Median,
                &pts,
                6,
                &UniformCfg { size: 30, l: 5, seed },
                &sim,
            );
            let big = run(
                &space,
                Objective::Median,
                &pts,
                6,
                &UniformCfg { size: 600, l: 5, seed },
                &sim,
            );
            small_total += small.full_cost;
            big_total += big.full_cost;
        }
        assert!(big_total <= small_total * 1.1, "big {big_total} vs small {small_total}");
    }
}
