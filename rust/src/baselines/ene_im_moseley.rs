//! Ene–Im–Moseley iterative-sampling MapReduce coreset (KDD'11, paper
//! ref [10]), adapted to our substrate.
//!
//! Their `Iterative-Sample` routine builds a coreset by repeated uniform
//! sampling: in each iteration, add a uniform sample S to the pivot set
//! C, compute every remaining point's distance to C, and discard the
//! closest half (they are "well served" by C); stop when the remainder
//! fits in one machine and add it wholesale. Points are finally weighted
//! by the Voronoi cell sizes of the pivots over the whole input. Running
//! an α-approximation on the weighted pivots gives their weak
//! (10α + 3)-style guarantee — the accuracy gap E8 measures against the
//! paper's ε-coreset.
//!
//! MapReduce shape: the sampling iterations are driven from the leader
//! over the simulator in O(log(n / (k·n^δ))) implicit rounds; we count
//! one round per sampling iteration plus one weighting round.

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::Instance;
use crate::mapreduce::Simulator;
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::rng::Rng;

use super::BaselineReport;

pub struct EimCfg {
    /// Per-iteration sample size (their k·|P|^δ; pick ~coreset_target/iters).
    pub sample_per_iter: usize,
    /// Stop when the remaining set is at most this large.
    pub stop_below: usize,
    pub seed: u64,
}

pub fn run(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &EimCfg,
    sim: &Simulator,
) -> BaselineReport {
    let mut rng = Rng::new(cfg.seed);
    let mut remaining: Vec<u32> = pts.to_vec();
    let mut pivots: Vec<u32> = Vec::new();
    let mut rounds = 0usize;

    while remaining.len() > cfg.stop_below.max(1) {
        // sample uniformly from the remaining points
        let s = cfg.sample_per_iter.min(remaining.len());
        let sample: Vec<u32> =
            rng.sample_distinct(remaining.len(), s).into_iter().map(|i| remaining[i]).collect();
        pivots.extend_from_slice(&sample);

        // one MR round: distance of each remaining point to the pivots
        let parts = crate::mapreduce::partition(
            &remaining,
            8,
            crate::mapreduce::PartitionStrategy::RoundRobin,
        );
        let pivots_ref = &pivots;
        let dist_parts = sim.round("eim-sample-filter", parts, move |_, part, meter| {
            meter.charge(part.len() + pivots_ref.len());
            let a = space.assign(part, pivots_ref);
            meter.release(part.len() + pivots_ref.len());
            (part.clone(), a.dist)
        });
        rounds += 1;

        // discard the closest half (well-served points)
        let mut flat: Vec<(u32, f64)> = dist_parts
            .into_iter()
            .flat_map(|(part, dist)| part.into_iter().zip(dist))
            .collect();
        flat.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let keep_from = flat.len() / 2;
        remaining = flat[keep_from..].iter().map(|&(p, _)| p).collect();
    }
    pivots.extend_from_slice(&remaining);
    pivots.sort_unstable();
    pivots.dedup();

    // weighting round: Voronoi counts of pivots over the full input
    let parts =
        crate::mapreduce::partition(pts, 8, crate::mapreduce::PartitionStrategy::RoundRobin);
    let pivots_ref = &pivots;
    let counts = sim.round("eim-weight", parts, move |_, part, meter| {
        meter.charge(part.len() + pivots_ref.len());
        let a = space.assign(part, pivots_ref);
        let mut w = vec![0u64; pivots_ref.len()];
        for &j in &a.idx {
            w[j as usize] += 1;
        }
        meter.release(part.len() + pivots_ref.len());
        w
    });
    rounds += 1;
    let mut weights = vec![0u64; pivots.len()];
    for w in counts {
        for (acc, wi) in weights.iter_mut().zip(w) {
            *acc += wi;
        }
    }
    // drop zero-weight pivots (duplicates that never win an assignment)
    let mut idxs = Vec::new();
    let mut wts = Vec::new();
    for (i, &w) in weights.iter().enumerate() {
        if w > 0 {
            idxs.push(pivots[i]);
            wts.push(w);
        }
    }
    let coreset = WeightedSet::new(idxs, wts);

    // final solve on the weighted pivots
    let sols = sim.round("eim-solve", vec![coreset.clone()], |_, cs, meter| {
        meter.charge(cs.len());
        let ls = LocalSearchCfg { seed: cfg.seed ^ 0xE1E, ..Default::default() };
        local_search(space, obj, Instance::new(&cs.indices, &cs.weights), k, None, &ls)
    });
    rounds += 1;
    let solution = sols.into_iter().next().unwrap();
    let full_cost = space.assign(pts, &solution.centers).cost_unit(obj);
    BaselineReport {
        name: "ene-im-moseley",
        solution,
        full_cost,
        summary_size: coreset.len(),
        rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    #[test]
    fn terminates_and_solves() {
        let (data, _) =
            GaussianMixtureSpec { n: 2000, d: 2, k: 4, seed: 1, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..2000).collect();
        let sim = Simulator::new();
        let cfg = EimCfg { sample_per_iter: 60, stop_below: 100, seed: 7 };
        let rep = run(&space, Objective::Median, &pts, 4, &cfg, &sim);
        assert_eq!(rep.solution.centers.len(), 4);
        assert!(rep.full_cost.is_finite() && rep.full_cost > 0.0);
        // halving from 2000 to 100: ~5 sample rounds + weight + solve
        assert!(rep.rounds >= 4 && rep.rounds <= 10, "rounds {}", rep.rounds);
        assert!(rep.summary_size >= 100);
    }

    #[test]
    fn weight_total_conserved() {
        let (data, _) =
            GaussianMixtureSpec { n: 1000, d: 2, k: 3, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..1000).collect();
        let sim = Simulator::new();
        let cfg = EimCfg { sample_per_iter: 40, stop_below: 80, seed: 9 };
        // the report doesn't expose the coreset, so sanity-check the
        // externally-visible invariants instead:
        let rep = run(&space, Objective::Means, &pts, 3, &cfg, &sim);
        assert!(rep.summary_size < 1000);
        assert!(rep.full_cost > 0.0);
    }
}
