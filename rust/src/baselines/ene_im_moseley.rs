//! Ene–Im–Moseley iterative-sampling MapReduce coreset (KDD'11, paper
//! ref [10]), adapted to our substrate.
//!
//! Their `Iterative-Sample` routine builds a coreset by repeated uniform
//! sampling: in each iteration, add a uniform sample S to the pivot set
//! C, compute every remaining point's distance to C, and discard the
//! closest half (they are "well served" by C); stop when the remainder
//! fits in one machine and add it wholesale. Points are finally weighted
//! by the Voronoi cell sizes of the pivots over the whole input. Running
//! an α-approximation on the weighted pivots gives their weak
//! (10α + 3)-style guarantee — the accuracy gap E8 measures against the
//! paper's ε-coreset.
//!
//! MapReduce shape: the sampling iterations are driven from the leader
//! over the simulator in O(log(n / (k·n^δ))) implicit rounds; we count
//! one round per sampling iteration plus one weighting round.
//!
//! Pruning: surviving points carry their nearest-pivot state across
//! iterations, so each filtering round only folds the *new* pivots —
//! and those folds go through [`NearestTracker`] against center-to-
//! center rows the leader broadcasts once per iteration. The state
//! carry requires `uniform_precision` (distances must not depend on
//! batch composition); otherwise the pruned entry point transparently
//! runs the reference full recompute. [`run_unpruned`] is the public
//! reference twin, bit-identical by construction.

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::Instance;
use crate::mapreduce::{partition, PartitionStrategy, Simulator};
use crate::metric::pruned::{assign_pruned, assign_reference, center_rows, NearestTracker};
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::rng::Rng;

use super::BaselineReport;

pub struct EimCfg {
    /// Per-iteration sample size (their k·|P|^δ; pick ~coreset_target/iters).
    pub sample_per_iter: usize,
    /// Stop when the remaining set is at most this large.
    pub stop_below: usize,
    pub seed: u64,
}

/// NaN-safe total-order sort by (distance, point id): the kept half is
/// well-defined regardless of the gather order of the reducer outputs
/// (distance ties broken by id), and a hostile metric emitting NaN
/// sorts last instead of panicking the comparator.
fn sort_by_distance(flat: &mut [(u32, f64, u32)]) {
    flat.sort_unstable_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
}

/// Bounds-pruned Ene–Im–Moseley (bit-identical to [`run_unpruned`]).
pub fn run(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &EimCfg,
    sim: &Simulator,
) -> BaselineReport {
    run_impl(space, obj, pts, k, cfg, sim, true)
}

/// Reference twin: identical structure and RNG stream, every filtering
/// and weighting round recomputed in full.
pub fn run_unpruned(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &EimCfg,
    sim: &Simulator,
) -> BaselineReport {
    run_impl(space, obj, pts, k, cfg, sim, false)
}

fn run_impl(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    cfg: &EimCfg,
    sim: &Simulator,
    pruned: bool,
) -> BaselineReport {
    // carrying per-point state across iterations assumes a distance is
    // the same scalar regardless of the batch it was computed in
    let carry = pruned && space.uniform_precision();
    let mut rng = Rng::new(cfg.seed);
    let mut remaining: Vec<u32> = pts.to_vec();
    // nearest-pivot state aligned with `remaining` (carry mode): exact
    // distance and pivot index over the pivot prefix folded so far
    let mut rdist: Vec<f64> = vec![f64::INFINITY; remaining.len()];
    let mut ridx: Vec<u32> = vec![u32::MAX; remaining.len()];
    let mut pivots: Vec<u32> = Vec::new();
    let mut rounds = 0usize;

    while remaining.len() > cfg.stop_below.max(1) {
        // sample uniformly from the remaining points
        let s = cfg.sample_per_iter.min(remaining.len());
        let sample: Vec<u32> =
            rng.sample_distinct(remaining.len(), s).into_iter().map(|i| remaining[i]).collect();
        let old_len = pivots.len();
        pivots.extend_from_slice(&sample);

        // leader broadcast: rows d(new pivot, all earlier pivots), shared
        // by every reducer's triangle bounds
        let rows: Vec<Vec<f64>> = if carry {
            (old_len..pivots.len())
                .map(|j| {
                    let mut row = vec![0.0; j];
                    if j > 0 {
                        space.dist_batch(&pivots[..j], pivots[j], &mut row);
                    }
                    row
                })
                .collect()
        } else {
            Vec::new()
        };

        // one MR round: distance of each remaining point to the pivots;
        // each part ships its slice of the carried state
        let positions: Vec<u32> = (0..remaining.len() as u32).collect();
        let pos_parts = partition(&positions, 8, PartitionStrategy::RoundRobin);
        let parts: Vec<(Vec<u32>, Vec<f64>, Vec<u32>)> = pos_parts
            .into_iter()
            .map(|ps| {
                let ids: Vec<u32> = ps.iter().map(|&i| remaining[i as usize]).collect();
                if carry {
                    let d: Vec<f64> = ps.iter().map(|&i| rdist[i as usize]).collect();
                    let x: Vec<u32> = ps.iter().map(|&i| ridx[i as usize]).collect();
                    (ids, d, x)
                } else {
                    (ids, Vec::new(), Vec::new())
                }
            })
            .collect();
        let pivots_ref = &pivots;
        let rows_ref = &rows;
        let state_parts =
            sim.round("eim-sample-filter", parts, move |_, (ids, d0, x0), meter| {
                meter.charge(ids.len() + pivots_ref.len());
                let (dist, idx) = if carry {
                    let mut tr = if old_len == 0 {
                        NearestTracker::new(space, ids, true)
                    } else {
                        NearestTracker::with_state(
                            space,
                            ids,
                            pivots_ref[..old_len].to_vec(),
                            d0.clone(),
                            x0.clone(),
                            true,
                        )
                    };
                    for (jn, &c) in pivots_ref[old_len..].iter().enumerate() {
                        tr.push_with_row(c, &rows_ref[jn]);
                    }
                    tr.into_state()
                } else {
                    let a = assign_reference(space, ids, pivots_ref);
                    (a.dist, a.idx)
                };
                meter.release(ids.len() + pivots_ref.len());
                (ids.clone(), dist, idx)
            });
        rounds += 1;

        // discard the closest half (well-served points)
        let mut flat: Vec<(u32, f64, u32)> = Vec::with_capacity(remaining.len());
        for (ids, dist, idx) in state_parts {
            for ((p, d), j) in ids.into_iter().zip(dist).zip(idx) {
                flat.push((p, d, j));
            }
        }
        sort_by_distance(&mut flat);
        let keep_from = flat.len() / 2;
        remaining.clear();
        rdist.clear();
        ridx.clear();
        for &(p, d, j) in &flat[keep_from..] {
            remaining.push(p);
            rdist.push(d);
            ridx.push(j);
        }
    }
    pivots.extend_from_slice(&remaining);
    pivots.sort_unstable();
    pivots.dedup();

    // weighting round: Voronoi counts of pivots over the full input; the
    // leader broadcasts the full pivot-to-pivot rows once, each reducer
    // folds them through a tracker
    let rows: Vec<Vec<f64>> = if carry { center_rows(space, &pivots) } else { Vec::new() };
    let parts = partition(pts, 8, PartitionStrategy::RoundRobin);
    let pivots_ref = &pivots;
    let rows_ref = &rows;
    let counts = sim.round("eim-weight", parts, move |_, part, meter| {
        meter.charge(part.len() + pivots_ref.len());
        let idx = if carry {
            let mut tr = NearestTracker::new(space, part, true);
            for (j, &c) in pivots_ref.iter().enumerate() {
                tr.push_with_row(c, &rows_ref[j]);
            }
            let (_, idx) = tr.into_state();
            idx
        } else {
            assign_reference(space, part, pivots_ref).idx
        };
        let mut w = vec![0u64; pivots_ref.len()];
        for &j in &idx {
            w[j as usize] += 1;
        }
        meter.release(part.len() + pivots_ref.len());
        w
    });
    rounds += 1;
    let mut weights = vec![0u64; pivots.len()];
    for w in counts {
        for (acc, wi) in weights.iter_mut().zip(w) {
            *acc += wi;
        }
    }
    // drop zero-weight pivots (duplicates that never win an assignment)
    let mut idxs = Vec::new();
    let mut wts = Vec::new();
    for (i, &w) in weights.iter().enumerate() {
        if w > 0 {
            idxs.push(pivots[i]);
            wts.push(w);
        }
    }
    let coreset = WeightedSet::new(idxs, wts);

    // final solve on the weighted pivots
    let sols = sim.round("eim-solve", vec![coreset.clone()], |_, cs, meter| {
        meter.charge(cs.len());
        let ls = LocalSearchCfg { seed: cfg.seed ^ 0xE1E, ..Default::default() };
        let sol = local_search(space, obj, Instance::new(&cs.indices, &cs.weights), k, None, &ls);
        meter.release(cs.len());
        sol
    });
    rounds += 1;
    let solution = sols.into_iter().next().unwrap();
    let full_cost = if pruned {
        assign_pruned(space, pts, &solution.centers).cost_unit(obj)
    } else {
        assign_reference(space, pts, &solution.centers).cost_unit(obj)
    };
    BaselineReport {
        name: "ene-im-moseley",
        solution,
        full_cost,
        summary_size: coreset.len(),
        rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::GaussianMixtureSpec;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    #[test]
    fn terminates_and_solves() {
        let (data, _) =
            GaussianMixtureSpec { n: 2000, d: 2, k: 4, seed: 1, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..2000).collect();
        let sim = Simulator::new();
        let cfg = EimCfg { sample_per_iter: 60, stop_below: 100, seed: 7 };
        let rep = run(&space, Objective::Median, &pts, 4, &cfg, &sim);
        assert_eq!(rep.solution.centers.len(), 4);
        assert!(rep.full_cost.is_finite() && rep.full_cost > 0.0);
        // halving from 2000 to 100: ~5 sample rounds + weight + solve
        assert!(rep.rounds >= 4 && rep.rounds <= 10, "rounds {}", rep.rounds);
        assert!(rep.summary_size >= 100);
    }

    #[test]
    fn weight_total_conserved() {
        let (data, _) =
            GaussianMixtureSpec { n: 1000, d: 2, k: 3, seed: 2, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..1000).collect();
        let sim = Simulator::new();
        let cfg = EimCfg { sample_per_iter: 40, stop_below: 80, seed: 9 };
        // the report doesn't expose the coreset, so sanity-check the
        // externally-visible invariants instead:
        let rep = run(&space, Objective::Means, &pts, 3, &cfg, &sim);
        assert!(rep.summary_size < 1000);
        assert!(rep.full_cost > 0.0);
    }

    /// Regression (filter sort): the old comparator was
    /// `partial_cmp().unwrap()` — it panicked on NaN and broke distance
    /// ties by gather order, leaving the kept half dependent on the
    /// partition layout.
    #[test]
    fn filter_sort_nan_safe_and_tie_stable() {
        let mut a = vec![
            (5u32, 1.0f64, 0u32),
            (3, f64::NAN, 1),
            (9, 0.5, 0),
            (1, 1.0, 2),
            (7, 1.0, 1),
        ];
        // same multiset, different gather order
        let mut b = vec![a[3], a[1], a[4], a[0], a[2]];
        sort_by_distance(&mut a);
        sort_by_distance(&mut b);
        let ka: Vec<u32> = a.iter().map(|t| t.0).collect();
        let kb: Vec<u32> = b.iter().map(|t| t.0).collect();
        assert_eq!(ka, kb, "kept half must not depend on gather order");
        // ties (d=1.0) ordered by id; NaN sorts last
        assert_eq!(ka, vec![9, 1, 5, 7, 3]);
    }
}
