//! Literature baselines the paper positions itself against (§1.1):
//!
//! - `uniform`: uniform-sample coreset (the naive composable baseline).
//! - `ene_im_moseley`: the iterative-sampling MapReduce coreset of Ene,
//!   Im, Moseley (KDD'11, ref [10]) — weak (10α+3)-style guarantee.
//! - `kmeans_parallel`: k-means‖ (Bahmani et al., PVLDB'12, ref [5]).
//! - `pamae_lite`: sampling + PAM + refinement in the spirit of PAMAE
//!   (Song, Lee, Han, KDD'17, ref [24]).
//!
//! All baselines consume the same `MetricSpace`/`Simulator` substrate and
//! emit a `BaselineReport` so E8 can compare them at matched coreset
//! sizes against the paper's construction.

pub mod ene_im_moseley;
pub mod kmeans_parallel;
pub mod pamae_lite;
pub mod uniform;

use crate::algorithms::Solution;

/// Uniform result shape for the comparison experiments.
#[derive(Clone, Debug)]
pub struct BaselineReport {
    pub name: &'static str,
    pub solution: Solution,
    /// Cost of `solution` on the full input under the experiment's
    /// objective (filled by the caller's evaluation pass).
    pub full_cost: f64,
    /// Size of the summary the method built (coreset / candidate set).
    pub summary_size: usize,
    pub rounds: usize,
}
