//! Seeding / bi-criteria approximations.
//!
//! `dpp_seeding` is weighted k-means++ / k-median++: iteratively sample
//! the next center with probability proportional to `w(x) · cost(d(x, S))`
//! (D² weighting for k-means [1], D¹ for k-median). With oversampling
//! `m > k` this is the bi-criteria β-approximation the paper recommends
//! for the per-partition sets `T_ℓ` (§3.4, refs [5, 25]): small constant
//! β, fast, and the coreset size only grows linearly in m.
//!
//! `gonzalez` (farthest-first traversal) is the classic 2-approximation
//! for k-center, used as a deterministic alternative T_ℓ and by tests.

use crate::metric::{MetricSpace, Objective};
use crate::util::rng::Rng;

use super::{Instance, Solution};

/// Weighted D^p-sampling seeding with `m` centers (m ≥ 1). Returns the
/// selected centers and the final instance cost.
pub fn dpp_seeding(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    m: usize,
    rng: &mut Rng,
) -> Solution {
    let n = inst.n();
    assert!(m >= 1);
    let m = m.min(n);
    // first center ~ weights
    let wprobs: Vec<f64> = inst.weights.iter().map(|&w| w as f64).collect();
    let first = inst.pts[rng.weighted_index(&wprobs).expect("positive weights")];
    let mut centers = vec![first];
    let mut mind: Vec<f64> = vec![f64::INFINITY; n];
    space.min_update(inst.pts, first, &mut mind);
    let mut probs = vec![0.0f64; n];
    while centers.len() < m {
        for i in 0..n {
            probs[i] = inst.weights[i] as f64 * obj.cost_of(mind[i]);
        }
        let next = match rng.weighted_index(&probs) {
            Some(i) => inst.pts[i],
            // All residual distances zero: every point coincides with a
            // center; pick an arbitrary non-center if any remain.
            None => match inst.pts.iter().find(|p| !centers.contains(p)) {
                Some(&p) => p,
                None => break,
            },
        };
        if !centers.contains(&next) {
            centers.push(next);
            space.min_update(inst.pts, next, &mut mind);
        } else {
            // zero-probability guard: duplicated sample (possible only via
            // float round-off); fall back to best uncovered point
            let far = (0..n)
                .filter(|&i| !centers.contains(&inst.pts[i]))
                .max_by(|&a, &b| mind[a].partial_cmp(&mind[b]).unwrap());
            match far {
                Some(i) => {
                    let p = inst.pts[i];
                    centers.push(p);
                    space.min_update(inst.pts, p, &mut mind);
                }
                None => break,
            }
        }
    }
    let cost = (0..n).map(|i| inst.weights[i] as f64 * obj.cost_of(mind[i])).sum();
    Solution { centers, cost }
}

/// Farthest-first traversal (Gonzalez). Deterministic given the start.
pub fn gonzalez(space: &dyn MetricSpace, inst: Instance<'_>, m: usize, start: usize) -> Vec<u32> {
    let n = inst.n();
    assert!(n > 0 && start < n);
    let m = m.min(n);
    let mut centers = vec![inst.pts[start]];
    let mut mind = vec![f64::INFINITY; n];
    space.min_update(inst.pts, inst.pts[start], &mut mind);
    while centers.len() < m {
        let far = (0..n).max_by(|&a, &b| mind[a].partial_cmp(&mind[b]).unwrap()).unwrap();
        if mind[far] == 0.0 {
            break; // all points covered exactly (duplicates)
        }
        centers.push(inst.pts[far]);
        space.min_update(inst.pts, inst.pts[far], &mut mind);
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::three_cluster_line;
    use crate::metric::cost_unit;

    #[test]
    fn kmeanspp_finds_all_clusters() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let mut rng = Rng::new(42);
        let sol = dpp_seeding(&space, Objective::Means, inst, 3, &mut rng);
        assert_eq!(sol.centers.len(), 3);
        // one center per cluster: cost must be near-floor (clusters 100 apart)
        assert!(sol.cost < 100.0, "cost {}", sol.cost);
        // clusters are index ranges 0..5, 5..10, 10..15
        let mut buckets = [0; 3];
        for c in &sol.centers {
            buckets[(*c / 5) as usize] += 1;
        }
        assert_eq!(buckets, [1, 1, 1]);
    }

    #[test]
    fn median_seeding_works_too() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let mut rng = Rng::new(7);
        let sol = dpp_seeding(&space, Objective::Median, Instance::new(&pts, &w), 3, &mut rng);
        assert!(sol.cost <= 30.0, "cost {}", sol.cost);
    }

    #[test]
    fn oversampling_reduces_cost() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let k3 = dpp_seeding(&space, Objective::Means, Instance::new(&pts, &w), 3, &mut r1);
        let k9 = dpp_seeding(&space, Objective::Means, Instance::new(&pts, &w), 9, &mut r2);
        assert!(k9.cost <= k3.cost);
        assert_eq!(k9.centers.len(), 9);
    }

    #[test]
    fn weights_bias_selection() {
        // heavy point must be chosen as the first (and only) center w.h.p.
        let (space, pts) = three_cluster_line();
        let mut w = vec![1u64; pts.len()];
        w[7] = 1_000_000;
        let mut hits = 0;
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let sol = dpp_seeding(&space, Objective::Means, Instance::new(&pts, &w), 1, &mut rng);
            if sol.centers[0] == pts[7] {
                hits += 1;
            }
        }
        assert!(hits >= 18, "heavy point chosen {hits}/20");
    }

    #[test]
    fn m_capped_at_n_and_duplicates_handled() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let mut rng = Rng::new(9);
        let sol = dpp_seeding(&space, Objective::Means, Instance::new(&pts, &w), 100, &mut rng);
        assert_eq!(sol.centers.len(), pts.len());
        assert_eq!(sol.cost, 0.0);
    }

    #[test]
    fn gonzalez_covers_clusters() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let centers = gonzalez(&space, Instance::new(&pts, &w), 3, 0);
        assert_eq!(centers.len(), 3);
        let c = cost_unit(&space, Objective::Median, &pts, &centers);
        assert!(c <= 30.0, "cost {c}");
    }

    #[test]
    fn gonzalez_stops_on_duplicates() {
        use crate::metric::dense::EuclideanSpace;
        use crate::points::VectorData;
        use std::sync::Arc;
        let v = VectorData::from_rows(&vec![vec![1.0f32]; 6]);
        let space = EuclideanSpace::new(Arc::new(v));
        let pts: Vec<u32> = (0..6).collect();
        let w = vec![1u64; 6];
        let centers = gonzalez(&space, Instance::new(&pts, &w), 4, 2);
        assert_eq!(centers.len(), 1, "all duplicates: one center suffices");
    }
}
