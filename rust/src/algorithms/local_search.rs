//! Single-swap local search for weighted k-median / k-means
//! (Arya et al. [2] for k-median; Gupta–Tangwongsan [12] / Kanungo et
//! al. [18] analyses for k-means). This is the paper's sequential
//! α-approximation — it runs on each partition (T_ℓ, §3.2/3.3 step 1,
//! optionally) and on the final coreset instance (§3.4 round 3).
//!
//! Swap evaluation uses the standard nearest/second-nearest bookkeeping:
//! with d₁/d₂ maintained per point, the cost of solution S − {out} + {in}
//! is computable in one O(n) pass per candidate, so a full improvement
//! scan is O(n·(k + |candidates|)) distance evaluations — all issued as
//! `dist_batch` bulk queries (one per center / candidate), so the hot
//! loops hit the batched distance engine instead of per-pair virtual
//! calls.
//!
//! # Incremental bookkeeping
//!
//! Historically every *accepted* swap paid a full O(nk) book rebuild.
//! The production path now updates the book incrementally: the winning
//! candidate's distance row — already computed during the swap scan, so
//! no fresh query — folds into each point's (d₁, i₁, d₂, i₂) with
//! exactly `rebuild_book`'s comparison and tie-break semantics (strict
//! `<` over ascending center positions, equal distances resolve to the
//! smaller position); only points whose nearest or second-nearest
//! center was the evicted one are re-scanned against all centers. The
//! result is bit-identical to a full rebuild — pinned against
//! [`local_search_reference`] by
//! `tests/prop_pruned_equivalence.rs` — assuming `dist_batch` is
//! element-wise deterministic, which holds for every in-tree space (the
//! optional XLA engine path documents its own f32 numerics and is
//! off by default). Candidate membership tests use a bitset
//! (`util::bitset`) instead of an O(k) `contains` scan, and the
//! per-candidate delta scratch is allocated once per search, not once
//! per candidate. `cargo bench -- micro` compares the incremental and
//! rebuild paths and records dist_evals saved in `BENCH_pruning.json`.
//!
//! `t`-swap (multi-swap) gives α = 3+2/t (median) / 5+4/t (means); we
//! implement t = 1 plus a sampled multi-candidate scan, which already
//! sits far below the worst-case bound on non-adversarial instances.

use crate::metric::{MetricSpace, Objective};
use crate::obs::counters as obs;
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;

use super::{seeding, Instance, Solution};

#[derive(Clone, Debug)]
pub struct LocalSearchCfg {
    /// Minimum relative improvement to accept a swap (the 1−δ factor in
    /// Arya et al.; guarantees polynomial convergence).
    pub min_rel_improvement: f64,
    /// Upper bound on improvement passes.
    pub max_passes: usize,
    /// Swap-in candidates per pass: all points if n ≤ exhaustive_below,
    /// else a uniform sample of this size.
    pub sample_candidates: usize,
    pub exhaustive_below: usize,
    /// With sampled candidate pools, stop only after this many
    /// consecutive passes without an improving swap (a single unlucky
    /// sample must not end the search); exhaustive pools stop at once.
    pub patience: usize,
    pub seed: u64,
}

impl Default for LocalSearchCfg {
    fn default() -> Self {
        LocalSearchCfg {
            min_rel_improvement: 1e-4,
            max_passes: 40,
            sample_candidates: 64,
            exhaustive_below: 256,
            patience: 5,
            seed: 0xC0FFEE,
        }
    }
}

/// Nearest + second-nearest center bookkeeping for each point (shared
/// with the outlier-robust finisher, which runs the same single-swap
/// scheme over the z-excluded objective). Positions refer into the
/// current `centers` slice; `i2` exists so an accepted swap can detect
/// which points lost their second-nearest entry and must be re-scanned.
pub(crate) struct Book {
    pub(crate) d1: Vec<f64>,
    pub(crate) i1: Vec<u32>, // position within `centers`
    pub(crate) d2: Vec<f64>,
    pub(crate) i2: Vec<u32>, // position of the second-nearest center
}

pub(crate) fn rebuild_book(space: &dyn MetricSpace, pts: &[u32], centers: &[u32]) -> Book {
    let n = pts.len();
    let mut d1 = vec![f64::INFINITY; n];
    let mut i1 = vec![0u32; n];
    let mut d2 = vec![f64::INFINITY; n];
    let mut i2 = vec![0u32; n];
    let mut buf = vec![0.0f64; n];
    for (j, &c) in centers.iter().enumerate() {
        space.dist_batch(pts, c, &mut buf);
        for (x, &d) in buf.iter().enumerate() {
            if d < d1[x] {
                d2[x] = d1[x];
                i2[x] = i1[x];
                d1[x] = d;
                i1[x] = j as u32;
            } else if d < d2[x] {
                d2[x] = d;
                i2[x] = j as u32;
            }
        }
    }
    Book { d1, i1, d2, i2 }
}

/// Restore `book` to exactly what `rebuild_book(space, pts, centers)`
/// would produce after the swap that replaced position `q` (the incoming
/// center already written to `centers[q]`), given the incoming center's
/// distance row `dnew[x] = d(pts[x], centers[q])` — which the swap scan
/// already computed, so the common case costs zero fresh evaluations.
///
/// Points whose nearest or second-nearest center was the evicted one
/// lost bookkeeping the O(1) fold cannot restore; they are re-scanned
/// against the full center list (reusing `dnew` for position `q`, so the
/// re-scan costs |affected|·(k−1) evaluations). Every other point folds
/// the incoming center in with rebuild's exact comparison and tie-break
/// semantics: strict `<` over centers in ascending position order, so on
/// equal distances the smaller position wins.
pub(crate) fn update_book_after_swap(
    space: &dyn MetricSpace,
    pts: &[u32],
    centers: &[u32],
    q: usize,
    dnew: &[f64],
    book: &mut Book,
) {
    let n = pts.len();
    debug_assert_eq!(dnew.len(), n);
    let qq = q as u32;
    let mut affected: Vec<u32> = Vec::new();
    for x in 0..n {
        if book.i1[x] == qq || book.i2[x] == qq {
            affected.push(x as u32);
            continue;
        }
        // The old top-2 entries both survive the eviction, so the new
        // top-2 is the old pair merged with (dnew, q).
        let dn = dnew[x];
        if dn < book.d1[x] || (dn == book.d1[x] && qq < book.i1[x]) {
            book.d2[x] = book.d1[x];
            book.i2[x] = book.i1[x];
            book.d1[x] = dn;
            book.i1[x] = qq;
        } else if dn < book.d2[x] || (dn == book.d2[x] && qq < book.i2[x]) {
            book.d2[x] = dn;
            book.i2[x] = qq;
        }
    }
    if affected.is_empty() {
        return;
    }
    let aff_pts: Vec<u32> = affected.iter().map(|&x| pts[x as usize]).collect();
    for &x in &affected {
        let x = x as usize;
        book.d1[x] = f64::INFINITY;
        book.i1[x] = 0;
        book.d2[x] = f64::INFINITY;
        book.i2[x] = 0;
    }
    let mut buf = vec![0.0f64; affected.len()];
    for (j, &c) in centers.iter().enumerate() {
        if j == q {
            for (i, &x) in affected.iter().enumerate() {
                buf[i] = dnew[x as usize];
            }
        } else {
            space.dist_batch(&aff_pts, c, &mut buf);
        }
        for (i, &x) in affected.iter().enumerate() {
            let x = x as usize;
            let d = buf[i];
            if d < book.d1[x] {
                book.d2[x] = book.d1[x];
                book.i2[x] = book.i1[x];
                book.d1[x] = d;
                book.i1[x] = j as u32;
            } else if d < book.d2[x] {
                book.d2[x] = d;
                book.i2[x] = j as u32;
            }
        }
    }
}

/// Apply an accepted swap — shared by the plain and outlier-robust
/// searches: replace `centers[q]`, maintain the membership bitset
/// (duplicate-aware: an init with duplicate centers keeps its bit until
/// the last copy is swapped out), and restore the book — incrementally
/// from the candidate's distance row already computed during the scan
/// (no re-query), or by full rebuild for the reference paths.
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_swap(
    space: &dyn MetricSpace,
    pts: &[u32],
    centers: &mut [u32],
    in_centers: &mut Bitset,
    q: usize,
    cand: u32,
    cand_dists: &[f64],
    book: &mut Book,
    incremental: bool,
) {
    let evicted = centers[q];
    centers[q] = cand;
    if !centers.contains(&evicted) {
        in_centers.remove(evicted);
    }
    in_centers.insert(cand);
    if incremental {
        update_book_after_swap(space, pts, centers, q, cand_dists, book);
    } else {
        *book = rebuild_book(space, pts, centers);
    }
}

/// Cost of the current solution from the book.
fn book_cost(book: &Book, obj: Objective, weights: &[u64]) -> f64 {
    book.d1.iter().zip(weights).map(|(&d, &w)| w as f64 * obj.cost_of(d)).sum()
}

/// Sampled swap-in candidate pool (shared with the outlier-robust
/// finisher): half uniform, half drawn from `probs` — the cost-biased
/// D^p intuition that badly-served heavy points are the promising
/// swap-ins — deduplicated and in ascending order. The RNG consumption
/// order (distinct sample first, then the weighted draws) is part of
/// the determinism contract.
pub(crate) fn sampled_candidate_pool(
    n: usize,
    probs: &[f64],
    sample_candidates: usize,
    rng: &mut Rng,
) -> Vec<usize> {
    let m = sample_candidates.min(n);
    let mut pool = rng.sample_distinct(n, m / 2);
    for _ in 0..(m - m / 2) {
        if let Some(i) = rng.weighted_index(probs) {
            pool.push(i);
        }
    }
    pool.sort_unstable();
    pool.dedup();
    pool
}

/// Evaluate all k swaps (out ∈ S) for one candidate `cand` in a single
/// pass: returns (best_out_position, best_total_cost). `dc` and `delta`
/// are caller scratch buffers (length n resp. k, reused across the whole
/// candidate scan instead of reallocated per candidate); `dc` is filled
/// with one `dist_batch` query.
#[allow(clippy::too_many_arguments)]
fn eval_candidate(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    book: &Book,
    k: usize,
    cand: u32,
    dc: &mut [f64],
    delta: &mut Vec<f64>,
) -> (usize, f64) {
    // base: cost if we only ADD cand (each point takes min(d1, d(cand)));
    // delta[q]: correction if center q is REMOVED — points whose nearest
    // is q fall back to min(d2, d(cand)) instead of min(d1, d(cand)).
    space.dist_batch(inst.pts, cand, dc);
    let mut base = 0.0f64;
    delta.clear();
    delta.resize(k, 0.0);
    for x in 0..inst.n() {
        let w = inst.weights[x] as f64;
        let with_add = obj.cost_of(dc[x].min(book.d1[x]));
        base += w * with_add;
        let q = book.i1[x] as usize;
        let fallback = obj.cost_of(dc[x].min(book.d2[x]));
        delta[q] += w * (fallback - with_add);
    }
    let mut best_q = 0usize;
    let mut best = f64::INFINITY;
    for (q, &dq) in delta.iter().enumerate() {
        let total = base + dq;
        if total < best {
            best = total;
            best_q = q;
        }
    }
    (best_q, best)
}

/// Run local search from an initial solution (seeded with D^p sampling if
/// `init` is None). Returns the locally-optimal solution. Uses the
/// incremental book update after accepted swaps (see the module docs).
pub fn local_search(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    init: Option<Vec<u32>>,
    cfg: &LocalSearchCfg,
) -> Solution {
    local_search_impl(space, obj, inst, k, init, cfg, true)
}

/// Reference implementation paying a full O(nk) `rebuild_book` after
/// every accepted swap — the bit-exact oracle the incremental path is
/// pinned to (`tests/prop_pruned_equivalence.rs`) and the baseline side
/// of the `BENCH_pruning.json` swap-scan comparison.
pub fn local_search_reference(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    init: Option<Vec<u32>>,
    cfg: &LocalSearchCfg,
) -> Solution {
    local_search_impl(space, obj, inst, k, init, cfg, false)
}

fn local_search_impl(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    init: Option<Vec<u32>>,
    cfg: &LocalSearchCfg,
    incremental: bool,
) -> Solution {
    // The incremental book reuses distance rows across differently-sized
    // bulk queries; a space with block-size-dependent precision (the
    // engine-attached Euclidean path) would drift from the rebuild
    // reference, so it keeps the historical full-rebuild behavior.
    let incremental = incremental && space.uniform_precision();
    let n = inst.n();
    let k = k.min(n);
    let mut rng = Rng::new(cfg.seed);
    let mut centers = match init {
        Some(c) => {
            assert!(!c.is_empty());
            c
        }
        None => seeding::dpp_seeding(space, obj, inst, k, &mut rng).centers,
    };
    if centers.len() >= n {
        // every point can be a center
        let cost = inst.cost(space, obj, &centers);
        return Solution { centers, cost };
    }
    let mut book = rebuild_book(space, inst.pts, &centers);
    let mut cost = book_cost(&book, obj, inst.weights);
    let exhaustive = n <= cfg.exhaustive_below;
    let mut dry_passes = 0usize;
    let mut passes: u64 = 0;
    let mut swaps: u64 = 0;
    let mut dc_buf = vec![0.0f64; n];
    let mut best_dc = vec![0.0f64; n];
    let mut delta_buf: Vec<f64> = Vec::with_capacity(centers.len());
    let mut in_centers = Bitset::from_members(space.n_points(), &centers);
    for _pass in 0..cfg.max_passes {
        passes += 1;
        // candidate pool: exhaustive for small instances; otherwise half
        // uniform, half cost-biased (w·cost(d1) — the D^p intuition:
        // badly-served heavy points are the promising swap-ins, and rare
        // far clusters would almost never enter a uniform sample).
        let cand_idx: Vec<usize> = if exhaustive {
            (0..n).collect()
        } else {
            let probs: Vec<f64> = (0..n)
                .map(|i| inst.weights[i] as f64 * obj.cost_of(book.d1[i]))
                .collect();
            sampled_candidate_pool(n, &probs, cfg.sample_candidates, &mut rng)
        };
        let mut best_cost = cost;
        let mut best_swap: Option<(usize, u32)> = None;
        for ci in cand_idx {
            let cand = inst.pts[ci];
            if in_centers.contains(cand) {
                continue;
            }
            let (q, total) = eval_candidate(
                space,
                obj,
                inst,
                &book,
                centers.len(),
                cand,
                &mut dc_buf,
                &mut delta_buf,
            );
            if total < best_cost {
                best_cost = total;
                best_swap = Some((q, cand));
                // keep the winner's distance row: the accepted swap folds
                // it into the book without re-querying the metric
                best_dc.copy_from_slice(&dc_buf);
            }
        }
        match best_swap {
            Some((q, cand)) if best_cost <= cost * (1.0 - cfg.min_rel_improvement) => {
                apply_swap(
                    space,
                    inst.pts,
                    &mut centers,
                    &mut in_centers,
                    q,
                    cand,
                    &best_dc,
                    &mut book,
                    incremental,
                );
                cost = book_cost(&book, obj, inst.weights);
                dry_passes = 0;
                swaps += 1;
            }
            _ if exhaustive => break, // true local optimum
            _ => {
                dry_passes += 1;
                if dry_passes >= cfg.patience {
                    break; // repeatedly dry sampled pools: call it converged
                }
            }
        }
    }
    // per-call telemetry (snapshotted per reducer by the simulator)
    obs::add("local_search.passes", passes);
    obs::add("local_search.swaps", swaps);
    Solution { centers, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::brute::brute_force;
    use crate::algorithms::testutil::three_cluster_line;

    #[test]
    fn reaches_cluster_structure() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let sol = local_search(&space, obj, inst, 3, None, &LocalSearchCfg::default());
            let mut buckets = [0; 3];
            for c in &sol.centers {
                buckets[(*c / 5) as usize] += 1;
            }
            assert_eq!(buckets, [1, 1, 1], "{obj}: centers {:?}", sol.centers);
        }
    }

    #[test]
    fn matches_brute_force_on_tiny_instances() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let opt = brute_force(&space, obj, inst, 2);
            let ls = local_search(&space, obj, inst, 2, None, &LocalSearchCfg::default());
            assert!(
                ls.cost <= opt.cost * 1.7 + 1e-9,
                "{obj}: ls {} vs opt {}",
                ls.cost,
                opt.cost
            );
        }
    }

    #[test]
    fn respects_initial_solution_and_improves_it() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let bad_init = vec![pts[0], pts[1], pts[2]]; // all in one cluster
        let init_cost = inst.cost(&space, Objective::Median, &bad_init);
        let sol = local_search(
            &space,
            Objective::Median,
            inst,
            3,
            Some(bad_init),
            &LocalSearchCfg::default(),
        );
        assert!(sol.cost < init_cost * 0.2, "cost {} vs init {}", sol.cost, init_cost);
    }

    #[test]
    fn weighted_points_pull_centers() {
        let (space, pts) = three_cluster_line();
        let mut w = vec![1u64; pts.len()];
        w[12] = 10_000; // heavy point in the third cluster
        let inst = Instance::new(&pts, &w);
        let sol = local_search(&space, Objective::Means, inst, 1, None, &LocalSearchCfg::default());
        assert_eq!(sol.centers, vec![pts[12]]);
    }

    /// The incremental update must reproduce `rebuild_book` exactly —
    /// including on the tie-heavy symmetric line (points at ±1, ±2 of
    /// each cluster center produce equal distances that exercise the
    /// smaller-position tie-break).
    #[test]
    fn incremental_book_update_matches_rebuild() {
        let (space, pts) = three_cluster_line();
        let mut centers = vec![pts[0], pts[7], pts[12]];
        let mut book = rebuild_book(&space, &pts, &centers);
        let mut dnew = vec![0.0f64; pts.len()];
        for (q, cand) in [(1usize, pts[3]), (0, pts[8]), (2, pts[1]), (0, pts[2])] {
            centers[q] = cand;
            space.dist_batch(&pts, cand, &mut dnew);
            update_book_after_swap(&space, &pts, &centers, q, &dnew, &mut book);
            let reference = rebuild_book(&space, &pts, &centers);
            for x in 0..pts.len() {
                assert_eq!(book.d1[x].to_bits(), reference.d1[x].to_bits(), "d1 x={x}");
                assert_eq!(book.i1[x], reference.i1[x], "i1 x={x}");
                assert_eq!(book.d2[x].to_bits(), reference.d2[x].to_bits(), "d2 x={x}");
                assert_eq!(book.i2[x], reference.i2[x], "i2 x={x}");
            }
        }
    }

    /// Incremental and reference searches agree end to end on the tiny
    /// instance (the property test covers randomized instances).
    #[test]
    fn incremental_search_matches_reference_end_to_end() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let a = local_search(&space, obj, inst, 3, None, &LocalSearchCfg::default());
            let b = local_search_reference(&space, obj, inst, 3, None, &LocalSearchCfg::default());
            assert_eq!(a.centers, b.centers, "{obj}");
            assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{obj}");
        }
    }

    #[test]
    fn k_ge_n_is_exact_zero() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let sol = local_search(
            &space,
            Objective::Means,
            Instance::new(&pts, &w),
            pts.len() + 5,
            None,
            &LocalSearchCfg::default(),
        );
        assert_eq!(sol.cost, 0.0);
    }
}
