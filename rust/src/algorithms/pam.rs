//! PAM (Partitioning Around Medoids, Kaufman–Rousseeuw [19]): BUILD +
//! exhaustive SWAP on weighted instances. O(k·n²)-ish per iteration, so
//! it is reserved for small instances — exactly how the PAMAE baseline
//! [24] uses it (PAM on random samples).

use crate::metric::{MetricSpace, Objective};

use super::{Instance, Solution};

#[derive(Clone, Debug)]
pub struct PamCfg {
    pub max_iters: usize,
    /// Hard cap on instance size (distance matrix cost grows as n²).
    pub max_n: usize,
}

impl Default for PamCfg {
    fn default() -> Self {
        PamCfg { max_iters: 30, max_n: 2048 }
    }
}

/// BUILD: greedily add the medoid that most decreases total cost. Each
/// candidate is scored from one `dist_batch` bulk query.
fn build(space: &dyn MetricSpace, obj: Objective, inst: Instance<'_>, k: usize) -> Vec<u32> {
    let n = inst.n();
    let mut centers: Vec<u32> = Vec::with_capacity(k);
    let mut mind = vec![f64::INFINITY; n];
    let mut dc = vec![0.0f64; n];
    for _ in 0..k.min(n) {
        let mut best: Option<(usize, f64)> = None;
        for (ci, &c) in inst.pts.iter().enumerate() {
            if centers.contains(&c) {
                continue;
            }
            space.dist_batch(inst.pts, c, &mut dc);
            let mut cost = 0.0;
            for x in 0..n {
                cost += inst.weights[x] as f64 * obj.cost_of(dc[x].min(mind[x]));
            }
            if best.map_or(true, |(_, bc)| cost < bc) {
                best = Some((ci, cost));
            }
        }
        let (ci, _) = best.expect("nonempty instance");
        let c = inst.pts[ci];
        centers.push(c);
        space.min_update(inst.pts, c, &mut mind);
    }
    centers
}

/// Full PAM: BUILD then first-improvement SWAP passes until local optimum.
pub fn pam(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    cfg: &PamCfg,
) -> Solution {
    assert!(
        inst.n() <= cfg.max_n,
        "pam: n={} exceeds cfg.max_n={} (use local_search for large instances)",
        inst.n(),
        cfg.max_n
    );
    let mut centers = build(space, obj, inst, k);
    let mut cost = inst.cost(space, obj, &centers);
    for _ in 0..cfg.max_iters {
        let mut improved = false;
        'swap: for q in 0..centers.len() {
            for &cand in inst.pts {
                if centers.contains(&cand) {
                    continue;
                }
                let old = centers[q];
                centers[q] = cand;
                let c = inst.cost(space, obj, &centers);
                if c + 1e-12 < cost {
                    cost = c;
                    improved = true;
                    break 'swap;
                }
                centers[q] = old;
            }
        }
        if !improved {
            break;
        }
    }
    Solution { centers, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::brute::brute_force;
    use crate::algorithms::testutil::three_cluster_line;

    #[test]
    fn pam_matches_brute_on_tiny() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let opt = brute_force(&space, obj, inst, 3);
            let p = pam(&space, obj, inst, 3, &PamCfg::default());
            assert!((p.cost - opt.cost).abs() < 1e-9, "{obj}: pam {} opt {}", p.cost, opt.cost);
        }
    }

    #[test]
    fn weighted_medoid_shifts() {
        let (space, pts) = three_cluster_line();
        let mut w = vec![1u64; pts.len()];
        w[0] = 1000; // pull the first cluster's medoid to index 0
        let inst = Instance::new(&pts, &w);
        let p = pam(&space, Objective::Median, inst, 3, &PamCfg::default());
        assert!(p.centers.contains(&pts[0]), "centers {:?}", p.centers);
    }

    #[test]
    #[should_panic(expected = "exceeds cfg.max_n")]
    fn size_guard() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let cfg = PamCfg { max_n: 10, ..Default::default() };
        let _ = pam(&space, Objective::Median, Instance::new(&pts, &w), 2, &cfg);
    }
}
