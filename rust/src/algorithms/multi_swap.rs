//! t-swap local search (paper §3.4: Arya et al. [2] achieve α = 3 + 2/t
//! for k-median and Gupta–Tangwongsan [12] α = 5 + 4/t for k-means with
//! t simultaneous swaps). Exhaustive t-swap is O(n^t k^t); this is the
//! standard sampled variant: start from the 1-swap local optimum, then
//! attempt random t-subsets of (out-centers, in-candidates), with
//! candidates drawn cost-biased. Never worse than its 1-swap start.

use crate::metric::{MetricSpace, Objective};
use crate::util::rng::Rng;

use super::local_search::{local_search, LocalSearchCfg};
use super::{Instance, Solution};

#[derive(Clone, Debug)]
pub struct MultiSwapCfg {
    /// Simultaneous swaps t ≥ 1 (t = 1 degenerates to `local_search`).
    pub t: usize,
    /// Random t-swap attempts per pass.
    pub tries_per_pass: usize,
    pub max_passes: usize,
    pub seed: u64,
}

impl Default for MultiSwapCfg {
    fn default() -> Self {
        MultiSwapCfg { t: 2, tries_per_pass: 64, max_passes: 20, seed: 0x7557 }
    }
}

/// Run 1-swap local search to a local optimum, then escape with sampled
/// t-swaps.
pub fn multi_swap_search(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    cfg: &MultiSwapCfg,
    ls_cfg: &LocalSearchCfg,
) -> Solution {
    assert!(cfg.t >= 1);
    let base = local_search(space, obj, inst, k, None, ls_cfg);
    if cfg.t == 1 || base.centers.len() < cfg.t || inst.n() <= base.centers.len() {
        return base;
    }
    let mut rng = Rng::new(cfg.seed);
    let mut centers = base.centers;
    let mut cost = base.cost;
    let n = inst.n();
    for _pass in 0..cfg.max_passes {
        let mut improved = false;
        // cost-biased candidate weights from the current assignment
        let assign = space.assign(inst.pts, &centers);
        let probs: Vec<f64> = (0..n)
            .map(|i| inst.weights[i] as f64 * obj.cost_of(assign.dist[i]))
            .collect();
        for _ in 0..cfg.tries_per_pass {
            // t distinct out-positions
            let outs = rng.sample_distinct(centers.len(), cfg.t);
            // t distinct in-candidates (cost-biased, not already centers)
            let mut ins: Vec<u32> = Vec::with_capacity(cfg.t);
            let mut guard = 0;
            while ins.len() < cfg.t && guard < 32 * cfg.t {
                guard += 1;
                let pick = match rng.weighted_index(&probs) {
                    Some(i) => inst.pts[i],
                    None => inst.pts[rng.below(n)],
                };
                if !centers.contains(&pick) && !ins.contains(&pick) {
                    ins.push(pick);
                }
            }
            if ins.len() < cfg.t {
                continue;
            }
            let mut trial = centers.clone();
            for (o, i) in outs.iter().zip(&ins) {
                trial[*o] = *i;
            }
            let c = inst.cost(space, obj, &trial);
            if c + 1e-12 < cost {
                centers = trial;
                cost = c;
                improved = true;
                break; // re-derive biases from the new solution
            }
        }
        if !improved {
            break;
        }
    }
    Solution { centers, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::three_cluster_line;

    #[test]
    fn never_worse_than_single_swap() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let ls_cfg = LocalSearchCfg::default();
            let single = local_search(&space, obj, inst, 3, None, &ls_cfg);
            let multi =
                multi_swap_search(&space, obj, inst, 3, &MultiSwapCfg::default(), &ls_cfg);
            assert!(multi.cost <= single.cost + 1e-9, "{obj}");
            assert_eq!(multi.centers.len(), 3);
        }
    }

    #[test]
    fn t1_equals_local_search() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let ls_cfg = LocalSearchCfg::default();
        let cfg = MultiSwapCfg { t: 1, ..Default::default() };
        let a = local_search(&space, Objective::Median, inst, 3, None, &ls_cfg);
        let b = multi_swap_search(&space, Objective::Median, inst, 3, &cfg, &ls_cfg);
        assert_eq!(a, b);
    }

    #[test]
    fn escapes_paired_local_optimum() {
        // Geometry where 1-swap stalls: two tight far pairs and a broad
        // middle cluster, k=2. From centers (mid, mid) a single swap that
        // grabs one far pair strands the other; 2-swap grabs both pairs.
        use crate::metric::dense::EuclideanSpace;
        use crate::points::VectorData;
        use std::sync::Arc;
        let mut rows = vec![];
        for off in [-1.0f32, 1.0] {
            rows.push(vec![-1000.0 + off]);
        }
        for off in [-1.0f32, 1.0] {
            rows.push(vec![1000.0 + off]);
        }
        for i in 0..20 {
            rows.push(vec![(i as f32 - 10.0) * 0.5]);
        }
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let pts: Vec<u32> = (0..rows.len() as u32).collect();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let ls_cfg = LocalSearchCfg::default();
        let cfg = MultiSwapCfg { t: 2, tries_per_pass: 256, max_passes: 40, seed: 3 };
        let multi = multi_swap_search(&space, Objective::Means, inst, 3, &cfg, &ls_cfg);
        // good solutions serve both far pairs: cost < 1e5 (a stranded pair
        // alone costs ~ (2000)^2 * 2 = 8e6)
        assert!(multi.cost < 1.0e5, "cost {}", multi.cost);
    }
}
