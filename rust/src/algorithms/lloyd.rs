//! Weighted Lloyd iteration for the CONTINUOUS k-means variant (§3.1
//! "Application to the continuous case", §3.3 closing remark): centers
//! are arbitrary points of R^d (centroids), not members of P. Works
//! directly on dense vectors, outside the `MetricSpace` index world.
//!
//! [`lloyd`] carries Hamerly-style per-point bounds across iterations:
//! an upper bound on the distance to the assigned centroid and a lower
//! bound on the distance to every other one, maintained under centroid
//! movement. A point whose (margined) upper bound stays strictly below
//! its lower bound provably keeps its assignment and costs one distance
//! evaluation instead of k. Every distance here is the same scalar f64
//! `sq_euclidean` expression regardless of batch shape, so the
//! `uniform_precision` requirement for trusting carried bounds holds by
//! construction (no engine dispatch on this path). [`lloyd_reference`]
//! is the historical exact full-scan twin — bit-identical results, the
//! property suite pins it.

use crate::metric::counter;
use crate::metric::dense::sq_euclidean;
use crate::points::VectorData;
use crate::util::rng::Rng;

/// Margins for the Hamerly skip test `ub·INFL < lb·DEFL`: the bounds
/// accumulate one add/sub plus a sqrt of float error per iteration
/// (~1e-16 relative each, ≤ 50 iterations), so a 1e-12 relative guard
/// band dwarfs the drift; comparisons inside the band rescan exactly.
const BOUND_INFL: f64 = 1.0 + 1e-12;
const BOUND_DEFL: f64 = 1.0 - 1e-12;

/// Blocked nearest-centroid scan: centers outer, points inner, so each
/// centroid row stays hot while it streams the point block (and the
/// whole pass is two flat arrays, no per-point center chasing). Fills
/// `best` (squared distance) and `bj` (centroid position). Charges the
/// distance-evaluation counter like any other bulk query.
fn nearest_centroids(
    data: &VectorData,
    pts: &[u32],
    centers: &[Vec<f32>],
    best: &mut [f64],
    bj: &mut [usize],
) {
    counter::charge(pts.len() * centers.len());
    best.fill(f64::INFINITY);
    for b in bj.iter_mut() {
        *b = 0;
    }
    for (j, c) in centers.iter().enumerate() {
        for (i, &p) in pts.iter().enumerate() {
            let dd = sq_euclidean(data.row(p), c);
            if dd < best[i] {
                best[i] = dd;
                bj[i] = j;
            }
        }
    }
}

/// [`nearest_centroids`] fused with the second-nearest squared distance
/// (seed for the Hamerly lower bound). Identical `best`/`bj` results:
/// per point the comparisons run in the same centroid order with the
/// same strict `<`.
fn nearest_two_centroids(
    data: &VectorData,
    pts: &[u32],
    centers: &[Vec<f32>],
    best: &mut [f64],
    bj: &mut [usize],
    second: &mut [f64],
) {
    counter::charge(pts.len() * centers.len());
    best.fill(f64::INFINITY);
    second.fill(f64::INFINITY);
    for b in bj.iter_mut() {
        *b = 0;
    }
    for (j, c) in centers.iter().enumerate() {
        for (i, &p) in pts.iter().enumerate() {
            let dd = sq_euclidean(data.row(p), c);
            if dd < best[i] {
                second[i] = best[i];
                best[i] = dd;
                bj[i] = j;
            } else if dd < second[i] {
                second[i] = dd;
            }
        }
    }
}

/// A continuous solution: k centroids in R^d + its weighted k-means cost.
#[derive(Clone, Debug)]
pub struct ContinuousSolution {
    pub centroids: VectorData,
    pub cost: f64,
}

#[derive(Clone, Debug)]
pub struct LloydCfg {
    pub max_iters: usize,
    /// Stop when relative cost improvement falls below this.
    pub tol: f64,
    pub seed: u64,
}

impl Default for LloydCfg {
    fn default() -> Self {
        LloydCfg { max_iters: 50, tol: 1e-6, seed: 0xF00D }
    }
}

/// Weighted k-means++ initialization over dense rows.
fn init_pp(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    k: usize,
    rng: &mut Rng,
) -> Vec<Vec<f32>> {
    let n = pts.len();
    let wprobs: Vec<f64> = weights.iter().map(|&w| w as f64).collect();
    let first = pts[rng.weighted_index(&wprobs).expect("positive weights")];
    let mut centers: Vec<Vec<f32>> = vec![data.row(first).to_vec()];
    counter::charge(pts.len());
    let mut mind: Vec<f64> = pts.iter().map(|&p| sq_euclidean(data.row(p), &centers[0])).collect();
    let mut probs = vec![0.0; n];
    while centers.len() < k.min(n) {
        for i in 0..n {
            probs[i] = weights[i] as f64 * mind[i];
        }
        let next = match rng.weighted_index(&probs) {
            Some(i) => pts[i],
            None => break, // all residuals zero
        };
        let row = data.row(next).to_vec();
        counter::charge(pts.len());
        for (i, &p) in pts.iter().enumerate() {
            let d = sq_euclidean(data.row(p), &row);
            if d < mind[i] {
                mind[i] = d;
            }
        }
        centers.push(row);
    }
    centers
}

/// Positions of the `count` heaviest-cost points (max `w·d²` under the
/// current assignment), distinct, ties to the lowest position — the
/// deterministic reseed targets for empty clusters.
fn reseed_targets(weights: &[u64], best: &[f64], count: usize) -> Vec<usize> {
    let mut picks = Vec::with_capacity(count);
    let mut taken = vec![false; weights.len()];
    for _ in 0..count {
        let mut arg = 0usize;
        let mut top = f64::NEG_INFINITY;
        for i in 0..weights.len() {
            if taken[i] {
                continue;
            }
            let contrib = weights[i] as f64 * best[i];
            if contrib.total_cmp(&top) == std::cmp::Ordering::Greater {
                top = contrib;
                arg = i;
            }
        }
        taken[arg] = true;
        picks.push(arg);
    }
    picks
}

/// Weighted accumulation + centroid update for one Lloyd iteration.
/// Empty clusters are re-seeded from the heaviest-cost points
/// ([`reseed_targets`] — deterministic given `bj`/`best`, no RNG draw).
/// Returns the iteration's cost; fills `moved` (plain distance each
/// centroid traveled) when given, charging one evaluation per centroid.
fn update_step(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    best: &[f64],
    bj: &[usize],
    centers: &mut [Vec<f32>],
    mut moved: Option<&mut [f64]>,
) -> f64 {
    let d = data.d();
    let kk = centers.len();
    let mut sums = vec![vec![0.0f64; d]; kk];
    let mut wsum = vec![0u64; kk];
    let mut cost = 0.0;
    for (i, &p) in pts.iter().enumerate() {
        cost += weights[i] as f64 * best[i];
        wsum[bj[i]] += weights[i];
        for (s, &x) in sums[bj[i]].iter_mut().zip(data.row(p)) {
            *s += weights[i] as f64 * x as f64;
        }
    }
    let empties = wsum.iter().filter(|&&w| w == 0).count();
    let picks = reseed_targets(weights, best, empties);
    let mut next_pick = 0usize;
    for (j, c) in centers.iter_mut().enumerate() {
        let old = moved.is_some().then(|| c.clone());
        if wsum[j] > 0 {
            for (x, s) in c.iter_mut().zip(&sums[j]) {
                *x = (*s / wsum[j] as f64) as f32;
            }
        } else {
            let far = pts[picks[next_pick]];
            next_pick += 1;
            *c = data.row(far).to_vec();
        }
        if let Some(mv) = moved.as_deref_mut() {
            mv[j] = sq_euclidean(&old.unwrap(), c).sqrt();
        }
    }
    if moved.is_some() {
        counter::charge(kk);
    }
    cost
}

/// One assignment pass: exact full scan (reference mode), or the
/// Hamerly-bounded scan (bounded mode) which skips a point's centroid
/// loop entirely when its bounds prove the assignment unchanged.
#[allow(clippy::too_many_arguments)]
fn assign_pass(
    data: &VectorData,
    pts: &[u32],
    centers: &[Vec<f32>],
    bounded: bool,
    first: &mut bool,
    best: &mut [f64],
    bj: &mut [usize],
    ub: &mut [f64],
    lb: &mut [f64],
) {
    if !bounded {
        nearest_centroids(data, pts, centers, best, bj);
        return;
    }
    if *first {
        let mut second = vec![f64::INFINITY; pts.len()];
        nearest_two_centroids(data, pts, centers, best, bj, &mut second);
        for i in 0..pts.len() {
            ub[i] = best[i].sqrt();
            lb[i] = second[i].sqrt();
        }
        *first = false;
        return;
    }
    let kk = centers.len();
    let mut charged = 0usize;
    for (i, &p) in pts.iter().enumerate() {
        if ub[i] * BOUND_INFL < lb[i] * BOUND_DEFL {
            // strictly-unique nearest centroid (a tie would violate the
            // strict margined inequality): assignment unchanged, one
            // evaluation refreshes the exact distance and tightens ub
            charged += 1;
            let dd = sq_euclidean(data.row(p), &centers[bj[i]]);
            best[i] = dd;
            ub[i] = dd.sqrt();
        } else {
            // full rescan for this point, refreshing both bounds
            charged += kk;
            let row = data.row(p);
            let mut b = f64::INFINITY;
            let mut sec = f64::INFINITY;
            let mut a = 0usize;
            for (j, c) in centers.iter().enumerate() {
                let dd = sq_euclidean(row, c);
                if dd < b {
                    sec = b;
                    b = dd;
                    a = j;
                } else if dd < sec {
                    sec = dd;
                }
            }
            best[i] = b;
            bj[i] = a;
            ub[i] = b.sqrt();
            lb[i] = sec.sqrt();
        }
    }
    counter::charge(charged);
}

/// Weighted Lloyd on (pts ⊆ data, weights), Hamerly-bounded. Returns
/// centroids + cost (sum of w·d² to nearest centroid). Bit-identical to
/// [`lloyd_reference`].
pub fn lloyd(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    k: usize,
    cfg: &LloydCfg,
) -> ContinuousSolution {
    lloyd_impl(data, pts, weights, k, cfg, true)
}

/// Reference twin: the historical exact full scan every iteration.
pub fn lloyd_reference(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    k: usize,
    cfg: &LloydCfg,
) -> ContinuousSolution {
    lloyd_impl(data, pts, weights, k, cfg, false)
}

fn lloyd_impl(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    k: usize,
    cfg: &LloydCfg,
    bounded: bool,
) -> ContinuousSolution {
    assert_eq!(pts.len(), weights.len());
    assert!(!pts.is_empty());
    let n = pts.len();
    let mut rng = Rng::new(cfg.seed);
    let mut centers = init_pp(data, pts, weights, k, &mut rng);
    let kk = centers.len();
    let mut prev_cost = f64::INFINITY;
    let mut best = vec![f64::INFINITY; n];
    let mut bj = vec![0usize; n];
    // Hamerly state (bounded mode): ub upper-bounds the distance to the
    // assigned centroid, lb lower-bounds the distance to all others —
    // plain distances, not squared
    let mut ub = vec![0.0f64; n];
    let mut lb = vec![0.0f64; n];
    let mut moved = vec![0.0f64; kk];
    let mut first = true;
    for _ in 0..cfg.max_iters {
        assign_pass(data, pts, &centers, bounded, &mut first, &mut best, &mut bj, &mut ub, &mut lb);
        let cost = update_step(
            data,
            pts,
            weights,
            &best,
            &bj,
            &mut centers,
            bounded.then_some(&mut moved[..]),
        );
        if bounded {
            // centroid motion loosens the bounds: the assigned centroid
            // may have come `moved[bj]` closer is irrelevant (ub grows by
            // its motion), every other centroid came at most `delta_max`
            // closer
            let delta_max = moved.iter().copied().fold(0.0, f64::max);
            for i in 0..n {
                ub[i] += moved[bj[i]];
                lb[i] -= delta_max;
            }
        }
        if prev_cost.is_finite() && (prev_cost - cost).abs() <= cfg.tol * prev_cost {
            break;
        }
        prev_cost = cost;
    }
    // final cost against final centroids
    assign_pass(data, pts, &centers, bounded, &mut first, &mut best, &mut bj, &mut ub, &mut lb);
    let mut cost = 0.0;
    for i in 0..n {
        cost += weights[i] as f64 * best[i];
    }
    ContinuousSolution { centroids: VectorData::from_rows(&centers), cost }
}

/// Continuous k-means cost of arbitrary centroids over a weighted set
/// (blocked: centroids outer, points inner, like `nearest_centroids`).
pub fn continuous_cost(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    centroids: &VectorData,
) -> f64 {
    counter::charge(pts.len() * centroids.n());
    let mut best = vec![f64::INFINITY; pts.len()];
    for j in 0..centroids.n() {
        let crow = centroids.row(j as u32);
        for (i, &p) in pts.iter().enumerate() {
            let dd = sq_euclidean(data.row(p), crow);
            if dd < best[i] {
                best[i] = dd;
            }
        }
    }
    pts.iter().enumerate().map(|(i, _)| weights[i] as f64 * best[i]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs() -> VectorData {
        let mut rows = Vec::new();
        let mut rng = Rng::new(5);
        for c in [-50.0f64, 50.0] {
            for _ in 0..100 {
                rows.push(vec![(c + rng.gaussian()) as f32, (c + rng.gaussian()) as f32]);
            }
        }
        VectorData::from_rows(&rows)
    }

    fn five_blobs() -> VectorData {
        let mut rows = Vec::new();
        let mut rng = Rng::new(17);
        for c in [-80.0f64, -40.0, 0.0, 40.0, 80.0] {
            for _ in 0..120 {
                rows.push(vec![(c + rng.gaussian()) as f32, (c / 2.0 + rng.gaussian()) as f32]);
            }
        }
        VectorData::from_rows(&rows)
    }

    #[test]
    fn recovers_centroids() {
        let data = two_blobs();
        let pts: Vec<u32> = (0..200).collect();
        let w = vec![1u64; 200];
        let sol = lloyd(&data, &pts, &w, 2, &LloydCfg::default());
        assert_eq!(sol.centroids.n(), 2);
        // centroids near (±50, ±50): per-point cost ~2 (2 dims of unit var)
        assert!(sol.cost / 200.0 < 4.0, "avg cost {}", sol.cost / 200.0);
        let mut xs: Vec<f32> = (0..2).map(|j| sol.centroids.row(j)[0]).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((xs[0] + 50.0).abs() < 2.0 && (xs[1] - 50.0).abs() < 2.0, "{xs:?}");
    }

    #[test]
    fn weights_shift_centroid() {
        let data = VectorData::from_rows(&[vec![0.0], vec![10.0]]);
        let pts = vec![0u32, 1u32];
        let w = vec![9u64, 1u64];
        let sol = lloyd(&data, &pts, &w, 1, &LloydCfg::default());
        let c = sol.centroids.row(0)[0];
        assert!((c - 1.0).abs() < 1e-5, "weighted centroid {c}");
    }

    #[test]
    fn continuous_beats_discrete_cost() {
        // the centroid of {0, 1} at 0.5 costs 0.5; any discrete center costs 1.0
        let data = VectorData::from_rows(&[vec![0.0], vec![1.0]]);
        let pts = vec![0u32, 1u32];
        let w = vec![1u64, 1u64];
        let sol = lloyd(&data, &pts, &w, 1, &LloydCfg::default());
        assert!((sol.cost - 0.5).abs() < 1e-6, "cost {}", sol.cost);
    }

    #[test]
    fn continuous_cost_helper_agrees() {
        let data = two_blobs();
        let pts: Vec<u32> = (0..200).collect();
        let w = vec![1u64; 200];
        let sol = lloyd(&data, &pts, &w, 2, &LloydCfg::default());
        let c = continuous_cost(&data, &pts, &w, &sol.centroids);
        assert!((c - sol.cost).abs() < 1e-6 * (1.0 + c.abs()));
    }

    #[test]
    fn bounded_matches_reference_bit_for_bit_and_saves_evals() {
        let data = five_blobs();
        let pts: Vec<u32> = (0..600).collect();
        for w in [vec![1u64; 600], (0..600u64).map(|i| 1 + i % 5).collect()] {
            let cfg = LloydCfg::default();
            let (reference, eref) = counter::counted(|| lloyd_reference(&data, &pts, &w, 5, &cfg));
            let (bounded, ebnd) = counter::counted(|| lloyd(&data, &pts, &w, 5, &cfg));
            assert_eq!(bounded.cost.to_bits(), reference.cost.to_bits());
            assert_eq!(bounded.centroids.n(), reference.centroids.n());
            for j in 0..reference.centroids.n() as u32 {
                let (a, b) = (bounded.centroids.row(j), reference.centroids.row(j));
                assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()), "centroid {j}");
            }
            assert!(ebnd < eref, "bounded {ebnd} >= reference {eref}");
        }
    }

    /// Regression (reseed contradiction): the doc always promised empty
    /// clusters re-seed from the heaviest-cost point, but the code drew
    /// a uniformly random one. Force the empty path directly through the
    /// update step and check the documented behavior.
    #[test]
    fn empty_cluster_reseeds_from_heaviest_cost_point() {
        let data = VectorData::from_rows(&[vec![0.0], vec![4.0], vec![9.0]]);
        let pts = vec![0u32, 1, 2];
        let weights = vec![1u64, 5, 1];
        // all points assigned to cluster 0 → cluster 1 is empty;
        // contributions w·d²: 16, 20, 81 → heaviest is point 2
        let best = vec![16.0, 4.0, 81.0];
        let bj = vec![0usize, 0, 0];
        let mut centers = vec![vec![1.0f32], vec![7.0f32]];
        update_step(&data, &pts, &weights, &best, &bj, &mut centers, None);
        assert_eq!(centers[1], vec![9.0f32], "reseed must pick the max w·d² point");
    }

    #[test]
    fn reseed_targets_orders_by_contribution_then_position() {
        let weights = [1u64, 5, 1, 2, 3];
        let best = [4.0, 1.0, 9.0, 9.0, 3.0];
        // contributions: 4, 5, 9, 18, 9 → top3 = positions 3, 2 (tie with
        // 4 broken by position), 4
        assert_eq!(reseed_targets(&weights, &best, 3), vec![3, 2, 4]);
    }
}
