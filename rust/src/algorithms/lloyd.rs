//! Weighted Lloyd iteration for the CONTINUOUS k-means variant (§3.1
//! "Application to the continuous case", §3.3 closing remark): centers
//! are arbitrary points of R^d (centroids), not members of P. Works
//! directly on dense vectors, outside the `MetricSpace` index world.

use crate::metric::counter;
use crate::metric::dense::sq_euclidean;
use crate::points::VectorData;
use crate::util::rng::Rng;

/// Blocked nearest-centroid scan: centers outer, points inner, so each
/// centroid row stays hot while it streams the point block (and the
/// whole pass is two flat arrays, no per-point center chasing). Fills
/// `best` (squared distance) and `bj` (centroid position). Charges the
/// distance-evaluation counter like any other bulk query.
fn nearest_centroids(
    data: &VectorData,
    pts: &[u32],
    centers: &[Vec<f32>],
    best: &mut [f64],
    bj: &mut [usize],
) {
    counter::charge(pts.len() * centers.len());
    best.fill(f64::INFINITY);
    for b in bj.iter_mut() {
        *b = 0;
    }
    for (j, c) in centers.iter().enumerate() {
        for (i, &p) in pts.iter().enumerate() {
            let dd = sq_euclidean(data.row(p), c);
            if dd < best[i] {
                best[i] = dd;
                bj[i] = j;
            }
        }
    }
}

/// A continuous solution: k centroids in R^d + its weighted k-means cost.
#[derive(Clone, Debug)]
pub struct ContinuousSolution {
    pub centroids: VectorData,
    pub cost: f64,
}

#[derive(Clone, Debug)]
pub struct LloydCfg {
    pub max_iters: usize,
    /// Stop when relative cost improvement falls below this.
    pub tol: f64,
    pub seed: u64,
}

impl Default for LloydCfg {
    fn default() -> Self {
        LloydCfg { max_iters: 50, tol: 1e-6, seed: 0xF00D }
    }
}

/// Weighted k-means++ initialization over dense rows.
fn init_pp(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    k: usize,
    rng: &mut Rng,
) -> Vec<Vec<f32>> {
    let n = pts.len();
    let wprobs: Vec<f64> = weights.iter().map(|&w| w as f64).collect();
    let first = pts[rng.weighted_index(&wprobs).expect("positive weights")];
    let mut centers: Vec<Vec<f32>> = vec![data.row(first).to_vec()];
    counter::charge(pts.len());
    let mut mind: Vec<f64> = pts.iter().map(|&p| sq_euclidean(data.row(p), &centers[0])).collect();
    let mut probs = vec![0.0; n];
    while centers.len() < k.min(n) {
        for i in 0..n {
            probs[i] = weights[i] as f64 * mind[i];
        }
        let next = match rng.weighted_index(&probs) {
            Some(i) => pts[i],
            None => break, // all residuals zero
        };
        let row = data.row(next).to_vec();
        counter::charge(pts.len());
        for (i, &p) in pts.iter().enumerate() {
            let d = sq_euclidean(data.row(p), &row);
            if d < mind[i] {
                mind[i] = d;
            }
        }
        centers.push(row);
    }
    centers
}

/// Weighted Lloyd on (pts ⊆ data, weights). Returns centroids + cost
/// (sum of w·d² to nearest centroid).
pub fn lloyd(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    k: usize,
    cfg: &LloydCfg,
) -> ContinuousSolution {
    assert_eq!(pts.len(), weights.len());
    assert!(!pts.is_empty());
    let d = data.d();
    let mut rng = Rng::new(cfg.seed);
    let mut centers = init_pp(data, pts, weights, k, &mut rng);
    let mut prev_cost = f64::INFINITY;
    #[allow(unused_assignments)]
    let mut cost = 0.0;
    let mut best = vec![f64::INFINITY; pts.len()];
    let mut bj = vec![0usize; pts.len()];
    for _ in 0..cfg.max_iters {
        // assignment (blocked bulk scan), then weighted accumulation
        nearest_centroids(data, pts, &centers, &mut best, &mut bj);
        let mut sums = vec![vec![0.0f64; d]; centers.len()];
        let mut wsum = vec![0u64; centers.len()];
        cost = 0.0;
        for (i, &p) in pts.iter().enumerate() {
            cost += weights[i] as f64 * best[i];
            wsum[bj[i]] += weights[i];
            for (s, &x) in sums[bj[i]].iter_mut().zip(data.row(p)) {
                *s += weights[i] as f64 * x as f64;
            }
        }
        // update (empty clusters re-seeded from the heaviest-cost point)
        for (j, c) in centers.iter_mut().enumerate() {
            if wsum[j] > 0 {
                for (x, s) in c.iter_mut().zip(&sums[j]) {
                    *x = (*s / wsum[j] as f64) as f32;
                }
            } else {
                let far = pts[rng.below(pts.len())];
                *c = data.row(far).to_vec();
            }
        }
        if prev_cost.is_finite() && (prev_cost - cost).abs() <= cfg.tol * prev_cost {
            break;
        }
        prev_cost = cost;
    }
    // final cost against final centroids
    nearest_centroids(data, pts, &centers, &mut best, &mut bj);
    cost = 0.0;
    for i in 0..pts.len() {
        cost += weights[i] as f64 * best[i];
    }
    ContinuousSolution { centroids: VectorData::from_rows(&centers), cost }
}

/// Continuous k-means cost of arbitrary centroids over a weighted set
/// (blocked: centroids outer, points inner, like `nearest_centroids`).
pub fn continuous_cost(
    data: &VectorData,
    pts: &[u32],
    weights: &[u64],
    centroids: &VectorData,
) -> f64 {
    counter::charge(pts.len() * centroids.n());
    let mut best = vec![f64::INFINITY; pts.len()];
    for j in 0..centroids.n() {
        let crow = centroids.row(j as u32);
        for (i, &p) in pts.iter().enumerate() {
            let dd = sq_euclidean(data.row(p), crow);
            if dd < best[i] {
                best[i] = dd;
            }
        }
    }
    pts.iter().enumerate().map(|(i, _)| weights[i] as f64 * best[i]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs() -> VectorData {
        let mut rows = Vec::new();
        let mut rng = Rng::new(5);
        for c in [-50.0f64, 50.0] {
            for _ in 0..100 {
                rows.push(vec![(c + rng.gaussian()) as f32, (c + rng.gaussian()) as f32]);
            }
        }
        VectorData::from_rows(&rows)
    }

    #[test]
    fn recovers_centroids() {
        let data = two_blobs();
        let pts: Vec<u32> = (0..200).collect();
        let w = vec![1u64; 200];
        let sol = lloyd(&data, &pts, &w, 2, &LloydCfg::default());
        assert_eq!(sol.centroids.n(), 2);
        // centroids near (±50, ±50): per-point cost ~2 (2 dims of unit var)
        assert!(sol.cost / 200.0 < 4.0, "avg cost {}", sol.cost / 200.0);
        let mut xs: Vec<f32> = (0..2).map(|j| sol.centroids.row(j)[0]).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((xs[0] + 50.0).abs() < 2.0 && (xs[1] - 50.0).abs() < 2.0, "{xs:?}");
    }

    #[test]
    fn weights_shift_centroid() {
        let data = VectorData::from_rows(&[vec![0.0], vec![10.0]]);
        let pts = vec![0u32, 1u32];
        let w = vec![9u64, 1u64];
        let sol = lloyd(&data, &pts, &w, 1, &LloydCfg::default());
        let c = sol.centroids.row(0)[0];
        assert!((c - 1.0).abs() < 1e-5, "weighted centroid {c}");
    }

    #[test]
    fn continuous_beats_discrete_cost() {
        // the centroid of {0, 1} at 0.5 costs 0.5; any discrete center costs 1.0
        let data = VectorData::from_rows(&[vec![0.0], vec![1.0]]);
        let pts = vec![0u32, 1u32];
        let w = vec![1u64, 1u64];
        let sol = lloyd(&data, &pts, &w, 1, &LloydCfg::default());
        assert!((sol.cost - 0.5).abs() < 1e-6, "cost {}", sol.cost);
    }

    #[test]
    fn continuous_cost_helper_agrees() {
        let data = two_blobs();
        let pts: Vec<u32> = (0..200).collect();
        let w = vec![1u64; 200];
        let sol = lloyd(&data, &pts, &w, 2, &LloydCfg::default());
        let c = continuous_cost(&data, &pts, &w, &sol.centroids);
        assert!((c - sol.cost).abs() < 1e-6 * (1.0 + c.abs()));
    }
}
