//! Exact solvers by enumeration — the test oracle for tiny instances.

use crate::metric::{MetricSpace, Objective};

use super::{Instance, Solution};

/// Exact optimum over all k-subsets of the instance's points. Cost is
/// exponential in k; guarded to tiny instances (C(n, k) ≤ ~2e6).
pub fn brute_force(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
) -> Solution {
    let n = inst.n();
    let k = k.min(n);
    assert!(binomial(n, k) <= 2_000_000, "brute_force: instance too large (n={n}, k={k})");
    let mut comb: Vec<usize> = (0..k).collect();
    let mut best = Solution { centers: Vec::new(), cost: f64::INFINITY };
    loop {
        let centers: Vec<u32> = comb.iter().map(|&i| inst.pts[i]).collect();
        let cost = inst.cost(space, obj, &centers);
        if cost < best.cost {
            best = Solution { centers, cost };
        }
        // next combination (lexicographic)
        let mut i = k;
        loop {
            if i == 0 {
                return best;
            }
            i -= 1;
            if comb[i] != i + n - k {
                break;
            }
        }
        comb[i] += 1;
        for j in i + 1..k {
            comb[j] = comb[j - 1] + 1;
        }
    }
}

/// Exact 1-median/1-mean of a weighted sub-cluster (used by PAM-style
/// refinement): the point of `pts` minimizing the weighted cost.
/// Distances are issued as chunked `dist_batch` bulk queries with the
/// early cutoff applied between chunks, so hopeless candidates still
/// skip most of their distance work (cost is monotone in the scan).
pub fn exact_one_center(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
) -> (u32, f64) {
    const CHUNK: usize = 256;
    let n = inst.n();
    let mut dc = vec![0.0f64; CHUNK.min(n)];
    let mut best = (inst.pts[0], f64::INFINITY);
    for &c in inst.pts {
        let mut cost = 0.0;
        let mut lo = 0usize;
        while lo < n && cost < best.1 {
            let hi = (lo + CHUNK).min(n);
            let buf = &mut dc[..hi - lo];
            space.dist_batch(&inst.pts[lo..hi], c, buf);
            for (x, d) in (lo..hi).zip(buf.iter()) {
                cost += inst.weights[x] as f64 * obj.cost_of(*d);
            }
            lo = hi;
        }
        if cost < best.1 {
            best = (c, cost);
        }
    }
    best
}

/// Bounds-pruned twin of [`exact_one_center`], bit-identical to it.
///
/// The first candidate's full distance row is kept; for every later
/// candidate `c`, one evaluation `d(c, c0)` yields per-point lower
/// bounds `|d(x, c0) - d(c, c0)|` whose (deflated, term-wise) cost sum
/// lower-bounds the candidate's true cost in the reference's own
/// accumulation order — if even that bound reaches the incumbent, the
/// whole candidate is skipped without touching its row. Term-wise
/// smaller non-negative values produce a smaller (or equal) float sum,
/// so a skipped candidate could never have won the reference's strict
/// `cost < best` comparison. Requires `uniform_precision`; otherwise
/// delegates to the reference.
pub fn exact_one_center_pruned(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
) -> (u32, f64) {
    if !space.uniform_precision() {
        return exact_one_center(space, obj, inst);
    }
    const CHUNK: usize = 256;
    const LB_MARGIN: f64 = 1e-12;
    let n = inst.n();
    let mut dc = vec![0.0f64; CHUNK.min(n)];
    // full row for the anchor candidate (the reference computes it in
    // full too: the incumbent starts at infinity)
    let c0 = inst.pts[0];
    let mut row0 = vec![0.0f64; n];
    space.dist_batch(inst.pts, c0, &mut row0);
    let mut cost0 = 0.0;
    for (x, &d) in row0.iter().enumerate() {
        cost0 += inst.weights[x] as f64 * obj.cost_of(d);
    }
    let mut best = (c0, cost0);
    for &c in &inst.pts[1..] {
        let dc0 = space.dist(c, c0);
        // lower-bound the candidate's cost from the anchor row alone
        let mut lb_cost = 0.0;
        for (x, &a) in row0.iter().enumerate() {
            let lb = ((a - dc0).abs() - LB_MARGIN * (a + dc0)).max(0.0);
            lb_cost += inst.weights[x] as f64 * obj.cost_of(lb);
        }
        if lb_cost >= best.1 {
            continue;
        }
        let mut cost = 0.0;
        let mut lo = 0usize;
        while lo < n && cost < best.1 {
            let hi = (lo + CHUNK).min(n);
            let buf = &mut dc[..hi - lo];
            space.dist_batch(&inst.pts[lo..hi], c, buf);
            for (x, d) in (lo..hi).zip(buf.iter()) {
                cost += inst.weights[x] as f64 * obj.cost_of(*d);
            }
            lo = hi;
        }
        if cost < best.1 {
            best = (c, cost);
        }
    }
    best
}

/// C(n, k) with saturation above 2^60 (shared with the outlier brute
/// reference's instance-size guard).
pub(crate) fn binomial(n: usize, k: usize) -> u128 {
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
        if acc > 1 << 60 {
            return acc;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::three_cluster_line;

    #[test]
    fn finds_obvious_optimum() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let sol = brute_force(&space, Objective::Median, inst, 3);
        // optimum: cluster midpoints (indices 2, 7, 12), cost 3*(2+1+0+1+2)=18... per cluster 6
        assert_eq!(sol.cost, 18.0);
        let mut c = sol.centers.clone();
        c.sort_unstable();
        assert_eq!(c, vec![2, 7, 12]);
    }

    #[test]
    fn k1_matches_exact_one_center() {
        let (space, pts) = three_cluster_line();
        let w: Vec<u64> = (0..pts.len() as u64).map(|i| i + 1).collect();
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let b = brute_force(&space, obj, inst, 1);
            let (c, cost) = exact_one_center(&space, obj, inst);
            assert_eq!(b.centers, vec![c]);
            assert!((b.cost - cost).abs() < 1e-9);
        }
    }

    #[test]
    fn pruned_one_center_bit_identical_and_cheaper() {
        use crate::data::synth::GaussianMixtureSpec;
        use crate::metric::counter;
        use crate::metric::dense::EuclideanSpace;
        use std::sync::Arc;
        let (data, _) = GaussianMixtureSpec {
            n: 500,
            d: 3,
            k: 4,
            spread: 15.0,
            seed: 21,
            ..Default::default()
        }
        .generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..500).collect();
        let w: Vec<u64> = (0..500u64).map(|i| 1 + i % 7).collect();
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let (reference, eref) = counter::counted(|| exact_one_center(&space, obj, inst));
            let (pruned, epr) = counter::counted(|| exact_one_center_pruned(&space, obj, inst));
            assert_eq!(pruned.0, reference.0, "{obj}");
            assert_eq!(pruned.1.to_bits(), reference.1.to_bits(), "{obj}");
            assert!(epr < eref, "{obj}: pruned {epr} >= reference {eref}");
        }
    }

    #[test]
    fn binomial_sane() {
        assert_eq!(binomial(5, 2), 10);
        assert_eq!(binomial(10, 0), 1);
        assert_eq!(binomial(10, 10), 1);
    }

    #[test]
    #[should_panic(expected = "too large")]
    fn guards_large_instances() {
        use crate::metric::dense::EuclideanSpace;
        use crate::points::VectorData;
        use std::sync::Arc;
        let rows: Vec<Vec<f32>> = (0..60).map(|i| vec![i as f32]).collect();
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let pts: Vec<u32> = (0..60).collect();
        let w = vec![1u64; 60];
        // C(60, 10) ≈ 7.5e10 — must be rejected
        let _ = brute_force(&space, Objective::Median, Instance::new(&pts, &w), 10);
    }
}
