//! Exact solvers by enumeration — the test oracle for tiny instances.

use crate::metric::{MetricSpace, Objective};

use super::{Instance, Solution};

/// Exact optimum over all k-subsets of the instance's points. Cost is
/// exponential in k; guarded to tiny instances (C(n, k) ≤ ~2e6).
pub fn brute_force(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
) -> Solution {
    let n = inst.n();
    let k = k.min(n);
    assert!(binomial(n, k) <= 2_000_000, "brute_force: instance too large (n={n}, k={k})");
    let mut comb: Vec<usize> = (0..k).collect();
    let mut best = Solution { centers: Vec::new(), cost: f64::INFINITY };
    loop {
        let centers: Vec<u32> = comb.iter().map(|&i| inst.pts[i]).collect();
        let cost = inst.cost(space, obj, &centers);
        if cost < best.cost {
            best = Solution { centers, cost };
        }
        // next combination (lexicographic)
        let mut i = k;
        loop {
            if i == 0 {
                return best;
            }
            i -= 1;
            if comb[i] != i + n - k {
                break;
            }
        }
        comb[i] += 1;
        for j in i + 1..k {
            comb[j] = comb[j - 1] + 1;
        }
    }
}

/// Exact 1-median/1-mean of a weighted sub-cluster (used by PAM-style
/// refinement): the point of `pts` minimizing the weighted cost.
/// Distances are issued as chunked `dist_batch` bulk queries with the
/// early cutoff applied between chunks, so hopeless candidates still
/// skip most of their distance work (cost is monotone in the scan).
pub fn exact_one_center(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
) -> (u32, f64) {
    const CHUNK: usize = 256;
    let n = inst.n();
    let mut dc = vec![0.0f64; CHUNK.min(n)];
    let mut best = (inst.pts[0], f64::INFINITY);
    for &c in inst.pts {
        let mut cost = 0.0;
        let mut lo = 0usize;
        while lo < n && cost < best.1 {
            let hi = (lo + CHUNK).min(n);
            let buf = &mut dc[..hi - lo];
            space.dist_batch(&inst.pts[lo..hi], c, buf);
            for (x, d) in (lo..hi).zip(buf.iter()) {
                cost += inst.weights[x] as f64 * obj.cost_of(*d);
            }
            lo = hi;
        }
        if cost < best.1 {
            best = (c, cost);
        }
    }
    best
}

/// C(n, k) with saturation above 2^60 (shared with the outlier brute
/// reference's instance-size guard).
pub(crate) fn binomial(n: usize, k: usize) -> u128 {
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
        if acc > 1 << 60 {
            return acc;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::three_cluster_line;

    #[test]
    fn finds_obvious_optimum() {
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let sol = brute_force(&space, Objective::Median, inst, 3);
        // optimum: cluster midpoints (indices 2, 7, 12), cost 3*(2+1+0+1+2)=18... per cluster 6
        assert_eq!(sol.cost, 18.0);
        let mut c = sol.centers.clone();
        c.sort_unstable();
        assert_eq!(c, vec![2, 7, 12]);
    }

    #[test]
    fn k1_matches_exact_one_center() {
        let (space, pts) = three_cluster_line();
        let w: Vec<u64> = (0..pts.len() as u64).map(|i| i + 1).collect();
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let b = brute_force(&space, obj, inst, 1);
            let (c, cost) = exact_one_center(&space, obj, inst);
            assert_eq!(b.centers, vec![c]);
            assert!((b.cost - cost).abs() < 1e-9);
        }
    }

    #[test]
    fn binomial_sane() {
        assert_eq!(binomial(5, 2), 10);
        assert_eq!(binomial(10, 0), 1);
        assert_eq!(binomial(10, 10), 1);
    }

    #[test]
    #[should_panic(expected = "too large")]
    fn guards_large_instances() {
        use crate::metric::dense::EuclideanSpace;
        use crate::points::VectorData;
        use std::sync::Arc;
        let rows: Vec<Vec<f32>> = (0..60).map(|i| vec![i as f32]).collect();
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let pts: Vec<u32> = (0..60).collect();
        let w = vec![1u64; 60];
        // C(60, 10) ≈ 7.5e10 — must be rejected
        let _ = brute_force(&space, Objective::Median, Instance::new(&pts, &w), 10);
    }
}
