//! Sequential clustering algorithms (paper §3.4 building blocks).
//!
//! The MapReduce constructions need two sequential primitives, both run
//! on weighted instances:
//!   1. a β-approximation (possibly bi-criteria, m ≥ k centers) to
//!      bootstrap each partition's `T_ℓ` — `seeding::*` (k-means++‖
//!      bi-criteria, refs [1, 5, 25]) or `local_search` (refs [2, 12, 18]);
//!   2. an α-approximation to solve the final weighted coreset instance —
//!      `local_search`, or `pam` / `lloyd` for baselines & the continuous
//!      variant.
//! `brute` provides exact optima on tiny instances as the test oracle.

pub mod brute;
pub mod lloyd;
pub mod local_search;
pub mod multi_swap;
pub mod pam;
pub mod seeding;

use crate::metric::{MetricSpace, Objective};

/// A clustering solution: center point indices (global, `S ⊆ P` per the
/// paper's discrete formulation) plus its cost on the instance it was
/// computed for.
#[derive(Clone, Debug, PartialEq)]
pub struct Solution {
    pub centers: Vec<u32>,
    pub cost: f64,
}

impl Solution {
    pub fn k(&self) -> usize {
        self.centers.len()
    }
}

/// A weighted instance view: points (global indices) + parallel weights.
#[derive(Clone, Copy, Debug)]
pub struct Instance<'a> {
    pub pts: &'a [u32],
    pub weights: &'a [u64],
}

impl<'a> Instance<'a> {
    pub fn new(pts: &'a [u32], weights: &'a [u64]) -> Instance<'a> {
        assert_eq!(pts.len(), weights.len());
        assert!(!pts.is_empty(), "empty instance");
        Instance { pts, weights }
    }

    pub fn n(&self) -> usize {
        self.pts.len()
    }

    pub fn total_weight(&self) -> u64 {
        self.weights.iter().sum()
    }

    pub fn cost(&self, space: &dyn MetricSpace, obj: Objective, centers: &[u32]) -> f64 {
        space.weighted_cost(obj, self.pts, self.weights, centers)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::metric::dense::EuclideanSpace;
    use crate::points::VectorData;
    use std::sync::Arc;

    /// Tiny 1-d space with three obvious clusters around 0, 100, 200.
    pub fn three_cluster_line() -> (EuclideanSpace, Vec<u32>) {
        let mut rows = Vec::new();
        for c in [0.0f32, 100.0, 200.0] {
            for off in [-2.0f32, -1.0, 0.0, 1.0, 2.0] {
                rows.push(vec![c + off]);
            }
        }
        let n = rows.len() as u32;
        (EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows))), (0..n).collect())
    }
}
