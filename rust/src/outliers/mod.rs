//! Outlier-robust clustering subsystem: distributed (k, z)-median and
//! (k, z)-means in general metric spaces on top of the coreset pipeline.
//!
//! Real workloads are never noise-free; a handful of corrupt points can
//! drag every center of a plain k-median/k-means solution. The classical
//! fix is the (k, z) objective — cluster with k centers but write off the
//! z most expensive points — and the coreset machinery of the base paper
//! extends to it with two changes:
//!
//! - **construction** ([`pipeline`]): oversample each partition's rough
//!   solution by z′ = ⌈z/L⌉·oversample extra centers so outlier
//!   candidates keep accurate representatives, then compress the weighted
//!   union through `cover_with_balls_weighted`;
//! - **finisher** ([`finisher`]): solve the weighted (k, z) instance on
//!   the union coreset by excluding the z heaviest-cost weight units
//!   (local search over the robust objective, plus an exact brute-force
//!   reference for tiny instances).
//!
//! End-to-end entry point: `coordinator::solve` with
//! `ClusterConfig::outliers > 0` (CLI: `mrcoreset run --z Z`).

pub mod finisher;
pub mod pipeline;

pub use finisher::{
    brute_force_outliers, local_search_outliers, local_search_outliers_reference, robust_cost,
    robust_cost_of_dists, RobustCost, RobustSolution,
};
pub use pipeline::{outlier_coreset, OutlierCoresetConfig};
