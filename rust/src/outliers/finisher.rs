//! Sequential finishers for the weighted (k, z) instance (round 3 of the
//! outlier-robust pipeline).
//!
//! The robust objective on a weighted instance (E_w, k, z) charges every
//! point its weighted distance cost EXCEPT for the z heaviest-cost weight
//! units, which are written off as outliers (the Lagrangian view of
//! Charikar et al.'s k-median-with-outliers, adapted to the composable
//! coreset recipe of Ceccarello et al. / Dandolo et al.): a coreset point
//! of weight w may be excluded partially, because it stands for w input
//! points of which only some are noise.
//!
//! Two solvers:
//! - [`local_search_outliers`]: single-swap local search over the robust
//!   objective (the production path — scales to coreset-sized instances);
//! - [`brute_force_outliers`]: exact optimum by enumeration (tiny
//!   instances only; the test oracle).

use crate::algorithms::brute::binomial;
use crate::algorithms::local_search::{
    apply_swap, rebuild_book, sampled_candidate_pool, LocalSearchCfg,
};
use crate::algorithms::seeding::{dpp_seeding, gonzalez};
use crate::algorithms::Instance;
use crate::metric::{MetricSpace, Objective};
use crate::util::bitset::Bitset;
use crate::util::rng::Rng;

/// A robust cost evaluation: the kept cost plus which points were
/// (fully or partially) written off.
#[derive(Clone, Debug)]
pub struct RobustCost {
    /// Weighted cost with z weight units excluded.
    pub cost: f64,
    /// Positions (into the evaluated point list) holding at least one
    /// excluded weight unit, most expensive first; the last entry may be
    /// only partially excluded when weights exceed the remaining budget.
    pub excluded: Vec<u32>,
}

/// A solution of the (k, z) instance.
#[derive(Clone, Debug)]
pub struct RobustSolution {
    /// Selected centers (global point indices, S ⊆ coreset).
    pub centers: Vec<u32>,
    /// Robust (z-excluded) weighted cost on the solved instance.
    pub cost: f64,
    /// Positions (into the instance's point list) of the excluded points,
    /// most expensive first (see [`RobustCost::excluded`]).
    pub excluded: Vec<u32>,
}

/// Robust cost of a per-point distance vector: exclude the z heaviest-cost
/// weight units (ties broken toward the earlier position, so the result
/// is deterministic), charge the rest. Weights must be positive (the
/// `WeightedSet` invariant): a zero-weight entry would occupy a top-z
/// slot while absorbing no exclusion budget.
pub fn robust_cost_of_dists(
    obj: Objective,
    dists: &[f64],
    weights: &[u64],
    z: u64,
) -> RobustCost {
    assert_eq!(dists.len(), weights.len());
    // hard check at the public entry (the hot internal path keeps a
    // debug_assert): a zero weight breaks the top-z selection invariant
    assert!(
        weights.iter().all(|&w| w > 0),
        "robust_cost_of_dists requires positive weights (the WeightedSet invariant)"
    );
    if z == 0 {
        let cost = dists
            .iter()
            .zip(weights)
            .map(|(&d, &w)| w as f64 * obj.cost_of(d))
            .sum();
        return RobustCost { cost, excluded: Vec::new() };
    }
    let mut scratch = Vec::new();
    let (cost, excluded) = robust_core(obj, dists, weights, z, &mut scratch, true);
    RobustCost { cost, excluded }
}

/// Cost-only robust evaluation with a reusable scratch buffer — the swap
/// loop's hot path (no allocation per evaluation).
fn robust_cost_value(
    obj: Objective,
    dists: &[f64],
    weights: &[u64],
    z: u64,
    scratch: &mut Vec<u32>,
) -> f64 {
    if z == 0 {
        return dists.iter().zip(weights).map(|(&d, &w)| w as f64 * obj.cost_of(d)).sum();
    }
    robust_core(obj, dists, weights, z, scratch, false).0
}

/// Shared core of the robust evaluations. The excluded set always lies
/// within the z most-distant points (every excluded point absorbs at
/// least one weight unit), so a select-nth partition plus an O(z log z)
/// sort of that region replaces a full O(n log n) sort; the remainder is
/// charged in a single unordered pass.
fn robust_core(
    obj: Objective,
    dists: &[f64],
    weights: &[u64],
    z: u64,
    scratch: &mut Vec<u32>,
    want_excluded: bool,
) -> (f64, Vec<u32>) {
    let n = dists.len();
    debug_assert!(
        weights.iter().all(|&w| w > 0),
        "robust cost requires positive weights (the WeightedSet invariant): a zero-weight \
         entry would occupy a top-z slot without absorbing exclusion budget"
    );
    scratch.clear();
    scratch.extend(0..n as u32);
    let zi = z.min(n as u64) as usize;
    let cmp =
        |a: &u32, b: &u32| dists[*b as usize].total_cmp(&dists[*a as usize]).then(a.cmp(b));
    if zi < n {
        scratch.select_nth_unstable_by(zi, cmp);
    }
    scratch[..zi].sort_unstable_by(cmp);
    let mut remaining = z;
    let mut cost = 0.0f64;
    let mut excluded = Vec::new();
    for &pos in &scratch[..zi] {
        let w = weights[pos as usize];
        let cut = w.min(remaining);
        if cut > 0 {
            remaining -= cut;
            if want_excluded {
                excluded.push(pos);
            }
        }
        cost += (w - cut) as f64 * obj.cost_of(dists[pos as usize]);
    }
    for &pos in &scratch[zi..] {
        cost += weights[pos as usize] as f64 * obj.cost_of(dists[pos as usize]);
    }
    (cost, excluded)
}

/// Robust cost of a center set on a weighted instance: one bulk Voronoi
/// pass, then z-unit exclusion.
pub fn robust_cost(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    centers: &[u32],
    z: u64,
) -> RobustCost {
    let assign = space.nearest_batch(inst.pts, centers);
    robust_cost_of_dists(obj, &assign.dist, inst.weights, z)
}

/// Single-swap local search over the robust objective. `init = None`
/// seeds with the better (under the robust cost) of D^p-seeding and
/// farthest-first — the latter chases outliers, so it must compete on the
/// robust objective rather than be trusted outright.
///
/// Swap evaluation: for a candidate `c` one `dist_batch` gives d(x, c);
/// removing center q sends each point to `min(d(x,c), d1|d2)`, and the
/// robust cost of that distance vector re-selects the excluded set — the
/// exclusion is NOT frozen across swaps, which is what makes the search
/// outlier-aware rather than merely outlier-tolerant.
///
/// Accepted swaps update the nearest/second-nearest book incrementally
/// (see `algorithms::local_search`): the winning candidate's distance
/// row kept from the scan plus a re-scan of the points whose book
/// entries named the evicted center, instead of a full O(nk) rebuild.
/// Bit-identical to [`local_search_outliers_reference`].
pub fn local_search_outliers(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    z: u64,
    init: Option<Vec<u32>>,
    cfg: &LocalSearchCfg,
) -> RobustSolution {
    local_search_outliers_impl(space, obj, inst, k, z, init, cfg, true)
}

/// Reference implementation with full `rebuild_book` after each accepted
/// swap — the bit-exact oracle for the incremental path.
pub fn local_search_outliers_reference(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    z: u64,
    init: Option<Vec<u32>>,
    cfg: &LocalSearchCfg,
) -> RobustSolution {
    local_search_outliers_impl(space, obj, inst, k, z, init, cfg, false)
}

#[allow(clippy::too_many_arguments)]
fn local_search_outliers_impl(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    z: u64,
    init: Option<Vec<u32>>,
    cfg: &LocalSearchCfg,
    incremental: bool,
) -> RobustSolution {
    // see local_search_impl: block-size-dependent precision forbids
    // reusing distance rows across queries
    let incremental = incremental && space.uniform_precision();
    let n = inst.n();
    let k = k.min(n);
    let mut rng = Rng::new(cfg.seed);
    let mut centers = match init {
        Some(c) => {
            assert!(!c.is_empty());
            c
        }
        None => {
            let dpp = dpp_seeding(space, obj, inst, k, &mut rng).centers;
            let gon = gonzalez(space, inst, k, 0);
            let dpp_cost = robust_cost(space, obj, inst, &dpp, z).cost;
            let gon_cost = robust_cost(space, obj, inst, &gon, z).cost;
            if gon_cost < dpp_cost {
                gon
            } else {
                dpp
            }
        }
    };
    if centers.len() >= n {
        let rc = robust_cost(space, obj, inst, &centers, z);
        return RobustSolution { centers, cost: rc.cost, excluded: rc.excluded };
    }
    let mut book = rebuild_book(space, inst.pts, &centers);
    let mut current = robust_cost_of_dists(obj, &book.d1, inst.weights, z);
    let exhaustive = n <= cfg.exhaustive_below;
    let mut dry_passes = 0usize;
    let mut dc_buf = vec![0.0f64; n];
    let mut best_dc = vec![0.0f64; n];
    let mut nd_buf = vec![0.0f64; n];
    let mut scratch: Vec<u32> = Vec::with_capacity(n);
    let mut in_centers = Bitset::from_members(space.n_points(), &centers);
    for _pass in 0..cfg.max_passes {
        // Candidate pool: exhaustive for small instances; otherwise half
        // uniform, half biased by the robust residual. Excluded points
        // keep only their still-charged residual weight in the bias: a
        // fully written-off point cannot improve the robust objective,
        // but a partially-excluded heavy representative (the last entry
        // of the greedy exclusion) still pays (w−cut)·cost(d1) — often
        // the dominant term — and must stay a promising swap-in.
        let cand_idx: Vec<usize> = if exhaustive {
            (0..n).collect()
        } else {
            let mut probs: Vec<f64> = (0..n)
                .map(|i| inst.weights[i] as f64 * obj.cost_of(book.d1[i]))
                .collect();
            let mut remaining = z;
            for &pos in &current.excluded {
                let w = inst.weights[pos as usize];
                let cut = w.min(remaining);
                remaining -= cut;
                probs[pos as usize] = (w - cut) as f64 * obj.cost_of(book.d1[pos as usize]);
            }
            sampled_candidate_pool(n, &probs, cfg.sample_candidates, &mut rng)
        };
        let mut best_cost = current.cost;
        let mut best_swap: Option<(usize, u32)> = None;
        for ci in cand_idx {
            let cand = inst.pts[ci];
            if in_centers.contains(cand) {
                continue;
            }
            space.dist_batch(inst.pts, cand, &mut dc_buf);
            let mut improved = false;
            for q in 0..centers.len() {
                for x in 0..n {
                    let kept = if book.i1[x] as usize == q { book.d2[x] } else { book.d1[x] };
                    nd_buf[x] = dc_buf[x].min(kept);
                }
                let total = robust_cost_value(obj, &nd_buf, inst.weights, z, &mut scratch);
                if total < best_cost {
                    best_cost = total;
                    best_swap = Some((q, cand));
                    improved = true;
                }
            }
            if improved {
                // keep the winner's distance row for the book update
                // (one copy per improving candidate, not per q)
                best_dc.copy_from_slice(&dc_buf);
            }
        }
        match best_swap {
            Some((q, cand)) if best_cost <= current.cost * (1.0 - cfg.min_rel_improvement) => {
                apply_swap(
                    space,
                    inst.pts,
                    &mut centers,
                    &mut in_centers,
                    q,
                    cand,
                    &best_dc,
                    &mut book,
                    incremental,
                );
                current = robust_cost_of_dists(obj, &book.d1, inst.weights, z);
                dry_passes = 0;
            }
            _ if exhaustive => break, // true local optimum of the robust objective
            _ => {
                dry_passes += 1;
                if dry_passes >= cfg.patience {
                    break;
                }
            }
        }
    }
    RobustSolution { centers, cost: current.cost, excluded: current.excluded }
}

/// Exact (k, z) optimum over all k-subsets — the weighted brute-force
/// reference for tiny instances.
pub fn brute_force_outliers(
    space: &dyn MetricSpace,
    obj: Objective,
    inst: Instance<'_>,
    k: usize,
    z: u64,
) -> RobustSolution {
    let n = inst.n();
    let k = k.min(n);
    assert!(
        binomial(n, k) <= 2_000_000,
        "brute_force_outliers: instance too large (n={n}, k={k})"
    );
    let mut comb: Vec<usize> = (0..k).collect();
    let mut best: Option<RobustSolution> = None;
    loop {
        let centers: Vec<u32> = comb.iter().map(|&i| inst.pts[i]).collect();
        let rc = robust_cost(space, obj, inst, &centers, z);
        let better = match &best {
            Some(b) => rc.cost < b.cost,
            None => true,
        };
        if better {
            best = Some(RobustSolution { centers, cost: rc.cost, excluded: rc.excluded });
        }
        // next combination (lexicographic)
        let mut i = k;
        loop {
            if i == 0 {
                return best.expect("at least one combination evaluated");
            }
            i -= 1;
            if comb[i] != i + n - k {
                break;
            }
        }
        comb[i] += 1;
        for j in i + 1..k {
            comb[j] = comb[j - 1] + 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::brute::brute_force;
    use crate::metric::dense::EuclideanSpace;
    use crate::points::VectorData;
    use std::sync::Arc;

    /// Three 1-d clusters around 0/100/200 (5 points each, offsets
    /// −2..2) plus two far noise points at 10 000 and 20 000.
    fn noisy_line() -> (EuclideanSpace, Vec<u32>) {
        let mut rows = Vec::new();
        for c in [0.0f32, 100.0, 200.0] {
            for off in [-2.0f32, -1.0, 0.0, 1.0, 2.0] {
                rows.push(vec![c + off]);
            }
        }
        rows.push(vec![10_000.0]);
        rows.push(vec![20_000.0]);
        let n = rows.len() as u32;
        (EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows))), (0..n).collect())
    }

    #[test]
    fn robust_cost_excludes_heaviest_units() {
        let dists = [5.0, 1.0, 2.0];
        let weights = [1u64, 2, 1];
        // z=1: drop the d=5 point entirely
        let rc = robust_cost_of_dists(Objective::Median, &dists, &weights, 1);
        assert_eq!(rc.excluded, vec![0]);
        assert!((rc.cost - (2.0 * 1.0 + 1.0 * 2.0)).abs() < 1e-12);
        // z=2: drop d=5, then d=2
        let rc = robust_cost_of_dists(Objective::Median, &dists, &weights, 2);
        assert_eq!(rc.excluded, vec![0, 2]);
        assert!((rc.cost - 2.0).abs() < 1e-12);
        // z=0: plain weighted cost, nothing excluded
        let rc = robust_cost_of_dists(Objective::Median, &dists, &weights, 0);
        assert!(rc.excluded.is_empty());
        assert!((rc.cost - (5.0 + 2.0 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn robust_cost_partial_exclusion() {
        // weight 3 at d=5, budget 2: one unit of the point stays charged
        let dists = [5.0, 1.0];
        let weights = [3u64, 2];
        let rc = robust_cost_of_dists(Objective::Median, &dists, &weights, 2);
        assert_eq!(rc.excluded, vec![0]);
        assert!((rc.cost - (1.0 * 5.0 + 2.0 * 1.0)).abs() < 1e-12);
    }

    #[test]
    fn cost_only_path_matches_full_evaluation() {
        let dists = [3.0, 7.0, 1.0, 7.0, 0.5];
        let weights = [2u64, 1, 5, 3, 1];
        let mut scratch = Vec::new();
        for z in 0..13u64 {
            let full = robust_cost_of_dists(Objective::Means, &dists, &weights, z);
            let fast = robust_cost_value(Objective::Means, &dists, &weights, z, &mut scratch);
            assert_eq!(full.cost.to_bits(), fast.to_bits(), "z={z}");
        }
    }

    #[test]
    fn robust_cost_budget_exceeding_total_weight_zeroes_cost() {
        let dists = [5.0, 1.0];
        let weights = [1u64, 1];
        let rc = robust_cost_of_dists(Objective::Means, &dists, &weights, 10);
        assert_eq!(rc.cost, 0.0);
        assert_eq!(rc.excluded, vec![0, 1]);
    }

    #[test]
    fn local_search_excludes_noise_and_finds_clusters() {
        let (space, pts) = noisy_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let sol =
                local_search_outliers(&space, obj, inst, 3, 2, None, &LocalSearchCfg::default());
            let mut buckets = [0usize; 3];
            for &c in &sol.centers {
                assert!(c < 15, "{obj}: center {c} sits on a noise point");
                buckets[(c / 5) as usize] += 1;
            }
            assert_eq!(buckets, [1, 1, 1], "{obj}: centers {:?}", sol.centers);
            let mut excl = sol.excluded.clone();
            excl.sort_unstable();
            assert_eq!(excl, vec![15, 16], "{obj}: excluded {:?}", sol.excluded);
        }
    }

    #[test]
    fn z_zero_degenerates_to_plain_objective() {
        let (space, pts) = noisy_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let sol = local_search_outliers(
            &space,
            Objective::Median,
            inst,
            3,
            0,
            None,
            &LocalSearchCfg::default(),
        );
        assert!(sol.excluded.is_empty());
        let check = robust_cost(&space, Objective::Median, inst, &sol.centers, 0);
        assert_eq!(sol.cost.to_bits(), check.cost.to_bits());
    }

    #[test]
    fn brute_reference_on_tiny_instance() {
        let (space, pts) = noisy_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        // optimum: midpoints 2/7/12, noise excluded; per cluster cost 6
        let opt = brute_force_outliers(&space, Objective::Median, inst, 3, 2);
        assert_eq!(opt.cost, 18.0);
        let mut c = opt.centers.clone();
        c.sort_unstable();
        assert_eq!(c, vec![2, 7, 12]);
        let mut excl = opt.excluded.clone();
        excl.sort_unstable();
        assert_eq!(excl, vec![15, 16]);
        // local search reaches the same ballpark
        let ls = local_search_outliers(
            &space,
            Objective::Median,
            inst,
            3,
            2,
            None,
            &LocalSearchCfg::default(),
        );
        assert!(ls.cost <= opt.cost * 1.7 + 1e-9, "ls {} vs opt {}", ls.cost, opt.cost);
    }

    #[test]
    fn brute_z_zero_matches_plain_brute_force() {
        use crate::algorithms::testutil::three_cluster_line;
        let (space, pts) = three_cluster_line();
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        for obj in [Objective::Median, Objective::Means] {
            let plain = brute_force(&space, obj, inst, 2);
            let robust = brute_force_outliers(&space, obj, inst, 2, 0);
            assert_eq!(plain.cost.to_bits(), robust.cost.to_bits(), "{obj}");
            assert_eq!(plain.centers, robust.centers, "{obj}");
        }
    }

    #[test]
    fn weighted_exclusion_prefers_far_light_points() {
        // heavy near cluster + one light far point: z=1 must write off
        // the far point, not a unit of the heavy one
        let rows = vec![vec![0.0f32], vec![1.0], vec![500.0]];
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let pts = vec![0u32, 1, 2];
        let w = vec![100u64, 100, 1];
        let inst = Instance::new(&pts, &w);
        let sol = local_search_outliers(
            &space,
            Objective::Median,
            inst,
            1,
            1,
            None,
            &LocalSearchCfg::default(),
        );
        assert_eq!(sol.excluded, vec![2]);
        assert!(sol.centers[0] < 2, "center {:?} chased the outlier", sol.centers);
    }
}
