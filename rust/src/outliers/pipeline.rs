//! Outlier-aware MapReduce coreset construction.
//!
//! The composable-coreset recipe extends to z outliers by oversampling
//! local centers (Ceccarello et al., arXiv:1802.09205; Dandolo et al.,
//! arXiv:2202.08173): a partition cannot know which of its points are
//! globally noise, so each reducer's rough solution T_ℓ gets
//! z′ = ⌈z/L⌉·oversample extra centers beyond k. Far-flung points then
//! capture their own T_ℓ center, keeping the local tolerance radius R_ℓ
//! small and guaranteeing every outlier candidate survives into the
//! coreset with an accurate representative — so the final (k, z) solver
//! can still choose which z weight units to write off.
//!
//! Rounds (mirroring §3.2/§3.3 of the base paper):
//! 1. `outliers-r1-local`: per partition, T_ℓ with k + z′ centers, then
//!    CoverWithBalls(P_ℓ, T_ℓ, R_ℓ, ·, ·) → weighted C_{w,ℓ}.
//! 2. `outliers-r2-compress`: one reducer takes the weighted union C_w,
//!    seeds a global rough solution T with k + z centers on the weighted
//!    instance, and runs `cover_with_balls_weighted`(C_w, w, T, R, ·, ·)
//!    — carrying the round-1 weights through — to produce E_w.
//!
//! Both rounds charge the executor's memory meter and (implicitly, via
//! the metric counter) the per-reducer distance-evaluation accounting,
//! so `RoundStats` attributes the oversampling overhead per round. Like
//! the base pipelines, this one is generic over [`Executor`], so the
//! spill backend can stage both rounds' inputs out of core.

use crate::algorithms::seeding::dpp_seeding;
use crate::algorithms::Instance;
use crate::coreset::cover::cover_with_balls_weighted;
use crate::coreset::local::cover_params;
use crate::coreset::pipeline::{global_radius, run_round1_named, CoresetConfig, PipelineOutput};
use crate::coreset::TlAlgo;
use crate::mapreduce::{partition_reported, ExecError, Executor, PartitionStrategy};
use crate::metric::{MetricSpace, Objective};
use crate::points::WeightedSet;
use crate::util::rng::Rng;

/// Configuration of the outlier-aware coreset construction.
#[derive(Clone, Debug)]
pub struct OutlierCoresetConfig {
    /// Precision parameter ε ∈ (0,1).
    pub eps: f64,
    /// Assumed approximation factor β of the T_ℓ algorithm.
    pub beta: f64,
    pub k: usize,
    /// Number of outliers z the final solver may write off.
    pub z: usize,
    /// Multiplier on ⌈z/L⌉ for the per-partition extra centers z′.
    pub oversample: usize,
    pub tl: TlAlgo,
    pub seed: u64,
}

impl OutlierCoresetConfig {
    pub fn new(k: usize, z: usize, eps: f64) -> OutlierCoresetConfig {
        OutlierCoresetConfig {
            eps,
            beta: 2.0,
            k,
            z,
            oversample: 2,
            tl: TlAlgo::DppSeeding,
            seed: 0x5EED,
        }
    }

    /// Per-partition center count k + z′ with z′ = ⌈z/L⌉·oversample.
    pub fn m_local(&self, l: usize) -> usize {
        let l = l.max(1);
        let z_ceil = self.z / l + usize::from(self.z % l != 0);
        self.k + z_ceil * self.oversample
    }
}

/// 2-round outlier-aware coreset construction; returns E_w (weights sum
/// to |P| — exclusion happens in the finisher, not here).
pub fn outlier_coreset<E: Executor>(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    l: usize,
    strategy: PartitionStrategy,
    cfg: &OutlierCoresetConfig,
    exec: &E,
) -> Result<PipelineOutput, ExecError> {
    let parts = partition_reported(pts, l, strategy, "outlier_coreset");
    let part_sizes: Vec<usize> = parts.iter().map(Vec::len).collect();

    // Round 1: the shared per-partition local-coreset round, with the
    // oversampled center count k + z′ and an outliers-specific seed salt.
    let r1cfg = CoresetConfig {
        eps: cfg.eps,
        beta: cfg.beta,
        m: cfg.m_local(parts.len()),
        tl: cfg.tl,
        seed: cfg.seed,
    };
    let inputs = exec.scatter(parts)?;
    let locals =
        run_round1_named(space, obj, &inputs, &r1cfg, exec, "outliers-r1-local", 0x0071_0000)?;
    let mut radii = Vec::new();
    let mut cw = WeightedSet::default();
    locals.for_each(|o| {
        radii.push(o.r);
        cw.merge(&o.cover.set);
    })?;
    let cw_size = cw.len();

    // Global tolerance radius R (same aggregation as the base pipeline).
    let global_r = global_radius(obj, &radii, &part_sizes);

    // Round 2: compress the weighted union with a weighted cover against
    // a global (k + z)-center rough solution.
    let (ce, cb) = cover_params(obj, cfg.eps, cfg.beta);
    let compress_in = exec.scatter(vec![cw])?;
    let e_parts = exec.round("outliers-r2-compress", &compress_in, move |_, cs, meter| {
        meter.charge(cs.len()); // resident weighted union C_w
        let mut rng = Rng::new(cfg.seed ^ 0x0171_CAFE);
        let m_global = (cfg.k + cfg.z).min(cs.len());
        let inst = Instance::new(&cs.indices, &cs.weights);
        let t = dpp_seeding(space, obj, inst, m_global, &mut rng).centers;
        meter.charge(t.len());
        let res =
            cover_with_balls_weighted(space, &cs.indices, Some(&cs.weights), &t, global_r, ce, cb);
        meter.charge(res.set.len()); // E_w
        meter.release(cs.len() + t.len() + res.set.len());
        res.set
    })?;
    let coreset = e_parts.into_items()?.into_iter().next().expect("one compress reducer");

    Ok(PipelineOutput { coreset, radii, part_sizes, cw_size, global_r: Some(global_r) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{GaussianMixtureSpec, NoiseSpec};
    use crate::mapreduce::Simulator;
    use crate::metric::dense::EuclideanSpace;
    use std::sync::Arc;

    fn noisy_mixture(n: usize, noise: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
        let spec = GaussianMixtureSpec { n, d: 2, k: 4, spread: 50.0, seed, ..Default::default() };
        let (data, _) = spec.generate_with_noise(&NoiseSpec {
            count: noise,
            expanse: 20.0,
            offset: 0.0,
            seed: seed ^ 0x9,
        });
        let total = data.n() as u32;
        (EuclideanSpace::new(Arc::new(data)), (0..total).collect())
    }

    #[test]
    fn two_rounds_and_weight_conservation() {
        let (space, pts) = noisy_mixture(1500, 30, 1);
        let sim = Simulator::new();
        let cfg = OutlierCoresetConfig::new(4, 30, 0.5);
        for obj in [Objective::Median, Objective::Means] {
            let out = outlier_coreset(
                &space,
                obj,
                &pts,
                5,
                PartitionStrategy::RoundRobin,
                &cfg,
                &sim,
            )
            .expect("pipeline");
            assert_eq!(out.coreset.total_weight(), pts.len() as u64, "{obj}");
            assert!(out.coreset.len() <= pts.len(), "{obj}");
            assert!(out.global_r.unwrap() > 0.0, "{obj}");
            assert_eq!(out.radii.len(), 5, "{obj}");
            let stats = sim.take_stats();
            assert_eq!(stats.num_rounds(), 2, "{obj}");
            assert_eq!(stats.rounds[0].name, "outliers-r1-local");
            assert_eq!(stats.rounds[1].name, "outliers-r2-compress");
            assert!(stats.rounds[0].dist_evals > 0, "{obj}: round-1 work unattributed");
            assert!(stats.rounds[1].dist_evals > 0, "{obj}: round-2 work unattributed");
        }
    }

    #[test]
    fn m_local_oversamples_by_partition_share() {
        let cfg = OutlierCoresetConfig::new(8, 50, 0.5);
        // ⌈50/10⌉·2 = 10 extra centers
        assert_eq!(cfg.m_local(10), 8 + 10);
        // ⌈50/7⌉·2 = 16
        assert_eq!(cfg.m_local(7), 8 + 16);
        // z = 0 degenerates to k
        assert_eq!(OutlierCoresetConfig::new(8, 0, 0.5).m_local(10), 8);
    }

    #[test]
    fn deterministic_across_runs() {
        let (space, pts) = noisy_mixture(800, 20, 2);
        let cfg = OutlierCoresetConfig::new(4, 20, 0.5);
        let sim = Simulator::new();
        let a = outlier_coreset(
            &space,
            Objective::Median,
            &pts,
            4,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        let b = outlier_coreset(
            &space,
            Objective::Median,
            &pts,
            4,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        assert_eq!(a.coreset, b.coreset);
        assert_eq!(a.radii, b.radii);
        assert_eq!(a.global_r, b.global_r);
    }

    #[test]
    fn single_partition_degenerates_gracefully() {
        let (space, pts) = noisy_mixture(400, 10, 3);
        let sim = Simulator::new();
        let cfg = OutlierCoresetConfig::new(3, 10, 0.6);
        let out = outlier_coreset(
            &space,
            Objective::Means,
            &pts,
            1,
            PartitionStrategy::Contiguous,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        assert_eq!(out.part_sizes, vec![410]);
        assert_eq!(out.coreset.total_weight(), 410);
    }
}
