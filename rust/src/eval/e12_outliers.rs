//! E12 — Outlier-robust clustering (the `outliers` subsystem).
//!
//! Workload: a Gaussian mixture in a small box plus a far uniform noise
//! blob (`NoiseSpec` with a large offset) — the adversarial regime where
//! a non-robust solver provably distorts, because dedicating a center to
//! the blob saves more than abandoning a real cluster costs.
//!
//! For each objective we run the robust (k, z) solver with z = the true
//! noise count against the plain z = 0 solver and the uniform /
//! k-means‖ baselines, and report:
//! - cost on the full input (noise included — the plain solvers'
//!   objective, which the robust solver deliberately does NOT minimize);
//! - cost on the ground-truth inliers (what actually matters);
//! - outlier recall: the fraction of injected noise among the z points
//!   the solution writes off.
//! A second table attributes the robust pipeline's distance-evaluation
//! work per MapReduce round (`JobStats::dist_evals_for`), making the
//! oversampling overhead visible.

use std::sync::Arc;

use crate::baselines::kmeans_parallel::{self, KmeansParCfg};
use crate::baselines::uniform::{self, UniformCfg};
use crate::coordinator::{solve, ClusterConfig};
use crate::data::synth::{GaussianMixtureSpec, NoiseSpec};
use crate::mapreduce::Simulator;
use crate::metric::dense::EuclideanSpace;
use crate::metric::{MetricSpace, Objective};
use crate::outliers::robust_cost_of_dists;
use crate::util::table::{fnum, Table};

use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 2000 } else { 10_000 };
    let noise = if quick { 40 } else { 200 };
    let k = 4;
    let spec =
        GaussianMixtureSpec { n, d: 2, k, spread: 30.0, seed: 1201, ..Default::default() };
    let (data, labels) = spec.generate_with_noise(&NoiseSpec {
        count: noise,
        expanse: 10.0,
        offset: 40.0,
        seed: 1301,
    });
    let total = data.n();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..total as u32).collect();
    let inliers: Vec<u32> =
        pts.iter().copied().filter(|&i| labels[i as usize] != u32::MAX).collect();

    let inlier_cost =
        |obj: Objective, centers: &[u32]| space.assign(&inliers, centers).cost_unit(obj);
    // Which z points would this solution write off, and how many of them
    // are injected noise? (Uniform treatment for robust and non-robust
    // methods: the z most expensive points under the method's centers.)
    let recall = |obj: Objective, centers: &[u32]| {
        let assign = space.assign(&pts, centers);
        let unit = vec![1u64; pts.len()];
        let rc = robust_cost_of_dists(obj, &assign.dist, &unit, noise as u64);
        let hits =
            rc.excluded.iter().filter(|&&p| labels[p as usize] == u32::MAX).count();
        hits as f64 / noise as f64
    };

    let mut table = Table::new(vec![
        "objective",
        "method",
        "summary size",
        "cost(full)",
        "cost(inliers)",
        "outlier recall",
    ]);
    let mut work = Table::new(vec!["objective", "round", "dist evals"]);

    for obj in [Objective::Median, Objective::Means] {
        let mut rcfg = ClusterConfig::new(obj, k, 0.5);
        rcfg.outliers = noise;
        let robust = solve(&space, &pts, &rcfg);
        let plain = solve(&space, &pts, &ClusterConfig::new(obj, k, 0.5));

        for (name, rep) in
            [("THIS PAPER robust (z=noise)", &robust), ("THIS PAPER plain (z=0)", &plain)]
        {
            table.row(vec![
                obj.name().to_string(),
                name.to_string(),
                rep.coreset_size.to_string(),
                fnum(rep.full_cost),
                fnum(inlier_cost(obj, &rep.solution.centers)),
                fnum(recall(obj, &rep.solution.centers)),
            ]);
        }

        let sim = Simulator::new();
        let mut reports = vec![uniform::run(
            &space,
            obj,
            &pts,
            k,
            &UniformCfg { size: robust.coreset_size.max(8), l: robust.l, seed: 15 },
            &sim,
        )];
        if obj == Objective::Means {
            reports.push(kmeans_parallel::run(&space, obj, &pts, k, &KmeansParCfg::new(k), &sim));
        }
        for r in reports {
            table.row(vec![
                obj.name().to_string(),
                r.name.to_string(),
                r.summary_size.to_string(),
                fnum(r.full_cost),
                fnum(inlier_cost(obj, &r.solution.centers)),
                fnum(recall(obj, &r.solution.centers)),
            ]);
        }

        for round in ["outliers-r1-local", "outliers-r2-compress", "final-solve"] {
            work.row(vec![
                obj.name().to_string(),
                round.to_string(),
                robust.stats.dist_evals_for(round).to_string(),
            ]);
        }

        // geometry pruning in the kmeans|| baseline on the same noisy
        // workload: assignment-path evals of the pruned vs unpruned twin
        // (the shared "kmeans||-reduce" solve subtracted on both sides)
        if obj == Objective::Means {
            use crate::metric::counter;
            let cfg = KmeansParCfg::new(k);
            for (label, pruned) in [
                ("kmeans|| assign path (pruned)", true),
                ("kmeans|| assign path (unpruned)", false),
            ] {
                let sim = Simulator::new().with_threads(1);
                let (_, total) = counter::counted(|| {
                    if pruned {
                        kmeans_parallel::run(&space, obj, &pts, k, &cfg, &sim)
                    } else {
                        kmeans_parallel::run_unpruned(&space, obj, &pts, k, &cfg, &sim)
                    }
                });
                let evals = total - sim.take_stats().dist_evals_for("kmeans||-reduce");
                work.row(vec![obj.name().to_string(), label.to_string(), evals.to_string()]);
            }
        }
    }

    ExpResult {
        id: "e12",
        title: "Outlier-robust (k,z) clustering vs plain solvers and baselines",
        tables: vec![
            ("inlier objective and outlier recall".to_string(), table),
            ("robust pipeline work attribution".to_string(), work),
        ],
        notes: vec![
            "cost(full) rewards serving the noise blob; cost(inliers) is what the robust \
             solver optimizes by writing off z points."
                .to_string(),
            "Plain solvers dedicate a center to the far blob (cheaper under cost(full)), \
             abandoning a real cluster — a worse cost(inliers)."
                .to_string(),
            "Outlier recall counts injected noise among the z written-off points; the \
             oversampled coreset keeps noise representable for the finisher to identify."
                .to_string(),
        ],
    }
}
