//! E5 — End-to-end k-means accuracy (Theorems 3.13 / 3.14): the k-means
//! twin of E4, exercising the (√2ε, √β) parametrization and the squared
//! objective throughout.

use crate::metric::Objective;

use super::e4_kmedian_accuracy::run_for;
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    run_for(Objective::Means, "e5", "End-to-end k-means accuracy (Thm 3.13)", quick)
}
