//! E6 — Local-memory sublinearity (Theorem 3.14).
//!
//! At L = ∛(n/k), the theory puts per-reducer memory at
//! O(n^{2/3} k^{1/3} (16β/ε)^{2D} log² n). We sweep n at fixed (k, ε, D)
//! and fit the measured M_L growth exponent: it should land near 2/3
//! (the log² factor nudges it slightly above; the coreset terms on
//! benign data nudge it below).
//!
//! The theorem is about the *maximum* reducer, so the table also shows
//! the per-reducer peak-memory distribution of round 1 (p50/p95 and the
//! skew factor max/p50): under round-robin partitioning the workload is
//! near-uniform and the max must track the median, not run away from it.

use crate::coordinator::{solve, ClusterConfig};
use crate::metric::Objective;
use crate::util::stats::power_fit;
use crate::util::table::{fnum, Table};

use super::common::mixture_space;
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let k = 8;
    let ns: Vec<usize> = if quick {
        vec![2000, 4000, 8000, 16000]
    } else {
        vec![4000, 8000, 16000, 32000, 64000]
    };
    let mut table = Table::new(vec![
        "n", "L", "|E_w|", "M_L", "M_A", "M_L/n", "r1 mem p50", "r1 mem p95", "r1 skew",
    ]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &n in &ns {
        let (space, pts) = mixture_space(n, 2, k, 51);
        let cfg = ClusterConfig::new(Objective::Median, k, 0.6);
        let rep = solve(&space, &pts, &cfg);
        let r1 = rep.stats.rounds.first().expect("solve records round stats");
        let md = r1.mem_distribution();
        let skew = md.skew();
        // Round-robin partitions are uniform to ±1 point, so a reducer
        // whose peak memory runs far ahead of the median indicates a
        // balance bug (bad partitioning or a straggling cover), not
        // data skew. The bound is loose: cover-set growth varies a
        // little across partitions of the same mixture.
        assert!(
            skew <= 2.5,
            "n={n}: round-1 memory skew {skew:.2} (max={} p50={}) — \
             uniform partitions must stay near-balanced",
            md.max,
            md.p50
        );
        table.row(vec![
            n.to_string(),
            rep.l.to_string(),
            rep.coreset_size.to_string(),
            rep.max_local_memory.to_string(),
            rep.aggregate_memory.to_string(),
            fnum(rep.max_local_memory as f64 / n as f64),
            fnum(md.p50),
            fnum(md.p95),
            format!("{skew:.2}"),
        ]);
        xs.push(n as f64);
        ys.push(rep.max_local_memory as f64);
    }
    let (c, e, r2) = power_fit(&xs, &ys);

    // aggregate memory should stay linear-ish in n (paper: M_A = O(n))
    let agg_ratio_first = ys.first().copied().unwrap_or(1.0);
    let _ = agg_ratio_first;

    ExpResult {
        id: "e6",
        title: "Local memory sublinear in n (Thm 3.14)",
        tables: vec![("memory vs n".to_string(), table)],
        notes: vec![
            format!(
                "fit: M_L ≈ {} · n^{} (r²={}); the theory predicts exponent ≈ 2/3 (+o(1)).",
                fnum(c),
                fnum(e),
                fnum(r2)
            ),
            "M_L/n must shrink monotonically — the defining signature of sublinear local memory."
                .to_string(),
            "r1 skew = max/p50 of round-1 per-reducer memory peaks; asserted ≤ 2.5 under \
             round-robin partitioning."
                .to_string(),
        ],
    }
}
