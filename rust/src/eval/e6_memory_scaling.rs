//! E6 — Local-memory sublinearity (Theorem 3.14).
//!
//! At L = ∛(n/k), the theory puts per-reducer memory at
//! O(n^{2/3} k^{1/3} (16β/ε)^{2D} log² n). We sweep n at fixed (k, ε, D)
//! and fit the measured M_L growth exponent: it should land near 2/3
//! (the log² factor nudges it slightly above; the coreset terms on
//! benign data nudge it below).
//!
//! The theorem is about the *maximum* reducer, so the table also shows
//! the per-reducer peak-memory distribution of round 1 (p50/p95 and the
//! skew factor max/p50): under round-robin partitioning the workload is
//! near-uniform and the max must track the median, not run away from it.
//! A second table re-runs one workload under every `PartitionStrategy` —
//! round-robin is the best case, and contiguous/shuffled splits show how
//! much skew the partitioner (not the data) is responsible for.
//!
//! Next to simulated item counts, the executor meters *bytes*: the
//! encoded shard footprint each reducer actually holds (`M_B`). The
//! backend table runs the same workload in-memory and out-of-core
//! (`SpillExecutor`) under a hard budget equal to the in-memory peak,
//! asserting the byte-parity contract — identical `RunReport::to_json`,
//! identical peaks, and a spill run that fits exactly within its budget.

use crate::coordinator::{solve, ClusterConfig};
use crate::mapreduce::{ExecutorCfg, PartitionStrategy};
use crate::metric::Objective;
use crate::util::stats::power_fit;
use crate::util::table::{fnum, Table};

use super::common::mixture_space;
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let k = 8;
    let ns: Vec<usize> = if quick {
        vec![2000, 4000, 8000, 16000]
    } else {
        vec![4000, 8000, 16000, 32000, 64000]
    };
    let mut table = Table::new(vec![
        "n", "L", "|E_w|", "M_L", "M_A", "M_B", "M_L/n", "r1 mem p50", "r1 mem p95", "r1 skew",
    ]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &n in &ns {
        let (space, pts) = mixture_space(n, 2, k, 51);
        let cfg = ClusterConfig::new(Objective::Median, k, 0.6);
        let rep = solve(&space, &pts, &cfg);
        let r1 = rep.stats.rounds.first().expect("solve records round stats");
        let md = r1.mem_distribution();
        let skew = md.skew();
        // Round-robin partitions are uniform to ±1 point, so a reducer
        // whose peak memory runs far ahead of the median indicates a
        // balance bug (bad partitioning or a straggling cover), not
        // data skew. The bound is loose: cover-set growth varies a
        // little across partitions of the same mixture.
        assert!(
            skew <= 2.5,
            "n={n}: round-1 memory skew {skew:.2} (max={} p50={}) — \
             uniform partitions must stay near-balanced",
            md.max,
            md.p50
        );
        table.row(vec![
            n.to_string(),
            rep.l.to_string(),
            rep.coreset_size.to_string(),
            rep.max_local_memory.to_string(),
            rep.aggregate_memory.to_string(),
            rep.max_local_bytes.to_string(),
            fnum(rep.max_local_memory as f64 / n as f64),
            fnum(md.p50),
            fnum(md.p95),
            format!("{skew:.2}"),
        ]);
        xs.push(n as f64);
        ys.push(rep.max_local_memory as f64);
    }
    let (c, e, r2) = power_fit(&xs, &ys);

    // --- partition-strategy skew: same workload, three splits ---------
    // Round-robin interleaves the mixture (every reducer sees every
    // cluster); contiguous hands whole clusters to single reducers (the
    // synthetic store lays points out cluster by cluster), and shuffled
    // is a seeded random permutation. The skew column shows what the
    // partitioner alone does to the per-reducer memory distribution.
    let strat_n = if quick { 4000 } else { 16000 };
    let (space, pts) = mixture_space(strat_n, 2, k, 51);
    let mut strat_tab = Table::new(vec![
        "strategy", "L", "|E_w|", "M_L", "M_B", "r1 mem p50", "r1 mem p95", "r1 skew",
    ]);
    let strategies: [(&str, PartitionStrategy); 3] = [
        ("round-robin", PartitionStrategy::RoundRobin),
        ("contiguous", PartitionStrategy::Contiguous),
        ("shuffled", PartitionStrategy::Shuffled(51)),
    ];
    for (label, strategy) in strategies {
        let mut cfg = ClusterConfig::new(Objective::Median, k, 0.6);
        cfg.strategy = strategy;
        let rep = solve(&space, &pts, &cfg);
        let r1 = rep.stats.rounds.first().expect("round stats");
        let md = r1.mem_distribution();
        strat_tab.row(vec![
            label.to_string(),
            rep.l.to_string(),
            rep.coreset_size.to_string(),
            rep.max_local_memory.to_string(),
            rep.max_local_bytes.to_string(),
            fnum(md.p50),
            fnum(md.p95),
            format!("{:.2}", md.skew()),
        ]);
    }

    // --- executor backends: measured bytes + byte-parity check --------
    // The spill run gets a hard budget of exactly the in-memory peak:
    // byte parity says it must fit (and a single byte less must not —
    // see the executor unit tests). Reports must be bit-identical.
    let backend_n = if quick { 2000 } else { 8000 };
    let (space, pts) = mixture_space(backend_n, 2, k, 51);
    let mem_cfg = {
        let mut c = ClusterConfig::new(Objective::Median, k, 0.6);
        c.executor = ExecutorCfg::in_memory();
        c
    };
    let mem_rep = solve(&space, &pts, &mem_cfg);
    let budget = mem_rep.max_local_bytes;
    let spill_cfg = {
        let mut c = ClusterConfig::new(Objective::Median, k, 0.6);
        c.executor = ExecutorCfg::spill().with_budget(budget);
        c
    };
    let spill_rep = solve(&space, &pts, &spill_cfg);
    assert_eq!(
        mem_rep.to_json(),
        spill_rep.to_json(),
        "byte parity: in-memory and spill reports must be bit-identical"
    );
    assert!(
        spill_rep.max_local_bytes <= budget,
        "spill run exceeded its hard budget: {} > {budget}",
        spill_rep.max_local_bytes
    );
    let mut backend_tab =
        Table::new(vec!["backend", "budget B", "M_B", "M_L", "spill written", "report"]);
    for (label, rep, written) in [
        ("in-memory", &mem_rep, mem_rep.stats.spill_write_bytes()),
        ("spill", &spill_rep, spill_rep.stats.spill_write_bytes()),
    ] {
        backend_tab.row(vec![
            label.to_string(),
            if label == "spill" { budget.to_string() } else { "-".to_string() },
            rep.max_local_bytes.to_string(),
            rep.max_local_memory.to_string(),
            written.to_string(),
            "identical".to_string(),
        ]);
    }

    ExpResult {
        id: "e6",
        title: "Local memory sublinear in n (Thm 3.14)",
        tables: vec![
            ("memory vs n".to_string(), table),
            ("round-1 skew by partition strategy".to_string(), strat_tab),
            ("execution backends (byte parity)".to_string(), backend_tab),
        ],
        notes: vec![
            format!(
                "fit: M_L ≈ {} · n^{} (r²={}); the theory predicts exponent ≈ 2/3 (+o(1)).",
                fnum(c),
                fnum(e),
                fnum(r2)
            ),
            "M_L/n must shrink monotonically — the defining signature of sublinear local memory."
                .to_string(),
            "r1 skew = max/p50 of round-1 per-reducer memory peaks; asserted ≤ 2.5 under \
             round-robin partitioning (strategy table shows contiguous/shuffled for contrast)."
                .to_string(),
            format!(
                "backends: M_B (peak resident shard bytes) is backend-invariant; the spill run \
                 completed under a hard budget of exactly B={budget} bytes with a bit-identical \
                 RunReport."
            ),
        ],
    }
}
