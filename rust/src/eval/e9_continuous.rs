//! E9 — Continuous variants (§3.1 "Application to the continuous case",
//! §3.3 closing remark).
//!
//! In R^d with centroids unconstrained, the paper shows the 1-round C_w
//! already yields α+O(ε) (no factor 2): we run weighted Lloyd on C_w and
//! compare against Lloyd on the full input, sweeping ε. We also report
//! the continuous-vs-discrete gap on the same data (continuous cost is
//! lower by definition).

use crate::algorithms::lloyd::{continuous_cost, lloyd, LloydCfg};
use crate::coordinator::{solve, ClusterConfig};
use crate::coreset::{one_round_coreset, CoresetConfig};
use crate::mapreduce::{default_l, PartitionStrategy, Simulator};
use crate::metric::dense::EuclideanSpace;
use crate::metric::Objective;
use crate::util::table::{fnum, Table};
use std::sync::Arc;

use super::common::mixture_data;
use super::ExpResult;

/// best-of-3 restarts: vanilla Lloyd is seed-sensitive and the ratio
/// column needs a stable reference on both sides.
fn lloyd_best(
    data: &crate::points::VectorData,
    pts: &[u32],
    w: &[u64],
    k: usize,
) -> crate::algorithms::lloyd::ContinuousSolution {
    (0..3)
        .map(|s| lloyd(data, pts, w, k, &LloydCfg { seed: 0xF00D + s, ..Default::default() }))
        .min_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap())
        .unwrap()
}

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 3000 } else { 15000 };
    let k = 8;
    let data = mixture_data(n, 4, k, 81);
    let pts: Vec<u32> = (0..n as u32).collect();
    let unit = vec![1u64; n];

    // full-input continuous reference
    let full = lloyd_best(&data, &pts, &unit, k);

    let space = EuclideanSpace::new(Arc::new(data.clone()));
    let mut table =
        Table::new(vec!["eps", "|C_w|", "cost(Lloyd on C_w)", "cost(Lloyd full)", "ratio"]);
    for eps in [0.25, 0.5, 0.9] {
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(k, eps);
        let out = one_round_coreset(
            &space,
            Objective::Means,
            &pts,
            default_l(n, k),
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        let sol = lloyd_best(&data, &out.coreset.indices, &out.coreset.weights, k);
        // evaluate the coreset-derived centroids on the FULL input
        let cost_full_input = continuous_cost(&data, &pts, &unit, &sol.centroids);
        table.row(vec![
            fnum(eps),
            out.coreset.len().to_string(),
            fnum(cost_full_input),
            fnum(full.cost),
            fnum(cost_full_input / full.cost),
        ]);
    }

    // discrete-vs-continuous gap at one ε
    let mut gap = Table::new(vec!["variant", "cost"]);
    let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Means, k, 0.5));
    gap.row(vec!["discrete 3-round (centers ⊆ P)".to_string(), fnum(rep.full_cost)]);
    gap.row(vec!["continuous Lloyd (full input)".to_string(), fnum(full.cost)]);

    ExpResult {
        id: "e9",
        title: "Continuous k-means via the 1-round coreset (§3.1/§3.3)",
        tables: vec![
            ("coreset Lloyd vs full Lloyd".to_string(), table),
            ("discrete vs continuous".to_string(), gap),
        ],
        notes: vec![
            "ratio → 1 as ε ↓ : the 1-round C_w suffices in the continuous case (no factor 2)."
                .to_string(),
            "continuous cost ≤ discrete cost (centroids unconstrained); the gap is the price \
             of S ⊆ P."
                .to_string(),
        ],
    }
}
