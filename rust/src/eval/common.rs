//! Shared helpers for the experiment suite.

use std::sync::Arc;

use crate::algorithms::local_search::{local_search, LocalSearchCfg};
use crate::algorithms::{Instance, Solution};
use crate::data::synth::{GaussianMixtureSpec, ManifoldSpec};
use crate::metric::dense::EuclideanSpace;
use crate::metric::{MetricSpace, Objective};
use crate::points::VectorData;

/// Standard mixture workload for accuracy experiments.
pub fn mixture_space(n: usize, d: usize, k: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
    let (data, _) = GaussianMixtureSpec { n, d, k, seed, ..Default::default() }.generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

/// Manifold workload with controlled intrinsic dimension.
pub fn manifold_space(
    n: usize,
    intrinsic: usize,
    ambient: usize,
    k: usize,
    seed: u64,
) -> (EuclideanSpace, Vec<u32>) {
    let (data, _) = ManifoldSpec {
        n,
        intrinsic_dim: intrinsic,
        ambient_dim: ambient,
        k,
        seed,
        ..Default::default()
    }
    .generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

/// Strong sequential reference solution — the "α-approximation run on
/// the full input" that Theorems 3.9/3.13 compare against (opt itself is
/// intractable beyond toy sizes; see DESIGN.md §4.3).
pub fn sequential_reference(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    k: usize,
    seed: u64,
) -> Solution {
    let w = vec![1u64; pts.len()];
    let cfg = LocalSearchCfg {
        max_passes: 60,
        sample_candidates: 128,
        seed,
        ..Default::default()
    };
    local_search(space, obj, Instance::new(pts, &w), k, None, &cfg)
}

/// Raw data accessor for continuous experiments.
pub fn mixture_data(n: usize, d: usize, k: usize, seed: u64) -> VectorData {
    GaussianMixtureSpec { n, d, k, seed, ..Default::default() }.generate().0
}
