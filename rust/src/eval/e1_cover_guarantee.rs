//! E1 — CoverWithBalls per-point guarantee (Lemma 3.1) across metrics.
//!
//! For each metric and (ε, β): run CoverWithBalls and report the worst
//! observed ratio d(x, τ(x)) / max{R, d(x, T)} against the guaranteed
//! bound ε/(2β), plus the output size. The ratio column must never
//! exceed 1.0 of the bound — this is the paper's foundational invariant.
//! The `evals saved` column compares the geometry-pruned production
//! cover against the unpruned reference (which must agree exactly)
//! and reports the distance-evaluation reduction per metric.

use crate::coreset::{cover_with_balls, cover_with_balls_weighted_unpruned};
use crate::data::strings::StringClusterSpec;
use crate::metric::counter;
use crate::metric::levenshtein::StringSpace;
use crate::metric::MetricSpace;
use crate::util::table::{fnum, Table};

use super::common::mixture_space;
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 800 } else { 6000 };
    let mut table = Table::new(vec![
        "metric",
        "eps",
        "beta",
        "|P|",
        "|T|",
        "|C_w|",
        "max d/max{R,dT}",
        "bound eps/2b",
        "ok",
        "evals saved",
    ]);

    let mut cases: Vec<(&'static str, Box<dyn MetricSpace>, Vec<u32>)> = Vec::new();
    let (eu, pts_eu) = mixture_space(n, 2, 6, 11);
    cases.push(("euclidean", Box::new(eu), pts_eu));
    {
        use crate::metric::dense::ManhattanSpace;
        use std::sync::Arc;
        let (data, _) = crate::data::synth::GaussianMixtureSpec {
            n,
            d: 2,
            k: 6,
            seed: 12,
            ..Default::default()
        }
        .generate();
        let pts: Vec<u32> = (0..n as u32).collect();
        cases.push(("manhattan", Box::new(ManhattanSpace::new(Arc::new(data))), pts));
    }
    {
        let (strs, _) = StringClusterSpec {
            n: if quick { 300 } else { 1500 },
            clusters: 8,
            ..Default::default()
        }
        .generate();
        let ns = strs.len() as u32;
        cases.push(("levenshtein", Box::new(StringSpace::new(strs)), (0..ns).collect()));
    }

    for (name, space, pts) in &cases {
        let t: Vec<u32> =
            (0..6u32).map(|i| pts[(i as usize * pts.len() / 6).min(pts.len() - 1)]).collect();
        let assign = space.assign(pts, &t);
        let r = assign.dist.iter().sum::<f64>() / pts.len() as f64;
        for (eps, beta) in [(0.25, 2.0), (0.5, 2.0), (0.5, 1.0)] {
            let (res, evals_pruned) =
                counter::counted(|| cover_with_balls(space.as_ref(), pts, &t, r, eps, beta));
            let (reference, evals_unpruned) = counter::counted(|| {
                cover_with_balls_weighted_unpruned(space.as_ref(), pts, None, &t, r, eps, beta)
            });
            assert_eq!(res.set.indices, reference.set.indices, "{name}: pruned cover drifted");
            assert_eq!(res.tau, reference.tau, "{name}: pruned tau drifted");
            let saved = evals_unpruned as f64 / evals_pruned.max(1) as f64;
            let bound = eps / (2.0 * beta);
            let mut worst: f64 = 0.0;
            for (i, &x) in pts.iter().enumerate() {
                let rep = res.set.indices[res.tau[i] as usize];
                let denom = res.dist_to_t[i].max(r);
                if denom > 0.0 {
                    worst = worst.max(space.dist(x, rep) / denom);
                }
            }
            table.row(vec![
                name.to_string(),
                fnum(eps),
                fnum(beta),
                pts.len().to_string(),
                t.len().to_string(),
                res.set.len().to_string(),
                fnum(worst),
                fnum(bound),
                (worst <= bound + 1e-9).to_string(),
                format!("{saved:.1}x"),
            ]);
        }
    }

    ExpResult {
        id: "e1",
        title: "CoverWithBalls per-point guarantee (Lemma 3.1)",
        tables: vec![("guarantee".to_string(), table)],
        notes: vec![
            "`ok` must be true everywhere: the observed worst shrink ratio never exceeds ε/(2β)."
                .to_string(),
            "`evals saved` = unpruned / pruned distance evaluations; outputs are asserted \
             identical, so the savings are free."
                .to_string(),
        ],
    }
}
