//! E3 — ε-bounded coreset property (Lemmas 3.5/3.7 for k-median,
//! 3.10/3.11 for k-means).
//!
//! Measures the proximity sums of Definition 2.3 against the optimal
//! cost (approximated by the strong sequential reference):
//!   k-median: Σ d(x, τ(x))      ≤ 2ε · ν(opt)
//!   k-means:  Σ d(x, τ(x))²     ≤ 4ε² · μ(opt)
//! for the union C_w of round-1 local coresets, per the composability
//! lemma (2.7). The reported ratio/bound column should stay ≤ 1 (it is
//! an upper bound with β conservatively set, so typically ≪ 1).

use crate::coreset::local::{local_coreset, TlAlgo};
use crate::mapreduce::{partition, PartitionStrategy};
use crate::metric::{MetricSpace, Objective};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

use super::common::{mixture_space, sequential_reference};
use super::ExpResult;

fn proximity_over_partitions(
    space: &dyn MetricSpace,
    obj: Objective,
    pts: &[u32],
    l: usize,
    eps: f64,
    beta: f64,
) -> (f64, usize) {
    let parts = partition(pts, l, PartitionStrategy::RoundRobin);
    let mut total = 0.0;
    let mut size = 0usize;
    for (i, part) in parts.iter().enumerate() {
        let mut rng = Rng::new(31 + i as u64);
        let out = local_coreset(space, obj, part, 12, eps, beta, TlAlgo::DppSeeding, &mut rng);
        total += match obj {
            Objective::Median => out.cover.proximity_sum(space, part),
            Objective::Means => out.cover.proximity_sum_sq(space, part),
        };
        size += out.cover.set.len();
    }
    (total, size)
}

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 2000 } else { 10000 };
    let k = 6;
    let (space, pts) = mixture_space(n, 2, k, 31);
    let l = 4;
    let beta = 2.0;

    let mut table = Table::new(vec![
        "objective", "eps", "proximity", "opt~ cost", "ratio", "bound", "ratio/bound",
    ]);
    for obj in [Objective::Median, Objective::Means] {
        let reference = sequential_reference(&space, obj, &pts, k, 77);
        for eps in [0.2, 0.4, 0.8] {
            let (prox, _sz) = proximity_over_partitions(&space, obj, &pts, l, eps, beta);
            let ratio = prox / reference.cost;
            let bound = match obj {
                Objective::Median => 2.0 * eps,
                Objective::Means => 4.0 * eps * eps,
            };
            table.row(vec![
                obj.name().to_string(),
                fnum(eps),
                fnum(prox),
                fnum(reference.cost),
                fnum(ratio),
                fnum(bound),
                fnum(ratio / bound),
            ]);
        }
    }

    ExpResult {
        id: "e3",
        title: "ε-bounded coreset property (Lemmas 3.5/3.10 + 2.7)",
        tables: vec![("proximity vs bound".to_string(), table)],
        notes: vec![
            "opt~ (strong local search) upper-bounds the true opt cost, so the measured ratio \
             slightly underestimates the true one; the ratio/bound column sitting well below \
             1 (not merely at 1) is what certifies the lemma with margin."
                .to_string(),
        ],
    }
}
