//! E11 — Design-choice ablations (DESIGN.md §4): the knobs the paper
//! leaves to the implementer, measured one at a time on a fixed
//! workload:
//!   (a) T_ℓ algorithm: D^p-seeding vs local search vs Gonzalez;
//!   (b) oversampling m ∈ {k, 2k, 4k};
//!   (c) partition strategy: round-robin vs contiguous vs shuffled;
//!   (d) number of partitions L around the ∛(n/k) default.
//! Each row reports coreset size, local memory, and cost ratio to the
//! sequential reference.

use crate::coordinator::{solve, ClusterConfig};
use crate::coreset::TlAlgo;
use crate::mapreduce::PartitionStrategy;
use crate::metric::Objective;
use crate::util::table::{fnum, Table};

use super::common::{mixture_space, sequential_reference};
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 4000 } else { 16000 };
    let k = 8;
    let (space, pts) = mixture_space(n, 2, k, 101);
    let seq = sequential_reference(&space, Objective::Median, &pts, k, 201);
    let base = ClusterConfig::new(Objective::Median, k, 0.5);

    let run_row = |label: String, cfg: &ClusterConfig, table: &mut Table| {
        let rep = solve(&space, &pts, cfg);
        table.row(vec![
            label,
            rep.coreset_size.to_string(),
            rep.max_local_memory.to_string(),
            fnum(rep.full_cost / seq.cost),
        ]);
    };
    let header = vec!["variant", "|E_w|", "M_L", "cost/seq"];

    // (a) T_ℓ algorithm
    let mut t_tl = Table::new(header.clone());
    for (name, tl) in [
        ("dpp-seeding (default)", TlAlgo::DppSeeding),
        ("local-search", TlAlgo::LocalSearch),
        ("gonzalez", TlAlgo::Gonzalez),
    ] {
        let mut cfg = base.clone();
        cfg.tl = tl;
        run_row(name.to_string(), &cfg, &mut t_tl);
    }

    // (b) oversampling m
    let mut t_m = Table::new(header.clone());
    for mult in [1usize, 2, 4] {
        let mut cfg = base.clone();
        cfg.m = Some(mult * k);
        run_row(format!("m = {mult}k"), &cfg, &mut t_m);
    }

    // (c) partition strategy
    let mut t_s = Table::new(header.clone());
    for (name, s) in [
        ("round-robin (default)", PartitionStrategy::RoundRobin),
        ("contiguous", PartitionStrategy::Contiguous),
        ("shuffled", PartitionStrategy::Shuffled(5)),
    ] {
        let mut cfg = base.clone();
        cfg.strategy = s;
        run_row(name.to_string(), &cfg, &mut t_s);
    }

    // (d) L around the default
    let l0 = crate::mapreduce::default_l(n, k);
    let mut t_l = Table::new(header.clone());
    for (name, l) in [
        (format!("L = {} (default ∛(n/k))", l0), l0),
        (format!("L = {}", l0 / 2), (l0 / 2).max(1)),
        (format!("L = {}", l0 * 2), l0 * 2),
    ] {
        let mut cfg = base.clone();
        cfg.l = Some(l);
        run_row(name, &cfg, &mut t_l);
    }

    ExpResult {
        id: "e11",
        title: "Design-choice ablations (T_ℓ algo, m, strategy, L)",
        tables: vec![
            ("(a) T_ℓ algorithm".to_string(), t_tl),
            ("(b) oversampling m".to_string(), t_m),
            ("(c) partition strategy".to_string(), t_s),
            ("(d) partitions L".to_string(), t_l),
        ],
        notes: vec![
            "All variants stay within O(ε) of the reference: the construction is robust to \
             its knobs; they trade coreset size (memory) against constant factors, as §3.4 \
             discusses."
                .to_string(),
        ],
    }
}
