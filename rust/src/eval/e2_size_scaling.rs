//! E2 — Coreset size scaling (Theorem 3.3, Lemmas 3.6/3.8/3.12).
//!
//! The theory: |C_w| ≲ |T|·(16β/ε)^D·log n — exponential in the
//! *doubling* dimension D, polynomial in 1/ε with exponent D, and only
//! logarithmic in n. We sweep (intrinsic D, ε, n) on manifold workloads
//! (ambient dim fixed at 16) and fit the growth exponent of |E_w| in
//! 1/ε per intrinsic dimension — it should increase with D and sit in
//! the vicinity of D — and the growth in n, which should be strongly
//! sublinear.

use crate::coreset::{two_round_coreset, CoresetConfig};
use crate::mapreduce::{default_l, PartitionStrategy, Simulator};
use crate::metric::Objective;
use crate::util::stats::power_fit;
use crate::util::table::{fnum, Table};

use super::common::manifold_space;
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let k = 6;
    let base_n = if quick { 4000 } else { 16000 };
    let eps_grid = [0.2, 0.3, 0.45, 0.65, 0.9];
    let dims: &[usize] = if quick { &[1, 2] } else { &[1, 2, 3] };

    let mut size_tab = Table::new(vec!["intrinsic D", "eps", "|C_w|", "|E_w|", "|E_w|/n"]);
    let mut fit_tab = Table::new(vec!["intrinsic D", "fit |E_w| ~ C*(1/eps)^e", "r2"]);
    for &dim in dims {
        let (space, pts) = manifold_space(base_n, dim, 16, k, 21);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &eps in &eps_grid {
            let sim = Simulator::new();
            let cfg = CoresetConfig::new(k, eps);
            let out = two_round_coreset(
                &space,
                Objective::Median,
                &pts,
                default_l(base_n, k),
                PartitionStrategy::RoundRobin,
                &cfg,
                &sim,
            )
            .expect("pipeline");
            size_tab.row(vec![
                dim.to_string(),
                fnum(eps),
                out.cw_size.to_string(),
                out.coreset.len().to_string(),
                fnum(out.coreset.len() as f64 / base_n as f64),
            ]);
            xs.push(1.0 / eps);
            ys.push(out.coreset.len() as f64);
        }
        let (c, e, r2) = power_fit(&xs, &ys);
        fit_tab.row(vec![dim.to_string(), format!("{} * (1/eps)^{}", fnum(c), fnum(e)), fnum(r2)]);
    }

    // n-scaling at fixed eps: |E_w| should grow ≪ linearly
    let mut n_tab = Table::new(vec!["n", "|E_w|", "|E_w|/n"]);
    let ns: Vec<usize> =
        if quick { vec![2000, 4000, 8000] } else { vec![4000, 8000, 16000, 32000] };
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &n in &ns {
        let (space, pts) = manifold_space(n, 2, 16, k, 22);
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(k, 0.5);
        let out = two_round_coreset(
            &space,
            Objective::Median,
            &pts,
            default_l(n, k),
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        n_tab.row(vec![
            n.to_string(),
            out.coreset.len().to_string(),
            fnum(out.coreset.len() as f64 / n as f64),
        ]);
        xs.push(n as f64);
        ys.push(out.coreset.len() as f64);
    }
    let (_, e_n, r2_n) = power_fit(&xs, &ys);

    ExpResult {
        id: "e2",
        title: "Coreset size scaling in ε, D, n (Thm 3.3 / Lem 3.8)",
        tables: vec![
            ("size vs (D, eps)".to_string(), size_tab),
            ("1/eps growth exponent per D".to_string(), fit_tab),
            ("size vs n at eps=0.5, D=2".to_string(), n_tab),
        ],
        notes: vec![
            "The 1/ε exponent should increase with intrinsic D (≈ 2D worst case; less when benign)."
                .to_string(),
            format!(
                "n-scaling exponent: |E_w| ~ n^{} (r²={}) — sublinear, as the bound predicts.",
                fnum(e_n),
                fnum(r2_n)
            ),
        ],
    }
}
