//! Experiment harness: every quantitative claim in the paper becomes an
//! experiment (the paper has no empirical section of its own — see
//! DESIGN.md §3 for the full index E1..E10). `cargo bench` and
//! `mrcoreset exp <id>` both route here; results are recorded in
//! EXPERIMENTS.md.

pub mod common;
mod e1_cover_guarantee;
mod e2_size_scaling;
mod e3_bounded_quality;
mod e4_kmedian_accuracy;
mod e5_kmeans_accuracy;
mod e6_memory_scaling;
mod e7_rounds;
mod e8_baselines;
mod e9_continuous;
mod e10_dimension_adaptivity;
mod e11_ablation;

use crate::util::table::Table;

/// Result of one experiment: named tables plus free-form notes.
pub struct ExpResult {
    pub id: &'static str,
    pub title: &'static str,
    pub tables: Vec<(String, Table)>,
    pub notes: Vec<String>,
}

impl ExpResult {
    pub fn render(&self) -> String {
        let mut s = format!("## {} — {}\n\n", self.id, self.title);
        for (name, t) in &self.tables {
            s.push_str(&format!("### {name}\n\n{}\n", t.to_markdown()));
        }
        for n in &self.notes {
            s.push_str(&format!("- {n}\n"));
        }
        s
    }
}

pub const ALL_IDS: &[&str] = &["e1", "e2", "e3", "e4", "e5", "e6", "e7", "e8", "e9", "e10", "e11"];

/// Run an experiment by id. `quick` shrinks workloads for CI.
pub fn run_experiment(id: &str, quick: bool) -> Option<ExpResult> {
    match id {
        "e1" => Some(e1_cover_guarantee::run(quick)),
        "e2" => Some(e2_size_scaling::run(quick)),
        "e3" => Some(e3_bounded_quality::run(quick)),
        "e4" => Some(e4_kmedian_accuracy::run(quick)),
        "e5" => Some(e5_kmeans_accuracy::run(quick)),
        "e6" => Some(e6_memory_scaling::run(quick)),
        "e7" => Some(e7_rounds::run(quick)),
        "e8" => Some(e8_baselines::run(quick)),
        "e9" => Some(e9_continuous::run(quick)),
        "e10" => Some(e10_dimension_adaptivity::run(quick)),
        "e11" => Some(e11_ablation::run(quick)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every experiment must run end-to-end in quick mode and produce at
    /// least one non-empty table.
    #[test]
    fn all_experiments_run_quick() {
        for id in ALL_IDS {
            let res = run_experiment(id, true).unwrap_or_else(|| panic!("unknown id {id}"));
            assert!(!res.tables.is_empty(), "{id}: no tables");
            for (name, t) in &res.tables {
                assert!(!t.is_empty(), "{id}/{name}: empty table");
            }
            let rendered = res.render();
            assert!(rendered.contains(res.title));
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run_experiment("e99", true).is_none());
    }
}
