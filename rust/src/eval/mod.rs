//! Experiment harness: every quantitative claim in the paper becomes an
//! experiment (the paper has no empirical section of its own — see
//! DESIGN.md §3 for the index E1..E10; E11 ablations and E12 outliers
//! extend it). `cargo bench` and `mrcoreset exp <id>` both route here;
//! results are recorded in EXPERIMENTS.md.

pub mod common;
mod e1_cover_guarantee;
mod e2_size_scaling;
mod e3_bounded_quality;
mod e4_kmedian_accuracy;
mod e5_kmeans_accuracy;
mod e6_memory_scaling;
mod e7_rounds;
mod e8_baselines;
mod e9_continuous;
mod e10_dimension_adaptivity;
mod e11_ablation;
mod e12_outliers;

use crate::util::table::Table;

/// Result of one experiment: named tables plus free-form notes.
pub struct ExpResult {
    pub id: &'static str,
    pub title: &'static str,
    pub tables: Vec<(String, Table)>,
    pub notes: Vec<String>,
}

impl ExpResult {
    pub fn render(&self) -> String {
        let mut s = format!("## {} — {}\n\n", self.id, self.title);
        for (name, t) in &self.tables {
            s.push_str(&format!("### {name}\n\n{}\n", t.to_markdown()));
        }
        for n in &self.notes {
            s.push_str(&format!("- {n}\n"));
        }
        s
    }
}

pub const ALL_IDS: &[&str] =
    &["e1", "e2", "e3", "e4", "e5", "e6", "e7", "e8", "e9", "e10", "e11", "e12"];

/// Run an experiment by id. `quick` shrinks workloads for CI.
pub fn run_experiment(id: &str, quick: bool) -> Option<ExpResult> {
    match id {
        "e1" => Some(e1_cover_guarantee::run(quick)),
        "e2" => Some(e2_size_scaling::run(quick)),
        "e3" => Some(e3_bounded_quality::run(quick)),
        "e4" => Some(e4_kmedian_accuracy::run(quick)),
        "e5" => Some(e5_kmeans_accuracy::run(quick)),
        "e6" => Some(e6_memory_scaling::run(quick)),
        "e7" => Some(e7_rounds::run(quick)),
        "e8" => Some(e8_baselines::run(quick)),
        "e9" => Some(e9_continuous::run(quick)),
        "e10" => Some(e10_dimension_adaptivity::run(quick)),
        "e11" => Some(e11_ablation::run(quick)),
        "e12" => Some(e12_outliers::run(quick)),
        _ => None,
    }
}

/// Error for an experiment id `run_experiment` does not know.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownExperiment {
    pub id: String,
}

impl std::fmt::Display for UnknownExperiment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown experiment `{}` (known: {})", self.id, ALL_IDS.join(", "))
    }
}

impl std::error::Error for UnknownExperiment {}

/// Check a batch of ids against the registry without running anything,
/// so callers can fail fast before any (expensive) experiment starts.
pub fn validate_ids(ids: &[&str]) -> Result<(), UnknownExperiment> {
    for id in ids {
        if !ALL_IDS.contains(id) {
            return Err(UnknownExperiment { id: (*id).to_string() });
        }
    }
    Ok(())
}

/// Run a batch of experiments by id, collecting every result before
/// returning; fails with a proper error — not a panic — on an unknown
/// id, validated up front so a typo costs nothing. This is the
/// collect-all library entry; the CLI instead pairs [`validate_ids`]
/// with per-id [`run_experiment`] calls so tables stream as each
/// experiment completes.
pub fn run_all(ids: &[&str], quick: bool) -> Result<Vec<ExpResult>, UnknownExperiment> {
    validate_ids(ids)?;
    Ok(ids.iter().map(|id| run_experiment(id, quick).expect("validated id")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every experiment must run end-to-end in quick mode and produce at
    /// least one non-empty table.
    #[test]
    fn all_experiments_run_quick() {
        for id in ALL_IDS {
            let res = run_experiment(id, true).unwrap_or_else(|| panic!("unknown id {id}"));
            assert!(!res.tables.is_empty(), "{id}: no tables");
            for (name, t) in &res.tables {
                assert!(!t.is_empty(), "{id}/{name}: empty table");
            }
            let rendered = res.render();
            assert!(rendered.contains(res.title));
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run_experiment("e99", true).is_none());
    }

    #[test]
    fn run_all_surfaces_unknown_ids_as_errors() {
        let err = run_all(&["e1", "e99"], true).unwrap_err();
        assert_eq!(err.id, "e99");
        let msg = err.to_string();
        assert!(msg.contains("e99") && msg.contains("e12"), "message: {msg}");
    }

    #[test]
    fn validate_ids_accepts_registry_and_rejects_unknown() {
        assert!(validate_ids(ALL_IDS).is_ok());
        assert!(validate_ids(&[]).is_ok());
        assert_eq!(validate_ids(&["e12", "nope"]).unwrap_err().id, "nope");
    }

    #[test]
    fn run_all_returns_results_in_order() {
        let res = run_all(&["e7", "e1"], true).unwrap();
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].id, "e7");
        assert_eq!(res[1].id, "e1");
    }
}
