//! E4 — End-to-end k-median accuracy (Theorems 3.9 / 3.14).
//!
//! The headline claim: the 3-round MapReduce solution costs at most
//! (α + O(ε)) · opt. We measure cost(MR(ε)) / cost(sequential α-approx
//! on the full input) over an ε sweep: the ratio should approach ~1 as
//! ε shrinks, and the coreset (hence round-3 memory) should grow. The
//! one-round §3.1 construction is included as the ablation column — the
//! paper proves it loses a factor 2 in the worst case.

use crate::coordinator::{solve, ClusterConfig};
use crate::metric::Objective;
use crate::util::table::{fnum, Table};

use super::common::{mixture_space, sequential_reference};
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    run_for(Objective::Median, "e4", "End-to-end k-median accuracy (Thm 3.9)", quick)
}

pub(super) fn run_for(
    obj: Objective,
    id: &'static str,
    title: &'static str,
    quick: bool,
) -> ExpResult {
    let n = if quick { 3000 } else { 20000 };
    let k = 8;
    let mut table = Table::new(vec![
        "eps", "|E_w|", "M_L", "cost(MR)", "cost(seq)", "ratio", "ratio 1-round",
    ]);
    let mut notes = Vec::new();
    let eps_grid = if quick { vec![0.25, 0.5, 0.9] } else { vec![0.15, 0.25, 0.4, 0.6, 0.9] };

    // average over seeds to tame randomized-seeding variance
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };
    for &eps in &eps_grid {
        let mut ratio_acc = 0.0;
        let mut ratio1_acc = 0.0;
        let mut coreset = 0usize;
        let mut ml = 0usize;
        let mut mr_cost = 0.0;
        let mut seq_cost = 0.0;
        for &seed in seeds {
            let (space, pts) = mixture_space(n, 2, k, 40 + seed);
            let seq = sequential_reference(&space, obj, &pts, k, 97 + seed);
            let mut cfg = ClusterConfig::new(obj, k, eps);
            cfg.seed = seed;
            let rep = solve(&space, &pts, &cfg);
            let mut cfg1 = cfg.clone();
            cfg1.one_round = true;
            let rep1 = solve(&space, &pts, &cfg1);
            ratio_acc += rep.full_cost / seq.cost;
            ratio1_acc += rep1.full_cost / seq.cost;
            coreset = rep.coreset_size;
            ml = rep.max_local_memory;
            mr_cost = rep.full_cost;
            seq_cost = seq.cost;
        }
        let m = seeds.len() as f64;
        table.row(vec![
            fnum(eps),
            coreset.to_string(),
            ml.to_string(),
            fnum(mr_cost),
            fnum(seq_cost),
            fnum(ratio_acc / m),
            fnum(ratio1_acc / m),
        ]);
    }
    notes.push(
        "ratio → 1+O(ε) as ε ↓ (2-round); the 1-round ablation may trail (§3.1's factor 2) \
         though on benign data both sit close to 1."
            .to_string(),
    );
    ExpResult { id, title, tables: vec![("accuracy vs eps".to_string(), table)], notes }
}
