//! E10 — Oblivious adaptation to intrinsic dimension (§1.2, §4).
//!
//! The algorithms never receive D; the claim is that they adapt to the
//! dataset's intrinsic dimension, which can be far below the ambient
//! one. We fix intrinsic D = 2 and sweep the ambient dimension: coreset
//! size and accuracy should stay ~flat (while the correlation-dimension
//! estimate confirms the intrinsic D is what the data exposes).

use crate::coordinator::{solve, ClusterConfig};
use crate::metric::doubling::correlation_dimension;
use crate::metric::Objective;
use crate::util::table::{fnum, Table};

use super::common::{manifold_space, sequential_reference};
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 3000 } else { 12000 };
    let k = 6;
    let ambients: &[usize] = if quick { &[4, 16] } else { &[4, 16, 64] };
    let mut table = Table::new(vec![
        "ambient d", "est. intrinsic D", "|E_w|", "M_L", "cost/seq",
    ]);
    for &amb in ambients {
        let (space, pts) = manifold_space(n, 2, amb, k, 91);
        let est_d = correlation_dimension(&space, &pts, 20_000, 7);
        let seq = sequential_reference(&space, Objective::Median, &pts, k, 191);
        let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, k, 0.5));
        table.row(vec![
            amb.to_string(),
            fnum(est_d),
            rep.coreset_size.to_string(),
            rep.max_local_memory.to_string(),
            fnum(rep.full_cost / seq.cost),
        ]);
    }
    ExpResult {
        id: "e10",
        title: "Coreset size tracks intrinsic (not ambient) dimension (§1.2)",
        tables: vec![("ambient sweep at intrinsic D=2".to_string(), table)],
        notes: vec![
            "|E_w| and M_L stay ~flat as the ambient dimension grows 16x: the construction \
             is oblivious to D and adapts to the manifold."
                .to_string(),
        ],
    }
}
