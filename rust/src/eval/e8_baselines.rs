//! E8 — Accuracy vs the literature baselines (§1.1/§1.2).
//!
//! The paper's pitch is *accuracy*: α+O(ε) vs Ene-Im-Moseley's weak
//! (10α+3), k-means‖'s O(α), PAMAE's no-tight-analysis, and uniform
//! sampling's no-guarantee. We run all five at comparable summary sizes
//! on a noisy mixture (5% outliers — where sampling baselines hurt,
//! because sparse regions are exactly what uniform samples miss and
//! exactly what CoverWithBalls must cover) and report full-input cost
//! ratios to the sequential reference.

use crate::baselines::ene_im_moseley::{self, EimCfg};
use crate::baselines::kmeans_parallel::{self, KmeansParCfg};
use crate::baselines::pamae_lite::{self, PamaeCfg};
use crate::baselines::uniform::{self, UniformCfg};
use crate::coordinator::{solve, ClusterConfig};
use crate::data::synth::GaussianMixtureSpec;
use crate::mapreduce::Simulator;
use crate::metric::dense::EuclideanSpace;
use crate::metric::Objective;
use crate::util::table::{fnum, Table};
use std::sync::Arc;

use super::common::sequential_reference;
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 3000 } else { 15000 };
    let k = 8;
    let (data, _) = GaussianMixtureSpec {
        n,
        d: 2,
        k,
        spread: 30.0,
        outlier_frac: 0.05,
        seed: 71,
    }
    .generate();
    let shared = Arc::new(data);
    let space = EuclideanSpace::new(shared.clone());
    let pts: Vec<u32> = (0..n as u32).collect();

    let mut table = Table::new(vec![
        "objective", "method", "summary size", "rounds", "cost", "cost/seq",
    ]);

    for obj in [Objective::Median, Objective::Means] {
        let seq = sequential_reference(&space, obj, &pts, k, 171);

        // ours: pick eps, then match baselines to the resulting size
        let cfg = ClusterConfig::new(obj, k, 0.5);
        let ours = solve(&space, &pts, &cfg);
        let target = ours.coreset_size.max(8);
        table.row(vec![
            obj.name().to_string(),
            "THIS PAPER (3-round, eps=0.5)".to_string(),
            ours.coreset_size.to_string(),
            ours.rounds.to_string(),
            fnum(ours.full_cost),
            fnum(ours.full_cost / seq.cost),
        ]);

        let sim = Simulator::new();
        let uni = uniform::run(
            &space,
            obj,
            &pts,
            k,
            &UniformCfg { size: target, l: ours.l, seed: 5 },
            &sim,
        );
        let eim = ene_im_moseley::run(
            &space,
            obj,
            &pts,
            k,
            &EimCfg {
                sample_per_iter: (target / 6).max(k),
                stop_below: (target / 4).max(2 * k),
                seed: 6,
            },
            &sim,
        );
        let mut reports = vec![uni, eim];
        if obj == Objective::Means {
            reports.push(kmeans_parallel::run(&space, obj, &pts, k, &KmeansParCfg::new(k), &sim));
        } else {
            reports.push(pamae_lite::run(&space, obj, &pts, k, &PamaeCfg::new(k), &sim));
        }
        for r in reports {
            table.row(vec![
                obj.name().to_string(),
                r.name.to_string(),
                r.summary_size.to_string(),
                r.rounds.to_string(),
                fnum(r.full_cost),
                fnum(r.full_cost / seq.cost),
            ]);
        }
    }

    // --- geometry pruning inside the baselines: evals saved ---
    let pruning_tab = baseline_pruning_comparison(&space, &shared, &pts, k);

    // --- needle workload: where the per-point guarantee separates ---
    // Base mass + many tiny far-away "needle" clusters. With k large
    // enough that the optimum puts a center on every needle, a summary
    // that *misses* a needle (uniform sampling misses each w.p.
    // (1-s/n)^5) cannot place a center there and pays the full transport
    // cost. CoverWithBalls guarantees every needle survives into E_w.
    let needle_tab = needle_comparison(quick);

    ExpResult {
        id: "e8",
        title: "Accuracy vs literature baselines at matched summary sizes",
        tables: vec![
            ("comparison (noisy mixture)".to_string(), table),
            ("baseline pruning: assignment-path evals saved".to_string(), pruning_tab),
            ("needle workload (k-median, rare far clusters)".to_string(), needle_tab),
        ],
        notes: vec![
            "Noisy mixture: all methods are competitive (benign case); the separation \
             appears on the needle workload."
                .to_string(),
            "Pruning table: assignment-path work only — the rounds shared verbatim by \
             both twins (the PAM/local-search solves) are attributed by the simulator \
             and subtracted from each side; outputs are bit-identical by construction."
                .to_string(),
            "Needle workload: uniform/EIM drop needles from their summaries and pay the \
             transport cost; the per-point CoverWithBalls guarantee keeps every needle \
             representable, so its ratio stays ≈ 1."
                .to_string(),
        ],
    }
}

/// Assignment-path distance evaluations of each baseline's pruned vs
/// unpruned twin. Each run executes under a 1-thread simulator inside
/// `counter::counted` (so leader-side folds are captured too); the
/// solver rounds that are byte-for-byte shared by both twins
/// ("kmeans||-reduce", "pamae-pam", "eim-solve") are subtracted via the
/// simulator's per-round attribution, isolating the assignment paths
/// the pruning PR touches. Lloyd has no simulator rounds; its twins are
/// counted whole.
fn baseline_pruning_comparison(
    space: &EuclideanSpace,
    data: &crate::points::VectorData,
    pts: &[u32],
    k: usize,
) -> Table {
    use crate::algorithms::lloyd::{lloyd, lloyd_reference, LloydCfg};
    use crate::metric::counter;

    let mut table =
        Table::new(vec!["method", "unpruned evals", "pruned evals", "saved (x)"]);
    let mut push = |name: &str, eref: u64, epr: u64| {
        table.row(vec![
            name.to_string(),
            eref.to_string(),
            epr.to_string(),
            fnum(eref as f64 / epr.max(1) as f64),
        ]);
    };

    // kmeans|| (Means): candidate folds + final Voronoi weighting
    let kp_cfg = KmeansParCfg::new(k);
    let (epr, eref) = {
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            kmeans_parallel::run(space, Objective::Means, pts, k, &kp_cfg, &sim)
        });
        let epr = total - sim.take_stats().dist_evals_for("kmeans||-reduce");
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            kmeans_parallel::run_unpruned(space, Objective::Means, pts, k, &kp_cfg, &sim)
        });
        (epr, total - sim.take_stats().dist_evals_for("kmeans||-reduce"))
    };
    push("kmeans|| assignment path", eref, epr);

    // PAMAE-lite (Median): candidate eval + phase-2 assign + refinement
    let pm_cfg = PamaeCfg::new(k);
    let (epr, eref) = {
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            pamae_lite::run(space, Objective::Median, pts, k, &pm_cfg, &sim)
        });
        let epr = total - sim.take_stats().dist_evals_for("pamae-pam");
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            pamae_lite::run_unpruned(space, Objective::Median, pts, k, &pm_cfg, &sim)
        });
        (epr, total - sim.take_stats().dist_evals_for("pamae-pam"))
    };
    push("pamae-lite assignment path", eref, epr);

    // Ene-Im-Moseley (Median): carried filter folds + weighting round
    let eim_cfg = EimCfg {
        sample_per_iter: (pts.len() / 60).max(k),
        stop_below: (pts.len() / 20).max(2 * k),
        seed: 6,
    };
    let (epr, eref) = {
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            ene_im_moseley::run(space, Objective::Median, pts, k, &eim_cfg, &sim)
        });
        let epr = total - sim.take_stats().dist_evals_for("eim-solve");
        let sim = Simulator::new().with_threads(1);
        let (_, total) = counter::counted(|| {
            ene_im_moseley::run_unpruned(space, Objective::Median, pts, k, &eim_cfg, &sim)
        });
        (epr, total - sim.take_stats().dist_evals_for("eim-solve"))
    };
    push("ene-im-moseley assignment path", eref, epr);

    // Lloyd (continuous k-means): Hamerly bounds across iterations
    let ll_cfg = LloydCfg::default();
    let w = vec![1u64; pts.len()];
    let (_, epr) = counter::counted(|| lloyd(data, pts, &w, k, &ll_cfg));
    let (_, eref) = counter::counted(|| lloyd_reference(data, pts, &w, k, &ll_cfg));
    push("lloyd iterations", eref, epr);

    table
}

/// Build the needle workload and compare methods on it.
fn needle_comparison(quick: bool) -> Table {
    use crate::points::VectorData;
    use crate::util::rng::Rng;

    let n_base = if quick { 3000 } else { 12000 };
    let needles = 16;
    let needle_size = 4;
    let mut rng = Rng::new(0x4EED);
    let mut rows: Vec<Vec<f32>> = Vec::new();
    // base mass: 8 clusters near the origin region
    let base_spec =
        GaussianMixtureSpec { n: n_base, d: 2, k: 8, spread: 30.0, seed: 72, ..Default::default() };
    let (base, _) = base_spec.generate();
    for i in 0..base.n() {
        rows.push(base.row(i as u32).to_vec());
    }
    // needles: tiny clusters on a ring at radius ~3000
    for j in 0..needles {
        let ang = j as f64 / needles as f64 * std::f64::consts::TAU;
        let (cx, cy) = (3000.0 * ang.cos(), 3000.0 * ang.sin());
        for _ in 0..needle_size {
            rows.push(vec![(cx + rng.gaussian()) as f32, (cy + rng.gaussian()) as f32]);
        }
    }
    let n = rows.len();
    let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
    let pts: Vec<u32> = (0..n as u32).collect();
    let k = 8 + needles; // optimum serves every needle

    let obj = Objective::Median;
    // reference: Gonzalez (farthest-first) init — it provably picks up
    // every needle — refined by strong local search. A plain sampled
    // local search would itself miss needles and make ratios meaningless.
    let seq = {
        use crate::algorithms::local_search::{local_search, LocalSearchCfg};
        use crate::algorithms::seeding::gonzalez;
        use crate::algorithms::Instance;
        let w = vec![1u64; pts.len()];
        let inst = Instance::new(&pts, &w);
        let init = gonzalez(&space, inst, k, 0);
        let cfg = LocalSearchCfg { max_passes: 60, sample_candidates: 128, ..Default::default() };
        local_search(&space, obj, inst, k, Some(init), &cfg)
    };
    let mut table = Table::new(vec!["method", "summary size", "cost", "cost/seq"]);

    let ours = solve(&space, &pts, &ClusterConfig::new(obj, k, 0.7));
    table.row(vec![
        "THIS PAPER (3-round, eps=0.7)".to_string(),
        ours.coreset_size.to_string(),
        fnum(ours.full_cost),
        fnum(ours.full_cost / seq.cost),
    ]);
    let sim = Simulator::new();
    let uni = uniform::run(
        &space,
        obj,
        &pts,
        k,
        &UniformCfg { size: ours.coreset_size, l: ours.l, seed: 8 },
        &sim,
    );
    let eim = ene_im_moseley::run(
        &space,
        obj,
        &pts,
        k,
        &EimCfg {
            sample_per_iter: (ours.coreset_size / 6).max(k),
            stop_below: (ours.coreset_size / 4).max(2 * k),
            seed: 9,
        },
        &sim,
    );
    for r in [uni, eim] {
        table.row(vec![
            r.name.to_string(),
            r.summary_size.to_string(),
            fnum(r.full_cost),
            fnum(r.full_cost / seq.cost),
        ]);
    }
    table
}
