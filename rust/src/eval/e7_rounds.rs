//! E7 — Round structure (§3.4): the algorithm must complete in exactly
//! 3 MapReduce rounds for both objectives, with the per-round memory
//! profile the paper describes (round 2 dominated by the broadcast C_w,
//! round 3 by |E_w|), and aggregate memory linear in n.

use crate::coordinator::{solve, ClusterConfig};
use crate::metric::Objective;
use crate::util::table::{fnum, Table};

use super::common::mixture_space;
use super::ExpResult;

pub fn run(quick: bool) -> ExpResult {
    let n = if quick { 4000 } else { 20000 };
    let k = 8;
    let mut rounds_tab = Table::new(vec![
        "objective", "round", "reducers", "max local peak", "aggregate peak", "dist evals",
        "wall (ms)",
    ]);
    let mut summary_tab = Table::new(vec!["objective", "rounds", "M_L", "M_A", "M_A/n"]);
    for obj in [Objective::Median, Objective::Means] {
        let (space, pts) = mixture_space(n, 2, k, 61);
        let cfg = ClusterConfig::new(obj, k, 0.5);
        let rep = solve(&space, &pts, &cfg);
        for r in &rep.stats.rounds {
            rounds_tab.row(vec![
                obj.name().to_string(),
                r.name.clone(),
                r.reducers.to_string(),
                r.max_local_peak.to_string(),
                r.aggregate_peak.to_string(),
                r.dist_evals.to_string(),
                fnum(r.wall.as_secs_f64() * 1e3),
            ]);
        }
        summary_tab.row(vec![
            obj.name().to_string(),
            rep.rounds.to_string(),
            rep.max_local_memory.to_string(),
            rep.aggregate_memory.to_string(),
            fnum(rep.aggregate_memory as f64 / n as f64),
        ]);
        assert_eq!(rep.rounds, 3, "paper: exactly 3 rounds");
    }
    ExpResult {
        id: "e7",
        title: "3-round structure and per-round memory profile (§3.4)",
        tables: vec![
            ("per round".to_string(), rounds_tab),
            ("job summary".to_string(), summary_tab),
        ],
        notes: vec![
            "Exactly 3 rounds for both objectives (asserted).".to_string(),
            "M_A/n is O(1): aggregate memory stays linear in the input as claimed.".to_string(),
        ],
    }
}
