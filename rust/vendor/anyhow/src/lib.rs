//! Offline shim for the `anyhow` crate (the real crate is unavailable in
//! the offline image). Implements the small surface this workspace uses:
//! `Error`, `Result`, `anyhow!`, `bail!`, and the `Context` extension for
//! `Result`/`Option`. Errors carry only a formatted message — sufficient
//! for the crate's diagnostics, which always stringify errors.

use std::fmt;

/// Message-carrying error type. Like the real `anyhow::Error`, it
/// deliberately does NOT implement `std::error::Error`, which is what
/// lets the blanket `From` impl below coexist with coherence rules.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `?`-conversion from any std error (io, parse, ...).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error or a missing `Option` value.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("not a number")?;
        if v < 0 {
            bail!("negative: {v}");
        }
        Ok(v)
    }

    #[test]
    fn ok_path() {
        assert_eq!(parse("41").unwrap(), 41);
    }

    #[test]
    fn context_and_bail() {
        let e = parse("x").unwrap_err().to_string();
        assert!(e.starts_with("not a number:"), "{e}");
        assert_eq!(parse("-2").unwrap_err().to_string(), "negative: -2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/here")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }
}
