//! Failure injection: corrupt artifacts, missing buckets, and bad
//! inputs must degrade gracefully (scalar fallback / typed errors),
//! never panic across the public API.

use std::sync::Arc;

use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::csv::load_csv;
use mrcoreset::metric::dense::{BulkEngine, EuclideanSpace};
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::points::VectorData;
use mrcoreset::runtime::XlaEngine;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("mrcoreset_fail_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupt_manifest_is_an_error() {
    let d = tmpdir("manifest");
    std::fs::write(d.join("manifest.txt"), "assign_cost notanumber 4 128 f.hlo.txt\n").unwrap();
    assert!(XlaEngine::load(&d).is_err());
}

#[test]
fn empty_manifest_is_an_error() {
    let d = tmpdir("empty");
    std::fs::write(d.join("manifest.txt"), "# nothing\n").unwrap();
    let err = match XlaEngine::load(&d) {
        Ok(_) => panic!("empty manifest must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("no artifacts"), "{err}");
}

#[test]
fn corrupt_hlo_fails_at_execute_not_load() {
    let d = tmpdir("hlo");
    std::fs::write(
        d.join("manifest.txt"),
        "assign_cost 256 4 128 bogus.hlo.txt\nmin_update 256 4 1 bogus.hlo.txt\n",
    )
    .unwrap();
    std::fs::write(d.join("bogus.hlo.txt"), "HloModule utterly { broken").unwrap();
    // load parses only the manifest — lazily compiling artifacts means
    // load succeeds and the error surfaces on first use as Err (not panic)
    let engine = XlaEngine::load(&d).expect("lazy load");
    let x = VectorData::new(vec![0.0; 16 * 4], 4);
    let c = VectorData::new(vec![0.0; 2 * 4], 4);
    assert!(engine.assign_block(&x, &c).is_err());
}

#[test]
fn engine_error_falls_back_to_scalar_in_space() {
    let d = tmpdir("fallback");
    std::fs::write(
        d.join("manifest.txt"),
        "assign_cost 256 4 128 bogus.hlo.txt\nmin_update 256 4 1 bogus.hlo.txt\n",
    )
    .unwrap();
    std::fs::write(d.join("bogus.hlo.txt"), "HloModule nope { ").unwrap();
    let mut engine = XlaEngine::load(&d).unwrap();
    engine.set_dispatch_threshold(1); // force engine path -> error -> fallback
    let data = Arc::new(VectorData::from_rows(&[
        vec![0.0, 0.0, 0.0, 0.0],
        vec![1.0, 0.0, 0.0, 0.0],
        vec![5.0, 0.0, 0.0, 0.0],
    ]));
    let space = EuclideanSpace::with_engine(data, Arc::new(engine));
    // must produce correct scalar results despite the broken engine
    let a = space.assign(&[0, 1, 2], &[0, 2]);
    assert_eq!(a.idx, vec![0, 0, 1]);
    assert_eq!(a.dist, vec![0.0, 1.0, 0.0]);
}

#[test]
fn solver_still_works_with_broken_engine() {
    let d = tmpdir("solve");
    std::fs::write(
        d.join("manifest.txt"),
        "assign_cost 256 4 128 bogus.hlo.txt\nmin_update 256 4 1 bogus.hlo.txt\n",
    )
    .unwrap();
    std::fs::write(d.join("bogus.hlo.txt"), "not hlo at all").unwrap();
    let mut engine = XlaEngine::load(&d).unwrap();
    engine.set_dispatch_threshold(1);
    let (data, _) = mrcoreset::data::synth::GaussianMixtureSpec {
        n: 600,
        d: 4,
        k: 3,
        seed: 5,
        ..Default::default()
    }
    .generate();
    let space = EuclideanSpace::with_engine(Arc::new(data), Arc::new(engine));
    let pts: Vec<u32> = (0..600).collect();
    let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, 3, 0.5));
    assert_eq!(rep.rounds, 3);
    assert!(rep.full_cost.is_finite());
}

// ---- spill-shard integrity + injected faults ------------------------
//
// The MRCSPILL frame carries a CRC32 footer; any on-disk damage to a
// shard must surface through the executor API as a structured
// `ExecError` naming the round/reducer/shard — never garbage decode
// output and never a panic.

use mrcoreset::mapreduce::{
    ExecError, Executor, ExecutorCfg, FaultPlan, Simulator, SpillExecutor,
};
use mrcoreset::obs;

/// Build a spill executor over an explicit directory, scatter two
/// partitions, and hand back (executor, manifest, path of shard 0).
fn spill_fixture(
    name: &str,
) -> (SpillExecutor, mrcoreset::mapreduce::Manifest<Vec<u32>>, std::path::PathBuf) {
    let d = tmpdir(name);
    let ex = SpillExecutor::new(Simulator::new().with_threads(1), Some(&d)).expect("store");
    let inputs = ex.scatter(vec![vec![1u32, 2, 3], vec![4, 5]]).expect("scatter");
    let shard0 = d.join("s0-0.shard");
    assert!(shard0.is_file(), "scatter must have written {}", shard0.display());
    (ex, inputs, shard0)
}

#[test]
fn truncated_spill_shard_is_a_structured_corrupt_error() {
    let (ex, inputs, shard0) = spill_fixture("trunc_shard");
    let bytes = std::fs::read(&shard0).unwrap();
    std::fs::write(&shard0, &bytes[..bytes.len() - 6]).unwrap(); // lose CRC + tail
    let err = match ex.round("r-trunc", &inputs, |_, p: &Vec<u32>, _| p.clone()) {
        Ok(_) => panic!("truncated shard must fail the round"),
        Err(e) => e,
    };
    match err {
        ExecError::Corrupt { round, reducer, shard, detail } => {
            assert_eq!((round.as_str(), reducer), ("r-trunc", 0));
            assert_eq!(shard, "s0-0");
            assert!(detail.contains("truncated"), "{detail}");
        }
        other => panic!("expected Corrupt, got {other}"),
    }
}

#[test]
fn bit_flipped_spill_shard_is_a_structured_corrupt_error() {
    let (ex, inputs, shard0) = spill_fixture("flip_shard");
    let mut bytes = std::fs::read(&shard0).unwrap();
    let i = bytes.len() - 7; // inside the payload, ahead of the CRC footer
    bytes[i] ^= 0x40;
    std::fs::write(&shard0, &bytes).unwrap();
    let err = match ex.round("r-flip", &inputs, |_, p: &Vec<u32>, _| p.clone()) {
        Ok(_) => panic!("checksum mismatch must fail the round"),
        Err(e) => e,
    };
    match err {
        ExecError::Corrupt { round, reducer, shard, detail } => {
            assert_eq!((round.as_str(), reducer), ("r-flip", 0));
            assert_eq!(shard, "s0-0");
            assert!(detail.contains("checksum"), "{detail}");
        }
        other => panic!("expected Corrupt, got {other}"),
    }
}

#[test]
fn fault_plan_injected_read_error_is_structured_io() {
    let plan = FaultPlan::parse("read@0.1").unwrap();
    let sim = Simulator::new().with_threads(2).with_faults(plan);
    let inputs = sim.scatter(vec![vec![1u32], vec![2u32]]).expect("scatter");
    let err = match Executor::round(&sim, "r-inj", &inputs, |_, p: &Vec<u32>, _| p.clone()) {
        Ok(_) => panic!("max_attempts defaults to 1 on a bare simulator"),
        Err(e) => e,
    };
    match err {
        ExecError::Io { context, source } => {
            assert!(context.contains("injected read fault"), "{context}");
            assert!(context.contains("reducer 1"), "{context}");
            let _ = source.to_string(); // Display + Error::source stay usable
        }
        other => panic!("expected Io, got {other}"),
    }
}

/// The same contract holds through the full solver stack: a fault plan
/// that outlives the retry budget turns the whole run into an `Err`,
/// never an abort.
#[test]
fn exhausted_fault_plan_fails_a_full_solve_structurally() {
    use mrcoreset::coordinator::try_solve_traced;
    use mrcoreset::data::synth::GaussianMixtureSpec;
    let (data, _) =
        GaussianMixtureSpec { n: 400, d: 2, k: 3, seed: 9, ..Default::default() }.generate();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..400).collect();
    let mut cfg = ClusterConfig::new(Objective::Median, 3, 0.5);
    cfg.executor = ExecutorCfg::spill()
        .with_faults(FaultPlan::parse("flip@0.0x9").unwrap())
        .with_retries(1);
    let err = try_solve_traced(&space, &pts, &cfg, obs::noop())
        .expect_err("a x9 fault site outlives 2 attempts");
    match err {
        ExecError::Corrupt { reducer, detail, .. } => {
            assert_eq!(reducer, 0);
            assert!(detail.contains("bit-flip"), "{detail}");
        }
        other => panic!("expected Corrupt, got {other}"),
    }
}

#[test]
fn csv_error_paths() {
    let d = tmpdir("csv");
    assert!(load_csv(&d.join("missing.csv")).is_err());
    std::fs::write(d.join("empty.csv"), "# only comments\n").unwrap();
    assert!(load_csv(&d.join("empty.csv")).is_err());
    std::fs::write(d.join("nan_row.csv"), "1,2\nx,y\n").unwrap();
    assert!(load_csv(&d.join("nan_row.csv")).is_err());
}
