//! Failure injection: corrupt artifacts, missing buckets, and bad
//! inputs must degrade gracefully (scalar fallback / typed errors),
//! never panic across the public API.

use std::sync::Arc;

use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::csv::load_csv;
use mrcoreset::metric::dense::{BulkEngine, EuclideanSpace};
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::points::VectorData;
use mrcoreset::runtime::XlaEngine;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("mrcoreset_fail_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupt_manifest_is_an_error() {
    let d = tmpdir("manifest");
    std::fs::write(d.join("manifest.txt"), "assign_cost notanumber 4 128 f.hlo.txt\n").unwrap();
    assert!(XlaEngine::load(&d).is_err());
}

#[test]
fn empty_manifest_is_an_error() {
    let d = tmpdir("empty");
    std::fs::write(d.join("manifest.txt"), "# nothing\n").unwrap();
    let err = match XlaEngine::load(&d) {
        Ok(_) => panic!("empty manifest must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("no artifacts"), "{err}");
}

#[test]
fn corrupt_hlo_fails_at_execute_not_load() {
    let d = tmpdir("hlo");
    std::fs::write(
        d.join("manifest.txt"),
        "assign_cost 256 4 128 bogus.hlo.txt\nmin_update 256 4 1 bogus.hlo.txt\n",
    )
    .unwrap();
    std::fs::write(d.join("bogus.hlo.txt"), "HloModule utterly { broken").unwrap();
    // load parses only the manifest — lazily compiling artifacts means
    // load succeeds and the error surfaces on first use as Err (not panic)
    let engine = XlaEngine::load(&d).expect("lazy load");
    let x = VectorData::new(vec![0.0; 16 * 4], 4);
    let c = VectorData::new(vec![0.0; 2 * 4], 4);
    assert!(engine.assign_block(&x, &c).is_err());
}

#[test]
fn engine_error_falls_back_to_scalar_in_space() {
    let d = tmpdir("fallback");
    std::fs::write(
        d.join("manifest.txt"),
        "assign_cost 256 4 128 bogus.hlo.txt\nmin_update 256 4 1 bogus.hlo.txt\n",
    )
    .unwrap();
    std::fs::write(d.join("bogus.hlo.txt"), "HloModule nope { ").unwrap();
    let mut engine = XlaEngine::load(&d).unwrap();
    engine.set_dispatch_threshold(1); // force engine path -> error -> fallback
    let data = Arc::new(VectorData::from_rows(&[
        vec![0.0, 0.0, 0.0, 0.0],
        vec![1.0, 0.0, 0.0, 0.0],
        vec![5.0, 0.0, 0.0, 0.0],
    ]));
    let space = EuclideanSpace::with_engine(data, Arc::new(engine));
    // must produce correct scalar results despite the broken engine
    let a = space.assign(&[0, 1, 2], &[0, 2]);
    assert_eq!(a.idx, vec![0, 0, 1]);
    assert_eq!(a.dist, vec![0.0, 1.0, 0.0]);
}

#[test]
fn solver_still_works_with_broken_engine() {
    let d = tmpdir("solve");
    std::fs::write(
        d.join("manifest.txt"),
        "assign_cost 256 4 128 bogus.hlo.txt\nmin_update 256 4 1 bogus.hlo.txt\n",
    )
    .unwrap();
    std::fs::write(d.join("bogus.hlo.txt"), "not hlo at all").unwrap();
    let mut engine = XlaEngine::load(&d).unwrap();
    engine.set_dispatch_threshold(1);
    let (data, _) = mrcoreset::data::synth::GaussianMixtureSpec {
        n: 600,
        d: 4,
        k: 3,
        seed: 5,
        ..Default::default()
    }
    .generate();
    let space = EuclideanSpace::with_engine(Arc::new(data), Arc::new(engine));
    let pts: Vec<u32> = (0..600).collect();
    let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, 3, 0.5));
    assert_eq!(rep.rounds, 3);
    assert!(rep.full_cost.is_finite());
}

#[test]
fn csv_error_paths() {
    let d = tmpdir("csv");
    assert!(load_csv(&d.join("missing.csv")).is_err());
    std::fs::write(d.join("empty.csv"), "# only comments\n").unwrap();
    assert!(load_csv(&d.join("empty.csv")).is_err());
    std::fs::write(d.join("nan_row.csv"), "1,2\nx,y\n").unwrap();
    assert!(load_csv(&d.join("nan_row.csv")).is_err());
}
