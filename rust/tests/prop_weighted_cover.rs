//! Property tests for `cover_with_balls_weighted` (the weighted-instance
//! CoverWithBalls the outlier pipeline's compress round rides on):
//!
//! 1. unit input weights reproduce the unweighted `cover_with_balls`
//!    output **bit-for-bit** (same representatives, same weights, same
//!    τ, same d(·,T) — the weighted path must be a strict generalization,
//!    not a near-miss);
//! 2. total weight is conserved under arbitrary positive input weights
//!    (Definition 2.3 generalized: w(c) = Σ_{y: τ(y)=c} w_in(y));
//! 3. τ stays total and every representative keeps a positive weight.

use std::sync::Arc;

use mrcoreset::coreset::{cover_with_balls, cover_with_balls_weighted};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::metric::dense::{EuclideanSpace, ManhattanSpace};
use mrcoreset::metric::MetricSpace;
use mrcoreset::prop_assert;
use mrcoreset::util::prop::check;
use mrcoreset::util::rng::Rng;

/// One randomized cover instance: spaces under test plus the cover
/// parameters.
struct CoverCase {
    spaces: Vec<Box<dyn MetricSpace>>,
    pts: Vec<u32>,
    t: Vec<u32>,
    r: f64,
    eps: f64,
    beta: f64,
}

/// Random mixture spaces (Euclidean + Manhattan, so both the tiled fast
/// path and the generic scalar path are covered) with random cover
/// parameters.
fn random_case(rng: &mut Rng) -> CoverCase {
    let n = 40 + rng.below(160);
    let d = 1 + rng.below(4);
    let (data, _) = GaussianMixtureSpec {
        n,
        d,
        k: 1 + rng.below(4),
        spread: 1.0 + rng.f64() * 30.0,
        outlier_frac: 0.0,
        seed: rng.next_u64(),
    }
    .generate();
    let shared = Arc::new(data);
    let spaces: Vec<Box<dyn MetricSpace>> = vec![
        Box::new(EuclideanSpace::new(shared.clone())),
        Box::new(ManhattanSpace::new(shared)),
    ];
    let pts: Vec<u32> = (0..n as u32).collect();
    let t_size = 1 + rng.below(6);
    let t: Vec<u32> = (0..t_size).map(|_| rng.below(n) as u32).collect();
    CoverCase {
        spaces,
        pts,
        t,
        r: rng.f64() * 5.0,
        eps: 0.1 + rng.f64() * 0.8,
        beta: 1.0 + rng.f64() * 3.0,
    }
}

#[test]
fn unit_weights_reproduce_unweighted_cover_bit_for_bit() {
    check("unit-weights-equal-unweighted", 0xC0DE, 40, |rng| {
        let CoverCase { spaces, pts, t, r, eps, beta } = random_case(rng);
        for space in &spaces {
            let unit = vec![1u64; pts.len()];
            let a = cover_with_balls(space.as_ref(), &pts, &t, r, eps, beta);
            let b =
                cover_with_balls_weighted(space.as_ref(), &pts, Some(&unit), &t, r, eps, beta);
            prop_assert!(
                a.set.indices == b.set.indices,
                "{}: representatives differ: {:?} vs {:?}",
                space.name(),
                a.set.indices,
                b.set.indices
            );
            prop_assert!(
                a.set.weights == b.set.weights,
                "{}: weights differ: {:?} vs {:?}",
                space.name(),
                a.set.weights,
                b.set.weights
            );
            prop_assert!(a.tau == b.tau, "{}: tau differs", space.name());
            let bits_equal = a
                .dist_to_t
                .iter()
                .zip(&b.dist_to_t)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            prop_assert!(bits_equal, "{}: dist_to_t not bit-identical", space.name());
        }
        Ok(())
    });
}

#[test]
fn arbitrary_weights_conserve_total_weight() {
    check("weighted-cover-weight-conservation", 0xFEED, 40, |rng| {
        let CoverCase { spaces, pts, t, r, eps, beta } = random_case(rng);
        let weights: Vec<u64> = pts.iter().map(|_| 1 + rng.below(1000) as u64).collect();
        let total: u64 = weights.iter().sum();
        for space in &spaces {
            let res = cover_with_balls_weighted(
                space.as_ref(),
                &pts,
                Some(&weights),
                &t,
                r,
                eps,
                beta,
            );
            prop_assert!(
                res.set.total_weight() == total,
                "{}: total weight {} != input {}",
                space.name(),
                res.set.total_weight(),
                total
            );
            prop_assert!(
                res.tau.iter().all(|&ti| (ti as usize) < res.set.len()),
                "{}: tau not total",
                space.name()
            );
            prop_assert!(
                res.set.weights.iter().all(|&w| w > 0),
                "{}: zero-weight representative",
                space.name()
            );
            // weights are exactly the τ-preimage weight sums
            let mut sums = vec![0u64; res.set.len()];
            for (i, &ti) in res.tau.iter().enumerate() {
                sums[ti as usize] += weights[i];
            }
            prop_assert!(
                sums == res.set.weights,
                "{}: weights are not preimage sums",
                space.name()
            );
        }
        Ok(())
    });
}
