//! Property: the execution backends are interchangeable bit for bit.
//!
//! `InMemoryExecutor` and `SpillExecutor` must produce identical
//! `RunReport::to_json()` strings, identical stable-form trace lines
//! (wall-clock and spill traffic omitted — see `Event::stable_json`),
//! and identical `dist_evals` on the e2-style mixture at 1 and 8
//! threads. This is the byte-parity contract of `mapreduce::executor`:
//! both backends charge the same byte sequence per reducer (encoded
//! input size before loading, arithmetic output size before encoding),
//! so even the byte peaks in traces and reports agree exactly.
//!
//! The budget half of the contract: a spill run under hard budget B
//! either completes with peak resident bytes ≤ B, or fails with a
//! structured `ExecError::OverBudget` — never an abort.

use std::sync::Arc;

use mrcoreset::coordinator::{solve, solve_traced, try_solve_traced, ClusterConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{ExecError, ExecutorCfg};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;
use mrcoreset::obs::{self, MemSink, Recorder};

fn mixture(n: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
    let (data, _) =
        GaussianMixtureSpec { n, d: 2, k: 5, seed, ..Default::default() }.generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

/// One traced solve under an explicit backend/thread choice; returns the
/// three comparable artifacts (report JSON, stable trace, dist_evals).
fn traced_run(
    space: &EuclideanSpace,
    pts: &[u32],
    obj: Objective,
    executor: ExecutorCfg,
    threads: usize,
) -> (String, Vec<String>, u64) {
    let sink = Arc::new(MemSink::new());
    let rec: Arc<dyn Recorder> = sink.clone();
    let mut cfg = ClusterConfig::new(obj, 5, 0.4);
    cfg.threads = Some(threads);
    cfg.executor = executor;
    let rep = solve_traced(space, pts, &cfg, rec);
    let trace: Vec<String> = sink.snapshot().iter().map(|e| e.stable_json()).collect();
    (rep.to_json(), trace, rep.dist_evals)
}

#[test]
fn backends_bit_identical_reports_traces_and_dist_evals() {
    let (space, pts) = mixture(2500, 42);
    for obj in [Objective::Median, Objective::Means] {
        let (ref_json, ref_trace, ref_evals) =
            traced_run(&space, &pts, obj, ExecutorCfg::in_memory(), 1);
        assert!(ref_trace.len() > 5, "{obj}: expected run/round/reducer events");
        let variants: [(&str, ExecutorCfg, usize); 3] = [
            ("mem/8", ExecutorCfg::in_memory(), 8),
            ("spill/1", ExecutorCfg::spill(), 1),
            ("spill/8", ExecutorCfg::spill(), 8),
        ];
        for (label, executor, threads) in variants {
            let (json, trace, evals) = traced_run(&space, &pts, obj, executor, threads);
            assert_eq!(ref_json, json, "{obj} {label}: RunReport::to_json differs");
            assert_eq!(ref_trace, trace, "{obj} {label}: stable trace lines differ");
            assert_eq!(ref_evals, evals, "{obj} {label}: dist_evals differ");
        }
    }
}

/// The outlier pipeline exercises the remaining manifest paths (the
/// weighted-union scatter and the single-reducer compress round), so it
/// gets its own backend-parity check.
#[test]
fn outlier_pipeline_backend_parity() {
    use mrcoreset::data::synth::NoiseSpec;
    let spec =
        GaussianMixtureSpec { n: 1200, d: 2, k: 4, spread: 30.0, seed: 33, ..Default::default() };
    let (data, _) = spec.generate_with_noise(&NoiseSpec {
        count: 30,
        expanse: 10.0,
        offset: 40.0,
        seed: 34,
    });
    let total = data.n() as u32;
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..total).collect();
    let run = |executor: ExecutorCfg, threads: usize| {
        let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
        cfg.outliers = 30;
        cfg.threads = Some(threads);
        cfg.executor = executor;
        solve(&space, &pts, &cfg)
    };
    let a = run(ExecutorCfg::in_memory(), 1);
    let b = run(ExecutorCfg::spill(), 8);
    assert_eq!(a.to_json(), b.to_json(), "robust reports must be backend-invariant");
    assert_eq!(a.excluded, b.excluded);
    assert_eq!(a.dist_evals, b.dist_evals);
}

/// A spill run whose hard budget is exactly the measured in-memory peak
/// must complete — byte parity means the spill backend needs not one
/// byte more — and its reported peak must respect the budget.
#[test]
fn spill_run_fits_exactly_within_measured_peak_budget() {
    let (space, pts) = mixture(1500, 7);
    let mut mem_cfg = ClusterConfig::new(Objective::Median, 5, 0.4);
    mem_cfg.executor = ExecutorCfg::in_memory();
    let mem_rep = solve(&space, &pts, &mem_cfg);
    let budget = mem_rep.max_local_bytes;
    assert!(budget > 0, "byte metering must be active");

    let mut spill_cfg = ClusterConfig::new(Objective::Median, 5, 0.4);
    spill_cfg.executor = ExecutorCfg::spill().with_budget(budget);
    let spill_rep = try_solve_traced(&space, &pts, &spill_cfg, obs::noop())
        .expect("a budget of exactly the peak must suffice");
    assert!(
        spill_rep.max_local_bytes <= budget,
        "peak {} exceeds hard budget {budget}",
        spill_rep.max_local_bytes
    );
    assert_eq!(mem_rep.to_json(), spill_rep.to_json(), "budgeted run must not change results");
}

/// Under a budget that cannot hold even one partition, both backends
/// fail with the structured over-budget error — same round, same budget,
/// deterministically — instead of aborting or OOMing.
#[test]
fn impossible_budget_yields_structured_error_on_both_backends() {
    let (space, pts) = mixture(500, 11);
    for executor in [ExecutorCfg::in_memory(), ExecutorCfg::spill()] {
        let mut cfg = ClusterConfig::new(Objective::Median, 3, 0.5);
        cfg.executor = executor.with_budget(64);
        let err = try_solve_traced(&space, &pts, &cfg, obs::noop())
            .expect_err("64 bytes cannot hold a partition shard");
        match err {
            ExecError::OverBudget { round, reducer, needed, budget, resident } => {
                assert_eq!(budget, 64);
                assert_eq!(round, "coreset-r1-local", "round 1 must trip first");
                assert_eq!(reducer, 0, "first reducer in input order wins");
                assert_eq!(resident, 0, "the input shard is the very first charge");
                assert!(needed > 64, "a round-1 shard is larger than the budget");
            }
            other => panic!("expected OverBudget, got {other}"),
        }
    }
}
