//! Property tests pinning the geometry-pruned hot paths **bit-for-bit**
//! to their unpruned references:
//!
//! 1. `cover_with_balls_weighted` (bucketed, bounds-pruned greedy) vs
//!    `cover_with_balls_weighted_unpruned` — Euclidean, Manhattan, and
//!    Levenshtein spaces, weighted and unweighted, random parameters;
//! 2. `local_search` / `local_search_outliers` (incremental book after
//!    accepted swaps) vs their full-rebuild `*_reference` twins;
//! 3. the pruned pipeline stays bit-identical across simulator thread
//!    counts (1 vs 8), so pruning introduces no scheduling sensitivity.
//!
//! Pruning must only skip evaluations whose comparison was already
//! decided by a bound — any drift in representatives, τ, weights,
//! centers, or cost bits is a bug, not a tolerance question.

use std::sync::Arc;

use mrcoreset::algorithms::local_search::{local_search, local_search_reference, LocalSearchCfg};
use mrcoreset::algorithms::Instance;
use mrcoreset::coreset::{
    cover_with_balls_weighted, cover_with_balls_weighted_unpruned, two_round_coreset,
    CoresetConfig, CoverResult,
};
use mrcoreset::data::strings::StringClusterSpec;
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{PartitionStrategy, Simulator};
use mrcoreset::metric::dense::{EuclideanSpace, ManhattanSpace};
use mrcoreset::metric::kernel::KernelKind;
use mrcoreset::metric::levenshtein::StringSpace;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::outliers::{local_search_outliers, local_search_outliers_reference};
use mrcoreset::prop_assert;
use mrcoreset::util::prop::check;
use mrcoreset::util::rng::Rng;

fn covers_bit_identical(a: &CoverResult, b: &CoverResult) -> Result<(), String> {
    if a.set.indices != b.set.indices {
        return Err(format!("representatives differ: {:?} vs {:?}", a.set.indices, b.set.indices));
    }
    if a.set.weights != b.set.weights {
        return Err(format!("weights differ: {:?} vs {:?}", a.set.weights, b.set.weights));
    }
    if a.tau != b.tau {
        return Err("tau differs".to_string());
    }
    let bits = a.dist_to_t.iter().zip(&b.dist_to_t).all(|(x, y)| x.to_bits() == y.to_bits());
    if !bits {
        return Err("dist_to_t not bit-identical".to_string());
    }
    Ok(())
}

/// Random vector spaces: Euclidean exercises the overridden pruned
/// batch; Manhattan exercises the macro override on the generic path.
fn random_vector_spaces(rng: &mut Rng) -> (Vec<Box<dyn MetricSpace>>, usize) {
    let n = 40 + rng.below(200);
    let (data, _) = GaussianMixtureSpec {
        n,
        d: 1 + rng.below(4),
        k: 1 + rng.below(5),
        spread: 1.0 + rng.f64() * 30.0,
        outlier_frac: 0.0,
        seed: rng.next_u64(),
    }
    .generate();
    let shared = Arc::new(data);
    // pinned to an exact kernel: these are bit-for-bit pruning contracts,
    // and must hold even when MRCORESET_KERNEL selects an inexact backend
    let spaces: Vec<Box<dyn MetricSpace>> = vec![
        Box::new(EuclideanSpace::with_kernel(shared.clone(), KernelKind::Blocked)),
        Box::new(ManhattanSpace::with_kernel(shared, KernelKind::Blocked)),
    ];
    (spaces, n)
}

fn random_weights(rng: &mut Rng, n: usize) -> Option<Vec<u64>> {
    if rng.below(2) == 0 {
        None
    } else {
        Some((0..n).map(|_| 1 + rng.below(9) as u64).collect())
    }
}

#[test]
fn pruned_cover_matches_unpruned_on_vector_spaces() {
    check("pruned-cover-vector", 0x9E0_C0DE, 40, |rng| {
        let (spaces, n) = random_vector_spaces(rng);
        let pts: Vec<u32> = (0..n as u32).collect();
        let t_size = 1 + rng.below(8);
        let t: Vec<u32> = (0..t_size).map(|_| rng.below(n) as u32).collect();
        let r = rng.f64() * 5.0;
        let eps = 0.1 + rng.f64() * 0.8;
        let beta = 1.0 + rng.f64() * 3.0;
        let weights = random_weights(rng, n);
        for space in &spaces {
            let pruned = cover_with_balls_weighted(
                space.as_ref(),
                &pts,
                weights.as_deref(),
                &t,
                r,
                eps,
                beta,
            );
            let reference = cover_with_balls_weighted_unpruned(
                space.as_ref(),
                &pts,
                weights.as_deref(),
                &t,
                r,
                eps,
                beta,
            );
            covers_bit_identical(&pruned, &reference)
                .map_err(|e| format!("{}: {e}", space.name()))?;
        }
        Ok(())
    });
}

#[test]
fn pruned_cover_matches_unpruned_on_levenshtein() {
    check("pruned-cover-levenshtein", 0x1EE7_C0DE, 15, |rng| {
        let n = 40 + rng.below(120);
        let (strings, _) = StringClusterSpec {
            n,
            clusters: 1 + rng.below(6),
            base_len: 8 + rng.below(16),
            max_edits: 3,
            seed: rng.next_u64(),
        }
        .generate();
        let space = StringSpace::new(strings);
        let pts: Vec<u32> = (0..n as u32).collect();
        let t_size = 1 + rng.below(6);
        let t: Vec<u32> = (0..t_size).map(|_| rng.below(n) as u32).collect();
        // edit distances are integers: exercise thresholds at and around
        // integer boundaries
        let r = rng.below(6) as f64;
        let eps = 0.1 + rng.f64() * 0.8;
        let beta = 1.0 + rng.f64() * 3.0;
        let weights = random_weights(rng, n);
        let pruned = cover_with_balls_weighted(&space, &pts, weights.as_deref(), &t, r, eps, beta);
        let reference = cover_with_balls_weighted_unpruned(
            &space,
            &pts,
            weights.as_deref(),
            &t,
            r,
            eps,
            beta,
        );
        covers_bit_identical(&pruned, &reference)
    });
}

/// Shared body: incremental-book local search must equal the
/// full-rebuild reference on every space, bit for bit.
fn assert_local_search_equivalent(
    space: &dyn MetricSpace,
    rng: &mut Rng,
    n: usize,
) -> Result<(), String> {
    let pts: Vec<u32> = (0..n as u32).collect();
    let weights: Vec<u64> = random_weights(rng, n).unwrap_or_else(|| vec![1u64; n]);
    let inst = Instance::new(&pts, &weights);
    let k = 1 + rng.below(6);
    // force both the exhaustive (small n) and sampled pool branches
    let cfg = LocalSearchCfg {
        exhaustive_below: if rng.below(2) == 0 { 0 } else { 256 },
        sample_candidates: 24,
        max_passes: 12,
        seed: rng.next_u64(),
        ..LocalSearchCfg::default()
    };
    for obj in [Objective::Median, Objective::Means] {
        let inc = local_search(space, obj, inst, k, None, &cfg);
        let reference = local_search_reference(space, obj, inst, k, None, &cfg);
        prop_assert!(
            inc.centers == reference.centers,
            "{} {obj}: centers {:?} vs {:?}",
            space.name(),
            inc.centers,
            reference.centers
        );
        prop_assert!(
            inc.cost.to_bits() == reference.cost.to_bits(),
            "{} {obj}: cost {} vs {}",
            space.name(),
            inc.cost,
            reference.cost
        );
    }
    Ok(())
}

#[test]
fn incremental_local_search_matches_reference_on_vector_spaces() {
    check("incremental-ls-vector", 0xB00C, 25, |rng| {
        let (spaces, n) = random_vector_spaces(rng);
        for space in &spaces {
            assert_local_search_equivalent(space.as_ref(), rng, n)?;
        }
        Ok(())
    });
}

#[test]
fn incremental_local_search_matches_reference_on_levenshtein() {
    check("incremental-ls-levenshtein", 0xB00D, 10, |rng| {
        let n = 30 + rng.below(80);
        let (strings, _) = StringClusterSpec {
            n,
            clusters: 1 + rng.below(5),
            base_len: 10 + rng.below(10),
            max_edits: 3,
            seed: rng.next_u64(),
        }
        .generate();
        let space = StringSpace::new(strings);
        assert_local_search_equivalent(&space, rng, n)
    });
}

#[test]
fn incremental_outlier_search_matches_reference() {
    check("incremental-ls-outliers", 0xB00E, 15, |rng| {
        let (spaces, n) = random_vector_spaces(rng);
        let pts: Vec<u32> = (0..n as u32).collect();
        let weights: Vec<u64> = random_weights(rng, n).unwrap_or_else(|| vec![1u64; n]);
        let inst = Instance::new(&pts, &weights);
        let k = 1 + rng.below(5);
        let z = rng.below(1 + n / 10) as u64;
        let cfg = LocalSearchCfg {
            exhaustive_below: if rng.below(2) == 0 { 0 } else { 256 },
            sample_candidates: 24,
            max_passes: 8,
            seed: rng.next_u64(),
            ..LocalSearchCfg::default()
        };
        for space in &spaces {
            for obj in [Objective::Median, Objective::Means] {
                let inc = local_search_outliers(space.as_ref(), obj, inst, k, z, None, &cfg);
                let reference = local_search_outliers_reference(
                    space.as_ref(),
                    obj,
                    inst,
                    k,
                    z,
                    None,
                    &cfg,
                );
                prop_assert!(
                    inc.centers == reference.centers,
                    "{} {obj} z={z}: centers {:?} vs {:?}",
                    space.name(),
                    inc.centers,
                    reference.centers
                );
                prop_assert!(
                    inc.cost.to_bits() == reference.cost.to_bits(),
                    "{} {obj} z={z}: cost {} vs {}",
                    space.name(),
                    inc.cost,
                    reference.cost
                );
                prop_assert!(
                    inc.excluded == reference.excluded,
                    "{} {obj} z={z}: excluded {:?} vs {:?}",
                    space.name(),
                    inc.excluded,
                    reference.excluded
                );
            }
        }
        Ok(())
    });
}

/// The pruned cover runs inside every round-1/round-2 reducer; the whole
/// pipeline must stay bit-identical across simulator thread counts.
#[test]
fn pruned_pipeline_bit_identical_across_thread_counts() {
    let (data, _) =
        GaussianMixtureSpec { n: 2500, d: 3, k: 5, seed: 31, ..Default::default() }.generate();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..2500).collect();
    let cfg = CoresetConfig { seed: 0xBEEF, ..CoresetConfig::new(5, 0.4) };
    for obj in [Objective::Median, Objective::Means] {
        let sim1 = Simulator::new().with_threads(1);
        let a = two_round_coreset(&space, obj, &pts, 6, PartitionStrategy::RoundRobin, &cfg, &sim1)
            .expect("pipeline");
        let sim8 = Simulator::new().with_threads(8);
        let b = two_round_coreset(&space, obj, &pts, 6, PartitionStrategy::RoundRobin, &cfg, &sim8)
            .expect("pipeline");
        assert_eq!(a.coreset.indices, b.coreset.indices, "{obj}");
        assert_eq!(a.coreset.weights, b.coreset.weights, "{obj}");
        assert_eq!(a.radii, b.radii, "{obj}");
        assert_eq!(a.global_r, b.global_r, "{obj}");
        // the honest work metric is scheduling-independent too
        let e1 = sim1.take_stats().total_dist_evals();
        let e8 = sim8.take_stats().total_dist_evals();
        assert_eq!(e1, e8, "{obj}: dist_evals drift across thread counts");
    }
}
