//! Property tests pinning the bounds-pruned baselines **bit-for-bit** to
//! their unpruned reference twins:
//!
//! 1. `kmeans_parallel::run` vs `run_unpruned` — the incremental
//!    candidate folds and final Voronoi weighting through
//!    `NearestTracker`;
//! 2. `pamae_lite::run` vs `run_unpruned` — pruned candidate evaluation,
//!    phase-2 assignment, and `exact_one_center_pruned` refinement;
//! 3. `ene_im_moseley::run` vs `run_unpruned` — carried nearest-pivot
//!    state with broadcast center rows, including duplicate-heavy /
//!    integer tie grids that hammer the NaN-safe filter sort, and the
//!    Levenshtein space (integer distances, the general-metric path);
//! 4. `lloyd` vs `lloyd_reference` — Hamerly bounds across iterations,
//!    weighted and unweighted;
//! 5. the pruned baselines stay bit-identical across simulator thread
//!    counts (1 vs 8) with identical attributed distance evaluations.
//!
//! Pruning must only skip evaluations whose comparison a bound already
//! decided — any drift in centers, costs, summary sizes, or round counts
//! is a bug, not a tolerance question.

use std::sync::Arc;

use mrcoreset::algorithms::lloyd::{lloyd, lloyd_reference, LloydCfg};
use mrcoreset::baselines::ene_im_moseley::{self, EimCfg};
use mrcoreset::baselines::kmeans_parallel::{self, KmeansParCfg};
use mrcoreset::baselines::pamae_lite::{self, PamaeCfg};
use mrcoreset::baselines::BaselineReport;
use mrcoreset::data::strings::StringClusterSpec;
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::Simulator;
use mrcoreset::metric::dense::{EuclideanSpace, ManhattanSpace};
use mrcoreset::metric::kernel::KernelKind;
use mrcoreset::metric::levenshtein::StringSpace;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::points::VectorData;
use mrcoreset::util::prop::check;
use mrcoreset::util::rng::Rng;

fn reports_bit_identical(a: &BaselineReport, b: &BaselineReport) -> Result<(), String> {
    if a.solution.centers != b.solution.centers {
        return Err(format!("centers differ: {:?} vs {:?}", a.solution.centers, b.solution.centers));
    }
    if a.solution.cost.to_bits() != b.solution.cost.to_bits() {
        return Err(format!("solution cost differs: {} vs {}", a.solution.cost, b.solution.cost));
    }
    if a.full_cost.to_bits() != b.full_cost.to_bits() {
        return Err(format!("full cost differs: {} vs {}", a.full_cost, b.full_cost));
    }
    if a.summary_size != b.summary_size {
        return Err(format!("summary size differs: {} vs {}", a.summary_size, b.summary_size));
    }
    if a.rounds != b.rounds {
        return Err(format!("rounds differ: {} vs {}", a.rounds, b.rounds));
    }
    Ok(())
}

/// Euclidean exercises the overridden pruned batch, Manhattan the macro
/// override on the generic path.
fn random_vector_spaces(rng: &mut Rng) -> (Vec<Box<dyn MetricSpace>>, usize) {
    let n = 150 + rng.below(400);
    let (data, _) = GaussianMixtureSpec {
        n,
        d: 1 + rng.below(4),
        k: 1 + rng.below(5),
        spread: 1.0 + rng.f64() * 30.0,
        outlier_frac: if rng.below(3) == 0 { 0.05 } else { 0.0 },
        seed: rng.next_u64(),
    }
    .generate();
    let shared = Arc::new(data);
    // pinned to an exact kernel: pruned-vs-unpruned bit identity is a
    // bounds contract and must hold under any MRCORESET_KERNEL setting
    let spaces: Vec<Box<dyn MetricSpace>> = vec![
        Box::new(EuclideanSpace::with_kernel(shared.clone(), KernelKind::Blocked)),
        Box::new(ManhattanSpace::with_kernel(shared, KernelKind::Blocked)),
    ];
    (spaces, n)
}

/// Duplicate-heavy integer lattice: scores of exact ties in every
/// distance comparison, the worst case for tie-handling in the EIM
/// filter sort and the trackers' strict `<` updates.
fn tie_grid_space(rng: &mut Rng) -> (EuclideanSpace, usize) {
    let n = 150 + rng.below(250);
    let side = 2 + rng.below(4);
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|_| vec![rng.below(side) as f32, rng.below(side) as f32])
        .collect();
    (EuclideanSpace::with_kernel(Arc::new(VectorData::from_rows(&rows)), KernelKind::Blocked), n)
}

fn random_subset(rng: &mut Rng, n: usize) -> Vec<u32> {
    // sometimes the identity, sometimes a shuffled strict subset — the
    // baselines must never assume `pts[i] == i`
    if rng.below(2) == 0 {
        (0..n as u32).collect()
    } else {
        let m = n / 2 + rng.below(n / 2);
        let mut ids: Vec<u32> = rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect();
        rng.shuffle(&mut ids);
        ids
    }
}

#[test]
fn kmeans_parallel_pruned_matches_unpruned() {
    check("kmeans-par-equivalence", 0x6B3A_0001, 12, |rng| {
        let (spaces, n) = random_vector_spaces(rng);
        let pts = random_subset(rng, n);
        let k = 2 + rng.below(5);
        let cfg = KmeansParCfg {
            ell: 2.0 + rng.f64() * 16.0,
            rounds: 2 + rng.below(3),
            seed: rng.next_u64(),
        };
        for space in &spaces {
            for obj in [Objective::Median, Objective::Means] {
                let sim = Simulator::new();
                let pruned = kmeans_parallel::run(space.as_ref(), obj, &pts, k, &cfg, &sim);
                let reference =
                    kmeans_parallel::run_unpruned(space.as_ref(), obj, &pts, k, &cfg, &sim);
                reports_bit_identical(&pruned, &reference)
                    .map_err(|e| format!("{} {obj}: {e}", space.name()))?;
            }
        }
        Ok(())
    });
}

#[test]
fn pamae_lite_pruned_matches_unpruned() {
    check("pamae-equivalence", 0x6B3A_0002, 8, |rng| {
        let (spaces, n) = random_vector_spaces(rng);
        let pts = random_subset(rng, n);
        let k = 2 + rng.below(4);
        let cfg = PamaeCfg {
            num_samples: 2 + rng.below(2),
            sample_size: 60 + rng.below(60),
            refine_size: 60 + rng.below(60),
            seed: rng.next_u64(),
        };
        for space in &spaces {
            for obj in [Objective::Median, Objective::Means] {
                let sim = Simulator::new();
                let pruned = pamae_lite::run(space.as_ref(), obj, &pts, k, &cfg, &sim);
                let reference = pamae_lite::run_unpruned(space.as_ref(), obj, &pts, k, &cfg, &sim);
                reports_bit_identical(&pruned, &reference)
                    .map_err(|e| format!("{} {obj}: {e}", space.name()))?;
            }
        }
        Ok(())
    });
}

#[test]
fn ene_im_moseley_pruned_matches_unpruned() {
    check("eim-equivalence", 0x6B3A_0003, 10, |rng| {
        let (spaces, n) = random_vector_spaces(rng);
        let pts = random_subset(rng, n);
        let k = 2 + rng.below(4);
        let cfg = EimCfg {
            sample_per_iter: 20 + rng.below(30),
            stop_below: 40 + rng.below(40),
            seed: rng.next_u64(),
        };
        for space in &spaces {
            for obj in [Objective::Median, Objective::Means] {
                let sim = Simulator::new();
                let pruned = ene_im_moseley::run(space.as_ref(), obj, &pts, k, &cfg, &sim);
                let reference =
                    ene_im_moseley::run_unpruned(space.as_ref(), obj, &pts, k, &cfg, &sim);
                reports_bit_identical(&pruned, &reference)
                    .map_err(|e| format!("{} {obj}: {e}", space.name()))?;
            }
        }
        Ok(())
    });
}

/// Duplicate-heavy tie grids: every carried comparison and the filter
/// sort see massive distance ties; kept halves and assignments must
/// still match the reference exactly.
#[test]
fn ene_im_moseley_equivalent_on_tie_grids() {
    check("eim-tie-grid", 0x6B3A_0004, 10, |rng| {
        let (space, n) = tie_grid_space(rng);
        let pts: Vec<u32> = (0..n as u32).collect();
        let k = 2 + rng.below(3);
        let cfg = EimCfg {
            sample_per_iter: 15 + rng.below(25),
            stop_below: 30 + rng.below(30),
            seed: rng.next_u64(),
        };
        for obj in [Objective::Median, Objective::Means] {
            let sim = Simulator::new();
            let pruned = ene_im_moseley::run(&space, obj, &pts, k, &cfg, &sim);
            let reference = ene_im_moseley::run_unpruned(&space, obj, &pts, k, &cfg, &sim);
            reports_bit_identical(&pruned, &reference).map_err(|e| format!("{obj}: {e}"))?;
        }
        Ok(())
    });
}

/// Levenshtein: integer distances (tie-heavy) on the true general-metric
/// path — the tracker's batched DP folds must stay exact.
#[test]
fn baselines_equivalent_on_levenshtein() {
    check("baselines-levenshtein", 0x6B3A_0005, 5, |rng| {
        let n = 80 + rng.below(120);
        let (strings, _) = StringClusterSpec {
            n,
            clusters: 1 + rng.below(5),
            base_len: 8 + rng.below(10),
            max_edits: 3,
            seed: rng.next_u64(),
        }
        .generate();
        let space = StringSpace::new(strings);
        let pts: Vec<u32> = (0..n as u32).collect();
        let k = 2 + rng.below(3);
        let sim = Simulator::new();
        let ecfg =
            EimCfg { sample_per_iter: 15, stop_below: 30, seed: rng.next_u64() };
        let pruned = ene_im_moseley::run(&space, Objective::Median, &pts, k, &ecfg, &sim);
        let reference =
            ene_im_moseley::run_unpruned(&space, Objective::Median, &pts, k, &ecfg, &sim);
        reports_bit_identical(&pruned, &reference).map_err(|e| format!("eim: {e}"))?;
        let kcfg = KmeansParCfg { ell: 6.0, rounds: 3, seed: rng.next_u64() };
        let pruned = kmeans_parallel::run(&space, Objective::Median, &pts, k, &kcfg, &sim);
        let reference =
            kmeans_parallel::run_unpruned(&space, Objective::Median, &pts, k, &kcfg, &sim);
        reports_bit_identical(&pruned, &reference).map_err(|e| format!("kmeans||: {e}"))?;
        Ok(())
    });
}

#[test]
fn lloyd_bounded_matches_reference() {
    check("lloyd-equivalence", 0x6B3A_0006, 10, |rng| {
        let n = 150 + rng.below(400);
        let (data, _) = GaussianMixtureSpec {
            n,
            d: 1 + rng.below(4),
            k: 1 + rng.below(5),
            spread: 1.0 + rng.f64() * 40.0,
            outlier_frac: 0.0,
            seed: rng.next_u64(),
        }
        .generate();
        let pts: Vec<u32> = (0..n as u32).collect();
        let weights: Vec<u64> = if rng.below(2) == 0 {
            vec![1u64; n]
        } else {
            (0..n).map(|_| 1 + rng.below(9) as u64).collect()
        };
        let k = 1 + rng.below(6);
        let cfg = LloydCfg { seed: rng.next_u64(), ..LloydCfg::default() };
        let bounded = lloyd(&data, &pts, &weights, k, &cfg);
        let reference = lloyd_reference(&data, &pts, &weights, k, &cfg);
        if bounded.cost.to_bits() != reference.cost.to_bits() {
            return Err(format!("cost differs: {} vs {}", bounded.cost, reference.cost));
        }
        if bounded.centroids.n() != reference.centroids.n() {
            return Err("centroid count differs".to_string());
        }
        for j in 0..reference.centroids.n() as u32 {
            let (a, b) = (bounded.centroids.row(j), reference.centroids.row(j));
            if !a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()) {
                return Err(format!("centroid {j} differs: {a:?} vs {b:?}"));
            }
        }
        Ok(())
    });
}

/// The pruned baselines run reducers in real threads; results and the
/// attributed work metric must not depend on the thread count.
#[test]
fn pruned_baselines_bit_identical_across_thread_counts() {
    let (data, _) = GaussianMixtureSpec {
        n: 2000,
        d: 3,
        k: 5,
        spread: 25.0,
        seed: 41,
        ..Default::default()
    }
    .generate();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..2000).collect();
    type Runner = dyn Fn(&dyn MetricSpace, &[u32], &Simulator) -> BaselineReport;
    let runners: Vec<(&str, Box<Runner>)> = vec![
        (
            "kmeans||",
            Box::new(|s, p, sim| {
                kmeans_parallel::run(s, Objective::Means, p, 5, &KmeansParCfg::new(5), sim)
            }),
        ),
        (
            "pamae",
            Box::new(|s, p, sim| {
                let cfg = PamaeCfg { num_samples: 2, sample_size: 120, refine_size: 150, seed: 9 };
                pamae_lite::run(s, Objective::Median, p, 5, &cfg, sim)
            }),
        ),
        (
            "eim",
            Box::new(|s, p, sim| {
                let cfg = EimCfg { sample_per_iter: 50, stop_below: 120, seed: 9 };
                ene_im_moseley::run(s, Objective::Median, p, 5, &cfg, sim)
            }),
        ),
    ];
    for (name, run) in &runners {
        let sim1 = Simulator::new().with_threads(1);
        let a = run(&space, &pts, &sim1);
        let sim8 = Simulator::new().with_threads(8);
        let b = run(&space, &pts, &sim8);
        reports_bit_identical(&a, &b).unwrap_or_else(|e| panic!("{name}: {e}"));
        let e1 = sim1.take_stats().total_dist_evals();
        let e8 = sim8.take_stats().total_dist_evals();
        assert_eq!(e1, e8, "{name}: dist_evals drift across thread counts");
    }
}
