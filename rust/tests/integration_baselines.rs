//! Integration: baselines vs the paper's algorithm on a shared workload
//! — the relations E8 depends on must hold robustly.

use std::sync::Arc;

use mrcoreset::baselines::ene_im_moseley::{self, EimCfg};
use mrcoreset::baselines::kmeans_parallel::{self, KmeansParCfg};
use mrcoreset::baselines::pamae_lite::{self, PamaeCfg};
use mrcoreset::baselines::uniform::{self, UniformCfg};
use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::Simulator;
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;

fn workload(n: usize) -> (EuclideanSpace, Vec<u32>) {
    let (data, _) = GaussianMixtureSpec {
        n,
        d: 2,
        k: 6,
        spread: 30.0,
        outlier_frac: 0.05,
        seed: 11,
        ..Default::default()
    }
    .generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

#[test]
fn all_baselines_produce_k_centers() {
    let (space, pts) = workload(2500);
    let k = 6;
    let sim = Simulator::new();
    let reports = vec![
        uniform::run(
            &space,
            Objective::Median,
            &pts,
            k,
            &UniformCfg { size: 300, l: 5, seed: 1 },
            &sim,
        ),
        ene_im_moseley::run(
            &space,
            Objective::Median,
            &pts,
            k,
            &EimCfg { sample_per_iter: 50, stop_below: 100, seed: 2 },
            &sim,
        ),
        kmeans_parallel::run(&space, Objective::Means, &pts, k, &KmeansParCfg::new(k), &sim),
        pamae_lite::run(&space, Objective::Median, &pts, k, &PamaeCfg::new(k), &sim),
    ];
    for r in &reports {
        assert_eq!(r.solution.centers.len(), k, "{}", r.name);
        assert!(r.full_cost.is_finite() && r.full_cost > 0.0, "{}", r.name);
        assert!(r.summary_size > 0, "{}", r.name);
        // centers distinct
        let mut cs = r.solution.centers.clone();
        cs.sort_unstable();
        cs.dedup();
        assert_eq!(cs.len(), k, "{}: duplicate centers", r.name);
    }
}

#[test]
fn ours_competitive_with_every_baseline() {
    let (space, pts) = workload(4000);
    let k = 6;
    let ours = solve(&space, &pts, &ClusterConfig::new(Objective::Median, k, 0.4));
    let sim = Simulator::new();
    let uni = uniform::run(
        &space,
        Objective::Median,
        &pts,
        k,
        &UniformCfg { size: ours.coreset_size, l: ours.l, seed: 3 },
        &sim,
    );
    let eim = ene_im_moseley::run(
        &space,
        Objective::Median,
        &pts,
        k,
        &EimCfg {
            sample_per_iter: ours.coreset_size / 6 + 1,
            stop_below: ours.coreset_size / 4 + 1,
            seed: 4,
        },
        &sim,
    );
    // ours should never be drastically worse than any sampling baseline
    // at the same summary size (it is usually better, E8 quantifies it)
    for (name, cost) in [("uniform", uni.full_cost), ("eim", eim.full_cost)] {
        assert!(
            ours.full_cost <= cost * 1.2,
            "ours {} vs {name} {cost}",
            ours.full_cost
        );
    }
}

#[test]
fn kmeans_parallel_beats_single_random_seed() {
    let (space, pts) = workload(3000);
    let k = 6;
    let sim = Simulator::new();
    let kp = kmeans_parallel::run(&space, Objective::Means, &pts, k, &KmeansParCfg::new(k), &sim);
    // a solution of k uniform random points, evaluated on the full input
    let mut rng = mrcoreset::util::rng::Rng::new(5);
    let rand_centers: Vec<u32> =
        rng.sample_distinct(pts.len(), k).into_iter().map(|i| pts[i]).collect();
    let rand_cost = mrcoreset::metric::MetricSpace::assign(&space, &pts, &rand_centers)
        .cost_unit(Objective::Means);
    assert!(kp.full_cost < rand_cost, "kmeans|| {} vs random {rand_cost}", kp.full_cost);
}
