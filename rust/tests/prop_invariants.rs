//! Property-based tests (seeded harness, DESIGN.md §5) on the paper's
//! invariants: CoverWithBalls guarantees, weight conservation through
//! composition, partition laws, and coordinator behaviour across random
//! configurations.

use std::sync::Arc;

use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::coreset::{cover_with_balls, two_round_coreset, CoresetConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{partition, PartitionStrategy, Simulator};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::points::VectorData;
use mrcoreset::prop_assert;
use mrcoreset::util::prop::check;
use mrcoreset::util::rng::Rng;

fn random_space(rng: &mut Rng) -> (EuclideanSpace, Vec<u32>) {
    let n = 100 + rng.below(900);
    let d = 1 + rng.below(4);
    let k = 2 + rng.below(6);
    let spread = 2.0 + rng.f64() * 40.0;
    let (data, _) = GaussianMixtureSpec {
        n,
        d,
        k,
        spread,
        outlier_frac: rng.f64() * 0.1,
        seed: rng.next_u64(),
    }
    .generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

#[test]
fn prop_cover_guarantee_and_weights() {
    check("cover-guarantee", 0xC0DE, 25, |rng| {
        let (space, pts) = random_space(rng);
        let tsize = 1 + rng.below(8);
        let t: Vec<u32> = (0..tsize as u32).map(|i| pts[(i as usize * 97) % pts.len()]).collect();
        let assign = space.assign(&pts, &t);
        let r = assign.dist.iter().sum::<f64>() / pts.len() as f64;
        let eps = 0.1 + rng.f64() * 0.85;
        let beta = 1.0 + rng.f64() * 4.0;
        let res = cover_with_balls(&space, &pts, &t, r, eps, beta);

        // Lemma 3.1 per-point guarantee
        let shrink = eps / (2.0 * beta);
        for (i, &x) in pts.iter().enumerate() {
            let rep = res.set.indices[res.tau[i] as usize];
            let d = space.dist(x, rep);
            let bound = shrink * res.dist_to_t[i].max(r);
            prop_assert!(d <= bound + 1e-9, "point {i}: {d} > {bound}");
        }
        // Definition 2.3 weights
        prop_assert!(
            res.set.total_weight() == pts.len() as u64,
            "weight {} != n {}",
            res.set.total_weight(),
            pts.len()
        );
        let mut counts = vec![0u64; res.set.len()];
        for &t in &res.tau {
            counts[t as usize] += 1;
        }
        prop_assert!(counts == res.set.weights, "weights are not preimage counts");
        Ok(())
    });
}

#[test]
fn prop_two_round_weight_conservation() {
    check("two-round-weights", 0xBEEF, 12, |rng| {
        let (space, pts) = random_space(rng);
        let k = 2 + rng.below(4);
        let l = 1 + rng.below(6);
        let obj = if rng.below(2) == 0 { Objective::Median } else { Objective::Means };
        let eps = 0.15 + rng.f64() * 0.8;
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(k, eps);
        let out = two_round_coreset(
            &space,
            obj,
            &pts,
            l,
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        prop_assert!(
            out.coreset.total_weight() == pts.len() as u64,
            "{obj}: weight {} != {}",
            out.coreset.total_weight(),
            pts.len()
        );
        prop_assert!(!out.coreset.is_empty(), "empty coreset");
        // coreset members must be actual input points (S ⊆ P)
        for &c in &out.coreset.indices {
            prop_assert!((c as usize) < pts.len(), "coreset index {c} out of range");
        }
        let stats = sim.take_stats();
        prop_assert!(stats.num_rounds() == 2, "2 coreset rounds, got {}", stats.num_rounds());
        Ok(())
    });
}

#[test]
fn prop_partition_laws() {
    check("partition-laws", 0xFACE, 40, |rng| {
        let n = 1 + rng.below(500);
        let pts: Vec<u32> = (0..n as u32).map(|i| i * 3 + 1).collect();
        let l = 1 + rng.below(12);
        let strategy = match rng.below(3) {
            0 => PartitionStrategy::RoundRobin,
            1 => PartitionStrategy::Contiguous,
            _ => PartitionStrategy::Shuffled(rng.next_u64()),
        };
        let parts = partition(&pts, l, strategy);
        // disjoint cover
        let mut all: Vec<u32> = parts.concat();
        all.sort_unstable();
        let mut want = pts.clone();
        want.sort_unstable();
        prop_assert!(all == want, "partition is not a disjoint cover");
        // balanced
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        prop_assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
        Ok(())
    });
}

#[test]
fn prop_solver_basic_contract() {
    check("solver-contract", 0xD00D, 8, |rng| {
        let (space, pts) = random_space(rng);
        let k = 1 + rng.below(5);
        let obj = if rng.below(2) == 0 { Objective::Median } else { Objective::Means };
        let mut cfg = ClusterConfig::new(obj, k, 0.2 + rng.f64() * 0.7);
        cfg.seed = rng.next_u64();
        let rep = solve(&space, &pts, &cfg);
        prop_assert!(rep.rounds == 3, "rounds {}", rep.rounds);
        prop_assert!(rep.solution.centers.len() == k.min(pts.len()), "k mismatch");
        // centers distinct and in range
        let mut cs = rep.solution.centers.clone();
        cs.sort_unstable();
        cs.dedup();
        prop_assert!(cs.len() == rep.solution.centers.len(), "duplicate centers");
        // cost on full input is consistent with re-evaluation
        let again = space.assign(&pts, &rep.solution.centers).cost_unit(obj);
        prop_assert!(
            (again - rep.full_cost).abs() <= 1e-9 * (1.0 + again),
            "cost not reproducible"
        );
        Ok(())
    });
}

#[test]
fn prop_duplicate_heavy_inputs() {
    // many duplicated points: covers must collapse, solver must not panic
    check("duplicates", 0xD0D0, 10, |rng| {
        let base = 1 + rng.below(5);
        let copies = 20 + rng.below(100);
        let mut rows = Vec::new();
        for b in 0..base {
            for _ in 0..copies {
                rows.push(vec![b as f32 * 10.0, b as f32 * -5.0]);
            }
        }
        let n = rows.len();
        let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
        let pts: Vec<u32> = (0..n as u32).collect();
        let k = 1 + rng.below(base);
        let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, k, 0.5));
        prop_assert!(rep.full_cost.is_finite(), "cost not finite");
        if k >= base {
            prop_assert!(rep.full_cost == 0.0, "k>=distinct points must cost 0");
        }
        Ok(())
    });
}
