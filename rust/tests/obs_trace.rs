//! Integration tests for the `obs` telemetry subsystem.
//!
//! Two contracts are pinned here:
//! 1. Schema round-trip: a trace written end-to-end by `solve_traced`
//!    through a [`JsonlSink`] parses back via [`Event::parse`] and
//!    re-serializes byte-identically — `mrcoreset report` can render
//!    any file this crate writes.
//! 2. The pruning engine's give-up ledger is not just an internal
//!    state flip: when a reducer hits a bounds-hostile input, the
//!    give-up lands in that reducer's span event in the trace.

use std::sync::Arc;

use mrcoreset::coordinator::{solve_traced, ClusterConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::Simulator;
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::pruned::NearestTracker;
use mrcoreset::metric::Objective;
use mrcoreset::obs::{Event, JsonlSink, MemSink, Recorder, TRACE_SCHEMA_VERSION};
use mrcoreset::points::VectorData;

#[test]
fn traced_solve_round_trips_through_jsonl_schema() {
    let (data, _) =
        GaussianMixtureSpec { n: 800, d: 2, k: 3, seed: 5, ..Default::default() }.generate();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..800).collect();
    let cfg = ClusterConfig::new(Objective::Median, 3, 0.5);

    let path = std::env::temp_dir().join("mrcoreset-obs-trace-roundtrip.jsonl");
    {
        let rec: Arc<dyn Recorder> =
            Arc::new(JsonlSink::create(&path).expect("create trace file"));
        let _ = solve_traced(&space, &pts, &cfg, rec);
    }
    let text = std::fs::read_to_string(&path).expect("trace written");
    let _ = std::fs::remove_file(&path);
    let parsed: Vec<Event> =
        text.lines().map(|l| Event::parse(l).expect("valid event line")).collect();

    assert!(
        matches!(parsed.first(), Some(Event::RunStart { schema, .. })
            if *schema == TRACE_SCHEMA_VERSION),
        "trace must open with a versioned run_start"
    );
    assert!(matches!(parsed.last(), Some(Event::RunEnd { .. })), "trace must close with run_end");
    assert!(parsed.iter().any(|e| matches!(e, Event::Reducer { .. })), "no reducer spans");

    // parse is the inverse of to_json: re-serializing the parsed events
    // reproduces the file byte-for-byte
    let reserialized: Vec<String> = parsed.iter().map(Event::to_json).collect();
    let original: Vec<&str> = text.lines().collect();
    assert_eq!(reserialized.len(), original.len());
    for (ours, theirs) in reserialized.iter().zip(&original) {
        assert_eq!(ours, theirs, "round-trip must be byte-identical");
    }

    // an in-memory trace of the identical seeded run matches the file
    // line-for-line once wall-clock is stripped
    let mem = Arc::new(MemSink::new());
    let rec: Arc<dyn Recorder> = mem.clone();
    let _ = solve_traced(&space, &pts, &cfg, rec);
    let mem_stable: Vec<String> = mem.take().iter().map(Event::stable_json).collect();
    let file_stable: Vec<String> = parsed.iter().map(Event::stable_json).collect();
    assert_eq!(mem_stable, file_stable, "same seeded config, same stable trace");
}

#[test]
fn give_up_ledger_reaches_the_trace_on_bounds_hostile_input() {
    // 64 duplicated points against 40 centers: every candidate center is
    // equidistant, so bound rows can never veto anything and their upkeep
    // exceeds the slack — the tracker must flip its give-up latch, and
    // that decision must surface in the reducer's span counters.
    let rows: Vec<Vec<f32>> = vec![vec![0.0, 0.0]; 64];
    let space = EuclideanSpace::new(Arc::new(VectorData::from_rows(&rows)));
    let sink = Arc::new(MemSink::new());
    let rec: Arc<dyn Recorder> = sink.clone();
    let sim = Simulator::new().with_threads(2).with_recorder(rec);
    let parts: Vec<Vec<u32>> = vec![(0..64).collect()];
    let _ = sim.round("adversarial-assign", parts, |_, part, _meter| {
        let mut t = NearestTracker::new(&space, part, true);
        for c in 0..40u32 {
            t.push(c);
        }
        let led = t.ledger();
        assert!(!led.bounds_paying, "latch must fire on duplicates: {led:?}");
        t.idx().to_vec()
    });
    let stats = sim.take_stats();
    let evs = sink.take();
    let counters = evs
        .iter()
        .find_map(|e| match e {
            Event::Reducer { counters, .. } => Some(counters.clone()),
            _ => None,
        })
        .expect("reducer span recorded");
    let give_up = counters.iter().find(|(k, _)| k == "pruned.give_up");
    assert_eq!(
        give_up,
        Some(&("pruned.give_up".to_string(), 1)),
        "give-up must fire exactly once: {counters:?}"
    );
    assert!(
        counters.iter().any(|(k, _)| k == "pruned.evals_charged"),
        "eval accounting missing: {counters:?}"
    );
    // the round stats carry the same ledger for untraced consumers
    assert_eq!(stats.rounds[0].counter("pruned.give_up"), 1);
}
