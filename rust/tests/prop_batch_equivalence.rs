//! Kernel-parity property suite for the pluggable distance backends
//! (`metric::kernel`): on every metric space and every kernel, the bulk
//! queries must agree with scalar `dist` loops, and every bulk query
//! must charge exactly |pts|·|centers| evaluations to the work counter
//! regardless of which backend served it.
//!
//! Parity tiers:
//!  - **exact** kernels (`scalar`, `blocked`, and both Levenshtein
//!    backends) are held to bit-identical results — the blocked kernel's
//!    f32 scan is only a bounding pass, its decisions are verified in
//!    f64, and Myers/banded bit-parallel edit distances are exact by
//!    construction. End-to-end, a full solve must serialize identically
//!    across exact kernels AND across thread counts.
//!  - the **simd** kernel computes f32 rows: results are held to a
//!    bounded relative error, it must report
//!    `uniform_precision() == false`, and its `dist_batch_pruned` must
//!    fall back to the plain batch (bounds computed by exact arithmetic
//!    cannot prune inexact values).

use std::sync::Arc;

use mrcoreset::coordinator::{solve_traced, ClusterConfig};
use mrcoreset::data::strings::StringClusterSpec;
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::metric::counter;
use mrcoreset::metric::counting::CountingSpace;
use mrcoreset::metric::dense::{ChebyshevSpace, EuclideanSpace, ManhattanSpace};
use mrcoreset::metric::extra::HammingSpace;
use mrcoreset::metric::kernel::KernelKind;
use mrcoreset::metric::levenshtein::{levenshtein, levenshtein_banded, StringSpace};
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::obs::{MemSink, Recorder};
use mrcoreset::prop_assert;
use mrcoreset::util::prop::check;
use mrcoreset::util::rng::Rng;

/// A space under an explicit kernel, plus whether that backend is exact
/// (bit-identical to scalar `dist` loops) or f32-approximate.
struct Case {
    space: Box<dyn MetricSpace>,
    exact: bool,
}

impl Case {
    fn label(&self) -> String {
        format!("{}/{}", self.space.name(), self.space.kernel_name())
    }
}

fn cases(rng: &mut Rng) -> Vec<Case> {
    let n = 30 + rng.below(120);
    let d = 1 + rng.below(6);
    let (data, _) = GaussianMixtureSpec {
        n,
        d,
        k: 1 + rng.below(4),
        spread: 1.0 + rng.f64() * 30.0,
        outlier_frac: 0.0,
        seed: rng.next_u64(),
    }
    .generate();
    let shared = Arc::new(data);
    let (strs, _) = StringClusterSpec {
        n,
        clusters: 1 + rng.below(5),
        base_len: 6 + rng.below(14),
        max_edits: rng.below(5),
        seed: rng.next_u64(),
    }
    .generate();
    let codes: Vec<Vec<u8>> = (0..n)
        .map(|i| (0..8).map(|b| ((i >> b) & 1) as u8 + rng.below(2) as u8).collect())
        .collect();
    vec![
        Case {
            space: Box::new(EuclideanSpace::with_kernel(shared.clone(), KernelKind::Scalar)),
            exact: true,
        },
        Case {
            space: Box::new(EuclideanSpace::with_kernel(shared.clone(), KernelKind::Blocked)),
            exact: true,
        },
        Case {
            space: Box::new(EuclideanSpace::with_kernel(shared.clone(), KernelKind::Simd)),
            exact: false,
        },
        Case {
            space: Box::new(ManhattanSpace::with_kernel(shared.clone(), KernelKind::Blocked)),
            exact: true,
        },
        Case {
            space: Box::new(ManhattanSpace::with_kernel(shared.clone(), KernelKind::Simd)),
            exact: false,
        },
        Case {
            space: Box::new(ChebyshevSpace::with_kernel(shared.clone(), KernelKind::Blocked)),
            exact: true,
        },
        Case {
            space: Box::new(ChebyshevSpace::with_kernel(shared, KernelKind::Simd)),
            exact: false,
        },
        Case {
            space: Box::new(StringSpace::with_kernel(strs.clone(), KernelKind::Scalar)),
            exact: true,
        },
        // Auto selects the Myers/banded bit-parallel backend — exact
        Case { space: Box::new(StringSpace::with_kernel(strs, KernelKind::Auto)), exact: true },
        Case { space: Box::new(HammingSpace::new(codes)), exact: true },
    ]
}

/// f32-row error envelope: generous relative bound covering the d-term
/// f32 accumulation (d ≤ 6 here, each step losing at most one f32 ulp).
fn simd_tol(want: f64) -> f64 {
    1e-4 * (1.0 + want)
}

fn pick_queries(rng: &mut Rng, n: usize) -> (Vec<u32>, Vec<u32>) {
    let np = 1 + rng.below(n);
    let pts: Vec<u32> = (0..np).map(|_| rng.below(n) as u32).collect();
    let k = 1 + rng.below(8.min(n));
    let centers: Vec<u32> = rng.sample_distinct(n, k).into_iter().map(|i| i as u32).collect();
    (pts, centers)
}

#[test]
fn prop_dist_batch_matches_scalar_dist_per_kernel() {
    check("kernel-dist-batch", 0xBA7C, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let mut out = vec![0.0f64; pts.len()];
            for &c in &centers {
                space.dist_batch(&pts, c, &mut out);
                for (i, &p) in pts.iter().enumerate() {
                    let want = space.dist(p, c);
                    let ok = if case.exact {
                        out[i].to_bits() == want.to_bits()
                    } else {
                        (out[i] - want).abs() <= simd_tol(want)
                    };
                    prop_assert!(
                        ok,
                        "{}: dist_batch[{i}] = {} vs dist = {want}",
                        case.label(),
                        out[i]
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nearest_batch_matches_scalar_loop_per_kernel() {
    check("kernel-nearest-batch", 0x4EA2, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let a = space.nearest_batch(&pts, &centers);
            for (i, &p) in pts.iter().enumerate() {
                // exact kernels must reproduce the strict-< scalar fold
                // bit for bit, winner index included
                let mut want = f64::INFINITY;
                let mut want_idx = 0u32;
                for (j, &c) in centers.iter().enumerate() {
                    let dj = space.dist(p, c);
                    if dj < want {
                        want = dj;
                        want_idx = j as u32;
                    }
                }
                if case.exact {
                    prop_assert!(
                        a.dist[i].to_bits() == want.to_bits() && a.idx[i] == want_idx,
                        "{}: nearest[{i}] = ({}, {}) vs scalar ({want}, {want_idx})",
                        case.label(),
                        a.dist[i],
                        a.idx[i]
                    );
                } else {
                    prop_assert!(
                        (a.dist[i] - want).abs() <= simd_tol(want),
                        "{}: nearest dist[{i}] = {} vs scalar min {want}",
                        case.label(),
                        a.dist[i]
                    );
                    // the reported winner must explain the reported
                    // distance to within the same f32 envelope
                    let via_idx = space.dist(p, centers[a.idx[i] as usize]);
                    prop_assert!(
                        (a.dist[i] - via_idx).abs() <= simd_tol(via_idx),
                        "{}: dist[{i}] inconsistent with reported winner",
                        case.label()
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_min_update_matches_scalar_fold_per_kernel() {
    check("kernel-min-update", 0x31FD, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let mut cur = vec![f64::INFINITY; pts.len()];
            let mut want = vec![f64::INFINITY; pts.len()];
            for &c in &centers {
                space.min_update(&pts, c, &mut cur);
                for (i, &p) in pts.iter().enumerate() {
                    let d = space.dist(p, c);
                    if d < want[i] {
                        want[i] = d;
                    }
                }
            }
            for i in 0..pts.len() {
                let ok = if case.exact {
                    cur[i].to_bits() == want[i].to_bits()
                } else {
                    (cur[i] - want[i]).abs() <= simd_tol(want[i])
                };
                prop_assert!(
                    ok,
                    "{}: min_update[{i}] = {} vs {}",
                    case.label(),
                    cur[i],
                    want[i]
                );
            }
        }
        Ok(())
    });
}

/// The honest-work contract is kernel-invariant: whichever backend
/// serves a bulk query, it charges exactly |pts|·|centers| — so
/// `dist_evals` in reports and traces stays comparable across kernels.
#[test]
fn prop_bulk_queries_charge_point_center_pairs_per_kernel() {
    check("kernel-eval-accounting", 0xACC7, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let (_, e) = counter::counted(|| space.nearest_batch(&pts, &centers));
            prop_assert!(
                e == (pts.len() * centers.len()) as u64,
                "{}: nearest_batch charged {e}, want {}",
                case.label(),
                pts.len() * centers.len()
            );
            let mut out = vec![0.0f64; pts.len()];
            let (_, e) = counter::counted(|| space.dist_batch(&pts, centers[0], &mut out));
            prop_assert!(
                e == pts.len() as u64,
                "{}: dist_batch charged {e}, want {}",
                case.label(),
                pts.len()
            );
            let mut cur = vec![f64::INFINITY; pts.len()];
            let (_, e) = counter::counted(|| space.min_update(&pts, centers[0], &mut cur));
            prop_assert!(
                e == pts.len() as u64,
                "{}: min_update charged {e}, want {}",
                case.label(),
                pts.len()
            );
        }
        Ok(())
    });
}

/// Inexact kernels must refuse to prune: their `dist_batch_pruned`
/// ignores the (exact-arithmetic) bounds, computes the full plain batch,
/// and reports every entry as charged — even when the bounds would have
/// pruned everything under an exact kernel.
#[test]
fn prop_inexact_kernel_pruned_batch_equals_plain_batch() {
    check("simd-pruned-fallback", 0xFA11, 20, |rng| {
        let n = 20 + rng.below(80);
        let (data, _) = GaussianMixtureSpec {
            n,
            d: 1 + rng.below(6),
            k: 2,
            spread: 1.0 + rng.f64() * 20.0,
            outlier_frac: 0.0,
            seed: rng.next_u64(),
        }
        .generate();
        let shared = Arc::new(data);
        let spaces: Vec<Box<dyn MetricSpace>> = vec![
            Box::new(EuclideanSpace::with_kernel(shared.clone(), KernelKind::Simd)),
            Box::new(ManhattanSpace::with_kernel(shared.clone(), KernelKind::Simd)),
            Box::new(ChebyshevSpace::with_kernel(shared, KernelKind::Simd)),
        ];
        let pts: Vec<u32> = (0..n as u32).collect();
        let c = rng.below(n) as u32;
        // adversarial bounds: would prune every entry if honoured
        let lower = vec![f64::INFINITY; n];
        let cutoff = vec![0.0f64; n];
        for space in &spaces {
            prop_assert!(
                !space.uniform_precision(),
                "{}: simd kernel must report uniform_precision() == false",
                space.name()
            );
            let mut plain = vec![0.0f64; n];
            space.dist_batch(&pts, c, &mut plain);
            let mut out = vec![0.0f64; n];
            let computed = space.dist_batch_pruned(&pts, c, &lower, &cutoff, &mut out);
            prop_assert!(
                computed == n,
                "{}: fallback must charge all {n} entries, got {computed}",
                space.name()
            );
            for i in 0..n {
                prop_assert!(
                    out[i].to_bits() == plain[i].to_bits(),
                    "{}: pruned fallback [{i}] = {} differs from plain batch {}",
                    space.name(),
                    out[i],
                    plain[i]
                );
            }
        }
        Ok(())
    });
}

/// The two Levenshtein backends (two-row DP vs Myers/banded
/// bit-parallel) are both exact: plain batches bit-identical, and the
/// pruned batch — where the banded backend may store the sentinel for
/// over-cutoff entries — must make identical keep/skip decisions and
/// charge identically.
#[test]
fn prop_string_backends_bit_identical() {
    check("string-kernel-parity", 0x5712, 15, |rng| {
        let n = 20 + rng.below(60);
        let (strs, _) = StringClusterSpec {
            n,
            clusters: 1 + rng.below(5),
            base_len: 6 + rng.below(20),
            max_edits: rng.below(6),
            seed: rng.next_u64(),
        }
        .generate();
        let scalar = StringSpace::with_kernel(strs.clone(), KernelKind::Scalar);
        let bitp = StringSpace::with_kernel(strs, KernelKind::Auto);
        let (pts, centers) = pick_queries(rng, n);
        let a = scalar.nearest_batch(&pts, &centers);
        let b = bitp.nearest_batch(&pts, &centers);
        prop_assert!(a.idx == b.idx, "winner indices differ between string backends");
        for i in 0..pts.len() {
            prop_assert!(
                a.dist[i].to_bits() == b.dist[i].to_bits(),
                "nearest dist[{i}] differs: {} vs {}",
                a.dist[i],
                b.dist[i]
            );
        }
        let c = centers[0];
        let mut want = vec![0.0f64; pts.len()];
        scalar.dist_batch(&pts, c, &mut want);
        let mut got = vec![0.0f64; pts.len()];
        bitp.dist_batch(&pts, c, &mut got);
        for i in 0..pts.len() {
            prop_assert!(
                got[i].to_bits() == want[i].to_bits(),
                "dist_batch[{i}] differs: {got:?} vs {want:?}"
            );
        }
        // pruned: same cutoff, both backends — identical charges and
        // identical keep/skip decisions (the bitparallel backend may
        // store INFINITY where the scalar one stores an exact value
        // above the cutoff; both are valid under the trait contract)
        for cut in [0.0, 1.0, 2.5, 6.0, f64::INFINITY] {
            let lower = vec![0.0f64; pts.len()];
            let cutoff = vec![cut; pts.len()];
            let mut so = vec![0.0f64; pts.len()];
            let sc = scalar.dist_batch_pruned(&pts, c, &lower, &cutoff, &mut so);
            let mut bo = vec![0.0f64; pts.len()];
            let bc = bitp.dist_batch_pruned(&pts, c, &lower, &cutoff, &mut bo);
            prop_assert!(sc == bc, "cut={cut}: charges differ ({sc} vs {bc})");
            for i in 0..pts.len() {
                prop_assert!(
                    (so[i] <= cut) == (bo[i] <= cut),
                    "cut={cut}: decision differs at [{i}]: {} vs {}",
                    so[i],
                    bo[i]
                );
                if bo[i].is_finite() {
                    prop_assert!(
                        bo[i].to_bits() == so[i].to_bits(),
                        "cut={cut}: finite value differs at [{i}]: {} vs {}",
                        so[i],
                        bo[i]
                    );
                }
            }
        }
        Ok(())
    });
}

/// Banded Levenshtein vs the full DP, including the band-overflow
/// sentinel: `Some(d)` iff the exact distance is ≤ k, and then `d` is
/// exact — probed at and around the decision boundary.
#[test]
fn prop_banded_levenshtein_matches_full_dp() {
    check("banded-levenshtein", 0xBA2D, 60, |rng| {
        let alphabet = b"abcd";
        let mut randstr = |len: usize| -> Vec<u8> {
            (0..len).map(|_| alphabet[rng.below(4)]).collect()
        };
        let a = randstr(rng.below(40));
        let b = randstr(rng.below(40));
        let exact = levenshtein(&a, &b);
        let probes =
            [0, exact.saturating_sub(1), exact, exact + 1, exact + 5, rng.below(45)];
        for &k in &probes {
            match levenshtein_banded(&a, &b, k) {
                Some(d) => prop_assert!(
                    d == exact && exact <= k,
                    "k={k}: banded returned {d}, exact {exact}"
                ),
                None => prop_assert!(
                    exact > k,
                    "k={k}: banded overflowed but exact {exact} <= k"
                ),
            }
        }
        Ok(())
    });
}

/// End-to-end: the exact Euclidean kernels must produce bit-identical
/// solves — same report JSON, same stable trace lines, same
/// `dist_evals` — across kernels AND across executor thread counts.
/// (Only the recorded kernel identity may differ; it is normalized out.)
#[test]
fn exact_kernels_solve_bit_identical_across_kernels_and_threads() {
    let (data, _) =
        GaussianMixtureSpec { n: 2000, d: 3, k: 5, seed: 77, ..Default::default() }.generate();
    let shared = Arc::new(data);
    let pts: Vec<u32> = (0..2000).collect();
    let mut runs: Vec<(String, String, Vec<String>)> = Vec::new();
    for kind in [KernelKind::Scalar, KernelKind::Blocked] {
        let space = EuclideanSpace::with_kernel(shared.clone(), kind);
        for threads in [1usize, 8] {
            let sink = Arc::new(MemSink::new());
            let rec: Arc<dyn Recorder> = sink.clone();
            let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
            cfg.threads = Some(threads);
            let rep = solve_traced(&space, &pts, &cfg, rec);
            assert_eq!(rep.kernel, kind.name(), "report must record the resolved kernel");
            let ktag = format!("\"kernel\":\"{}\"", kind.name());
            let json = rep.to_json().replace(&ktag, "\"kernel\":\"<k>\"");
            let ltag = format!("kernel={}", kind.name());
            let trace: Vec<String> = sink
                .snapshot()
                .iter()
                .map(|e| e.stable_json().replace(&ltag, "kernel=<k>"))
                .collect();
            assert!(trace.len() > 5, "expected run/round/reducer events");
            runs.push((format!("{} x{threads}", kind.name()), json, trace));
        }
    }
    let (ref_label, ref_json, ref_trace) = &runs[0];
    for (label, json, trace) in &runs[1..] {
        assert_eq!(ref_json, json, "{ref_label} vs {label}: reports differ");
        assert_eq!(ref_trace, trace, "{ref_label} vs {label}: traces differ");
    }
}

/// The counting wrapper must delegate bulk queries (keeping the inner
/// space's fast paths) while metering them as pts×centers.
#[test]
fn counting_space_delegates_and_meters_bulk_queries() {
    let (strs, _) = StringClusterSpec { n: 40, ..Default::default() }.generate();
    let inner = StringSpace::new(strs);
    let counting = CountingSpace::new(&inner);
    let pts: Vec<u32> = (0..40).collect();
    let centers = vec![3u32, 17, 31];

    let a = counting.nearest_batch(&pts, &centers);
    assert_eq!(counting.evals(), (40 * 3) as u64);
    assert_eq!(counting.kernel_name(), inner.kernel_name(), "wrapper must forward the kernel id");
    let b = inner.nearest_batch(&pts, &centers);
    assert_eq!(a.dist, b.dist);
    assert_eq!(a.idx, b.idx);

    counting.reset();
    let mut out = vec![0.0f64; 40];
    counting.dist_batch(&pts, 7, &mut out);
    assert_eq!(counting.evals(), 40);
    for (i, &p) in pts.iter().enumerate() {
        assert_eq!(out[i], inner.dist(p, 7));
    }
}
